//! Streaming serving plane demo (DESIGN.md §14): starts the std-only
//! HTTP front door on a loopback port, plays live client against it,
//! and prints every token frame the moment it crosses the wire —
//! then proves invariant 10 by comparing the streamed tokens against
//! the offline `run_trace` twin of the same seeded request set:
//!
//!   cargo run --release --example serve_stream -- --requests 4 --top-k 3
//!
//! No PJRT, no artifacts, no async runtime: `std::net` sockets on the
//! always-built HostBackend, tokens framed as NDJSON through the
//! incremental-JSON codec (`net::jsonframe`).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use bitrom::config::{ModelConfig, NetConfig, ServeConfig};
use bitrom::coordinator::Server;
use bitrom::net::jsonframe::{DecodeMode, FrameDecoder};
use bitrom::net::NetServer;
use bitrom::runtime::HostBackend;
use bitrom::trace::{generate, Request, TraceConfig};
use bitrom::util::args::ArgParser;
use bitrom::util::json::Json;

/// Strip complete `Transfer-Encoding: chunked` frames off the front of
/// `buf`, returning (payload bytes, saw the terminal zero chunk).
fn take_chunks(buf: &mut Vec<u8>) -> (Vec<u8>, bool) {
    let mut out = Vec::new();
    loop {
        let Some(le) = buf.windows(2).position(|w| w == b"\r\n") else {
            return (out, false);
        };
        let Ok(size) = usize::from_str_radix(&String::from_utf8_lossy(&buf[..le]), 16) else {
            return (out, false);
        };
        if size == 0 {
            buf.clear();
            return (out, true);
        }
        let total = le + 2 + size + 2;
        if buf.len() < total {
            return (out, false);
        }
        out.extend_from_slice(&buf[le + 2..le + 2 + size]);
        buf.drain(..total);
    }
}

/// POST one request and print its frames as they arrive; returns the
/// streamed token ids.
fn stream_one(addr: std::net::SocketAddr, req: &Request, t0: Instant) -> anyhow::Result<Vec<i32>> {
    let body = req.to_json().to_string_compact();
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "POST /v1/completions HTTP/1.1\r\nHost: demo\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )?;

    // read past the response head, keeping any early body bytes
    let mut buf = Vec::new();
    let mut scratch = [0u8; 512];
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p + 4;
        }
        let n = s.read(&mut scratch)?;
        anyhow::ensure!(n > 0, "connection closed before response head");
        buf.extend_from_slice(&scratch[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    anyhow::ensure!(head.starts_with("HTTP/1.1 200"), "unexpected response: {head}");
    buf.drain(..head_end);

    // the socket hands us arbitrary splits; the incremental decoder
    // re-frames them into whole JSON values
    let mut dec = FrameDecoder::new(DecodeMode::Strict);
    let mut tokens = Vec::new();
    loop {
        let (payload, finished) = take_chunks(&mut buf);
        for frame in dec.push(&payload)? {
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            if let Some(tok) = frame.get("token").and_then(Json::as_f64) {
                println!("  [{ms:8.2} ms] req {} token {}", req.id, tok as i32);
                tokens.push(tok as i32);
            } else if frame.get("done").and_then(Json::as_bool) == Some(true) {
                println!(
                    "  [{ms:8.2} ms] req {} done: {} tokens, ttft {:.1} ms",
                    req.id,
                    frame.get("n").and_then(Json::as_f64).unwrap_or(0.0),
                    frame.get("ttft_s").and_then(Json::as_f64).unwrap_or(0.0) * 1e3,
                );
            } else {
                println!("  [{ms:8.2} ms] req {} frame: {}", req.id, frame.to_string_compact());
            }
        }
        if finished {
            return Ok(tokens);
        }
        let n = s.read(&mut scratch)?;
        anyhow::ensure!(n > 0, "stream ended without the terminal chunk");
        buf.extend_from_slice(&scratch[..n]);
    }
}

fn main() -> anyhow::Result<()> {
    let args = ArgParser::new("serve_stream", "loopback streaming serving demo")
        .opt("requests", "4", "requests to stream")
        .opt("gen", "12", "max new tokens per request")
        .opt("top-k", "3", "sampling pool (1 = greedy)")
        .opt("seed", "1", "trace + weight seed")
        .parse_env();

    let model = ModelConfig::sim_tiny();
    let seed = args.u64("seed");
    let trace_cfg = TraceConfig {
        n_requests: args.usize("requests"),
        gen_len_min: 4.min(args.usize("gen")),
        gen_len_max: args.usize("gen"),
        vocab_size: model.vocab_size,
        seed,
        ..TraceConfig::default()
    };
    let serve = ServeConfig {
        top_k: args.usize("top-k"),
        ..ServeConfig::default()
    };
    let reqs = generate(&trace_cfg);

    println!("== BitROM streaming serving demo (NetServer over loopback) ==");

    // the offline twin first: the ground truth invariant 10 is
    // checked against
    let mut twin = Server::new(HostBackend::new(model.clone(), seed)?, serve.clone())?;
    let (twin_done, _) = twin.run_trace(reqs.clone())?;
    let twin_tokens: std::collections::BTreeMap<u64, Vec<i32>> =
        twin_done.into_iter().map(|r| (r.id, r.tokens)).collect();

    let net = NetConfig {
        listen: "127.0.0.1:0".into(),
        ..NetConfig::default()
    };
    let handle = NetServer::start(HostBackend::new(model, seed)?, serve, net)?;
    let addr = handle.addr();
    println!("listening on http://{addr} — streaming {} requests:", reqs.len());

    let t0 = Instant::now();
    let mut all_match = true;
    for req in &reqs {
        let tokens = stream_one(addr, req, t0)?;
        let matches = twin_tokens.get(&req.id) == Some(&tokens);
        all_match &= matches;
        println!(
            "  req {}: {} tokens streamed — offline twin {}",
            req.id,
            tokens.len(),
            if matches { "MATCHES (invariant 10)" } else { "DIVERGED" },
        );
    }
    anyhow::ensure!(all_match, "streamed tokens diverged from the offline twin");

    // a taste of the live exposition endpoint
    let mut s = TcpStream::connect(addr)?;
    write!(s, "GET /metrics HTTP/1.1\r\nHost: demo\r\n\r\n")?;
    let mut metrics_text = String::new();
    s.read_to_string(&mut metrics_text)?;
    println!("\n/metrics excerpt:");
    for line in metrics_text.lines().filter(|l| {
        l.starts_with("bitrom_requests_done_total")
            || l.starts_with("bitrom_tokens_total")
            || l.starts_with("bitrom_ttft_rounds{quantile=\"0.5\"}")
    }) {
        println!("  {line}");
    }

    let (done, metrics) = handle.shutdown()?;
    println!(
        "\ngraceful shutdown: {} completed, {} shed — all streams matched the offline twin",
        done.len(),
        metrics.faults.shed.len(),
    );
    println!("serve_stream OK");
    Ok(())
}
