//! Quickstart: load the AOT artifacts, verify against the python golden
//! trace, and generate a few tokens — the 60-second tour of the stack.
//!
//!   make artifacts && cargo run --release --example quickstart

use bitrom::runtime::{Manifest, ModelExecutor};

fn main() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    println!("== BitROM quickstart ==");
    println!("loading artifacts from {} ...", dir.display());
    let exec = ModelExecutor::load(&dir)?;
    let m = &exec.manifest;
    println!(
        "model {} — {} params, {} partitions x {} layers, ROM sparsity {:.1}%",
        m.model.name,
        m.model.param_count(),
        m.model.n_partitions,
        m.model.layers_per_partition(),
        m.rom_sparsity * 100.0
    );
    println!(
        "compiled {} executables in {:.2}s (weights are HLO constants — \
         nothing will ever be reloaded)",
        m.artifacts.len(),
        exec.load_time_s
    );

    // 1. cross-language check: replay the python golden trace
    if let Some(g) = m.golden.clone() {
        let got = exec.generate_greedy(&g.prompt, g.generated.len())?;
        assert_eq!(got, g.generated, "rust must match python exactly");
        println!("golden trace: OK ({} tokens match python)", got.len());
    }

    // 2. generate from a fresh prompt
    let prompt = vec![2, 71, 82, 33];
    let out = exec.generate_greedy(&prompt, 12)?;
    println!("prompt {prompt:?} -> {out:?}");
    println!("quickstart OK");
    Ok(())
}
