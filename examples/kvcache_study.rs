//! KV-cache study (paper §IV, Fig 5): per-step access analysis, the
//! reduction grid, a live DR-eDRAM retention demonstration, and the
//! host-side K/V projection compute that *produces* the cached values
//! (batched word-parallel GEMM).
//!
//!   cargo run --release --example kvcache_study -- --per-step --compute

use bitrom::bitnet::{absmax_quantize, ref_gemv, TernaryMatrix};
use bitrom::config::{EdramParams, ModelConfig, ServeConfig};
use bitrom::kvcache::{simulate_reduction, KvCacheManager};
use bitrom::report::{fig5a_report, fig5b_report};
use bitrom::util::args::ArgParser;
use bitrom::util::rng::Rng;
use bitrom::util::table::fmt_pct;

fn main() -> anyhow::Result<()> {
    let args = ArgParser::new("kvcache_study", "Fig 5 KV-cache experiments")
        .opt("seq", "128", "sequence length")
        .opt("buffer", "32", "on-die early tokens")
        .opt("tbt", "0.005", "simulated token-between-token time (s)")
        .flag("per-step", "print the Fig 5(a) per-step table")
        .flag("compute", "run the K/V projection host-compute study (batched GEMM)")
        .parse_env();

    if args.flag("per-step") {
        println!("{}", fig5a_report(16));
    }

    if args.flag("compute") {
        kv_projection_compute(args.usize("seq"));
    }

    println!("{}", fig5b_report());

    // live manager run: the actual serving accounting, with the eDRAM
    // retention clock advanced by the requested TBT
    let (s, b, tbt) = (args.usize("seq"), args.usize("buffer"), args.f64("tbt"));
    let model = ModelConfig::sim_tiny();
    let serve = ServeConfig {
        ondie_tokens: b,
        max_seq: s.max(1),
        prefill_len: 1,
        ..ServeConfig::default()
    };
    let mut kv = KvCacheManager::new(&model, &serve, EdramParams::default());
    kv.start_seq(0);
    kv.prefill(0, 1, 0.0);
    for step in 1..s {
        let now = step as f64 * tbt;
        kv.write_token(0, now);
        kv.read_context(0, now)?;
    }
    println!("live run: seq {s}, {b} on-die tokens, TBT {:.1} ms", tbt * 1e3);
    println!(
        "  external reduction (manager): {}   closed form: {}",
        fmt_pct(kv.stats.external_reduction()),
        fmt_pct(simulate_reduction(s, b)),
    );
    println!(
        "  eDRAM: {} reads, {} writes, {} explicit refreshes, {} retention failures",
        kv.edram().reads,
        kv.edram().writes,
        kv.edram().explicit_refreshes,
        kv.edram().retention_failures,
    );
    println!(
        "  external DRAM: {} accesses, {:.2} µJ",
        kv.dram().accesses(),
        kv.external_energy_j() * 1e6
    );
    assert_eq!(kv.edram().explicit_refreshes, 0);
    assert!(
        (kv.stats.external_reduction() - simulate_reduction(s, b)).abs() < 1e-9,
        "manager accounting must equal the closed form"
    );
    println!("kvcache_study OK");
    Ok(())
}

/// The KV values being cached come from the K/V projections. Run a
/// sequence's worth of decode-step activations through the ROM-shaped
/// K projection on the batched word-parallel bitplane GEMM — the host
/// compute path — and report the rate, with the first row checked
/// bit-exactly against the golden per-trit reference.
fn kv_projection_compute(seq: usize) {
    let cfg = ModelConfig::falcon3_1b();
    let (d_model, kv_dim) = (cfg.d_model, cfg.kv_dim());
    let mut rng = Rng::new(0x4B);
    let wk = TernaryMatrix::random(d_model, kv_dim, 0.3, &mut rng);
    let steps: Vec<Vec<i32>> = (0..seq.max(1))
        .map(|_| {
            let h: Vec<f32> = (0..d_model).map(|_| rng.normal() as f32).collect();
            absmax_quantize(&h, 8).values
        })
        .collect();
    let t0 = std::time::Instant::now();
    let ks = wk.gemm(&steps);
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(ks[0], ref_gemv(&steps[0], &wk), "GEMM diverged from reference");
    let macs = (seq.max(1) * d_model * kv_dim) as f64;
    println!(
        "K-projection compute ({}x{} ternary, seq {}): {:.2} ms total, {:.1} MMAC/s\n",
        d_model,
        kv_dim,
        seq.max(1),
        dt * 1e3,
        macs / dt / 1e6
    );
}
