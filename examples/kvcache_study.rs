//! KV-cache study (paper §IV, Fig 5): per-step access analysis, the
//! reduction grid, and a live DR-eDRAM retention demonstration.
//!
//!   cargo run --release --example kvcache_study -- --per-step

use bitrom::config::{EdramParams, ModelConfig, ServeConfig};
use bitrom::kvcache::{simulate_reduction, KvCacheManager};
use bitrom::report::{fig5a_report, fig5b_report};
use bitrom::util::args::ArgParser;
use bitrom::util::table::fmt_pct;

fn main() -> anyhow::Result<()> {
    let args = ArgParser::new("kvcache_study", "Fig 5 KV-cache experiments")
        .opt("seq", "128", "sequence length")
        .opt("buffer", "32", "on-die early tokens")
        .opt("tbt", "0.005", "simulated token-between-token time (s)")
        .flag("per-step", "print the Fig 5(a) per-step table")
        .parse_env();

    if args.flag("per-step") {
        println!("{}", fig5a_report(16));
    }

    println!("{}", fig5b_report());

    // live manager run: the actual serving accounting, with the eDRAM
    // retention clock advanced by the requested TBT
    let (s, b, tbt) = (args.usize("seq"), args.usize("buffer"), args.f64("tbt"));
    let model = ModelConfig::sim_tiny();
    let serve = ServeConfig {
        ondie_tokens: b,
        max_seq: s.max(1),
        prefill_len: 1,
        ..ServeConfig::default()
    };
    let mut kv = KvCacheManager::new(&model, &serve, EdramParams::default());
    kv.start_seq(0);
    kv.prefill(0, 1, 0.0);
    for step in 1..s {
        let now = step as f64 * tbt;
        kv.write_token(0, now);
        kv.read_context(0, now)?;
    }
    println!("live run: seq {s}, {b} on-die tokens, TBT {:.1} ms", tbt * 1e3);
    println!(
        "  external reduction (manager): {}   closed form: {}",
        fmt_pct(kv.stats.external_reduction()),
        fmt_pct(simulate_reduction(s, b)),
    );
    println!(
        "  eDRAM: {} reads, {} writes, {} explicit refreshes, {} retention failures",
        kv.edram().reads,
        kv.edram().writes,
        kv.edram().explicit_refreshes,
        kv.edram().retention_failures,
    );
    println!(
        "  external DRAM: {} accesses, {:.2} µJ",
        kv.dram().accesses(),
        kv.external_energy_j() * 1e6
    );
    assert_eq!(kv.edram().explicit_refreshes, 0);
    assert!(
        (kv.stats.external_reduction() - simulate_reduction(s, b)).abs() < 1e-9,
        "manager accounting must equal the closed form"
    );
    println!("kvcache_study OK");
    Ok(())
}
