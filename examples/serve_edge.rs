//! End-to-end edge-serving driver (the paper's §V-B deployment and the
//! repo's headline validation run, recorded in EXPERIMENTS.md):
//!
//! * trained (or seed) BitNet model compiled into 6 macro partitions,
//! * up to 6 batches pipelined through the partition executables,
//! * modeled-TBT slack check against tREF = 64 ms (the PJRT executor's
//!   device-side KV is opaque to the host, so the *measured* tiered-
//!   store statistics and live retention checking belong to the
//!   `serve_host` path — see DESIGN.md §10).
//!
//!   cargo run --release --example serve_edge -- --requests 24 --rate 20
//!
//! Also reports the batching ablation: the same trace at 1 vs 6 slots.

use bitrom::config::ServeConfig;
use bitrom::coordinator::Server;
use bitrom::runtime::{Manifest, ModelExecutor};
use bitrom::trace::{generate, TraceConfig};
use bitrom::util::args::ArgParser;
use bitrom::util::table::fmt_pct;

fn run(batches: usize, trace_cfg: &TraceConfig) -> anyhow::Result<(f64, f64)> {
    let exec = ModelExecutor::load(&Manifest::default_dir())?;
    let serve = ServeConfig {
        max_batches: batches,
        ..ServeConfig::default()
    };
    let mut server = Server::new(exec, serve)?;
    let (done, mut metrics) = server.run_trace(generate(trace_cfg))?;
    assert!(!done.is_empty());
    // the PJRT executor's KV is device-side and opaque to the host, so
    // no measured tier statistics exist on this path (run the
    // serve_host example for the store-backed measurement)
    assert!(metrics.kv.is_none());
    Ok((metrics.tokens_per_s(), metrics.tbt.pct(50.0)))
}

fn main() -> anyhow::Result<()> {
    let args = ArgParser::new("serve_edge", "end-to-end pipelined serving driver")
        .opt("requests", "18", "requests in the trace")
        .opt("rate", "0", "arrival rate (req/s; 0 = closed batch)")
        .opt("gen", "32", "max new tokens")
        .opt("seed", "1", "trace seed")
        .parse_env();

    let trace_cfg = TraceConfig {
        n_requests: args.usize("requests"),
        arrival_rate: args.f64("rate"),
        gen_len_min: 16.min(args.usize("gen")),
        gen_len_max: args.usize("gen"),
        seed: args.u64("seed"),
        ..TraceConfig::default()
    };

    println!("== BitROM edge-serving driver (paper §V-B) ==");
    println!(
        "trace: {} requests, prompts {}–{}, gen ≤{}, arrival {}",
        trace_cfg.n_requests,
        trace_cfg.prompt_len_min,
        trace_cfg.prompt_len_max,
        trace_cfg.gen_len_max,
        if trace_cfg.arrival_rate > 0.0 {
            format!("poisson {}/s", trace_cfg.arrival_rate)
        } else {
            "closed batch".into()
        }
    );

    println!("\n-- 6-batch pipeline (paper configuration) --");
    let (tput6, tbt6) = run(6, &trace_cfg)?;
    println!(
        "throughput {tput6:.1} tok/s | median TBT {:.2} ms | KV tier stats: \
         n/a on PJRT (see serve_host / report --fig5b-serving, reduction {})",
        tbt6 * 1e3,
        fmt_pct(bitrom::kvcache::simulate_reduction(128, 32)),
    );
    let hw_tbt = ServeConfig::default().hw_tbt_s;
    println!(
        "modeled hardware TBT {:.1} ms vs tREF 64 ms — slack {:.0}x \
         (wall-clock emulation TBT {:.2} ms is not the silicon's)",
        hw_tbt * 1e3,
        0.064 / hw_tbt,
        tbt6 * 1e3
    );
    assert!(hw_tbt < 0.064, "modeled TBT exceeds tREF");

    println!("\n-- single-batch baseline (pipeline ablation) --");
    let (tput1, tbt1) = run(1, &trace_cfg)?;
    println!("throughput {tput1:.1} tok/s | median TBT {:.2} ms", tbt1 * 1e3);

    println!(
        "\nbatching speedup: {:.2}x (6 slots vs 1)",
        tput6 / tput1.max(1e-9)
    );
    println!("serve_edge OK");
    Ok(())
}
