//! End-to-end *offline* serving driver: the same coordinator stack as
//! `serve_edge` (continuous batcher, 6-stage partition pipeline, the
//! tiered quantized KV store with live retention checking) but on
//! the always-built [`HostBackend`] — no PJRT, no artifacts, runs on a
//! clean checkout:
//!
//!   cargo run --release --example serve_host -- --requests 24 --rate 20
//!
//! Reports the batching ablation (1 vs 6 slots), a multi-tenant LoRA
//! pass (`--adapters N`, default 2: the same trace spread across N
//! tenant adapters, with measured per-token adapter overhead and
//! reload-free task-switch accounting), and, with `--events`, re-runs
//! the trace through the `cirom` macro simulators so the served tokens
//! double as an energy-event study.

use bitrom::config::{MacroGeometry, ModelConfig, ServeConfig};
use bitrom::coordinator::Server;
use bitrom::lora::AdapterRegistry;
use bitrom::runtime::HostBackend;
use bitrom::trace::{generate, TraceConfig};
use bitrom::util::args::ArgParser;
use bitrom::util::table::fmt_pct;

struct RunStats {
    tokens_per_s: f64,
    tbt_p50: f64,
    kv_reduction: f64,
    refreshes: u64,
    rom_sparsity: f64,
}

fn run(
    batches: usize,
    threads: usize,
    model: &ModelConfig,
    trace_cfg: &TraceConfig,
    seed: u64,
) -> anyhow::Result<RunStats> {
    let backend = HostBackend::new(model.clone(), seed)?;
    let serve = ServeConfig {
        max_batches: batches,
        threads,
        ..ServeConfig::default()
    };
    let mut server = Server::new(backend, serve)?;
    let (done, mut metrics) = server.run_trace(generate(trace_cfg))?;
    assert!(!done.is_empty());
    // measured on the store's actual accesses (not an accounting model)
    let kv = metrics.kv.clone().expect("host backend measures KV stats");
    Ok(RunStats {
        tokens_per_s: metrics.tokens_per_s(),
        tbt_p50: metrics.tbt.pct(50.0),
        kv_reduction: kv.external_reduction(),
        refreshes: kv.explicit_refreshes,
        rom_sparsity: server.backend().rom_sparsity(),
    })
}

fn main() -> anyhow::Result<()> {
    let args = ArgParser::new("serve_host", "offline end-to-end serving driver (HostBackend)")
        .opt("model", "sim-tiny", "model config name")
        .opt("requests", "18", "requests in the trace")
        .opt("rate", "0", "arrival rate (req/s; 0 = closed batch)")
        .opt("gen", "32", "max new tokens")
        .opt("seed", "1", "trace + weight seed")
        .opt("adapters", "2", "tenant LoRA adapters for the multi-tenant pass (0 = skip)")
        .opt("threads", "0", "worker threads (0 = BITROM_THREADS or serial)")
        .flag("events", "also run the trace through the cirom event-counting path")
        .parse_env();

    let mut model = ModelConfig::named(args.str("model"))
        .ok_or_else(|| anyhow::anyhow!("unknown model {:?}", args.str("model")))?
        .with_divisible_partitions();
    // KV pages are allocated on demand in the tiered store, but the
    // server requires serve.max_seq <= model.max_seq — cap the model
    // context at what this trace's ServeConfig can use
    model.max_seq = model.max_seq.min(ServeConfig::default().max_seq);
    let seed = args.u64("seed");
    let trace_cfg = TraceConfig {
        n_requests: args.usize("requests"),
        arrival_rate: args.f64("rate"),
        gen_len_min: 16.min(args.usize("gen")),
        gen_len_max: args.usize("gen"),
        vocab_size: model.vocab_size,
        seed,
        ..TraceConfig::default()
    };

    println!("== BitROM offline serving driver (Server<HostBackend>) ==");
    println!(
        "model {}: {} params, {} partitions",
        model.name,
        model.param_count(),
        model.n_partitions,
    );
    println!(
        "trace: {} requests, prompts {}–{}, gen ≤{}, arrival {}",
        trace_cfg.n_requests,
        trace_cfg.prompt_len_min,
        trace_cfg.prompt_len_max,
        trace_cfg.gen_len_max,
        if trace_cfg.arrival_rate > 0.0 {
            format!("poisson {}/s", trace_cfg.arrival_rate)
        } else {
            "closed batch".into()
        }
    );

    let threads = args.usize("threads");
    println!("\n-- 6-batch pipeline (paper configuration) --");
    let six = run(6, threads, &model, &trace_cfg, seed)?;
    println!(
        "fabricated ROM sparsity {} | throughput {:.1} tok/s | median TBT {:.3} ms | \
         KV external reduction {} | explicit eDRAM refreshes {}",
        fmt_pct(six.rom_sparsity),
        six.tokens_per_s,
        six.tbt_p50 * 1e3,
        fmt_pct(six.kv_reduction),
        six.refreshes,
    );
    assert_eq!(six.refreshes, 0, "DR eDRAM must need no explicit refreshes");

    println!("\n-- single-batch baseline (pipeline ablation) --");
    let one = run(1, threads, &model, &trace_cfg, seed)?;
    println!(
        "throughput {:.1} tok/s | median TBT {:.3} ms",
        one.tokens_per_s,
        one.tbt_p50 * 1e3
    );
    println!(
        "\nbatching speedup: {:.2}x (6 slots vs 1)",
        six.tokens_per_s / one.tokens_per_s.max(1e-9)
    );

    let width_probe = ServeConfig {
        threads,
        ..ServeConfig::default()
    };
    let resolved = width_probe.resolved_threads();
    if resolved != 1 {
        // thread ablation: the same 6-batch trace on the serial engine.
        // Tokens are bit-identical at any width (DESIGN.md §12) — only
        // the throughput moves. Skipped entirely when the deployment
        // already resolves to the serial engine.
        println!("\n-- serial baseline (threads ablation, width 1) --");
        let serial = run(6, 1, &model, &trace_cfg, seed)?;
        println!(
            "throughput {:.1} tok/s | parallel speedup {:.2}x at {resolved} worker thread(s)",
            serial.tokens_per_s,
            six.tokens_per_s / serial.tokens_per_s.max(1e-9),
        );
    }

    let n_adapters = args.usize("adapters");
    if n_adapters > 0 {
        println!("\n-- multi-tenant LoRA pass ({n_adapters} adapters, rank 16 on VOD) --");
        let serve = ServeConfig {
            n_adapters,
            threads,
            ..ServeConfig::default()
        };
        let lora = serve.lora_config()?.expect("adapters enabled");
        let registry = AdapterRegistry::fabricate(&model, &lora, n_adapters, seed ^ 0xADA9)?;
        let adapter_bytes = registry.adapter_bytes();
        let reload_bytes = registry.full_reload_bytes();
        let backend = HostBackend::with_adapters(model.clone(), seed, registry)?;
        let mut server = Server::new(backend, serve)?;
        // literally the same trace as the passes above (same prompts
        // and budgets), with tenants assigned round-robin post-hoc —
        // so the throughput line is comparable to the 6-batch run
        let mut reqs = generate(&trace_cfg);
        for (i, r) in reqs.iter_mut().enumerate() {
            r.adapter_id = Some((i % n_adapters) as u32);
        }
        let n_reqs = reqs.len();
        let (done, metrics) = server.run_trace(reqs)?;
        assert_eq!(done.len(), n_reqs);
        let tput = metrics.tokens_per_s();
        let stats = metrics.lora.expect("adapter backend measures LoRA stats");
        println!(
            "throughput {:.1} tok/s | measured adapter op overhead {} | binds {} \
             (cold loads {}, {} B streamed)",
            tput,
            fmt_pct(stats.measured_op_overhead()),
            stats.binds,
            stats.cold_loads,
            stats.bytes_streamed,
        );
        println!(
            "task switch: {adapter_bytes} B cold / 0 B resident — a full weight reload \
             would move {reload_bytes} B ({:.1}x more)",
            reload_bytes as f64 / adapter_bytes as f64,
        );
        assert!(stats.binds > 0, "adapter trace must bind tenants");
    }

    if args.flag("events") {
        println!("\n-- cirom event-counting pass (slow; same tokens) --");
        let backend = HostBackend::with_cirom_events(
            model.clone(),
            seed,
            MacroGeometry::default(),
        )?;
        let mut server = Server::new(backend, ServeConfig::default())?;
        let small = TraceConfig {
            n_requests: trace_cfg.n_requests.min(4),
            prompt_len_min: 4,
            prompt_len_max: 8,
            gen_len_min: 4,
            gen_len_max: 8,
            ..trace_cfg.clone()
        };
        let (_, metrics) = server.run_trace(generate(&small))?;
        let ev = server.backend().events().expect("event mode");
        println!(
            "{} tokens served through the macro simulators: {} MACs, \
             {} weight reads, zero-skip rate {}, saturations {}",
            metrics.tokens_out,
            ev.macs,
            ev.weight_reads,
            fmt_pct(ev.skip_rate()),
            ev.saturations,
        );
        assert_eq!(ev.saturations, 0, "TriMLA accumulators must not saturate");
    }

    println!("serve_host OK");
    Ok(())
}
