//! Fig 1(a) area explorer: silicon-area estimation across models and
//! nodes, plus the §V-B Falcon3-1B deployment point (ROM + DR eDRAM).
//!
//!   cargo run --release --example area_explorer

use bitrom::config::{EdramParams, HardwareConfig, ModelConfig, TechNode};
use bitrom::energy::{area_estimate, EnergyModel, ModelPoint};
use bitrom::report::fig1a_report;
use bitrom::util::args::ArgParser;

fn main() -> anyhow::Result<()> {
    let args = ArgParser::new("area_explorer", "Fig 1(a) + §V-B area study")
        .opt("sparsity", "0.30", "ROM sparsity for the energy point")
        .parse_env();

    let hw = HardwareConfig::default();
    println!("{}", fig1a_report(&hw));

    // §V-B deployment point: Falcon3-1B on BitROM at 14nm
    let cfg = ModelConfig::falcon3_1b();
    let rom_pt = ModelPoint::ternary("falcon3-1b (ROM weights)", cfg.rom_param_count());
    println!("== §V-B deployment: Falcon3-1B on BitROM ==");
    for node in [TechNode::N65, TechNode::N28, TechNode::N14] {
        let a = area_estimate(&hw, &rom_pt, node);
        // eDRAM macro area: 13.5 MB at an eDRAM cell density scaled from
        // the same fabric constants (2T-gain-cell ≈ 2x ROM cell area).
        let edram_bits = EdramParams::default().capacity_bytes as f64 * 8.0;
        let edram_mm2 = edram_bits * 2.0 * hw.geometry.cell_area_um2 * 1e-6
            / node.density_scale_vs_65();
        println!(
            "{:>5}nm: ROM {:.1} mm² ({} macros) + DR eDRAM {:.1} mm²  => total {:.1} mm²",
            node.nm(),
            a.rom_mm2,
            a.n_macros,
            edram_mm2,
            a.rom_mm2 + edram_mm2
        );
    }

    // the energy side of Table III at both voltages
    let sparsity = args.f64("sparsity");
    println!("\n== energy design points (sparsity {:.2}) ==", sparsity);
    for vdd in [0.6, 1.2] {
        let m = EnergyModel::new(HardwareConfig::default().at_voltage(vdd));
        println!(
            "  {vdd} V: {:>5.1} TOPS/W (4b acts)  {:>5.1} TOPS/W (8b bit-serial)",
            m.tops_per_watt_analytic(sparsity, 4),
            m.tops_per_watt_analytic(sparsity, 8),
        );
        let p = m.per_token(&ModelConfig::falcon3_1b(), sparsity);
        println!(
            "       falcon3-1b: {:.2} ms/token, {:.1} µJ/token, {:.2} W avg, {} macros",
            p.latency_per_token_s * 1e3,
            p.energy_per_token_j * 1e6,
            p.avg_power_w,
            p.n_macros
        );
    }
    println!("area_explorer OK");
    Ok(())
}
