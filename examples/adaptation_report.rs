//! Renders the adaptation study results (`results/adaptation.json`,
//! produced by `make experiments`) as paper-style tables: Table I,
//! Table II, Fig 6(a), Fig 6(b).
//!
//!   make experiments && cargo run --release --example adaptation_report

use std::path::PathBuf;

use bitrom::util::args::ArgParser;
use bitrom::util::json::Json;
use bitrom::util::table::Table;

fn fmt(v: Option<&Json>) -> String {
    v.and_then(Json::as_f64)
        .map(|x| format!("{x:.2}"))
        .unwrap_or_else(|| "-".into())
}

fn main() -> anyhow::Result<()> {
    let args = ArgParser::new("adaptation_report", "render Table I/II + Fig 6")
        .opt("results", "results/adaptation.json", "results file")
        .parse_env();
    let path = PathBuf::from(args.str("results"));
    let j = Json::parse_file(&path).map_err(|e| {
        anyhow::anyhow!("{e}\nrun `make experiments` first to produce {}", path.display())
    })?;

    println!(
        "adaptation study on config {:?} ({} base steps, {} LoRA steps)\n",
        j.get("config").and_then(Json::as_str).unwrap_or("?"),
        j.at(&["steps_base"]).and_then(Json::as_f64).unwrap_or(0.0),
        j.at(&["steps_lora"]).and_then(Json::as_f64).unwrap_or(0.0),
    );

    // ---- Table I ----------------------------------------------------------
    if let Some(t1) = j.get("table1") {
        let mut t = Table::new(
            "Table I — adapted | base across tasks (paper: adapted consistently wins)",
        )
        .header(&["metric", "base", "adapted", "direction ok?"]);
        let base = t1.get("base").unwrap();
        let adapted = t1.get("adapted").unwrap();
        if let Some(obj) = base.as_obj() {
            for (k, bv) in obj {
                let av = adapted.get(k);
                let (b, a) = (bv.as_f64().unwrap_or(0.0), av.and_then(Json::as_f64).unwrap_or(0.0));
                // ppl: lower is better; everything else: higher is better
                let ok = if k == "ppl" { a <= b } else { a >= b };
                t.row(&[
                    k.clone(),
                    format!("{b:.2}"),
                    format!("{a:.2}"),
                    if ok { "yes" } else { "NO" }.to_string(),
                ]);
            }
        }
        println!("{}", t.render());
    }

    // ---- Table II ---------------------------------------------------------
    if let Some(t2) = j.get("table2").and_then(Json::as_obj) {
        let mut t = Table::new(
            "Table II — adapter placement ablation on QA (paper: VOD ≈ ALL at 1/3 params)",
        )
        .header(&["placement", "params %", "EM", "F1"]);
        for label in ["QKGU", "D", "OD", "VOD", "ALL"] {
            if let Some(row) = t2.get(label) {
                t.row(&[
                    label.to_string(),
                    fmt(row.get("params_pct")),
                    fmt(row.get("em")),
                    fmt(row.get("f1")),
                ]);
            }
        }
        println!("{}", t.render());
    }

    // ---- Fig 6(a) ---------------------------------------------------------
    if let Some(f6a) = j.get("fig6a").and_then(Json::as_obj) {
        let mut t = Table::new(
            "Fig 6(a) — adapter weight bit-width vs QA score (paper: 6-bit suffices)",
        )
        .header(&["bits", "EM", "F1"]);
        for bits in ["2", "3", "4", "6", "8"] {
            if let Some(row) = f6a.get(bits) {
                t.row(&[bits.to_string(), fmt(row.get("em")), fmt(row.get("f1"))]);
            }
        }
        println!("{}", t.render());
    }

    // ---- Fig 6(b) ---------------------------------------------------------
    if let Some(f6b) = j.get("fig6b") {
        let mut t = Table::new(
            "Fig 6(b) — BitNet vs full-precision base (paper: BitNet ppl higher, task scores competitive; adapter quantization ≈ free)",
        )
        .header(&["quantity", "value"]);
        for k in [
            "bitnet_ppl",
            "fp_ppl",
            "bitnet_qa_quant_adapter",
            "bitnet_qa_fp_adapter",
            "fp_qa_quant_adapter",
            "fp_qa_fp_adapter",
        ] {
            t.row(&[k.to_string(), fmt(f6b.get(k))]);
        }
        println!("{}", t.render());
    }

    println!(
        "study wall time: {:.0}s",
        j.get("wall_s").and_then(Json::as_f64).unwrap_or(0.0)
    );
    Ok(())
}
