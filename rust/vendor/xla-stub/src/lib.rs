//! Offline stub of the subset of the `xla` (xla-rs) PJRT binding the
//! `bitrom` runtime uses. Host-side `Literal` construction/conversion
//! works for real (so `runtime::tensor` and its tests are exercisable
//! without a PJRT plugin); anything that needs an actual XLA runtime —
//! client creation, compilation, execution — returns a clean error.
//!
//! Swap this for the real binding by pointing the `xla` dependency in
//! `rust/Cargo.toml` at the xla-rs crate; no source change needed.

use std::fmt;
use std::path::Path;

/// Stub error — carries the reason PJRT functionality is unavailable.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real PJRT binding (this build vendors the \
         offline stub; point Cargo.toml's `xla` dependency at xla-rs)"
    )))
}

/// Element storage for host literals (f32 and i32 are the only types
/// the runtime moves across the boundary).
#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types supported by the stub's host literals.
pub trait NativeType: Sized + Clone {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side literal: typed buffer + shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: T::wrap(v.to_vec()),
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            dims: vec![],
            data: T::wrap(vec![v]),
        }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Copy the buffer out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Destructure a tuple literal. The stub never produces tuples
    /// (they only come out of executions), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("tuple literals (produced only by execution)")
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module — the stub only records the path it came from.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        // Validate existence so error messages stay precise, but defer
        // the "no runtime" error to compile time-of-use.
        let p = path.as_ref();
        if !p.exists() {
            return Err(Error(format!("HLO file not found: {}", p.display())));
        }
        Ok(HloModuleProto {
            path: p.display().to_string(),
        })
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        XlaComputation {
            proto: proto.clone(),
        }
    }
}

/// PJRT client handle. Construction fails in the stub: there is no
/// backing runtime to hand out.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu()")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile()")
    }
}

/// A compiled executable (unreachable in the stub — `compile` errors).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute()")
    }
}

/// A device buffer (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync()")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_bad_reshape() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[3]).is_err());
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("stub"));
    }
}
