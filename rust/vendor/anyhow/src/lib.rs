//! Offline shim for the `anyhow` crate — exactly the subset this
//! repository uses (`Error`, `Result`, `Context`, `anyhow!`, `bail!`,
//! `ensure!`), implemented from scratch so a clean checkout builds with
//! no registry access. Behaviour matches the real crate for these APIs:
//! `{e}` prints the outermost message, `{e:#}` the full cause chain
//! joined with ": ", and `{e:?}` the message plus a "Caused by" list.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamically-typed error with a cause chain.
///
/// Like the real `anyhow::Error`, this deliberately does NOT implement
/// `std::error::Error`, which is what allows the blanket
/// `From<E: std::error::Error>` conversion used by `?`.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    // The original typed error, when this level was built from one —
    // what makes `downcast_ref` work through context wrapping.
    payload: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    /// Create an error from a printable message (mirrors `Error::msg`).
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error {
            msg: msg.to_string(),
            source: None,
            payload: None,
        }
    }

    /// Create an error from a typed `std::error::Error`, keeping the
    /// value for later [`Error::downcast_ref`] (mirrors `Error::new`).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Self {
        Self::from(e)
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
            payload: None,
        }
    }

    /// The typed error this chain was built from, if any level of it
    /// was created via [`Error::new`] / the `From` conversion used by
    /// `?` (mirrors `anyhow::Error::downcast_ref`, searching through
    /// `context` wrapping outermost-first).
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(p) = e.payload.as_ref().and_then(|p| p.downcast_ref::<E>()) {
                return Some(p);
            }
            cur = e.source.as_deref();
        }
        None
    }

    /// True when [`Error::downcast_ref`] for `E` would succeed.
    pub fn is<E: 'static>(&self) -> bool {
        self.downcast_ref::<E>().is_some()
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = &e.source;
        }
        msgs.into_iter()
    }

    /// The outermost message (handy in tests).
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, colon-joined, like real anyhow
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // capture the std cause chain as nested shim errors
        fn build(e: &(dyn std::error::Error + 'static)) -> Error {
            Error {
                msg: e.to_string(),
                source: e.source().map(|s| Box::new(build(s))),
                payload: None,
            }
        }
        let mut err = build(&e);
        err.payload = Some(Box::new(e));
        err
    }
}

/// Context extension for `Result` and `Option` (mirrors
/// `anyhow::Context`).
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_wraps_and_alternate_prints_chain() {
        let e: Result<()> = std::result::Result::<(), _>::Err(io_err()).context("opening config");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} of {}", 1, 2);
        assert_eq!(format!("{e}"), "bad 1 of 2");
        fn guarded(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(guarded(5).is_ok());
        assert_eq!(format!("{}", guarded(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", guarded(200).unwrap_err()), "too big");
    }

    #[test]
    fn error_msg_accepts_strings() {
        let e = Error::msg("plain".to_string());
        assert_eq!(format!("{e}"), "plain");
        let r: Result<(), String> = Err("stringy".into());
        let e = r.map_err(Error::msg).unwrap_err();
        assert_eq!(format!("{e}"), "stringy");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn downcast_ref_finds_typed_errors_through_context() {
        let e = Error::new(io_err());
        assert_eq!(
            e.downcast_ref::<std::io::Error>().unwrap().kind(),
            std::io::ErrorKind::NotFound
        );
        // `?` conversion and context wrapping both keep the payload
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err().context("outer");
        assert!(e.is::<std::io::Error>());
        assert!(!e.is::<std::fmt::Error>());
        // message-only errors carry no payload
        assert!(!anyhow!("plain {}", 1).is::<std::io::Error>());
    }
}
