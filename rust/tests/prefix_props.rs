//! Property harness for shared-prefix KV caching (DESIGN.md §15,
//! invariant 11): under seeded random geometries (block size × eDRAM
//! capacity × eviction pressure × quantization) and random traces,
//!
//! * a shared-prefix serve is byte-identical to its private-KV twin at
//!   1/2/4 worker threads;
//! * reference counts return to zero after `retire_seq` in any order
//!   (no leaked blocks, no stale prefix-index entries);
//! * a copy-on-write fork never mutates a block another sequence
//!   still reads;
//! * eviction/demotion of a shared block is a tier move only — every
//!   reader keeps seeing the same bytes;
//! * the fairness/preemption scheduler (priorities, admission
//!   pressure, either preempt policy) changes placement and timing,
//!   never tokens.
//!
//! Failures print the case seed for deterministic replay
//! (`util::check`); `BITROM_FUZZ_CASES` bounds the case count.

use bitrom::config::{EdramParams, ModelConfig, ServeConfig};
use bitrom::coordinator::{CompletedRequest, ServeMetrics, Server};
use bitrom::dram::DramParams;
use bitrom::kvcache::{KvQuant, KvSeq, KvStore, KvStoreConfig};
use bitrom::runtime::HostBackend;
use bitrom::trace::{generate, Request, TraceConfig};
use bitrom::util::check::check;
use bitrom::{prop_assert, prop_assert_eq};

const WEIGHT_SEED: u64 = 0x9A9A;

fn fuzz_cases() -> u64 {
    std::env::var("BITROM_FUZZ_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6)
}

fn run(
    reqs: Vec<Request>,
    serve: ServeConfig,
) -> anyhow::Result<(Vec<CompletedRequest>, ServeMetrics)> {
    let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED)?;
    let mut server = Server::new(backend, serve)?;
    let (mut done, metrics) = server.run_trace(reqs)?;
    done.sort_by_key(|r| r.id);
    Ok((done, metrics))
}

/// Gather one layer's full dequantized view (no read counting — the
/// comparisons below are about values, not traffic).
fn view(store: &mut KvStore, seq: &KvSeq, layer: usize, n: usize) -> Result<Vec<f32>, String> {
    let (mut k, mut v) = (Vec::new(), Vec::new());
    store
        .gather(seq, layer, n, false, &mut k, &mut v)
        .map_err(|e| format!("gather layer {layer}: {e}"))?;
    k.extend_from_slice(&v);
    Ok(k)
}

#[test]
fn cow_refcount_and_eviction_properties() {
    check(0x9A01, fuzz_cases(), |g| {
        // random geometry: block size, quantization, on-die budget and
        // a deliberately small eDRAM so appends fight over the tier
        let kv_dim = 8usize;
        let n_layers = g.usize(1, 2);
        let bt = [2usize, 4, 8][g.usize(0, 2)];
        let quant = if g.f64() < 0.5 { KvQuant::Q8 } else { KvQuant::F32 };
        let base = KvStoreConfig {
            kv_dim,
            n_layers,
            block_tokens: bt,
            ondie_tokens: bt * g.usize(0, 4),
            quant,
            edram: EdramParams::default(),
            dram: DramParams::default(),
        };
        let cap_blocks = g.usize(1, 4) as u64;
        let cfg = KvStoreConfig {
            edram: EdramParams {
                capacity_bytes: cap_blocks * base.rows_per_block() as u64 * base.edram.row_bytes,
                ..base.edram.clone()
            },
            ..base
        };
        let mut store = KvStore::new(cfg);

        // donor: at least one full block plus a tail token
        let n = bt + 1 + g.size(2 * bt);
        let prompt: Vec<i32> = (0..n).map(|_| g.usize(0, 255) as i32).collect();
        let adapter = if g.f64() < 0.5 { None } else { Some(g.usize(0, 3) as u32) };
        let mut donor = store.new_seq();
        for _ in 0..n {
            let (k, v) = (g.vec_f32(kv_dim), g.vec_f32(kv_dim));
            for layer in 0..n_layers {
                store
                    .append(&mut donor, layer, &k, &v)
                    .map_err(|e| format!("donor append: {e}"))?;
            }
        }
        store.register_prefix(&donor, adapter, &prompt);
        let mut snapshot = Vec::new();
        for layer in 0..n_layers {
            snapshot.push(view(&mut store, &donor, layer, n)?);
        }

        // the longest full-block proper prefix binds; a mismatched
        // adapter never shares
        let bound = (n - 1) / bt * bt;
        let mut binder = store.new_seq();
        prop_assert_eq!(store.bind_prefix(&mut binder, adapter, &prompt), bound);
        let mut probe = store.new_seq();
        prop_assert_eq!(store.bind_prefix(&mut probe, Some(9), &prompt), 0);
        prop_assert!(
            store.block_ref_counts(&binder).iter().all(|&r| r == 2),
            "bound blocks must be shared exactly donor+binder: {:?}",
            store.block_ref_counts(&binder)
        );

        // binder writes its own tail — the donor's bytes must not move
        for _ in 0..(n - bound) + g.size(bt) {
            let (k, v) = (g.vec_f32(kv_dim), g.vec_f32(kv_dim));
            for layer in 0..n_layers {
                store
                    .append(&mut binder, layer, &k, &v)
                    .map_err(|e| format!("binder append: {e}"))?;
            }
        }
        for (layer, snap) in snapshot.iter().enumerate() {
            prop_assert!(
                view(&mut store, &donor, layer, n)? == *snap,
                "binder tail writes mutated the donor (layer {layer})"
            );
        }

        // a fork shares even the partial tail block; its first append
        // into that block must copy-on-write, never mutate in place
        let forks_before = store.stats().cow_forks;
        let mut forked = store.fork_seq(&donor);
        for _ in 0..1 + g.size(bt) {
            let (k, v) = (g.vec_f32(kv_dim), g.vec_f32(kv_dim));
            for layer in 0..n_layers {
                store
                    .append(&mut forked, layer, &k, &v)
                    .map_err(|e| format!("forked append: {e}"))?;
            }
        }
        if n % bt != 0 {
            prop_assert!(
                store.stats().cow_forks >= forks_before + n_layers as u64,
                "a write into a shared partial block must fork it"
            );
        }
        for (layer, snap) in snapshot.iter().enumerate() {
            prop_assert!(
                view(&mut store, &donor, layer, n)? == *snap,
                "a forked write mutated the donor (layer {layer})"
            );
        }

        // demotion of the (shared) donor is a tier move only: the
        // binder keeps reading identical bytes through shared blocks
        if g.f64() < 0.5 {
            store.demote_seq(&donor).map_err(|e| format!("demote: {e}"))?;
        }
        let d = kv_dim;
        for (layer, snap) in snapshot.iter().enumerate() {
            let b = view(&mut store, &binder, layer, bound)?;
            prop_assert!(
                b[..bound * d] == snap[..bound * d] && b[bound * d..] == snap[n * d..(n + bound) * d],
                "shared prefix bytes diverged after pressure (layer {layer})"
            );
        }

        // retirement in any order returns every refcount to zero:
        // no live blocks, no on-die rows, no stale prefix entries
        let mut seqs = vec![donor, binder, forked, probe];
        while !seqs.is_empty() {
            let i = g.usize(0, seqs.len() - 1);
            let mut s = seqs.swap_remove(i);
            store.retire_seq(&mut s);
        }
        prop_assert_eq!(store.live_blocks(), 0);
        prop_assert_eq!(store.prefix_entries(), 0);
        prop_assert_eq!(store.ondie_blocks_in_use(), 0);
        Ok(())
    });
}

#[test]
fn shared_prefix_serving_is_bit_identical_to_its_private_twin() {
    // trace grammar × capacity grammar: every prompt shares one pool
    // prefix of at least one full block, queued admissions bind it —
    // tokens must match the cache-off twin exactly, at every width
    check(0x9A02, fuzz_cases().min(4), |g| {
        let spl = 8 + g.usize(0, 8);
        let max_batches = g.usize(1, 3);
        let trace_cfg = TraceConfig {
            n_requests: max_batches + 1 + g.size(3),
            prompt_len_min: spl + 1,
            prompt_len_max: spl + 2 + g.size(6),
            gen_len_min: 2,
            gen_len_max: 2 + g.size(6),
            vocab_size: ModelConfig::sim_tiny().vocab_size,
            arrival_rate: 0.0,
            shared_prefix_len: spl,
            shared_prefixes: 1,
            seed: g.rng.next_u64(),
            ..TraceConfig::default()
        };
        let shared = ServeConfig {
            max_batches,
            prefix_cache: true,
            kv_edram_bytes: if g.f64() < 0.4 { 1 << 15 } else { 13_500_000 },
            ..ServeConfig::default()
        };
        let private = ServeConfig {
            prefix_cache: false,
            ..shared.clone()
        };
        let reqs = generate(&trace_cfg);
        let (base, _) = run(reqs.clone(), private).map_err(|e| format!("private twin: {e:#}"))?;
        prop_assert_eq!(base.len(), reqs.len());
        let mut counters = None;
        for threads in [1usize, 2, 4] {
            let cfg = ServeConfig {
                threads,
                ..shared.clone()
            };
            let (done, m) =
                run(reqs.clone(), cfg).map_err(|e| format!("shared (threads={threads}): {e:#}"))?;
            prop_assert_eq!(done.len(), base.len());
            for (a, b) in base.iter().zip(&done) {
                prop_assert!(
                    a.id == b.id && a.tokens == b.tokens,
                    "request {} diverged from the private twin at {threads} threads",
                    a.id
                );
            }
            let kv = m.kv.clone().ok_or("host backend must measure KV stats")?;
            prop_assert_eq!(kv.retention_failures, 0);
            // queued admissions arrive strictly after a first-wave
            // registration, so sharing must actually happen
            prop_assert!(kv.prefix_hits >= 1, "no prefix hits despite a common pool prompt");
            let c = (kv.prefix_hits, kv.prefix_bound_tokens, kv.cow_forks);
            match counters {
                None => counters = Some(c),
                Some(c0) => prop_assert!(
                    c0 == c,
                    "prefix counters diverged at {threads} threads: {c0:?} vs {c:?}"
                ),
            }
        }
        Ok(())
    });
}

#[test]
fn scheduling_knobs_change_placement_never_tokens() {
    // invariant 11, scheduler face: priorities, pressure-gated
    // admission, preemption under either KV policy — served tokens
    // stay identical to the relaxed run, and every fault counter is
    // width-invariant
    check(0x9A03, fuzz_cases().min(4), |g| {
        let trace_cfg = TraceConfig {
            n_requests: 2 + g.size(4),
            prompt_len_min: 2,
            prompt_len_max: 2 + g.size(8),
            gen_len_min: 2,
            gen_len_max: 2 + g.size(8),
            vocab_size: ModelConfig::sim_tiny().vocab_size,
            arrival_rate: 0.0,
            priority_classes: 2 + g.usize(0, 2),
            seed: g.rng.next_u64(),
            ..TraceConfig::default()
        };
        let relaxed = ServeConfig {
            max_batches: g.usize(1, 3),
            ..ServeConfig::default()
        };
        let pressure = 0.2 + 0.6 * g.f64();
        let edram = if g.f64() < 0.5 { 1 << 15 } else { 1 << 16 };
        let reqs = generate(&trace_cfg);
        let (base, _) = run(reqs.clone(), relaxed.clone()).map_err(|e| format!("relaxed: {e:#}"))?;
        prop_assert_eq!(base.len(), reqs.len());
        for policy in ["reload", "recompute"] {
            let mut faults = None;
            for threads in [1usize, 2, 4] {
                let cfg = ServeConfig {
                    threads,
                    admit_pressure: pressure,
                    preempt_under_pressure: true,
                    preempt_policy: policy.to_string(),
                    kv_edram_bytes: edram,
                    ..relaxed.clone()
                };
                let (done, m) = run(reqs.clone(), cfg)
                    .map_err(|e| format!("{policy} (threads={threads}): {e:#}"))?;
                prop_assert_eq!(done.len(), base.len());
                for (a, b) in base.iter().zip(&done) {
                    prop_assert!(
                        a.id == b.id && a.tokens == b.tokens,
                        "request {} changed under {policy} preemption at {threads} threads",
                        a.id
                    );
                }
                match &faults {
                    None => faults = Some(m.faults.clone()),
                    Some(f0) => prop_assert!(
                        *f0 == m.faults,
                        "{policy} fault counters diverged at {threads} threads"
                    ),
                }
            }
        }
        Ok(())
    });
}

// ---- deterministic scheduler scenarios --------------------------------

fn req(id: u64, base_tok: i32, gen: usize, priority: u8) -> Request {
    Request {
        id,
        arrival_s: 0.0,
        prompt: (base_tok..base_tok + 8).collect(),
        max_new_tokens: gen,
        adapter_id: None,
        priority,
    }
}

fn tokens_of(done: &[CompletedRequest]) -> Vec<(u64, Vec<i32>)> {
    done.iter().map(|r| (r.id, r.tokens.clone())).collect()
}

#[test]
fn preemption_victims_follow_priority_classes() {
    // two active slots under pressure with a queued third: the victim
    // must be the LOWEST class. Marking the long request high-priority
    // has to redirect the demotion onto the short one — observable as
    // strictly fewer external context reads (the long sequence keeps
    // its early blocks on-die), with tokens identical throughout.
    let serve = ServeConfig {
        max_batches: 2,
        kv_edram_bytes: 1 << 15,
        admit_pressure: 0.5,
        preempt_under_pressure: true,
        ..ServeConfig::default()
    };
    let trace = |prio_long: u8, prio_short: u8| {
        vec![
            req(0, 0, 40, prio_long),
            req(1, 100, 6, prio_short),
            req(2, 200, 6, 0),
        ]
    };
    let relaxed = ServeConfig {
        max_batches: 2,
        ..ServeConfig::default()
    };
    let (base, _) = run(trace(0, 0), relaxed).unwrap();
    assert_eq!(base.len(), 3);

    // A: the long request is the low class -> it is the victim
    let (done_a, m_a) = run(trace(0, 7), serve.clone()).unwrap();
    // B: priorities swapped -> the short request is the victim
    let (done_b, m_b) = run(trace(7, 0), serve).unwrap();
    assert_eq!(tokens_of(&done_a), tokens_of(&base), "priorities changed tokens (A)");
    assert_eq!(tokens_of(&done_b), tokens_of(&base), "priorities changed tokens (B)");
    assert!(m_a.faults.preemptions >= 1, "pressure never preempted (A)");
    assert!(m_b.faults.preemptions >= 1, "pressure never preempted (B)");
    let ext = |m: &ServeMetrics| m.kv.as_ref().unwrap().accesses.external_reads;
    assert!(
        ext(&m_a) > ext(&m_b),
        "demoting the long low-priority sequence must cost more external reads \
         ({} vs {}) — the victim choice ignored priority",
        ext(&m_a),
        ext(&m_b),
    );
}

#[test]
fn admission_gate_defers_until_pressure_clears() {
    // a starved tier keeps measured pressure above the threshold while
    // slots are busy: the queued request is deferred (counted), admits
    // once slots drain, and every token matches the ungated twin — at
    // every pool width
    let reqs: Vec<Request> = (0..3).map(|i| req(i, i as i32 * 80, 20, 0)).collect();
    let relaxed = ServeConfig {
        max_batches: 2,
        kv_edram_bytes: 1 << 14,
        ..ServeConfig::default()
    };
    let (base, base_m) = run(reqs.clone(), relaxed.clone()).unwrap();
    assert_eq!(base.len(), 3);
    assert_eq!(base_m.faults.admission_deferrals, 0);
    let mut counters = None;
    for threads in [1usize, 2, 4] {
        let gated = ServeConfig {
            threads,
            admit_pressure: 0.6,
            ..relaxed.clone()
        };
        let (done, m) = run(reqs.clone(), gated).unwrap();
        assert_eq!(tokens_of(&done), tokens_of(&base), "gating changed tokens");
        assert!(
            m.faults.admission_deferrals >= 1,
            "sustained pressure must defer the queued request"
        );
        match &counters {
            None => counters = Some(m.faults.clone()),
            Some(f0) => assert_eq!(
                *f0, m.faults,
                "admission counters diverged at {threads} threads"
            ),
        }
    }
}
