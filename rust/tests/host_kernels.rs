//! Cross-layer host-kernel integration tests (default features — no
//! PJRT, no artifacts): the word-parallel bitplane engine must agree
//! bit-exactly with the golden reference everywhere it is wired in:
//! the `bitnet` kernels themselves, the `cirom` functional paths, and
//! the LoRA merged-projection compute.

use bitrom::bitnet::{
    absmax_quantize, ref_gemv, BitplaneMatrix, KernelCtx, KernelPath, TernaryMatrix,
};
use bitrom::cirom::{BitRomMacro, EventCounters, MacroBank};
use bitrom::config::MacroGeometry;
use bitrom::lora::MergedProjection;
use bitrom::util::pool::Pool;
use bitrom::util::rng::Rng;

#[test]
fn bitplane_engine_matches_reference_across_llama_shapes() {
    let mut rng = Rng::new(0xE2E);
    // scaled-down versions of the LLaMA projection aspect ratios,
    // including a non-multiple-of-64 fan-in
    for (rows, cols) in [(256, 256), (256, 704), (193, 65)] {
        for sparsity in [0.0, 0.3, 0.9] {
            let w = TernaryMatrix::random(rows, cols, sparsity, &mut rng);
            let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
            assert_eq!(w.gemv(&x), ref_gemv(&x, &w), "{rows}x{cols} s={sparsity}");
        }
    }
}

#[test]
fn sharded_kernels_match_reference_at_every_width() {
    // DESIGN.md §12 at the integration level: the pooled TernaryMatrix
    // paths agree with the golden reference at 1/2/4/7 workers,
    // including a shape big enough to genuinely fork and widths far
    // beyond the column count
    let mut rng = Rng::new(0x12E2);
    let (rows, cols) = (1024, 96); // ≥ the kernels' parallel cutoff
    let w = TernaryMatrix::random(rows, cols, 0.3, &mut rng);
    let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
    let want = ref_gemv(&x, &w);
    let xs: Vec<Vec<i32>> = (0..4)
        .map(|_| (0..rows).map(|_| rng.i64(-127, 127) as i32).collect())
        .collect();
    let want_gemm: Vec<Vec<i64>> = xs.iter().map(|r| ref_gemv(r, &w)).collect();
    for threads in [1usize, 2, 4, 7, 256] {
        let ctx = KernelCtx::new(Pool::new(threads));
        assert_eq!(ctx.gemv(w.bitplanes(), &x), want, "gemv @ {threads} threads");
        assert_eq!(ctx.gemm(w.bitplanes(), &xs), want_gemm, "gemm @ {threads} threads");
    }
}

#[test]
fn kernel_paths_match_reference_across_shapes_widths_and_sparsities() {
    // DESIGN.md §17 at the integration level: every engine path ×
    // pool width agrees bit-exactly with the golden reference on odd
    // shapes (non-multiple-of-64 fan-ins hit the lane remainders)
    let mut rng = Rng::new(0x51D);
    for (rows, cols) in [(64, 17), (130, 33), (193, 65), (320, 48)] {
        for sparsity in [0.0, 0.5, 0.95] {
            let w = TernaryMatrix::random(rows, cols, sparsity, &mut rng);
            let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
            let want = ref_gemv(&x, &w);
            for path in [KernelPath::Auto, KernelPath::Scalar, KernelPath::BitSerial] {
                for threads in [1usize, 3] {
                    let ctx = KernelCtx::new(Pool::new(threads)).with_path(path);
                    assert_eq!(
                        ctx.gemv(w.bitplanes(), &x),
                        want,
                        "{path:?} @ {threads}t {rows}x{cols} s={sparsity}"
                    );
                }
            }
        }
    }
}

#[test]
fn flat_gemm_matches_nested_rows_on_every_path() {
    let mut rng = Rng::new(0xF1A7);
    let w = TernaryMatrix::random(150, 37, 0.4, &mut rng);
    let xs: Vec<Vec<i32>> = (0..5)
        .map(|_| (0..150).map(|_| rng.i64(-127, 127) as i32).collect())
        .collect();
    let want: Vec<Vec<i64>> = xs.iter().map(|r| ref_gemv(r, &w)).collect();
    for path in [KernelPath::Auto, KernelPath::Scalar, KernelPath::BitSerial] {
        let ctx = KernelCtx::serial().with_path(path);
        assert_eq!(ctx.gemm(w.bitplanes(), &xs), want, "{path:?} nested");
        let mut flat = Vec::new();
        ctx.gemm_flat(w.bitplanes(), &xs, &mut flat);
        let refit: Vec<&[i64]> = flat.chunks(37).collect();
        for (b, row) in refit.iter().enumerate() {
            assert_eq!(*row, &want[b][..], "{path:?} flat row {b}");
        }
    }
}

#[test]
fn macro_bank_functional_path_is_bit_exact_end_to_end() {
    let mut rng = Rng::new(0xBA11);
    let geom = MacroGeometry {
        rows: 16,
        cols: 8,
        cols_per_trimla: 8,
        ..Default::default()
    };
    // spans 3 fan-in tiles x 2 fan-out tiles
    let w = TernaryMatrix::random(40, 20, 0.3, &mut rng);
    let bank = MacroBank::fabricate(geom.clone(), &w);
    let x: Vec<f32> = (0..40).map(|_| rng.normal() as f32).collect();
    let acts = absmax_quantize(&x, 8);
    let mut ev = EventCounters::new();
    let via_circuit = bank.gemv(&acts, &mut ev);
    assert_eq!(via_circuit, ref_gemv(&acts.values, &w));
    assert_eq!(bank.gemv_functional(&acts), via_circuit);

    let single = TernaryMatrix::random(16, 8, 0.5, &mut rng);
    let m = BitRomMacro::fabricate(geom, &single);
    let acts1 = absmax_quantize(&(0..16).map(|_| rng.normal() as f32).collect::<Vec<_>>(), 4);
    let mut ev1 = EventCounters::new();
    assert_eq!(m.gemv_functional(&acts1), m.gemv(&acts1, &mut ev1));
}

#[test]
fn merged_projection_batched_compute_is_consistent() {
    let mut rng = Rng::new(0x10A);
    let base = TernaryMatrix::random(128, 48, 0.3, &mut rng);
    let rank = 4;
    let a: Vec<f32> = (0..128 * rank).map(|_| rng.normal() as f32 * 0.05).collect();
    let b: Vec<f32> = (0..rank * 48).map(|_| rng.normal() as f32 * 0.05).collect();
    let proj = MergedProjection::new(base, a, b, rank, 8.0);
    let qs: Vec<_> = (0..3)
        .map(|_| {
            let h: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
            absmax_quantize(&h, 8)
        })
        .collect();
    let batched = proj.forward_batch(&qs);
    for (q, want) in qs.iter().zip(&batched) {
        assert_eq!(&proj.forward(q), want);
    }
    // base integers inside the merge are the reference integers
    let base_only = MergedProjection::new(proj.base.clone(), vec![], vec![], 0, 0.0);
    let y = base_only.forward(&qs[0]);
    let want = ref_gemv(&qs[0].values, &proj.base);
    for (got, wi) in y.iter().zip(&want) {
        assert_eq!(*got, *wi as f32 * qs[0].scale * proj.base.scale);
    }
}

#[test]
fn plane_view_survives_clone_and_matches_storage() {
    let mut rng = Rng::new(0xC10);
    let w = TernaryMatrix::random(100, 30, 0.4, &mut rng);
    let plane = BitplaneMatrix::from_trits(
        100,
        30,
        &(0..100 * 30)
            .map(|i| w.get(i / 30, i % 30))
            .collect::<Vec<_>>(),
    );
    assert_eq!(&plane, w.bitplanes());
    assert!((plane.sparsity() - w.sparsity()).abs() < 1e-12);
}
