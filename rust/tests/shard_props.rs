//! Property harness for invariant 12 (DESIGN.md §16): shard count
//! changes throughput and placement, never tokens.
//!
//! Three layers of evidence, bottom-up:
//!
//! 1. **Kernel merge** — `sharded_gemv` / `sharded_gemm` column-split
//!    partials concatenate to the golden `ref_gemv` / `ref_gemm`
//!    integers exactly, over random geometries including uneven
//!    splits, 1-column shards, and more shards than columns.
//! 2. **Served traces** — full coordinator runs on `sim_tiny` are
//!    bit-identical across `--shards 1/2/3/5` × `--threads 1/4`,
//!    including mixed-tenant LoRA traffic and seeded top-k sampling,
//!    with merged adapter accounting equal to the unsharded run's.
//! 3. **Accounting** — per-shard KV-tier / energy counters sum to the
//!    merged view, the merged view matches the unsharded totals, and
//!    merged circuit-event counters on the event-counted cirom path
//!    equal the unsharded tally exactly.
//!
//! Cases come from `util::check`: the failing case seed is printed for
//! deterministic replay, and `BITROM_FUZZ_CASES` bounds the case count
//! (CI quick mode keeps it small).

use bitrom::bitnet::{ref_gemm, ref_gemv, TernaryMatrix};
use bitrom::config::{MacroGeometry, ModelConfig, ServeConfig};
use bitrom::coordinator::{CompletedRequest, ServeMetrics, Server};
use bitrom::kvcache::KvStoreStats;
use bitrom::lora::AdapterRegistry;
use bitrom::runtime::{
    sharded_gemm, sharded_gemv, HostBackend, InferenceBackend, KvControl, ShardedBackend,
};
use bitrom::trace::{generate, Request, TraceConfig};
use bitrom::util::check::check;
use bitrom::util::pool::Pool;
use bitrom::{prop_assert, prop_assert_eq};

const WEIGHT_SEED: u64 = 0x512D;
const ADAPTER_SEED: u64 = 0xADA7;

fn fuzz_cases() -> u64 {
    std::env::var("BITROM_FUZZ_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6)
}

/// One shard's worth of backend: the same model + weight seed every
/// time, with an adapter registry fabricated from the serve knobs when
/// tenant serving is on — every shard (and the unsharded twin) gets an
/// identical registry, mirroring how `main.rs` builds a fleet.
fn backend(model: &ModelConfig, serve: &ServeConfig) -> anyhow::Result<HostBackend> {
    match serve.lora_config()? {
        Some(lora) => {
            let reg = AdapterRegistry::fabricate(model, &lora, serve.n_adapters, ADAPTER_SEED)?;
            HostBackend::with_adapters(model.clone(), WEIGHT_SEED, reg)
        }
        None => HostBackend::new(model.clone(), WEIGHT_SEED),
    }
}

/// Run one trace on `sim_tiny` at the configured shard count,
/// returning completions (sorted by id), metrics, and the per-shard
/// KV statistics in shard order (a single vector when unsharded).
fn run(
    reqs: Vec<Request>,
    serve: ServeConfig,
) -> anyhow::Result<(Vec<CompletedRequest>, ServeMetrics, Vec<KvStoreStats>)> {
    let model = ModelConfig::sim_tiny();
    if serve.shards > 1 {
        let fleet = (0..serve.shards)
            .map(|_| backend(&model, &serve))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let mut server = Server::new(ShardedBackend::from_shards(fleet)?, serve)?;
        let (mut done, metrics) = server.run_trace(reqs)?;
        done.sort_by_key(|r| r.id);
        let per_shard = server.backend().shard_kv_stats();
        return Ok((done, metrics, per_shard));
    }
    let mut server = Server::new(backend(&model, &serve)?, serve)?;
    let (mut done, metrics) = server.run_trace(reqs)?;
    done.sort_by_key(|r| r.id);
    let per_shard = vec![server
        .backend()
        .kv_stats()
        .expect("host backends measure KV stats")];
    Ok((done, metrics, per_shard))
}

#[test]
fn sharded_kernels_merge_exactly_over_any_split() {
    // the tensor-parallel partial merge is exact i64 over disjoint
    // output columns: any shard count — uneven splits, 1-column
    // shards, more shards than columns — reproduces the golden
    // reference bit-for-bit at any pool width
    check(0x5A01, fuzz_cases(), |g| {
        let rows = 1 + g.usize(0, 39);
        let cols = 1 + g.usize(0, 39);
        let p_zero = 0.1 + 0.7 * g.f64();
        let w = TernaryMatrix::random(rows, cols, p_zero, &mut g.rng);
        let xs: Vec<Vec<i32>> = (0..1 + g.usize(0, 2))
            .map(|_| (0..rows).map(|_| g.rng.i64(-8, 8) as i32).collect())
            .collect();
        let pool = Pool::new(1 + g.usize(0, 3));
        let want_v = ref_gemv(&xs[0], &w);
        let want_m = ref_gemm(&xs, &w);
        for n_shards in [1, 2, 3, 5, cols, cols + 3] {
            prop_assert!(
                sharded_gemv(&xs[0], &w, n_shards, &pool) == want_v,
                "gemv partial merge diverged: {rows}x{cols} at {n_shards} shards"
            );
            prop_assert!(
                sharded_gemm(&xs, &w, n_shards, &pool) == want_m,
                "gemm partial merge diverged: {rows}x{cols} at {n_shards} shards"
            );
        }
        Ok(())
    });
}

#[test]
fn served_traces_are_bit_identical_across_shard_counts() {
    // invariant 12 end-to-end, asserted in CI at two thread widths:
    // the full serving loop — admission, pipeline rounds, mixed-tenant
    // adapter binds, seeded top-k sampling — produces the same tokens
    // for every request at --shards 1/2/3/5 × --threads 1/4, and the
    // merged adapter accounting is placement-invariant too
    check(0x5A02, fuzz_cases().min(4), |g| {
        let model = ModelConfig::sim_tiny();
        let trace_cfg = TraceConfig {
            n_requests: 2 + g.size(5),
            prompt_len_min: 2,
            prompt_len_max: 2 + g.size(10),
            gen_len_min: 2,
            gen_len_max: 2 + g.size(8),
            vocab_size: model.vocab_size,
            arrival_rate: 0.0,
            // mixed tenants: every request draws one of two adapters
            n_adapters: 2,
            seed: g.rng.next_u64(),
            ..TraceConfig::default()
        };
        let serve = ServeConfig {
            max_batches: g.usize(1, 4),
            n_adapters: 2,
            // sampled decoding must also be shard-invariant — the
            // logits are bit-identical, so the seeded draw is too
            top_k: 1 + g.usize(0, 2),
            seed: g.rng.next_u64(),
            ..ServeConfig::default()
        };
        let reqs = generate(&trace_cfg);
        let mut base: Option<(usize, usize, Vec<CompletedRequest>, ServeMetrics)> = None;
        for shards in [1usize, 2, 3, 5] {
            for threads in [1usize, 4] {
                let cfg = ServeConfig {
                    shards,
                    threads,
                    ..serve.clone()
                };
                let (done, m, _) = run(reqs.clone(), cfg)
                    .map_err(|e| format!("shards={shards} threads={threads}: {e:#}"))?;
                prop_assert_eq!(done.len(), reqs.len());
                let Some((bs, bt, base_done, base_m)) = &base else {
                    base = Some((shards, threads, done, m));
                    continue;
                };
                for (a, b) in base_done.iter().zip(&done) {
                    prop_assert!(
                        a.id == b.id && a.tokens == b.tokens && a.adapter_id == b.adapter_id,
                        "request {} diverged between shards={bs} threads={bt} \
                         and shards={shards} threads={threads}",
                        a.id
                    );
                }
                prop_assert_eq!(base_m.tokens_out, m.tokens_out);
                prop_assert!(
                    base_m.lora == m.lora,
                    "merged adapter accounting diverged at shards={shards} \
                     threads={threads}: {:?} vs {:?}",
                    base_m.lora,
                    m.lora
                );
            }
        }
        Ok(())
    });
}

#[test]
fn fused_decode_is_bit_identical_across_shard_counts() {
    // DESIGN.md §17 × invariant 12: the fused batched decode round —
    // whole-batch partition calls routed to each partition's owning
    // shard — must emit exactly the per-slot path's tokens at every
    // shard count and pool width, with the same merged KV accounting.
    check(0x5A04, fuzz_cases().min(3), |g| {
        let model = ModelConfig::sim_tiny();
        let trace_cfg = TraceConfig {
            n_requests: 3 + g.size(4),
            prompt_len_min: 2,
            prompt_len_max: 2 + g.size(8),
            gen_len_min: 2,
            gen_len_max: 2 + g.size(6),
            vocab_size: model.vocab_size,
            arrival_rate: 0.0,
            seed: g.rng.next_u64(),
            ..TraceConfig::default()
        };
        let reqs = generate(&trace_cfg);
        let serve = ServeConfig {
            max_batches: 2 + g.usize(0, 2),
            threads: 1 + g.usize(0, 3),
            ..ServeConfig::default()
        };
        let (base_done, base_m, _) = run(
            reqs.clone(),
            ServeConfig {
                shards: 1,
                fused_decode: false,
                ..serve.clone()
            },
        )
        .map_err(|e| format!("unfused unsharded run: {e:#}"))?;
        let base_kv = base_m.kv.ok_or("unsharded run must measure KV stats")?;
        for shards in [1usize, 2, 3] {
            let (done, m, _) = run(
                reqs.clone(),
                ServeConfig {
                    shards,
                    fused_decode: true,
                    ..serve.clone()
                },
            )
            .map_err(|e| format!("fused run at {shards} shards: {e:#}"))?;
            prop_assert_eq!(done.len(), base_done.len());
            for (a, b) in base_done.iter().zip(&done) {
                prop_assert!(
                    a.id == b.id && a.tokens == b.tokens,
                    "request {} diverged fused at {shards} shards",
                    a.id
                );
            }
            // the fused walk issues exactly the per-slot KV traffic
            let kv = m.kv.ok_or("sharded run must measure KV stats")?;
            prop_assert_eq!(kv.accesses.ondie_reads, base_kv.accesses.ondie_reads);
            prop_assert_eq!(kv.accesses.ondie_writes, base_kv.accesses.ondie_writes);
            prop_assert_eq!(kv.accesses.external_reads, base_kv.accesses.external_reads);
            prop_assert_eq!(kv.accesses.external_writes, base_kv.accesses.external_writes);
            prop_assert_eq!(kv.retention_failures, 0u64);
        }
        Ok(())
    });
}

#[test]
fn per_shard_kv_accounting_sums_to_the_unsharded_totals() {
    // the accounting half of invariant 12: under the roomy default
    // deployment (no capacity pressure, so placement is identical),
    // every per-tier access counter of the sharded run equals the
    // unsharded run's, the memory energies agree to float tolerance,
    // and the merged backend view is exactly the shard-ordered sum of
    // the per-shard views
    check(0x5A03, fuzz_cases().min(4), |g| {
        let model = ModelConfig::sim_tiny();
        let trace_cfg = TraceConfig {
            n_requests: 2 + g.size(4),
            prompt_len_min: 2,
            prompt_len_max: 2 + g.size(8),
            gen_len_min: 2,
            gen_len_max: 2 + g.size(6),
            vocab_size: model.vocab_size,
            seed: g.rng.next_u64(),
            ..TraceConfig::default()
        };
        let serve = ServeConfig {
            threads: 1,
            ..ServeConfig::default()
        };
        let n_shards = g.usize(2, 5);
        let reqs = generate(&trace_cfg);
        let (done1, m1, _) = run(
            reqs.clone(),
            ServeConfig {
                shards: 1,
                ..serve.clone()
            },
        )
        .map_err(|e| format!("unsharded run: {e:#}"))?;
        let (done_n, mn, per_shard) = run(
            reqs,
            ServeConfig {
                shards: n_shards,
                ..serve
            },
        )
        .map_err(|e| format!("{n_shards}-shard run: {e:#}"))?;

        // tokens first (invariant 12) — the counters below are only
        // comparable because the runs did identical work
        prop_assert_eq!(done1.len(), done_n.len());
        for (a, b) in done1.iter().zip(&done_n) {
            prop_assert!(
                a.id == b.id && a.tokens == b.tokens,
                "request {} diverged at {n_shards} shards",
                a.id
            );
        }

        let kv1 = m1.kv.ok_or("unsharded run must measure KV stats")?;
        let kvn = mn.kv.ok_or("sharded run must measure KV stats")?;
        // per-tier counters match exactly: placement is per-layer and
        // the roomy default capacity never forces a shard-dependent
        // spill or eviction
        prop_assert_eq!(kvn.accesses.ondie_reads, kv1.accesses.ondie_reads);
        prop_assert_eq!(kvn.accesses.ondie_writes, kv1.accesses.ondie_writes);
        prop_assert_eq!(kvn.accesses.external_reads, kv1.accesses.external_reads);
        prop_assert_eq!(kvn.accesses.external_writes, kv1.accesses.external_writes);
        prop_assert_eq!(kvn.evictions, kv1.evictions);
        prop_assert_eq!(kvn.retention_failures, 0u64);
        prop_assert_eq!(kv1.retention_failures, 0u64);
        // same accesses at the same tiers ⇒ same energy, up to the
        // f64 accumulation-order difference between one store and N
        for (name, a, b) in [
            ("edram", kvn.edram_energy_j, kv1.edram_energy_j),
            ("dram", kvn.dram_energy_j, kv1.dram_energy_j),
        ] {
            prop_assert!(
                (a - b).abs() <= 1e-9 * b.abs().max(1e-30),
                "{name} energy diverged at {n_shards} shards: {a} vs {b}"
            );
        }

        // the merged view is the shard-ordered field-wise sum of the
        // per-shard views — integer counters and energies both (the
        // fold below replays the merge's accumulation order, so even
        // the f64 sums are bit-identical)
        prop_assert_eq!(per_shard.len(), n_shards);
        let mut sum = per_shard[0].clone();
        for st in &per_shard[1..] {
            sum.accesses.ondie_reads += st.accesses.ondie_reads;
            sum.accesses.ondie_writes += st.accesses.ondie_writes;
            sum.accesses.external_reads += st.accesses.external_reads;
            sum.accesses.external_writes += st.accesses.external_writes;
            sum.evictions += st.evictions;
            sum.retention_failures += st.retention_failures;
            sum.edram_energy_j += st.edram_energy_j;
            sum.dram_energy_j += st.dram_energy_j;
        }
        prop_assert_eq!(sum.accesses.ondie_reads, kvn.accesses.ondie_reads);
        prop_assert_eq!(sum.accesses.ondie_writes, kvn.accesses.ondie_writes);
        prop_assert_eq!(sum.accesses.external_reads, kvn.accesses.external_reads);
        prop_assert_eq!(sum.accesses.external_writes, kvn.accesses.external_writes);
        prop_assert_eq!(sum.evictions, kvn.evictions);
        prop_assert!(
            sum.edram_energy_j == kvn.edram_energy_j && sum.dram_energy_j == kvn.dram_energy_j,
            "merged energies are not the shard-ordered sum"
        );
        // every shard actually did work — the plan never starves one
        prop_assert!(
            per_shard.iter().all(|s| s.accesses.total_accesses() > 0),
            "a shard served no KV traffic"
        );
        Ok(())
    });
}

/// Local 2-partition model small enough for the event-counted cirom
/// path (orders of magnitude slower than the bitplane kernels).
fn event_micro() -> ModelConfig {
    ModelConfig {
        name: "shard-props-micro".into(),
        n_layers: 2,
        d_model: 32,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 64,
        vocab_size: 64,
        max_seq: 32,
        n_partitions: 2,
        act_bits: 8,
    }
}

#[test]
fn event_counters_sum_to_the_unsharded_totals() {
    // circuit-event accounting under sharding: layer projections tally
    // in their owning shard, the head in shard 0, and the merged
    // integer counters equal the unsharded run's exactly — while the
    // tokens stay bit-identical (event mode routes the head through
    // shard 0 precisely so this sum holds)
    let geom = MacroGeometry {
        rows: 32,
        cols: 16,
        cols_per_trimla: 8,
        ..Default::default()
    };
    let prompt = [1, 2, 3];
    let solo = HostBackend::with_cirom_events(event_micro(), 5, geom.clone()).unwrap();
    let want_tokens = solo.generate_greedy(&prompt, 4).unwrap();
    let want = solo.events().expect("event mode counts events");
    let fleet: Vec<HostBackend> = (0..2)
        .map(|_| HostBackend::with_cirom_events(event_micro(), 5, geom.clone()).unwrap())
        .collect();
    let b = ShardedBackend::from_shards(fleet).unwrap();
    assert_eq!(
        b.generate_greedy(&prompt, 4).unwrap(),
        want_tokens,
        "event-mode tokens diverged under sharding"
    );
    assert_eq!(
        b.events().expect("merged event counters"),
        want,
        "merged event counters do not sum to the unsharded totals"
    );
}
