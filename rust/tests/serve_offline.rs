//! End-to-end offline serving tests (default features — no PJRT, no
//! artifacts): full request traces through `Server<HostBackend>`,
//! exercising continuous batching, the partition pipeline (validated
//! every round, DESIGN.md §7.8), the tiered quantized KV store (the
//! serving data plane, DESIGN.md §10), multi-tenant LoRA adapter
//! serving (DESIGN.md §11) and metrics under tier-1.

use std::time::Instant;

use bitrom::config::{ModelConfig, ServeConfig};
use bitrom::coordinator::{CompletedRequest, FailReason, ServeMetrics, Server};
use bitrom::kvcache::simulate_reduction;
use bitrom::lora::{AdapterRegistry, LoraConfig};
use bitrom::runtime::{HostBackend, InferenceBackend};
use bitrom::trace::{generate, Request, TraceConfig};

const WEIGHT_SEED: u64 = 0xB17;

fn host_server(max_batches: usize, top_k: usize) -> Server<HostBackend> {
    let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
    let serve = ServeConfig {
        max_batches,
        top_k,
        ..ServeConfig::default()
    };
    Server::new(backend, serve).unwrap()
}

fn trace(n_requests: usize, arrival_rate: f64, seed: u64) -> Vec<Request> {
    generate(&TraceConfig {
        n_requests,
        arrival_rate,
        seed,
        gen_len_min: 8,
        gen_len_max: 24,
        vocab_size: ModelConfig::sim_tiny().vocab_size,
        ..TraceConfig::default()
    })
}

fn by_id(mut done: Vec<CompletedRequest>) -> Vec<CompletedRequest> {
    done.sort_by_key(|r| r.id);
    done
}

#[test]
fn full_trace_completes_with_healthy_edram_and_metrics() {
    let mut reqs = trace(10, 0.0, 1);
    // pin one long sequence (40-token prompt + 24 generated = 64 > the
    // 32 on-die tokens) so the external-DRAM path is provably exercised
    reqs[0].prompt = (0..40).map(|i| i % 256).collect();
    reqs[0].max_new_tokens = 24;
    let n = reqs.len();
    let mut server = host_server(6, 1);
    let (done, mut metrics) = server.run_trace(reqs).unwrap();

    assert_eq!(done.len(), n, "every request completes");
    let vocab = ModelConfig::sim_tiny().vocab_size;
    for r in &done {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 24);
        assert!(r.tokens.iter().all(|&t| (t as usize) < vocab));
        assert!(r.ttft_s >= 0.0 && r.latency_s >= r.ttft_s);
    }
    assert_eq!(metrics.requests_done as usize, n);
    assert_eq!(
        metrics.tokens_out,
        done.iter().map(|r| r.tokens.len() as u64).sum::<u64>()
    );
    assert!(metrics.tokens_per_s() > 0.0);
    // prefill compute was measured once per request, decode per token
    assert_eq!(metrics.prefill_time.count() as usize, n);
    assert_eq!(metrics.decode_time.count(), metrics.tokens_out - n as u64);
    assert!(metrics.prefill_time.mean() > 0.0);

    // DR-eDRAM invariants held for the whole run (DESIGN.md inv. 5),
    // measured on the store's actual accesses
    let kv = metrics.kv.as_ref().expect("host backend measures KV stats");
    assert_eq!(kv.retention_failures, 0);
    assert_eq!(kv.explicit_refreshes, 0);
    // KV placement actually split traffic on-die vs external
    assert!(kv.accesses.ondie_reads > 0);
    assert!(kv.accesses.external_reads > 0);
    assert!(kv.external_reduction() > 0.1);
    assert!(kv.kv_energy_j() > 0.0);
    // every completed request retired its pages back to the store
    assert_eq!(server.kv_stats().unwrap().ondie_blocks_in_use, 0);
}

#[test]
fn served_kv_reduction_matches_analytic_fig5b_point() {
    // THE end-to-end acceptance point: a real served trace through the
    // store-backed HostBackend at the paper's (seq 128, 32 buffered)
    // operating point must measure an external-access reduction within
    // one percentage point of the analytic Fig 5(b) value (43.6%),
    // with zero retention failures. Short prompts keep the measured
    // path close to the model: prefill attention reads stay in
    // on-chip activation buffers, so only their writes are counted.
    let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
    let serve = ServeConfig {
        max_batches: 3,
        prefill_len: 8,
        max_seq: 128,
        ondie_tokens: 32,
        ..ServeConfig::default()
    };
    let mut server = Server::new(backend, serve).unwrap();
    let reqs: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i,
            arrival_s: 0.0,
            prompt: (0..8).map(|t| ((i * 31 + t * 7 + 1) % 256) as i32).collect(),
            max_new_tokens: 120,
            adapter_id: None,
            priority: 0,
        })
        .collect();
    let (done, metrics) = server.run_trace(reqs).unwrap();
    assert_eq!(done.len(), 3);
    for r in &done {
        // sequences ran to the full context (prompt 8 + 119 decode
        // writes + the final sampled token = 128-token sequences)
        assert_eq!(r.tokens.len(), 120);
    }

    let kv = metrics.kv.as_ref().expect("host backend measures KV stats");
    assert_eq!(kv.retention_failures, 0, "DR argument violated");
    assert_eq!(kv.explicit_refreshes, 0);
    assert_eq!(kv.evictions, 0, "13.5 MB tier must not overflow here");
    let measured = kv.external_reduction();
    let analytic = simulate_reduction(128, 32);
    assert!((analytic - 0.436).abs() < 0.0005, "analytic model moved");
    assert!(
        (measured - analytic).abs() < 0.01,
        "measured {measured:.4} vs analytic {analytic:.4} — more than 1pp apart"
    );
}

#[test]
fn starved_edram_tier_evicts_but_tokens_are_unchanged() {
    // an on-die tier too small for the working set must spill/evict —
    // and because tier placement never touches stored values, the
    // generated tokens must be identical to the roomy-tier run
    let run = |edram_bytes: u64| {
        let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
        let serve = ServeConfig {
            max_batches: 4,
            kv_edram_bytes: edram_bytes,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let (done, metrics) = server.run_trace(trace(8, 0.0, 13)).unwrap();
        (by_id(done), metrics.kv.unwrap())
    };
    let (roomy_done, roomy_kv) = run(13_500_000);
    // a few KiB: room for only a handful of blocks across 6 layers
    let (tiny_done, tiny_kv) = run(4096);
    assert_eq!(roomy_kv.evictions, 0);
    assert!(
        tiny_kv.evictions > 0 || tiny_kv.spilled_early_blocks > 0,
        "starved tier must overflow"
    );
    assert!(tiny_kv.external_reduction() < roomy_kv.external_reduction());
    assert_eq!(roomy_done.len(), tiny_done.len());
    for (a, b) in roomy_done.iter().zip(&tiny_done) {
        assert_eq!(a.tokens, b.tokens, "placement changed request {}", a.id);
    }
}

#[test]
fn serving_is_deterministic_under_fixed_seed() {
    let run = || {
        let mut server = host_server(6, 1);
        let (done, _) = server.run_trace(trace(8, 0.0, 3)).unwrap();
        by_id(done)
    };
    let (a, b) = (run(), run());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "request {} diverged", x.id);
    }
}

#[test]
fn top_k_sampling_is_deterministic_under_fixed_seed() {
    let run = || {
        let mut server = host_server(4, 4);
        let (done, _) = server.run_trace(trace(6, 0.0, 5)).unwrap();
        by_id(done)
    };
    let (a, b) = (run(), run());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tokens, y.tokens, "sampled request {} diverged", x.id);
    }
}

#[test]
fn batched_serving_matches_single_stream_generation() {
    // token-level determinism: the same request decoded alone (via the
    // backend's provided greedy driver) and inside a 6-way batch must
    // produce identical tokens — per-sequence KV state is isolated.
    let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
    let probe_prompt = vec![11, 22, 33, 44];
    let solo = backend.generate_greedy(&probe_prompt, 6).unwrap();

    let mut reqs = trace(6, 0.0, 7);
    reqs[0].prompt = probe_prompt;
    reqs[0].max_new_tokens = 6;
    let mut server = host_server(6, 1);
    let (done, _) = server.run_trace(reqs).unwrap();
    let probe = done.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(probe.tokens, solo, "batching must not change results");
}

#[test]
fn sparse_trace_skips_ahead_instead_of_busy_waiting() {
    // 6 requests spaced 2s apart: 10s of virtual trace time. The
    // offline backend skips idle gaps, so real elapsed time stays far
    // below the virtual span (the old 200µs idle spin slept through
    // all of it in real time).
    let span = 10.0;
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            id: i,
            arrival_s: i as f64 * 2.0,
            prompt: vec![1 + i as i32, 7, 19],
            max_new_tokens: 6,
            adapter_id: None,
            priority: 0,
        })
        .collect();
    let t0 = Instant::now();
    let mut server = host_server(2, 1);
    let (done, mut metrics) = server.run_trace(reqs).unwrap();
    let real = t0.elapsed().as_secs_f64();
    assert_eq!(done.len(), 6);
    // the serving clock covered the whole trace...
    assert!(metrics.wall_s >= span, "wall {} < span {span}", metrics.wall_s);
    // ...but real time did not (generous margin for slow CI boxes)
    assert!(real < span, "no skip-ahead: real {real}s >= span {span}s");
    assert!(metrics.tokens_per_s() > 0.0);
}

// ---- parallel execution engine (DESIGN.md §12) ------------------------

/// Tokens + the merged measured counters of one served trace — what
/// must be bit-identical at every worker-pool width.
fn run_at_threads(threads: usize, seed: u64) -> (Vec<CompletedRequest>, ServeMetrics) {
    let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
    let serve = ServeConfig {
        max_batches: 4,
        threads,
        ..ServeConfig::default()
    };
    let mut server = Server::new(backend, serve).unwrap();
    let (done, metrics) = server.run_trace(trace(8, 0.0, seed)).unwrap();
    (by_id(done), metrics)
}

#[test]
fn serving_is_bit_identical_across_thread_counts() {
    // THE §12 acceptance point: tokens, logits-derived choices, and
    // every merged counter agree at 1, 2, 4, and 7 threads — the
    // parallel engine changes throughput, never results
    let (serial_done, serial_metrics) = run_at_threads(1, 3);
    let serial_kv = serial_metrics.kv.as_ref().unwrap();
    for threads in [2usize, 4, 7] {
        let (done, metrics) = run_at_threads(threads, 3);
        assert_eq!(done.len(), serial_done.len());
        for (a, b) in serial_done.iter().zip(&done) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "request {} diverged at {threads} threads", a.id);
            assert_eq!(a.prompt_len, b.prompt_len);
        }
        assert_eq!(metrics.tokens_out, serial_metrics.tokens_out);
        assert_eq!(metrics.requests_done, serial_metrics.requests_done);
        // measured KV counters merge to the same totals: accesses,
        // placement, health, and the (count-derived) energy
        let kv = metrics.kv.as_ref().unwrap();
        assert_eq!(kv.accesses.ondie_reads, serial_kv.accesses.ondie_reads, "t={threads}");
        assert_eq!(kv.accesses.ondie_writes, serial_kv.accesses.ondie_writes);
        assert_eq!(kv.accesses.external_reads, serial_kv.accesses.external_reads);
        assert_eq!(kv.accesses.external_writes, serial_kv.accesses.external_writes);
        assert_eq!(kv.evictions, serial_kv.evictions);
        assert_eq!(kv.spilled_early_blocks, serial_kv.spilled_early_blocks);
        assert_eq!(kv.retention_failures, 0);
        assert_eq!(kv.kv_energy_j(), serial_kv.kv_energy_j(), "energy is count-derived");
    }
}

#[test]
fn fused_decode_is_bit_identical_to_per_slot_rounds_at_every_width() {
    // DESIGN.md §17: the fused batched decode round (one partition walk
    // over the whole batch) and the per-slot pool path must emit
    // identical tokens and merge identical measured KV counters, at
    // every worker-pool width and on every kernel path.
    let run = |fused: bool, threads: usize, path: &str| {
        let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
        let serve = ServeConfig {
            max_batches: 4,
            threads,
            fused_decode: fused,
            kernel_path: path.into(),
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let (done, metrics) = server.run_trace(trace(8, 0.0, 19)).unwrap();
        (by_id(done), metrics)
    };
    let (unfused, unfused_m) = run(false, 1, "auto");
    let unfused_kv = unfused_m.kv.as_ref().unwrap();
    let grid = [(1usize, "auto"), (2, "auto"), (4, "auto"), (1, "scalar"), (1, "bitserial")];
    for (threads, path) in grid {
        let (fused, fused_m) = run(true, threads, path);
        assert_eq!(fused.len(), unfused.len());
        for (a, b) in unfused.iter().zip(&fused) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.tokens, b.tokens,
                "request {} diverged fused at {threads} threads on {path}",
                a.id
            );
        }
        assert_eq!(fused_m.tokens_out, unfused_m.tokens_out);
        // the fused walk issues exactly the per-slot KV traffic
        let kv = fused_m.kv.as_ref().unwrap();
        assert_eq!(kv.accesses.ondie_reads, unfused_kv.accesses.ondie_reads, "t={threads}");
        assert_eq!(kv.accesses.ondie_writes, unfused_kv.accesses.ondie_writes);
        assert_eq!(kv.accesses.external_reads, unfused_kv.accesses.external_reads);
        assert_eq!(kv.accesses.external_writes, unfused_kv.accesses.external_writes);
        assert_eq!(kv.retention_failures, 0);
    }
}

#[test]
fn sampled_serving_is_bit_identical_across_thread_counts() {
    // top-k sampling draws from a per-request Rng (seeded from the
    // serve seed and the request id), so even non-greedy traces are
    // width-invariant — and independent of batching/arrival order,
    // which is what lets the live streaming plane match this offline
    // twin bit-for-bit (invariant 10)
    let run = |threads: usize| {
        let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
        let serve = ServeConfig {
            max_batches: 3,
            top_k: 4,
            threads,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let (done, _) = server.run_trace(trace(6, 0.0, 5)).unwrap();
        by_id(done)
    };
    let serial = run(1);
    for threads in [2usize, 7] {
        let done = run(threads);
        for (a, b) in serial.iter().zip(&done) {
            assert_eq!(a.tokens, b.tokens, "sampled request {} diverged", a.id);
        }
    }
}

#[test]
fn adapter_counters_are_thread_count_invariant() {
    // adapter accounting merges one tally per op under the registry
    // lock; binds and cold loads run on the coordinator — so the full
    // LoraServeStats is identical at any width
    let run = |threads: usize| {
        let serve = ServeConfig {
            max_batches: 4,
            n_adapters: 3,
            threads,
            ..ServeConfig::default()
        };
        let mut server = Server::new(adapter_backend(3, 0x7ada), serve).unwrap();
        let mut reqs = trace(7, 0.0, 11);
        for (i, r) in reqs.iter_mut().enumerate() {
            // two tenants plus the base model, round-robin
            if i % 3 != 2 {
                r.adapter_id = Some((i % 3) as u32);
            }
        }
        let (done, metrics) = server.run_trace(reqs).unwrap();
        (by_id(done), metrics.lora.unwrap())
    };
    let (serial_done, serial_lora) = run(1);
    for threads in [2usize, 4] {
        let (done, lora) = run(threads);
        for (a, b) in serial_done.iter().zip(&done) {
            assert_eq!(a.tokens, b.tokens, "request {} diverged", a.id);
            assert_eq!(a.adapter_id, b.adapter_id);
        }
        assert_eq!(lora, serial_lora, "adapter counters diverged at {threads} threads");
    }
}

#[test]
fn nested_pools_serve_correctly_from_parallel_rounds() {
    // a model whose MLP shapes clear the kernels' parallel cutoff:
    // worker threads running slot rounds fork their own kernel pools
    // (pool-in-pool), and the tokens still match the serial engine
    let wide = ModelConfig {
        name: "wide-nested".into(),
        n_layers: 2,
        d_model: 128,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 512,
        vocab_size: 64,
        max_seq: 128,
        n_partitions: 2,
        act_bits: 8,
    };
    let run = |threads: usize| {
        let backend = HostBackend::new(wide.clone(), WEIGHT_SEED).unwrap();
        let serve = ServeConfig {
            max_batches: 3,
            threads,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs = generate(&TraceConfig {
            n_requests: 5,
            gen_len_min: 6,
            gen_len_max: 10,
            vocab_size: wide.vocab_size,
            seed: 2,
            ..TraceConfig::default()
        });
        let (done, _) = server.run_trace(reqs).unwrap();
        by_id(done)
    };
    let serial = run(1);
    let nested = run(4);
    assert_eq!(serial.len(), nested.len());
    for (a, b) in serial.iter().zip(&nested) {
        assert_eq!(a.tokens, b.tokens, "nested-pool request {} diverged", a.id);
    }
}

// ---- survivable serving under injected faults (DESIGN.md §13) ---------

#[test]
fn retention_storms_recover_bit_identically_across_thread_counts() {
    // Invariant 9 on a pinned schedule: storm_p = 1.0 fires a
    // retention-clock skip every cooldown window, each one far past
    // tREF, so every decoding sequence's on-die rows genuinely expire.
    // The coordinator must observe each expiry as a typed KvError,
    // recompute the sequence (invariant 4 makes the rebuilt KV
    // bit-identical), and finish the trace with exactly the fault-free
    // tokens — at every pool width, with identical fault counters.
    let run = |threads: usize, fault_seed: u64| {
        let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
        let serve = ServeConfig {
            max_batches: 4,
            threads,
            fault_seed,
            fault_storm_p: 1.0,
            fault_transient_p: 0.0,
            fault_clock_skip_s: 0.1,
            retry_max: 10,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let (done, metrics) = server.run_trace(trace(6, 0.0, 17)).unwrap();
        (by_id(done), metrics)
    };
    let (clean, clean_m) = run(1, 0);
    assert_eq!(clean_m.faults, Default::default(), "seed 0 injects nothing");

    let (serial, serial_m) = run(1, 0xD00F);
    assert!(serial_m.faults.injected_skips > 0, "certain storms must fire");
    assert!(serial_m.faults.retention_events > 0, "storms must surface real expiries");
    assert!(serial_m.faults.recomputes > 0);
    assert!(serial_m.faults.recomputed_tokens > 0);
    assert!(serial_m.faults.shed.is_empty(), "the retry budget covers every storm");
    // the store counted exactly the expiries the coordinator recovered
    let kv = serial_m.kv.as_ref().unwrap();
    assert_eq!(kv.retention_failures, serial_m.faults.retention_events);
    // every request completed with its fault-free tokens
    assert_eq!(serial.len(), clean.len());
    for (a, b) in clean.iter().zip(&serial) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} diverged under storms", a.id);
    }
    // faulted serving stays width-invariant: tokens AND fault counters
    for threads in [2usize, 4] {
        let (done, m) = run(threads, 0xD00F);
        for (a, b) in serial.iter().zip(&done) {
            assert_eq!(a.tokens, b.tokens, "request {} diverged at {threads} threads", a.id);
        }
        assert_eq!(m.faults, serial_m.faults, "fault counters diverged at {threads} threads");
    }
}

#[test]
fn overloaded_queue_sheds_with_typed_reasons() {
    // a shedding deadline tighter than any real round: every request
    // still queued when a round begins is past deadline, so the server
    // drains the overload with typed Overload sheds — no error, no
    // hang, and completed + shed partition the trace
    let backend = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
    let serve = ServeConfig {
        max_batches: 2,
        shed_after_s: 1e-12,
        ..ServeConfig::default()
    };
    let mut server = Server::new(backend, serve).unwrap();
    let n = 5;
    let (done, metrics) = server.run_trace(trace(n, 0.0, 23)).unwrap();
    let shed = &metrics.faults.shed;
    assert_eq!(done.len() + shed.len(), n);
    // at most max_batches requests can have been admitted before the
    // first deadline check saw a positive clock
    assert!(shed.len() >= n - 2, "only {} of {n} shed", shed.len());
    assert!(shed.iter().all(|s| s.reason == FailReason::Overload));
    assert_eq!(metrics.faults.shed_count(FailReason::Overload), shed.len() as u64);
    assert_eq!(metrics.requests_done as usize, done.len());
}

#[test]
fn single_slot_server_preserves_fifo_completion_order() {
    let mut server = host_server(1, 1);
    let (done, _) = server.run_trace(trace(4, 0.0, 11)).unwrap();
    let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3], "1-slot serving must be FIFO");
}

// ---- multi-tenant LoRA adapter serving (DESIGN.md §11) ----------------

fn adapter_backend(n_adapters: usize, registry_seed: u64) -> HostBackend {
    let model = ModelConfig::sim_tiny();
    let reg = AdapterRegistry::fabricate(&model, &LoraConfig::paper(), n_adapters, registry_seed)
        .unwrap();
    HostBackend::with_adapters(model, WEIGHT_SEED, reg).unwrap()
}

#[test]
fn adapter_disabled_serving_is_bit_identical_to_baseline() {
    // DESIGN.md invariant 7: a deployment that merely CARRIES an
    // adapter registry, serving a trace in which no request binds one,
    // must emit exactly the tokens of the adapter-free baseline build
    let serve = || ServeConfig {
        max_batches: 4,
        ..ServeConfig::default()
    };
    let baseline = HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap();
    let mut base_server = Server::new(baseline, serve()).unwrap();
    let (base_done, _) = base_server.run_trace(trace(8, 0.0, 3)).unwrap();

    let mut adapter_server = Server::new(adapter_backend(4, 0xADA), serve()).unwrap();
    let (done, metrics) = adapter_server.run_trace(trace(8, 0.0, 3)).unwrap();

    let (a, b) = (by_id(base_done), by_id(done));
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tokens, y.tokens, "adapter-disabled request {} diverged", x.id);
        assert_eq!(y.adapter_id, None);
    }
    // the registry sat idle: stats are reported but count nothing
    let lora = metrics.lora.expect("adapter-capable backend reports LoRA stats");
    assert_eq!(lora.binds, 0);
    assert_eq!(lora.adapter_macs, 0);
    assert_eq!(lora.bytes_streamed, 0);
}

#[test]
fn mixed_adapter_batch_matches_solo_bound_generation() {
    // solo ≡ batched, extended to a batch that mixes three tenants and
    // the base model: every request must emit exactly the tokens of
    // its solo bound run — adapter binding is per sequence
    let prompts: [&[i32]; 4] = [&[11, 22, 33, 44], &[9, 8, 7], &[50, 60], &[100, 101, 102]];
    let adapters = [Some(0u32), Some(1), Some(2), None];
    let solo = adapter_backend(3, 0x10ada);
    let solos: Vec<Vec<i32>> = prompts
        .iter()
        .zip(adapters)
        .map(|(p, a)| solo.generate_greedy_bound(p, 6, a).unwrap())
        .collect();

    let serve = ServeConfig {
        max_batches: 4,
        n_adapters: 3,
        ..ServeConfig::default()
    };
    let mut server = Server::new(adapter_backend(3, 0x10ada), serve).unwrap();
    let reqs: Vec<Request> = prompts
        .iter()
        .zip(adapters)
        .enumerate()
        .map(|(i, (p, a))| Request {
            id: i as u64,
            arrival_s: 0.0,
            prompt: p.to_vec(),
            max_new_tokens: 6,
            adapter_id: a,
            priority: 0,
        })
        .collect();
    let (done, metrics) = server.run_trace(reqs).unwrap();
    assert_eq!(done.len(), 4);
    for r in by_id(done) {
        assert_eq!(
            r.tokens,
            solos[r.id as usize],
            "request {} diverged from its solo bound run",
            r.id
        );
        assert_eq!(r.adapter_id, adapters[r.id as usize]);
    }
    let lora = metrics.lora.unwrap();
    assert_eq!(lora.binds, 3, "three adapter-bound requests");
    assert_eq!(lora.cold_loads, 3, "three distinct tenants stream once each");
    assert!(lora.adapter_macs > 0);
}

#[test]
fn single_slot_mixed_adapter_trace_stays_fifo() {
    // tenant mix must not perturb scheduling: a 1-slot server
    // completes a mixed-adapter trace in arrival order, and each
    // completion carries its request's tenant tag
    let serve = ServeConfig {
        max_batches: 1,
        n_adapters: 2,
        ..ServeConfig::default()
    };
    let mut server = Server::new(adapter_backend(2, 7), serve).unwrap();
    let mut reqs = trace(4, 0.0, 11);
    let tenants = [Some(1u32), None, Some(0), Some(1)];
    for (r, &t) in reqs.iter_mut().zip(&tenants) {
        r.adapter_id = t;
    }
    let (done, _) = server.run_trace(reqs).unwrap();
    let ids: Vec<u64> = done.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3], "1-slot mixed-tenant serving must stay FIFO");
    for r in &done {
        assert_eq!(r.adapter_id, tenants[r.id as usize]);
    }
}

#[test]
fn adapters_specialize_generation_end_to_end() {
    // the same trace served under a tenant adapter must actually
    // differ from the base-model run (the deltas are live), while
    // staying deterministic per seed
    let run = |tenant: Option<u32>| {
        let serve = ServeConfig {
            max_batches: 2,
            n_adapters: 2,
            ..ServeConfig::default()
        };
        let mut server = Server::new(adapter_backend(2, 21), serve).unwrap();
        let mut reqs = trace(4, 0.0, 9);
        for r in reqs.iter_mut() {
            r.adapter_id = tenant;
        }
        let (done, _) = server.run_trace(reqs).unwrap();
        by_id(done)
    };
    let base = run(None);
    let bound = run(Some(0));
    let bound_again = run(Some(0));
    assert!(
        base.iter().zip(&bound).any(|(a, b)| a.tokens != b.tokens),
        "tenant 0's deltas changed no stream at all"
    );
    for (a, b) in bound.iter().zip(&bound_again) {
        assert_eq!(a.tokens, b.tokens, "bound serving must stay deterministic");
    }
}

#[test]
fn measured_adapter_overhead_matches_analytic_within_10pct() {
    // THE adapter acceptance point (the twin of what `bitrom report
    // --lora-serving` prints): per-token adapter op overhead measured
    // from executed MACs on a mixed-tenant served trace must land
    // within 10% relative of the analytic
    // LoraConfig::op_overhead_vs_host_projections at the paper
    // configuration (rank 16 on VOD)
    let r = bitrom::report::lora_serving_study(3, 6, 0xADA).unwrap();
    assert!(r.analytic_overhead > 0.0);
    let rel = (r.measured_overhead - r.analytic_overhead).abs() / r.analytic_overhead;
    assert!(
        rel < 0.10,
        "measured {} vs analytic {} ({rel} relative)",
        r.measured_overhead,
        r.analytic_overhead
    );
    // reload-vs-switch: the streamed bytes are per cold load, and a
    // switch is a small fraction of a hypothetical full reload
    assert_eq!(r.stats.bytes_streamed, r.stats.cold_loads * r.adapter_bytes);
    assert!(r.adapter_bytes < r.full_reload_bytes / 2);
}
