//! Property/fuzz harness for invariant 9 (DESIGN.md §13): under ANY
//! seeded fault schedule — retention storms, transient backend /
//! adapter / KV faults, pressure-gated admission, preemption, starved
//! eDRAM tiers — every request either completes with tokens
//! bit-identical to its fault-free twin or is shed with a typed
//! [`bitrom::coordinator::FailReason`]; never a panic, never a
//! corrupted sequence, and the whole faulted run stays bit-identical
//! across worker-pool widths.
//!
//! Cases are generated from a trace grammar × fault-schedule grammar;
//! the harness prints the failing case seed for deterministic replay
//! (`util::check`). `BITROM_FUZZ_CASES` bounds the case count (CI
//! quick mode keeps it small).
//!
//! The grammar also spans the shared-prefix cache and the
//! fairness/preemption scheduler (DESIGN.md §15): prompts may share
//! pool prefixes, the prefix cache and either preemption policy may be
//! on, and priority classes may be drawn — none of which may change a
//! completed request's tokens (invariant 11), even mid-storm.
//!
//! And a shard axis (DESIGN.md §16): the faulted deployment may be
//! split across 1–3 model shards, whose storms become shard-local
//! retention events on one shard's DR-eDRAM clock, while the fault-free
//! twin always runs single-shard — so invariants 9 and 12 are fuzzed
//! *jointly*: recovery under shard-local expiry and preemption must
//! still land every completed request bit-identical to the unsharded
//! fault-free twin.

use bitrom::config::{ModelConfig, ServeConfig};
use bitrom::coordinator::{CompletedRequest, FaultMetrics, ServeMetrics, Server};
use bitrom::runtime::{HostBackend, ShardedBackend};
use bitrom::trace::{generate, Request, TraceConfig};
use bitrom::util::check::check;
use bitrom::{prop_assert, prop_assert_eq};

const WEIGHT_SEED: u64 = 0x9917;

fn fuzz_cases() -> u64 {
    std::env::var("BITROM_FUZZ_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6)
}

fn run(
    reqs: Vec<Request>,
    serve: ServeConfig,
) -> anyhow::Result<(Vec<CompletedRequest>, ServeMetrics)> {
    let model = ModelConfig::sim_tiny();
    if serve.shards > 1 {
        // same-seed fleet: partition ownership + per-shard KV stores
        let fleet = (0..serve.shards)
            .map(|_| HostBackend::new(model.clone(), WEIGHT_SEED))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let mut server = Server::new(ShardedBackend::from_shards(fleet)?, serve)?;
        let (mut done, metrics) = server.run_trace(reqs)?;
        done.sort_by_key(|r| r.id);
        return Ok((done, metrics));
    }
    let backend = HostBackend::new(model, WEIGHT_SEED)?;
    let mut server = Server::new(backend, serve)?;
    let (mut done, metrics) = server.run_trace(reqs)?;
    done.sort_by_key(|r| r.id);
    Ok((done, metrics))
}

#[test]
fn any_fault_schedule_recovers_or_sheds_typed() {
    check(0xFA01, fuzz_cases(), |g| {
        // random workload — closed batch (every arrival at t = 0), so
        // admission order is structural and the faulted run is exactly
        // reproducible at any pool width. Prompts may share a pool
        // prefix of at least one block, and priority classes may be in
        // play (scheduling only — invariant 11).
        let spl = if g.f64() < 0.5 { 0 } else { 4 + g.usize(0, 6) };
        let trace_cfg = TraceConfig {
            n_requests: g.size(6),
            prompt_len_min: spl + 2,
            prompt_len_max: spl + 2 + g.size(10),
            gen_len_min: 2,
            gen_len_max: 2 + g.size(8),
            vocab_size: ModelConfig::sim_tiny().vocab_size,
            arrival_rate: 0.0,
            shared_prefix_len: spl,
            shared_prefixes: 1 + g.usize(0, 1),
            priority_classes: g.usize(0, 3),
            seed: g.rng.next_u64(),
            ..TraceConfig::default()
        };
        // random fault schedule + degradation policy: storms that may
        // or may not cross tREF, transient faults, a sometimes-starved
        // on-die tier, sometimes pressure-gated admission / preemption
        // (either KV policy), sometimes a live prefix cache over a
        // smaller page size so shared blocks sit in the blast radius —
        // and sometimes a sharded deployment (1–3 shards of sim_tiny's
        // 6 partitions), whose storms hit one shard's retention clock
        let pressure_on = g.f64() < 0.5;
        let faulted = ServeConfig {
            max_batches: g.usize(1, 4),
            shards: 1 + g.usize(0, 2),
            fault_seed: g.rng.next_u64() | 1,
            fault_storm_p: g.f64(),
            fault_transient_p: g.f64() * 0.3,
            fault_clock_skip_s: if g.f64() < 0.7 { 0.1 } else { 0.02 },
            retry_max: g.usize(2, 6),
            admit_pressure: if pressure_on { 0.5 + 0.5 * g.f64() } else { 0.0 },
            preempt_under_pressure: pressure_on && g.f64() < 0.5,
            preempt_policy: if g.f64() < 0.5 { "reload" } else { "recompute" }.to_string(),
            prefix_cache: g.f64() < 0.5,
            kv_block_tokens: [4usize, 8][g.usize(0, 1)],
            kv_edram_bytes: if g.f64() < 0.3 { 1 << 16 } else { 13_500_000 },
            ..ServeConfig::default()
        };
        // the twin shares the workload and geometry but runs fault-free
        // with private KV, no scheduling pressure, and a single shard —
        // so 9b below also asserts invariant 12 (sharded faulted tokens
        // ≡ unsharded fault-free tokens)
        let clean = ServeConfig {
            fault_seed: 0,
            admit_pressure: 0.0,
            preempt_under_pressure: false,
            prefix_cache: false,
            shards: 1,
            ..faulted.clone()
        };
        let reqs = generate(&trace_cfg);
        let mut all_ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        all_ids.sort_unstable();

        // the fault-free twin: completes everything, injects nothing
        let (base_done, base_m) =
            run(reqs.clone(), clean).map_err(|e| format!("fault-free run failed: {e:#}"))?;
        prop_assert!(
            base_m.faults == FaultMetrics::default(),
            "fault-free twin counted fault activity: {:?}",
            base_m.faults
        );
        prop_assert_eq!(base_done.len(), reqs.len());

        // the faulted run at three pool widths — any panic or untyped
        // error surfaces here as a failing case with its seed
        let mut results = Vec::new();
        for threads in [1usize, 2, 4] {
            let cfg = ServeConfig {
                threads,
                ..faulted.clone()
            };
            let r = run(reqs.clone(), cfg)
                .map_err(|e| format!("faulted run (threads={threads}) failed: {e:#}"))?;
            results.push(r);
        }
        let (done, m) = &results[0];

        // invariant 9a: completed ∪ shed is a partition of the trace
        let done_ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        let shed_ids: Vec<u64> = m.faults.shed.iter().map(|s| s.id).collect();
        let mut union: Vec<u64> = done_ids.iter().chain(&shed_ids).copied().collect();
        union.sort_unstable();
        prop_assert!(
            union == all_ids,
            "completed {done_ids:?} + shed {shed_ids:?} is not a partition of {all_ids:?}"
        );

        // invariant 9b: every completed request is bit-identical to
        // its fault-free twin (greedy recompute recovery, invariant 4)
        for r in done {
            let twin = &base_done[r.id as usize];
            prop_assert_eq!(twin.id, r.id);
            prop_assert!(
                twin.tokens == r.tokens,
                "request {} diverged from its fault-free twin",
                r.id
            );
        }

        // invariant 9c: the faulted run itself is width-invariant —
        // tokens AND every fault counter
        for (threads, (done_t, m_t)) in [2usize, 4].iter().zip(&results[1..]) {
            prop_assert_eq!(done.len(), done_t.len());
            for (a, b) in done.iter().zip(done_t) {
                prop_assert!(
                    a.id == b.id && a.tokens == b.tokens,
                    "faulted request {} diverged at {threads} threads",
                    a.id
                );
            }
            prop_assert!(
                m.faults == m_t.faults,
                "fault counters diverged at {threads} threads: {:?} vs {:?}",
                m.faults,
                m_t.faults
            );
            prop_assert_eq!(m.requests_done, m_t.requests_done);
        }
        Ok(())
    });
}

#[test]
fn retention_storms_expire_shared_blocks_and_every_reader_recovers() {
    // certain per-round clock skips past tREF expire on-die rows that
    // multiple sequences read through shared prefix blocks: every
    // reader must recompute privately (a recovery re-prefill never
    // binds) and land bit-identical to the cache-off, storm-free twin
    check(0xFA03, fuzz_cases().min(4), |g| {
        let spl = 8; // one full default block shared by every prompt
        let max_batches = g.usize(2, 3);
        let trace_cfg = TraceConfig {
            n_requests: max_batches + 2,
            prompt_len_min: spl + 1,
            prompt_len_max: spl + 2 + g.size(4),
            gen_len_min: 2,
            gen_len_max: 2 + g.size(4),
            vocab_size: ModelConfig::sim_tiny().vocab_size,
            arrival_rate: 0.0,
            shared_prefix_len: spl,
            shared_prefixes: 1,
            seed: g.rng.next_u64(),
            ..TraceConfig::default()
        };
        let stormy = ServeConfig {
            max_batches,
            prefix_cache: true,
            fault_seed: g.rng.next_u64() | 1,
            fault_storm_p: 1.0,
            fault_transient_p: 0.0,
            fault_clock_skip_s: 0.1,
            retry_max: 16,
            ..ServeConfig::default()
        };
        let clean = ServeConfig {
            fault_seed: 0,
            prefix_cache: false,
            ..stormy.clone()
        };
        let reqs = generate(&trace_cfg);
        let (base, _) = run(reqs.clone(), clean).map_err(|e| format!("clean twin: {e:#}"))?;
        prop_assert_eq!(base.len(), reqs.len());
        let (done, m) = run(reqs, stormy).map_err(|e| format!("stormy run: {e:#}"))?;
        prop_assert_eq!(done.len(), base.len());
        for (a, b) in base.iter().zip(&done) {
            prop_assert!(
                a.id == b.id && a.tokens == b.tokens,
                "request {} diverged after a shared-block expiry",
                a.id
            );
        }
        prop_assert!(m.faults.retention_events > 0, "certain storms never expired a row");
        prop_assert!(m.faults.recomputes > 0, "expiries must recover by recompute");
        let kv = m.kv.clone().ok_or("host backend must measure KV stats")?;
        // sharing really happened before the storms tore it down
        prop_assert!(kv.prefix_hits >= 1, "queued admissions never bound the pool prefix");
        Ok(())
    });
}

#[test]
fn quiet_fault_plans_change_nothing() {
    // a seeded plan whose probabilities are all zero draws its fixed
    // per-round stream but injects nothing: tokens must match the
    // plan-free run exactly (the off ⇒ zero-behavior-change edge)
    check(0xFA02, fuzz_cases().min(4), |g| {
        let trace_cfg = TraceConfig {
            n_requests: g.size(4),
            prompt_len_min: 2,
            prompt_len_max: 2 + g.size(8),
            gen_len_min: 2,
            gen_len_max: 2 + g.size(6),
            vocab_size: ModelConfig::sim_tiny().vocab_size,
            seed: g.rng.next_u64(),
            ..TraceConfig::default()
        };
        let quiet = ServeConfig {
            fault_seed: g.rng.next_u64() | 1,
            fault_storm_p: 0.0,
            fault_transient_p: 0.0,
            ..ServeConfig::default()
        };
        let off = ServeConfig {
            fault_seed: 0,
            ..quiet.clone()
        };
        let reqs = generate(&trace_cfg);
        let (base, _) = run(reqs.clone(), off).map_err(|e| format!("plan-free: {e:#}"))?;
        let (done, m) = run(reqs, quiet).map_err(|e| format!("quiet plan: {e:#}"))?;
        prop_assert!(
            m.faults == FaultMetrics::default(),
            "quiet plan counted activity: {:?}",
            m.faults
        );
        prop_assert_eq!(base.len(), done.len());
        for (a, b) in base.iter().zip(&done) {
            prop_assert!(
                a.tokens == b.tokens,
                "request {} changed under a quiet plan",
                a.id
            );
        }
        Ok(())
    });
}
