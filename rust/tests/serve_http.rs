//! Loopback end-to-end tests of the streaming serving plane (DESIGN.md
//! §14): real sockets, real threads, the full HTTP front door.
//!
//! THE acceptance point is invariant 10: the same seeded request set
//! served over loopback HTTP streaming is bit-identical to the offline
//! [`Server::run_trace`] twin — including mixed-tenant adapter traffic
//! and top-k sampling — and overload past `max_queue` yields typed 429
//! rejections counted in `ServeMetrics::faults` exactly like offline
//! sheds.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use bitrom::config::{ModelConfig, NetConfig, ServeConfig};
use bitrom::coordinator::{CompletedRequest, FailReason, Ingress, Server};
use bitrom::lora::{AdapterRegistry, LoraConfig};
use bitrom::net::http::decode_chunked;
use bitrom::net::jsonframe::{DecodeMode, FrameDecoder};
use bitrom::net::NetServer;
use bitrom::runtime::HostBackend;
use bitrom::trace::{generate, Request, TraceConfig};
use bitrom::util::json::Json;

const WEIGHT_SEED: u64 = 0xB17;

fn base_backend() -> HostBackend {
    HostBackend::new(ModelConfig::sim_tiny(), WEIGHT_SEED).unwrap()
}

fn adapter_backend(n_adapters: usize) -> HostBackend {
    let model = ModelConfig::sim_tiny();
    let reg = AdapterRegistry::fabricate(&model, &LoraConfig::paper(), n_adapters, 0xADA).unwrap();
    HostBackend::with_adapters(model, WEIGHT_SEED, reg).unwrap()
}

fn trace(n: usize, n_adapters: usize, seed: u64) -> Vec<Request> {
    generate(&TraceConfig {
        n_requests: n,
        gen_len_min: 8,
        gen_len_max: 16,
        vocab_size: ModelConfig::sim_tiny().vocab_size,
        n_adapters,
        seed,
        ..TraceConfig::default()
    })
}

fn twin_tokens(
    backend: HostBackend,
    serve: &ServeConfig,
    reqs: Vec<Request>,
) -> BTreeMap<u64, Vec<i32>> {
    let mut server = Server::new(backend, serve.clone()).unwrap();
    let (done, _) = server.run_trace(reqs).unwrap();
    done.into_iter().map(|r| (r.id, r.tokens)).collect()
}

/// One parsed HTTP response off the wire.
struct Resp {
    status: u16,
    head: String,
    body: String,
    frames: Vec<Json>,
}

fn parse_response(raw: &[u8], sse: bool) -> Resp {
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response head terminator")
        + 4;
    let head = String::from_utf8_lossy(&raw[..head_end]).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let payload = if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        decode_chunked(&raw[head_end..]).unwrap()
    } else {
        raw[head_end..].to_vec()
    };
    let body = String::from_utf8_lossy(&payload).to_string();
    // SSE framing is stripped by hand so the test also checks the
    // exact `data: ...\n\n` line shape; NDJSON feeds the strict
    // decoder as-is
    let json_text: String = if sse {
        body.lines()
            .filter(|l| !l.is_empty())
            .map(|l| {
                let v = l.strip_prefix("data: ").expect("SSE line starts with data: ");
                format!("{v}\n")
            })
            .collect()
    } else {
        body.clone()
    };
    let mut dec = FrameDecoder::new(DecodeMode::Strict);
    let mut frames = dec.push(json_text.as_bytes()).expect("wire frames decode");
    if let Some(last) = dec.finish().expect("no dangling frame bytes") {
        frames.push(last);
    }
    Resp { status, head, body, frames }
}

fn post(addr: SocketAddr, body: &str, sse: bool) -> Resp {
    let mut s = TcpStream::connect(addr).unwrap();
    let accept = if sse { "Accept: text/event-stream\r\n" } else { "" };
    write!(
        s,
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         {accept}Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    parse_response(&raw, sse)
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let status = raw.split_whitespace().nth(1).unwrap().parse().unwrap();
    (status, raw)
}

fn wait_queued(ingress: &Ingress, n: usize) {
    let t0 = Instant::now();
    while ingress.queued_len() < n {
        assert!(t0.elapsed() < Duration::from_secs(10), "queue never reached {n}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Streamed token ids of a 200 response, cross-checked against its
/// final `done` frame.
fn streamed_tokens(resp: &Resp) -> Vec<i32> {
    assert_eq!(resp.status, 200, "{}", resp.head);
    let streamed: Vec<i32> = resp
        .frames
        .iter()
        .filter_map(|f| f.get("token").and_then(Json::as_f64))
        .map(|t| t as i32)
        .collect();
    let last = resp.frames.last().expect("at least the done frame");
    assert_eq!(last.get("done").and_then(Json::as_bool), Some(true), "{}", resp.body);
    let final_tokens: Vec<i32> = last
        .get("tokens")
        .and_then(Json::as_arr)
        .expect("done frame carries the full token list")
        .iter()
        .filter_map(Json::as_f64)
        .map(|t| t as i32)
        .collect();
    assert_eq!(streamed, final_tokens, "incremental frames == final list");
    // token frames carry their stream index in order
    let idx: Vec<f64> = resp
        .frames
        .iter()
        .filter_map(|f| f.get("index").and_then(Json::as_f64))
        .collect();
    assert_eq!(idx, (0..streamed.len()).map(|i| i as f64).collect::<Vec<_>>());
    streamed
}

/// Serve `reqs` over loopback (paused admission until all are queued,
/// reproducing the twin's closed batch) and return per-id tokens plus
/// the drained handle's final state.
fn serve_over_http(
    backend: HostBackend,
    serve: &ServeConfig,
    net: NetConfig,
    reqs: &[Request],
    sse: bool,
) -> (BTreeMap<u64, Vec<i32>>, Vec<CompletedRequest>, bitrom::coordinator::ServeMetrics) {
    let handle = NetServer::start(backend, serve.clone(), net).unwrap();
    let addr = handle.addr();
    handle.ingress().pause();
    let clients: Vec<_> = reqs
        .iter()
        .map(|r| {
            let body = r.to_json().to_string_compact();
            let id = r.id;
            (id, std::thread::spawn(move || post(addr, &body, sse)))
        })
        .collect();
    wait_queued(handle.ingress(), reqs.len());
    handle.ingress().resume();
    let mut tokens = BTreeMap::new();
    for (id, c) in clients {
        let resp = c.join().unwrap();
        if sse {
            assert!(resp.head.contains("text/event-stream"), "{}", resp.head);
            assert!(resp.body.contains("data: "), "{}", resp.body);
        } else {
            assert!(resp.head.contains("application/x-ndjson"), "{}", resp.head);
        }
        tokens.insert(id, streamed_tokens(&resp));
    }
    let (done, metrics) = handle.shutdown().unwrap();
    (tokens, done, metrics)
}

#[test]
fn greedy_streaming_over_loopback_matches_the_offline_twin() {
    // DESIGN.md invariant 10, base model, greedy decode
    let reqs = trace(5, 0, 11);
    let serve = ServeConfig { max_batches: 3, ..ServeConfig::default() };
    let twin = twin_tokens(base_backend(), &serve, reqs.clone());

    let net = NetConfig { listen: "127.0.0.1:0".into(), max_queue: 16, ..NetConfig::default() };
    let (tokens, done, metrics) = serve_over_http(base_backend(), &serve, net, &reqs, false);

    assert_eq!(tokens, twin, "loopback-served tokens == offline twin");
    assert_eq!(done.len(), reqs.len());
    assert_eq!(metrics.requests_done as usize, reqs.len());
    assert!(metrics.faults.shed.is_empty(), "{:?}", metrics.faults.shed);
    // live serving measured its latency percentiles in rounds
    assert_eq!(metrics.ttft_rounds.len(), reqs.len());
    assert!(metrics.tbt_rounds.len() > 0);
}

#[test]
fn mixed_tenant_topk_sse_streams_match_the_offline_twin() {
    // the hard half of invariant 10: per-request top-k sampling and
    // per-sequence adapter binding survive the trip through live
    // admission + SSE framing
    let reqs = trace(6, 2, 23);
    assert!(reqs.iter().any(|r| r.adapter_id.is_some()), "trace mixes tenants");
    let serve = ServeConfig { max_batches: 3, top_k: 3, ..ServeConfig::default() };
    let twin = twin_tokens(adapter_backend(2), &serve, reqs.clone());

    let net = NetConfig { listen: "127.0.0.1:0".into(), max_queue: 16, ..NetConfig::default() };
    let (tokens, _, metrics) = serve_over_http(adapter_backend(2), &serve, net, &reqs, true);

    assert_eq!(tokens, twin, "sampled multi-tenant streams == offline twin");
    assert_eq!(metrics.requests_done as usize, reqs.len());
    assert!(metrics.faults.shed.is_empty());
}

fn tiny_req(id: u64) -> Request {
    Request {
        id,
        arrival_s: 0.0,
        prompt: vec![1, 2, 3],
        max_new_tokens: 4,
        adapter_id: None,
        priority: 0,
    }
}

#[test]
fn overload_past_max_queue_sheds_typed_429_counted_in_metrics() {
    let serve = ServeConfig { max_batches: 1, ..ServeConfig::default() };
    let net = NetConfig { listen: "127.0.0.1:0".into(), max_queue: 2, ..NetConfig::default() };
    let handle = NetServer::start(base_backend(), serve, net).unwrap();
    let addr = handle.addr();
    handle.ingress().pause();

    let clients: Vec<_> = [100u64, 101]
        .iter()
        .map(|&id| {
            let body = tiny_req(id).to_json().to_string_compact();
            std::thread::spawn(move || post(addr, &body, false))
        })
        .collect();
    wait_queued(handle.ingress(), 2);

    // the queue is full: the next three submissions are typed 429s
    for id in [102u64, 103, 104] {
        let resp = post(addr, &tiny_req(id).to_json().to_string_compact(), false);
        assert_eq!(resp.status, 429, "{}", resp.head);
        assert!(resp.head.contains("Retry-After: 1\r\n"), "{}", resp.head);
        assert!(resp.body.contains("admission queue full"), "{}", resp.body);
    }

    handle.ingress().resume();
    for c in clients {
        let resp = c.join().unwrap();
        let toks = streamed_tokens(&resp);
        assert_eq!(toks.len(), 4);
    }
    let (done, metrics) = handle.shutdown().unwrap();
    assert_eq!(done.len(), 2);
    assert_eq!(metrics.requests_done, 2);
    // the HTTP-rejected submissions are the same typed sheds the
    // offline plane counts
    assert_eq!(metrics.faults.shed_count(FailReason::Overload), 3);
}

#[test]
fn shutdown_drains_queued_requests_as_typed_wire_errors() {
    let serve = ServeConfig { max_batches: 1, ..ServeConfig::default() };
    let net = NetConfig { listen: "127.0.0.1:0".into(), max_queue: 4, ..NetConfig::default() };
    let handle = NetServer::start(base_backend(), serve, net).unwrap();
    let addr = handle.addr();
    handle.ingress().pause();

    let body = tiny_req(50).to_json().to_string_compact();
    let client = std::thread::spawn(move || post(addr, &body, false));
    wait_queued(handle.ingress(), 1);

    // begin draining while the request is still queued (and admission
    // paused): it must come back as a typed error frame, not a hang or
    // a mid-token truncation
    handle.ingress().shutdown();
    let resp = client.join().unwrap();
    assert_eq!(resp.status, 200, "stream already started: {}", resp.head);
    assert_eq!(resp.frames.len(), 1, "{}", resp.body);
    assert_eq!(resp.frames[0].get("error").and_then(Json::as_str), Some("shutdown"));
    assert_eq!(resp.frames[0].get("id").and_then(Json::as_f64), Some(50.0));

    // a draining server reports it on /healthz
    let (status, raw) = get(addr, "/healthz");
    assert_eq!(status, 503, "{raw}");
    assert!(raw.ends_with("draining\n"), "{raw}");

    // a post-shutdown submission is rejected up front with 503
    let late = post(addr, &tiny_req(51).to_json().to_string_compact(), false);
    assert_eq!(late.status, 503, "{}", late.head);
    assert!(late.body.contains("shutting down"), "{}", late.body);

    let (done, metrics) = handle.shutdown().unwrap();
    assert!(done.is_empty());
    assert_eq!(metrics.faults.shed_count(FailReason::Shutdown), 1);
    assert_eq!(metrics.requests_done, 0);
}

#[test]
fn malformed_submissions_get_400_not_a_stream() {
    let serve = ServeConfig { max_batches: 1, ..ServeConfig::default() };
    let net = NetConfig { listen: "127.0.0.1:0".into(), ..NetConfig::default() };
    let handle = NetServer::start(base_backend(), serve, net).unwrap();
    let addr = handle.addr();

    let resp = post(addr, "{not json", false);
    assert_eq!(resp.status, 400, "{}", resp.head);
    assert!(resp.frames[0].get("error").is_some());

    let resp = post(addr, r#"{"max_new_tokens": 4}"#, false);
    assert_eq!(resp.status, 400, "missing prompt: {}", resp.body);

    let resp = post(addr, r#"{"prompt": [], "max_new_tokens": 4}"#, false);
    assert_eq!(resp.status, 400, "empty prompt: {}", resp.body);

    // a prompt past the prefill bucket is rejected at the edge, not
    // deep in the serving loop
    let long: Vec<String> = (0..200).map(|i| (i % 7).to_string()).collect();
    let body = format!(r#"{{"prompt": [{}], "max_new_tokens": 4}}"#, long.join(","));
    let resp = post(addr, &body, false);
    assert_eq!(resp.status, 400, "{}", resp.head);
    assert!(resp.body.contains("prefill bucket"), "{}", resp.body);

    let (done, metrics) = handle.shutdown().unwrap();
    assert!(done.is_empty());
    assert_eq!(metrics.requests_done, 0);
}
