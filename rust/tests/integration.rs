//! Cross-layer integration tests: the rust runtime + coordinator
//! against the AOT artifacts produced by the python compile path.
//!
//! All tests share one ModelExecutor (compiling 16 HLO executables
//! takes seconds). Tests are skipped gracefully when `make artifacts`
//! has not been run.

use bitrom::config::ServeConfig;
use bitrom::coordinator::Server;
use bitrom::runtime::{Manifest, ModelExecutor};
use bitrom::trace::{generate, TraceConfig};

// PjRtClient is Rc-based (not Send), so each test loads its own
// executor; loads are a few seconds (16 small HLO compiles).
fn executor() -> Option<ModelExecutor> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("integration tests skipped: run `make artifacts` first");
        return None;
    }
    Some(ModelExecutor::load(&dir).expect("loading artifacts"))
}

#[test]
fn golden_trace_matches_python_exactly() {
    let Some(exec) = executor() else { return };
    let exec = &exec;
    let g = exec.manifest.golden.clone().expect("golden trace");

    let (_, logits) = exec.prefill(&g.prompt).unwrap();
    let max_err = logits
        .data
        .iter()
        .zip(&g.prefill_last_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 2e-3, "prefill logits diverge: {max_err}");

    let got = exec.generate_greedy(&g.prompt, g.generated.len()).unwrap();
    assert_eq!(got, g.generated, "token sequence must match python");
}

#[test]
fn prefill_equals_chunked_prefill_plus_decode() {
    // DESIGN.md invariant 4, checked through the compiled artifacts:
    // prefill(p[..n]) then decoding the remaining prompt tokens yields
    // the same logits as prefill(p).
    let Some(exec) = executor() else { return };
    let exec = &exec;
    let prompt: Vec<i32> = vec![9, 33, 77, 150, 2, 41];

    let (_, full_logits) = exec.prefill(&prompt).unwrap();

    let (mut state, _) = exec.prefill(&prompt[..3]).unwrap();
    let mut last = None;
    for &t in &prompt[3..] {
        last = Some(exec.decode_step(&mut state, t).unwrap());
    }
    let inc_logits = last.unwrap();
    let max_err = full_logits
        .data
        .iter()
        .zip(&inc_logits.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 5e-3, "prefill/decode inconsistency: {max_err}");
    // and the argmax (the actual sampling decision) must agree
    assert_eq!(full_logits.argmax(), inc_logits.argmax());
}

#[test]
fn prompt_padding_is_invisible() {
    // same prompt served through the padded bucket must not depend on
    // bucket garbage: two different pad-lengths, identical logits.
    let Some(exec) = executor() else { return };
    let exec = &exec;
    let (_, l1) = exec.prefill(&[5, 6, 7]).unwrap();
    let (_, l2) = exec.prefill(&[5, 6, 7]).unwrap();
    assert_eq!(l1.data, l2.data, "prefill must be deterministic");
    // compare a 3-token prompt against the same prompt decoded from 2+1
    let (mut st, _) = exec.prefill(&[5, 6]).unwrap();
    let l3 = exec.decode_step(&mut st, 7).unwrap();
    assert_eq!(l1.argmax(), l3.argmax());
}

#[test]
fn decode_respects_max_seq() {
    let Some(exec) = executor() else { return };
    let exec = &exec;
    let max = exec.manifest.model.max_seq;
    let (mut state, logits) = exec.prefill(&[1, 2, 3]).unwrap();
    let mut tok = logits.argmax() as i32;
    // positions 3..=127 are writable: 125 more decode steps succeed
    for _ in 0..(max - 3) {
        tok = exec.decode_step(&mut state, tok).unwrap().argmax() as i32;
    }
    // cache is now full: the next step must fail cleanly, not corrupt
    let err = exec.decode_step(&mut state, tok);
    assert!(err.is_err(), "overflow must be rejected");
}

#[test]
fn server_completes_trace_with_healthy_edram() {
    let Some(exec) = executor() else { return };
    let vocab = exec.manifest.model.vocab_size;
    let serve = ServeConfig::default();
    let trace = TraceConfig {
        n_requests: 5,
        gen_len_min: 4,
        gen_len_max: 10,
        prompt_len_min: 3,
        prompt_len_max: 20,
        vocab_size: vocab,
        ..TraceConfig::default()
    };
    let reqs = generate(&trace);
    let n = reqs.len();
    let mut server = Server::new(exec, serve).unwrap();
    let (done, mut metrics) = server.run_trace(reqs).unwrap();

    assert_eq!(done.len(), n, "every request completes");
    for r in &done {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 10);
        assert!(r.tokens.iter().all(|&t| (t as usize) < vocab));
        assert!(r.ttft_s > 0.0);
    }
    assert_eq!(metrics.requests_done as usize, n);
    assert!(metrics.tokens_per_s() > 0.0);
    // the PJRT executor's KV is opaque to the host, so no measured
    // tier statistics are reported (the host backend path measures
    // them — see tests/serve_offline.rs)
    assert!(metrics.kv.is_none());
    assert!(server.kv_stats().is_none());
}

#[test]
fn server_batched_output_matches_single_stream() {
    // token-level determinism: the same request decoded alone and
    // decoded inside a 6-way batch must produce identical tokens
    // (per-sequence KV state is fully isolated).
    let Some(exec_ref) = executor() else { return };
    let prompt = vec![11, 22, 33, 44];
    let solo = exec_ref.generate_greedy(&prompt, 6).unwrap();
    drop(exec_ref);

    let Some(exec) = executor() else { return };
    let vocab = exec.manifest.model.vocab_size;
    let mut reqs = generate(&TraceConfig {
        n_requests: 5,
        gen_len_min: 6,
        gen_len_max: 6,
        vocab_size: vocab,
        seed: 3,
        ..TraceConfig::default()
    });
    // request 0 is our probe
    reqs[0].prompt = prompt.clone();
    reqs[0].max_new_tokens = 6;
    let mut server = Server::new(exec, ServeConfig::default()).unwrap();
    let (done, _) = server.run_trace(reqs).unwrap();
    let probe = done.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(probe.tokens, solo, "batching must not change results");
}

#[test]
fn manifest_matches_rust_config() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() { return; }
    let m = &Manifest::load(&dir).unwrap().model;
    let rust_cfg = bitrom::config::ModelConfig::sim_tiny();
    assert_eq!(m.n_layers, rust_cfg.n_layers);
    assert_eq!(m.d_model, rust_cfg.d_model);
    assert_eq!(m.n_partitions, rust_cfg.n_partitions);
    assert_eq!(m.param_count(), rust_cfg.param_count());
}
