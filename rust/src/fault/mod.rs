//! Deterministic fault injection for the serving plane (DESIGN.md §13).
//!
//! A [`FaultPlan`] is a seeded schedule of adverse events — DR-eDRAM
//! retention-clock skips ("storms"), transient backend / adapter-load /
//! KV-capacity failures — consumed by `coordinator::Server::run_trace`
//! one [`RoundFaults`] per token round. The plan draws a **fixed**
//! number of random values per round (one storm draw, one storm-target
//! draw when the deployment is sharded, plus one draw per batch slot,
//! active or not), so the injected schedule depends only on the seed,
//! the round index and the topology: it is byte-identical across
//! `--threads` widths and across reruns, which is what lets invariant 9
//! assert that a faulted run's surviving tokens match the fault-free
//! twin exactly. Single-shard plans draw no storm target, so every
//! pre-sharding schedule replays byte-identically.
//!
//! Sharded deployments (DESIGN.md §16, [`FaultPlan::with_shards`]):
//! each storm picks one target shard uniformly, modeling a retention
//! event on one CiROM chip — the coordinator then skips only that
//! shard's DR-eDRAM clock, and recovery must hold invariants 9 ∧ 12
//! jointly (fuzzed in `tests/fault_fuzz.rs`).
//!
//! The plan injects *causes*; the server owns the *policy* (recompute
//! recovery, bounded retry with backoff, shedding) and the accounting
//! (`ServeMetrics::faults`). With no plan configured (`fault_seed == 0`)
//! nothing in this module runs and serving behavior is unchanged.

use crate::config::ServeConfig;
use crate::util::rng::Rng;

/// Rounds after a storm during which the next storm is suppressed, so
/// a high `fault_storm_p` produces periodic storms instead of a
/// permanent clock stall no sequence could ever survive. The
/// suppressed rounds still consume their storm draw, keeping the
/// random stream length per round fixed.
pub const STORM_COOLDOWN_ROUNDS: u64 = 6;

/// One class of injected transient failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The backend's token round fails transiently (compute fabric).
    Backend,
    /// An adapter cold load fails transiently (stream interrupted).
    AdapterLoad,
    /// KV slab/row allocation fails transiently (capacity exhausted).
    KvExhausted,
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultKind::Backend => write!(f, "backend"),
            FaultKind::AdapterLoad => write!(f, "adapter-load"),
            FaultKind::KvExhausted => write!(f, "kv-exhausted"),
        }
    }
}

/// The faults injected into one token round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundFaults {
    /// Extra seconds added to the DR-eDRAM hardware clock this round
    /// (0.0 = no storm). A skip larger than the retention window minus
    /// the round time expires every resident on-die row at once.
    pub clock_skip_s: f64,
    /// Shard whose retention clock the storm hits (`None` = the storm
    /// is global / the deployment is single-shard). Only ever `Some`
    /// when `clock_skip_s > 0` and the plan was built
    /// [`FaultPlan::with_shards`] > 1.
    pub storm_shard: Option<usize>,
    /// Per-slot transient failure, indexed by batch slot id.
    pub transient: Vec<Option<FaultKind>>,
}

impl RoundFaults {
    /// True when this round injects nothing.
    pub fn is_quiet(&self) -> bool {
        self.clock_skip_s == 0.0 && self.transient.iter().all(Option::is_none)
    }
}

/// A seeded, deterministic fault schedule (module docs).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    rng: Rng,
    n_slots: usize,
    storm_p: f64,
    transient_p: f64,
    clock_skip_s: f64,
    rounds_since_storm: u64,
    /// Shards storms can target (1 = global storms, the pre-sharding
    /// stream — no target draw is consumed).
    n_shards: usize,
}

impl FaultPlan {
    /// Plan over `n_slots` batch slots from explicit parameters.
    /// Probabilities are clamped to `[0, 1]`.
    pub fn new(
        seed: u64,
        n_slots: usize,
        storm_p: f64,
        transient_p: f64,
        clock_skip_s: f64,
    ) -> Self {
        FaultPlan {
            rng: Rng::new(seed),
            n_slots,
            storm_p: storm_p.clamp(0.0, 1.0),
            transient_p: transient_p.clamp(0.0, 1.0),
            clock_skip_s: clock_skip_s.max(0.0),
            rounds_since_storm: STORM_COOLDOWN_ROUNDS,
            n_shards: 1,
        }
    }

    /// Make storms shard-local: each storm targets one of `n_shards`
    /// shards uniformly (clamped to at least 1; 1 keeps global storms
    /// and the exact pre-sharding random stream).
    pub fn with_shards(mut self, n_shards: usize) -> Self {
        self.n_shards = n_shards.max(1);
        self
    }

    /// Plan configured by a [`ServeConfig`], or `None` when
    /// `fault_seed == 0` (fault injection off — the default).
    pub fn from_serve(cfg: &ServeConfig) -> Option<Self> {
        if cfg.fault_seed == 0 {
            return None;
        }
        Some(
            FaultPlan::new(
                cfg.fault_seed,
                cfg.max_batches,
                cfg.fault_storm_p,
                cfg.fault_transient_p,
                cfg.fault_clock_skip_s,
            )
            .with_shards(cfg.shards),
        )
    }

    /// Draw the next round's faults. Always consumes exactly
    /// `1 + n_slots` generator values (plus one storm-target draw when
    /// the plan is sharded) regardless of what fires.
    pub fn next_round(&mut self) -> RoundFaults {
        let storm_draw = self.rng.f64();
        let storm = storm_draw < self.storm_p && self.rounds_since_storm >= STORM_COOLDOWN_ROUNDS;
        if storm {
            self.rounds_since_storm = 0;
        } else {
            self.rounds_since_storm += 1;
        }
        // the target draw is consumed every round (fixed stream length)
        // but only surfaces when a storm actually fires
        let storm_shard = if self.n_shards > 1 {
            let target = (self.rng.next_u64() % self.n_shards as u64) as usize;
            (storm && self.clock_skip_s > 0.0).then_some(target)
        } else {
            None
        };
        let transient: Vec<Option<FaultKind>> = (0..self.n_slots)
            .map(|_| {
                // one u64 per slot: top 53 bits decide, low bits pick the kind
                let r = self.rng.next_u64();
                let p = (r >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
                if p < self.transient_p {
                    Some(match r % 3 {
                        0 => FaultKind::Backend,
                        1 => FaultKind::AdapterLoad,
                        _ => FaultKind::KvExhausted,
                    })
                } else {
                    None
                }
            })
            .collect();
        RoundFaults {
            clock_skip_s: if storm { self.clock_skip_s } else { 0.0 },
            storm_shard,
            transient,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(seed: u64, storm_p: f64, transient_p: f64) -> FaultPlan {
        FaultPlan::new(seed, 4, storm_p, transient_p, 0.1)
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let mut a = plan(7, 0.5, 0.3);
        let mut b = plan(7, 0.5, 0.3);
        for _ in 0..200 {
            assert_eq!(a.next_round(), b.next_round());
        }
        let mut c = plan(8, 0.5, 0.3);
        assert!((0..200).any(|_| a.next_round() != c.next_round()));
    }

    #[test]
    fn zero_probabilities_inject_nothing() {
        let mut p = plan(3, 0.0, 0.0);
        for _ in 0..100 {
            assert!(p.next_round().is_quiet());
        }
    }

    #[test]
    fn certain_storms_respect_the_cooldown() {
        let mut p = plan(5, 1.0, 0.0);
        let skips: Vec<bool> = (0..40).map(|_| p.next_round().clock_skip_s > 0.0).collect();
        assert!(skips[0], "first round must storm at p = 1");
        // storms are spaced exactly one cooldown apart
        for (i, &s) in skips.iter().enumerate() {
            assert_eq!(s, i as u64 % (STORM_COOLDOWN_ROUNDS + 1) == 0, "round {i}");
        }
    }

    #[test]
    fn transients_fire_at_roughly_the_configured_rate() {
        let mut p = plan(11, 0.0, 0.25);
        let n = 4000u32;
        let hits: u32 = (0..n / 4)
            .map(|_| p.next_round().transient.iter().flatten().count() as u32)
            .sum();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.03, "transient fraction {frac}");
    }

    #[test]
    fn all_fault_kinds_appear() {
        let mut p = plan(13, 0.0, 1.0);
        let mut seen = [false; 3];
        for _ in 0..50 {
            for k in p.next_round().transient.into_iter().flatten() {
                seen[match k {
                    FaultKind::Backend => 0,
                    FaultKind::AdapterLoad => 1,
                    FaultKind::KvExhausted => 2,
                }] = true;
            }
        }
        assert_eq!(seen, [true; 3]);
    }

    #[test]
    fn sharded_storms_pick_deterministic_targets() {
        // deterministic per seed, every target shard eventually hit,
        // and targets only surface on rounds that actually storm
        let mk = || plan(19, 0.6, 0.0).with_shards(3);
        let (mut a, mut b) = (mk(), mk());
        let mut seen = [false; 3];
        for _ in 0..300 {
            let ra = a.next_round();
            assert_eq!(ra, b.next_round());
            match ra.storm_shard {
                Some(s) => {
                    assert!(ra.clock_skip_s > 0.0, "target without a storm");
                    seen[s] = true;
                }
                None => assert_eq!(ra.clock_skip_s, 0.0),
            }
        }
        assert_eq!(seen, [true; 3], "some shard never targeted");
        // a single-shard plan never surfaces a target and replays the
        // exact pre-sharding stream (the target draw is gated, not
        // merely hidden)
        let mut legacy = plan(19, 0.6, 0.3);
        let mut single = plan(19, 0.6, 0.3).with_shards(1);
        for _ in 0..300 {
            let r = legacy.next_round();
            assert_eq!(r.storm_shard, None);
            assert_eq!(r, single.next_round());
        }
    }

    #[test]
    fn from_serve_is_off_by_default() {
        let cfg = ServeConfig::default();
        assert!(FaultPlan::from_serve(&cfg).is_none());
        let on = ServeConfig {
            fault_seed: 9,
            ..ServeConfig::default()
        };
        let mut p = FaultPlan::from_serve(&on).expect("seeded plan");
        assert_eq!(p.next_round().transient.len(), on.max_batches);
    }
}
