//! Deterministic fault injection for the serving plane (DESIGN.md §13).
//!
//! A [`FaultPlan`] is a seeded schedule of adverse events — DR-eDRAM
//! retention-clock skips ("storms"), transient backend / adapter-load /
//! KV-capacity failures — consumed by `coordinator::Server::run_trace`
//! one [`RoundFaults`] per token round. The plan draws a **fixed**
//! number of random values per round (one storm draw plus one draw per
//! batch slot, active or not), so the injected schedule depends only on
//! the seed and the round index: it is byte-identical across `--threads`
//! widths and across reruns, which is what lets invariant 9 assert that
//! a faulted run's surviving tokens match the fault-free twin exactly.
//!
//! The plan injects *causes*; the server owns the *policy* (recompute
//! recovery, bounded retry with backoff, shedding) and the accounting
//! (`ServeMetrics::faults`). With no plan configured (`fault_seed == 0`)
//! nothing in this module runs and serving behavior is unchanged.

use crate::config::ServeConfig;
use crate::util::rng::Rng;

/// Rounds after a storm during which the next storm is suppressed, so
/// a high `fault_storm_p` produces periodic storms instead of a
/// permanent clock stall no sequence could ever survive. The
/// suppressed rounds still consume their storm draw, keeping the
/// random stream length per round fixed.
pub const STORM_COOLDOWN_ROUNDS: u64 = 6;

/// One class of injected transient failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The backend's token round fails transiently (compute fabric).
    Backend,
    /// An adapter cold load fails transiently (stream interrupted).
    AdapterLoad,
    /// KV slab/row allocation fails transiently (capacity exhausted).
    KvExhausted,
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultKind::Backend => write!(f, "backend"),
            FaultKind::AdapterLoad => write!(f, "adapter-load"),
            FaultKind::KvExhausted => write!(f, "kv-exhausted"),
        }
    }
}

/// The faults injected into one token round.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundFaults {
    /// Extra seconds added to the DR-eDRAM hardware clock this round
    /// (0.0 = no storm). A skip larger than the retention window minus
    /// the round time expires every resident on-die row at once.
    pub clock_skip_s: f64,
    /// Per-slot transient failure, indexed by batch slot id.
    pub transient: Vec<Option<FaultKind>>,
}

impl RoundFaults {
    /// True when this round injects nothing.
    pub fn is_quiet(&self) -> bool {
        self.clock_skip_s == 0.0 && self.transient.iter().all(Option::is_none)
    }
}

/// A seeded, deterministic fault schedule (module docs).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    rng: Rng,
    n_slots: usize,
    storm_p: f64,
    transient_p: f64,
    clock_skip_s: f64,
    rounds_since_storm: u64,
}

impl FaultPlan {
    /// Plan over `n_slots` batch slots from explicit parameters.
    /// Probabilities are clamped to `[0, 1]`.
    pub fn new(
        seed: u64,
        n_slots: usize,
        storm_p: f64,
        transient_p: f64,
        clock_skip_s: f64,
    ) -> Self {
        FaultPlan {
            rng: Rng::new(seed),
            n_slots,
            storm_p: storm_p.clamp(0.0, 1.0),
            transient_p: transient_p.clamp(0.0, 1.0),
            clock_skip_s: clock_skip_s.max(0.0),
            rounds_since_storm: STORM_COOLDOWN_ROUNDS,
        }
    }

    /// Plan configured by a [`ServeConfig`], or `None` when
    /// `fault_seed == 0` (fault injection off — the default).
    pub fn from_serve(cfg: &ServeConfig) -> Option<Self> {
        if cfg.fault_seed == 0 {
            return None;
        }
        Some(FaultPlan::new(
            cfg.fault_seed,
            cfg.max_batches,
            cfg.fault_storm_p,
            cfg.fault_transient_p,
            cfg.fault_clock_skip_s,
        ))
    }

    /// Draw the next round's faults. Always consumes exactly
    /// `1 + n_slots` generator values regardless of what fires.
    pub fn next_round(&mut self) -> RoundFaults {
        let storm_draw = self.rng.f64();
        let storm = storm_draw < self.storm_p && self.rounds_since_storm >= STORM_COOLDOWN_ROUNDS;
        if storm {
            self.rounds_since_storm = 0;
        } else {
            self.rounds_since_storm += 1;
        }
        let transient: Vec<Option<FaultKind>> = (0..self.n_slots)
            .map(|_| {
                // one u64 per slot: top 53 bits decide, low bits pick the kind
                let r = self.rng.next_u64();
                let p = (r >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
                if p < self.transient_p {
                    Some(match r % 3 {
                        0 => FaultKind::Backend,
                        1 => FaultKind::AdapterLoad,
                        _ => FaultKind::KvExhausted,
                    })
                } else {
                    None
                }
            })
            .collect();
        RoundFaults {
            clock_skip_s: if storm { self.clock_skip_s } else { 0.0 },
            transient,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(seed: u64, storm_p: f64, transient_p: f64) -> FaultPlan {
        FaultPlan::new(seed, 4, storm_p, transient_p, 0.1)
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let mut a = plan(7, 0.5, 0.3);
        let mut b = plan(7, 0.5, 0.3);
        for _ in 0..200 {
            assert_eq!(a.next_round(), b.next_round());
        }
        let mut c = plan(8, 0.5, 0.3);
        assert!((0..200).any(|_| a.next_round() != c.next_round()));
    }

    #[test]
    fn zero_probabilities_inject_nothing() {
        let mut p = plan(3, 0.0, 0.0);
        for _ in 0..100 {
            assert!(p.next_round().is_quiet());
        }
    }

    #[test]
    fn certain_storms_respect_the_cooldown() {
        let mut p = plan(5, 1.0, 0.0);
        let skips: Vec<bool> = (0..40).map(|_| p.next_round().clock_skip_s > 0.0).collect();
        assert!(skips[0], "first round must storm at p = 1");
        // storms are spaced exactly one cooldown apart
        for (i, &s) in skips.iter().enumerate() {
            assert_eq!(s, i as u64 % (STORM_COOLDOWN_ROUNDS + 1) == 0, "round {i}");
        }
    }

    #[test]
    fn transients_fire_at_roughly_the_configured_rate() {
        let mut p = plan(11, 0.0, 0.25);
        let n = 4000u32;
        let hits: u32 = (0..n / 4)
            .map(|_| p.next_round().transient.iter().flatten().count() as u32)
            .sum();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.03, "transient fraction {frac}");
    }

    #[test]
    fn all_fault_kinds_appear() {
        let mut p = plan(13, 0.0, 1.0);
        let mut seen = [false; 3];
        for _ in 0..50 {
            for k in p.next_round().transient.into_iter().flatten() {
                seen[match k {
                    FaultKind::Backend => 0,
                    FaultKind::AdapterLoad => 1,
                    FaultKind::KvExhausted => 2,
                }] = true;
            }
        }
        assert_eq!(seen, [true; 3]);
    }

    #[test]
    fn from_serve_is_off_by_default() {
        let cfg = ServeConfig::default();
        assert!(FaultPlan::from_serve(&cfg).is_none());
        let on = ServeConfig {
            fault_seed: 9,
            ..ServeConfig::default()
        };
        let mut p = FaultPlan::from_serve(&on).expect("seeded plan");
        assert_eq!(p.next_round().transient.len(), on.max_batches);
    }
}
