//! LoRA domain adapters (paper §III-C): overhead accounting, the
//! digital adapter compute model, and the multi-tenant serving path.
//!
//! Three layers:
//!
//! * [`LoraConfig`] / [`Proj`] — parameter/op/storage overhead for any
//!   rank and placement (Table I/II), plus the placement-string
//!   grammar the CLI shares ([`LoraConfig::placement_str`] ↔
//!   [`LoraConfig::parse_placements`]).
//! * [`AdapterRegistry`] — seeded, deterministic per-tenant adapter
//!   weights served end-to-end by `runtime::HostBackend` (bound per
//!   sequence via `runtime::ServeTuning::bind_adapter`), with
//!   residency/task-switch accounting against the tiered memory model
//!   and measured MAC counters ([`LoraServeStats`]).
//! * [`MergedProjection`] / [`apply_adapter_delta`] — the host compute
//!   of one adapted projection: bitplane base GEMV/GEMM plus the
//!   rank-r f32 correction. The registry path and the merged path
//!   apply the *same* delta helper, so the two can never diverge
//!   (property-tested in this module's tests).
//!
//! Production adapters are *trained* in the python build path
//! (`compile/train_lora.py`); fabricated registry adapters exercise
//! the serving machinery deterministically.

mod registry;

pub use registry::{AdapterPair, AdapterRegistry, LoraServeStats};

use crate::bitnet::{KernelCtx, QuantizedActs, TernaryMatrix};
use crate::config::ModelConfig;

/// The seven adapter sites (paper Table II columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Proj {
    /// Query projection.
    Q,
    /// Key projection.
    K,
    /// Value projection.
    V,
    /// Attention output projection.
    O,
    /// MLP gate projection.
    Gate,
    /// MLP up projection.
    Up,
    /// MLP down projection.
    Down,
}

impl Proj {
    /// Every adapter site, Table II order.
    pub const ALL: [Proj; 7] = [
        Proj::Q,
        Proj::K,
        Proj::V,
        Proj::O,
        Proj::Gate,
        Proj::Up,
        Proj::Down,
    ];

    /// One-letter site label (Table II header style).
    pub fn short(self) -> &'static str {
        match self {
            Proj::Q => "Q",
            Proj::K => "K",
            Proj::V => "V",
            Proj::O => "O",
            Proj::Gate => "G",
            Proj::Up => "U",
            Proj::Down => "D",
        }
    }

    /// Inverse of [`Self::short`] (case-insensitive) — the grammar of
    /// placement strings like `"VOD"` in configs and CLI flags.
    pub fn from_short(c: char) -> Option<Proj> {
        match c.to_ascii_uppercase() {
            'Q' => Some(Proj::Q),
            'K' => Some(Proj::K),
            'V' => Some(Proj::V),
            'O' => Some(Proj::O),
            'G' => Some(Proj::Gate),
            'U' => Some(Proj::Up),
            'D' => Some(Proj::Down),
            _ => None,
        }
    }

    /// Dense index of this site in [`Self::ALL`] order (the
    /// [`AdapterRegistry`]'s per-layer site-table slot).
    pub fn site_index(self) -> usize {
        match self {
            Proj::Q => 0,
            Proj::K => 1,
            Proj::V => 2,
            Proj::O => 3,
            Proj::Gate => 4,
            Proj::Up => 5,
            Proj::Down => 6,
        }
    }

    /// (fan_in, fan_out) of this projection in `cfg`.
    pub fn dims(self, cfg: &ModelConfig) -> (usize, usize) {
        let d = cfg.d_model;
        let kv = cfg.kv_dim();
        let f = cfg.d_ff;
        match self {
            Proj::Q => (d, d),
            Proj::K => (d, kv),
            Proj::V => (d, kv),
            Proj::O => (d, d),
            Proj::Gate => (d, f),
            Proj::Up => (d, f),
            Proj::Down => (f, d),
        }
    }
}

/// An adapter configuration: which projections carry rank-`rank`
/// adapters, with `weight_bits` quantization.
#[derive(Debug, Clone, PartialEq)]
pub struct LoraConfig {
    /// Projections carrying adapters.
    pub placement: Vec<Proj>,
    /// Adapter rank.
    pub rank: usize,
    /// Adapter weight quantization (bits).
    pub weight_bits: usize,
    /// Adapter activation quantization (bits).
    pub act_bits: usize,
}

impl LoraConfig {
    /// The paper's chosen configuration: rank 16 on V, O, Down with
    /// 6-bit weights and 8-bit activations.
    pub fn paper() -> Self {
        LoraConfig {
            placement: vec![Proj::V, Proj::O, Proj::Down],
            rank: 16,
            weight_bits: 6,
            act_bits: 8,
        }
    }

    /// Compact placement label like `"VOD"` — exactly the string
    /// [`Self::parse_placements`] (and the `--placements` CLI flag)
    /// accepts, so labels round-trip.
    pub fn placement_str(&self) -> String {
        self.placement.iter().map(|p| p.short()).collect()
    }

    /// Parse a placement string (`Proj` short names, e.g. `"VOD"`,
    /// case-insensitive); rejects unknown and duplicate sites.
    pub fn parse_placements(s: &str) -> anyhow::Result<Vec<Proj>> {
        let mut out = Vec::new();
        for c in s.trim().chars() {
            let p = Proj::from_short(c).ok_or_else(|| {
                anyhow::anyhow!("unknown projection site {c:?} (expected letters from QKVOGUD)")
            })?;
            anyhow::ensure!(!out.contains(&p), "duplicate projection site {c:?}");
            out.push(p);
        }
        anyhow::ensure!(!out.is_empty(), "empty placement string");
        Ok(out)
    }

    /// Extra adapter parameters across the whole model.
    pub fn extra_params(&self, cfg: &ModelConfig) -> u64 {
        let per_layer: u64 = self
            .placement
            .iter()
            .map(|p| {
                let (fi, fo) = p.dims(cfg);
                ((fi + fo) * self.rank) as u64
            })
            .sum();
        per_layer * cfg.n_layers as u64
    }

    /// Extra parameters as a fraction of the base model (Table I col 2).
    pub fn param_overhead(&self, cfg: &ModelConfig) -> f64 {
        self.extra_params(cfg) as f64 / cfg.param_count() as f64
    }

    /// Extra MACs per token from the adapters.
    pub fn extra_macs_per_token(&self, cfg: &ModelConfig) -> u64 {
        self.extra_params(cfg) // one MAC per adapter weight per token
    }

    /// Adapter MACs as a fraction of the MACs of the projections they
    /// attach to (the paper's "0.7% of their corresponding projection
    /// layers").
    pub fn op_overhead_vs_host_projections(&self, cfg: &ModelConfig) -> f64 {
        let host: u64 = self
            .placement
            .iter()
            .map(|p| {
                let (fi, fo) = p.dims(cfg);
                (fi * fo) as u64
            })
            .sum::<u64>()
            * cfg.n_layers as u64;
        self.extra_macs_per_token(cfg) as f64 / host as f64
    }

    /// Adapter storage bytes (quantized weights).
    pub fn storage_bytes(&self, cfg: &ModelConfig) -> u64 {
        (self.extra_params(cfg) * self.weight_bits as u64 + 7) / 8
    }
}

/// The digital adapter datapath: a 4-input multiplier-adder unit per
/// macro (paper Fig: "simple 4-input multiplier-and-adder"). Computes
/// dy = (x·A)·B·(alpha/rank) in exact fixed-point, 4 MACs per cycle.
pub fn adapter_cycles(fan_in: usize, fan_out: usize, rank: usize) -> u64 {
    let macs = (fan_in * rank + rank * fan_out) as u64;
    (macs + 3) / 4
}

/// Add the low-rank delta `(x·A)·B·(α/r)` into `y`, where `x` is the
/// dequantized view of `acts` (`values · scale`). This is THE adapter
/// application: [`MergedProjection`] and the `HostBackend` registry
/// path both call it, so merged and dynamically-bound adapters are
/// bit-identical by construction. Zero activation digits and zero
/// intermediate terms are skipped (the 4-input unit idles on zeros).
pub fn apply_adapter_delta(
    acts: &QuantizedActs,
    a: &[f32],
    b: &[f32],
    rank: usize,
    alpha: f32,
    y: &mut [f32],
) {
    if rank == 0 {
        return;
    }
    let fan_out = y.len();
    debug_assert_eq!(a.len(), acts.values.len() * rank, "A shape mismatch");
    debug_assert_eq!(b.len(), rank * fan_out, "B shape mismatch");
    let gain = alpha / rank as f32;
    // t = x · A  (dequantized activations)
    let mut t = vec![0f32; rank];
    for (r, &xv) in acts.values.iter().enumerate() {
        if xv == 0 {
            continue;
        }
        let xf = xv as f32 * acts.scale;
        let arow = &a[r * rank..(r + 1) * rank];
        for (tj, &aj) in t.iter_mut().zip(arow) {
            *tj += xf * aj;
        }
    }
    // y += (t · B) · (α/r)
    for (j, &tj) in t.iter().enumerate() {
        if tj == 0.0 {
            continue;
        }
        let brow = &b[j * fan_out..(j + 1) * fan_out];
        for (yc, &bc) in y.iter_mut().zip(brow) {
            *yc += tj * bc * gain;
        }
    }
}

/// A ROM-resident ternary base projection merged with a digital LoRA
/// adapter: `y = scale_x · scale_w · (x · W) + (x · A) · B · (α/r)`.
///
/// The base term runs on the word-parallel bitplane kernel (exact
/// integers, bit-identical to `ref_gemv`); the low-rank adapter term is
/// the small dense f32 compute the paper's 4-input multiplier-adder
/// unit performs. This is the host-side model of a domain-adapted
/// projection — the compute the `report`/adaptation paths consume.
#[derive(Debug, Clone)]
pub struct MergedProjection {
    /// The frozen ternary base weights.
    pub base: TernaryMatrix,
    /// Down-projection, row-major `[fan_in × rank]`.
    pub a: Vec<f32>,
    /// Up-projection, row-major `[rank × fan_out]`.
    pub b: Vec<f32>,
    /// Adapter rank.
    pub rank: usize,
    /// LoRA scaling factor (α).
    pub alpha: f32,
}

impl MergedProjection {
    /// Attach adapters `a`/`b` to `base` (shape-checked).
    pub fn new(base: TernaryMatrix, a: Vec<f32>, b: Vec<f32>, rank: usize, alpha: f32) -> Self {
        assert_eq!(a.len(), base.rows * rank, "A shape mismatch");
        assert_eq!(b.len(), rank * base.cols, "B shape mismatch");
        MergedProjection {
            base,
            a,
            b,
            rank,
            alpha,
        }
    }

    /// Forward one activation vector (delegates to the batched path,
    /// so the cached bitplane view is reused — no scalar fallback).
    pub fn forward(&self, acts: &QuantizedActs) -> Vec<f32> {
        self.forward_batch(std::slice::from_ref(acts)).pop().unwrap()
    }

    /// Forward a batch of activation vectors. The base term goes
    /// through the batched bitplane GEMM so weight-mask decoding
    /// amortizes across the batch; the adapter term is the shared
    /// [`apply_adapter_delta`] — `O(rank·(fan_in + fan_out))` per row,
    /// dense f32.
    pub fn forward_batch(&self, acts: &[QuantizedActs]) -> Vec<Vec<f32>> {
        let batch: Vec<&[i32]> = acts.iter().map(|q| q.values.as_slice()).collect();
        // flat row-major output: one integer buffer for the whole
        // batch instead of a Vec per row
        let mut flat: Vec<i64> = Vec::new();
        KernelCtx::from_env().gemm_flat(self.base.bitplanes(), &batch, &mut flat);
        let cols = self.base.cols;
        acts.iter()
            .enumerate()
            .map(|(i, q)| {
                let mut y: Vec<f32> = flat[i * cols..(i + 1) * cols]
                    .iter()
                    .map(|&v| v as f32 * q.scale * self.base.scale)
                    .collect();
                apply_adapter_delta(q, &self.a, &self.b, self.rank, self.alpha, &mut y);
                y
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_placement_overhead_falcon3_1b() {
        // Table I: Falcon3-1B row reports 0.30% extra parameters.
        let cfg = ModelConfig::falcon3_1b();
        let pct = 100.0 * LoraConfig::paper().param_overhead(&cfg);
        assert!((pct - 0.30).abs() < 0.08, "got {pct:.3}%");
    }

    #[test]
    fn overhead_shrinks_with_model_size() {
        // Table I: 1B→0.30%, 7B→0.22% (wider channels dilute rank 16).
        let c1 = ModelConfig::named("falcon3-1b").unwrap();
        let c7 = ModelConfig::named("falcon3-7b").unwrap();
        let l = LoraConfig::paper();
        assert!(l.param_overhead(&c7) < l.param_overhead(&c1));
        let pct7 = 100.0 * l.param_overhead(&c7);
        assert!((0.1..0.4).contains(&pct7), "7B: {pct7:.3}%");
    }

    #[test]
    fn op_overhead_below_one_percent() {
        // Paper: "additional operations account for only 0.7% of their
        // corresponding projection layers". With our Falcon3-1B shape
        // assumptions we measure ~1.2% — same order, documented in
        // EXPERIMENTS.md (the exact ratio depends on the undisclosed
        // kv/ffn dims the authors used).
        let cfg = ModelConfig::falcon3_1b();
        let pct = 100.0 * LoraConfig::paper().op_overhead_vs_host_projections(&cfg);
        assert!((0.3..1.5).contains(&pct), "got {pct:.3}%");
    }

    #[test]
    fn table2_placements_ordered_by_params() {
        // Table II: QKGU (0.37%) > VOD (0.22%) > D (0.16%) on 7B.
        let cfg = ModelConfig::named("falcon3-7b").unwrap();
        let mk = |pl: &[Proj]| LoraConfig {
            placement: pl.to_vec(),
            rank: 16,
            weight_bits: 6,
            act_bits: 8,
        };
        let qkgu = mk(&[Proj::Q, Proj::K, Proj::Gate, Proj::Up]).param_overhead(&cfg);
        let vod = mk(&[Proj::V, Proj::O, Proj::Down]).param_overhead(&cfg);
        let d = mk(&[Proj::Down]).param_overhead(&cfg);
        let all = mk(&Proj::ALL).param_overhead(&cfg);
        assert!(qkgu > vod && vod > d, "{qkgu} {vod} {d}");
        assert!(all > qkgu);
    }

    #[test]
    fn adapter_cycles_scale_with_rank() {
        assert!(adapter_cycles(2048, 2048, 16) > adapter_cycles(2048, 2048, 4));
        // rank-16 on a 2048×2048 projection: 65,536 MACs / 4 per cycle
        assert_eq!(adapter_cycles(2048, 2048, 16), (2048 * 16 * 2) as u64 / 4);
    }

    #[test]
    fn storage_uses_weight_bits() {
        let cfg = ModelConfig::falcon3_1b();
        let l6 = LoraConfig::paper();
        let mut l8 = LoraConfig::paper();
        l8.weight_bits = 8;
        assert!(l6.storage_bytes(&cfg) < l8.storage_bytes(&cfg));
    }

    #[test]
    fn placement_string() {
        assert_eq!(LoraConfig::paper().placement_str(), "VOD");
    }

    #[test]
    fn placement_strings_round_trip_with_the_parser() {
        // the CLI's --placements grammar IS placement_str's output
        for s in ["VOD", "QKGU", "D", "QKVOGUD"] {
            let parsed = LoraConfig::parse_placements(s).unwrap();
            let cfg = LoraConfig {
                placement: parsed,
                ..LoraConfig::paper()
            };
            assert_eq!(cfg.placement_str(), s);
        }
        // case-insensitive in, canonical out
        let lower = LoraConfig::parse_placements("vod").unwrap();
        assert_eq!(lower, LoraConfig::paper().placement);
        assert!(LoraConfig::parse_placements("VX").is_err());
        assert!(LoraConfig::parse_placements("VV").is_err());
        assert!(LoraConfig::parse_placements("").is_err());
    }

    #[test]
    fn site_index_is_dense_and_matches_all_order() {
        for (i, p) in Proj::ALL.iter().enumerate() {
            assert_eq!(p.site_index(), i);
            assert_eq!(Proj::from_short(p.short().chars().next().unwrap()), Some(*p));
        }
        assert_eq!(Proj::from_short('x'), None);
    }

    #[test]
    fn dynamic_delta_equals_merged_projection_bitwise() {
        // the registry path (base GEMV + apply_adapter_delta) and the
        // merged path must agree bit-for-bit: they share the helper,
        // and this pins the contract
        let m = merged_fixture(40, 64, 24, 8);
        let mut rng = crate::util::rng::Rng::new(41);
        for _ in 0..5 {
            let x: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            let q = crate::bitnet::absmax_quantize(&x, 8);
            let mut dynamic: Vec<f32> = m
                .base
                .gemv(&q.values)
                .into_iter()
                .map(|v| v as f32 * q.scale * m.base.scale)
                .collect();
            apply_adapter_delta(&q, &m.a, &m.b, m.rank, m.alpha, &mut dynamic);
            assert_eq!(dynamic, m.forward(&q), "dynamic != merged");
        }
    }

    fn merged_fixture(seed: u64, fan_in: usize, fan_out: usize, rank: usize) -> MergedProjection {
        let mut rng = crate::util::rng::Rng::new(seed);
        let base = TernaryMatrix::random(fan_in, fan_out, 0.3, &mut rng);
        let a: Vec<f32> = (0..fan_in * rank).map(|_| rng.normal() as f32 * 0.1).collect();
        let b: Vec<f32> = (0..rank * fan_out).map(|_| rng.normal() as f32 * 0.1).collect();
        MergedProjection::new(base, a, b, rank, 2.0 * rank as f32)
    }

    #[test]
    fn merged_base_term_is_bit_exact_vs_reference() {
        let mut rng = crate::util::rng::Rng::new(31);
        let m = merged_fixture(30, 96, 40, 0); // rank 0: pure base path
        let x: Vec<f32> = (0..96).map(|_| rng.normal() as f32).collect();
        let q = crate::bitnet::absmax_quantize(&x, 8);
        let y = m.forward(&q);
        let want = crate::bitnet::ref_gemv(&q.values, &m.base);
        for (got, wi) in y.iter().zip(&want) {
            assert_eq!(*got, *wi as f32 * q.scale * m.base.scale);
        }
    }

    #[test]
    fn merged_forward_matches_dense_float_model() {
        let m = merged_fixture(32, 70, 24, 4);
        let mut rng = crate::util::rng::Rng::new(33);
        let x: Vec<f32> = (0..70).map(|_| rng.normal() as f32).collect();
        let q = crate::bitnet::absmax_quantize(&x, 8);
        let xf = q.dequant();
        let y = m.forward(&q);
        let gain = m.alpha / m.rank as f32;
        for c in 0..24 {
            let mut want = 0f64;
            for r in 0..70 {
                want += xf[r] as f64 * m.base.get(r, c) as f64 * m.base.scale as f64;
            }
            for j in 0..m.rank {
                let mut t = 0f64;
                for r in 0..70 {
                    t += xf[r] as f64 * m.a[r * m.rank + j] as f64;
                }
                want += t * m.b[j * 24 + c] as f64 * gain as f64;
            }
            assert!(
                (y[c] as f64 - want).abs() < 1e-3 * (1.0 + want.abs()),
                "col {c}: {} vs {want}",
                y[c]
            );
        }
    }

    #[test]
    fn merged_batch_equals_per_row_forward() {
        let m = merged_fixture(34, 80, 16, 8);
        let mut rng = crate::util::rng::Rng::new(35);
        let qs: Vec<crate::bitnet::QuantizedActs> = (0..5)
            .map(|_| {
                let x: Vec<f32> = (0..80).map(|_| rng.normal() as f32).collect();
                crate::bitnet::absmax_quantize(&x, 8)
            })
            .collect();
        let batched = m.forward_batch(&qs);
        for (q, want) in qs.iter().zip(&batched) {
            assert_eq!(&m.forward(q), want, "batched must be bit-identical");
        }
    }

    #[test]
    #[should_panic(expected = "A shape mismatch")]
    fn merged_rejects_bad_adapter_shapes() {
        let mut rng = crate::util::rng::Rng::new(36);
        let base = TernaryMatrix::random(8, 4, 0.3, &mut rng);
        MergedProjection::new(base, vec![0.0; 7], vec![0.0; 8], 2, 1.0);
    }
}
