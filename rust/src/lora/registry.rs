//! Multi-tenant adapter registry — the serving-side home of the
//! paper's §III-C LoRA pillar.
//!
//! The base ternary weights are fixed in ROM; what makes them usable
//! across downstream tasks is a small per-tenant low-rank correction
//! on a few projection sites. [`AdapterRegistry`] holds those
//! corrections for every tenant of a deployment: seeded, deterministic
//! A/B factor pairs at a [`LoraConfig`]'s rank/placement, fabricated
//! once and never mutated (adapters are as reload-free as the mask
//! set once resident).
//!
//! Residency is accounted against the tiered memory model: adapters
//! are stored quantized (`LoraConfig::weight_bits`) behind the
//! external-DRAM interface and stream on-die the *first* time a
//! sequence binds them (a cold load, counted in bytes and joules via
//! [`DramParams`]); every later bind is a pointer swap that moves zero
//! bytes — task switching without weight reload, the paper's headline
//! serving claim. [`LoraServeStats`] also counts the adapter and base
//! MACs actually executed at adapter sites, so a served trace
//! *measures* the per-token op overhead that
//! [`LoraConfig::op_overhead_vs_host_projections`] models
//! (`report::lora_serving` places the two side by side).

use std::sync::Mutex;

use crate::config::ModelConfig;
use crate::dram::DramParams;
use crate::util::rng::Rng;

use super::{LoraConfig, Proj};

/// One adapter site's factor pair: `a` is the down-projection
/// (row-major `[fan_in × rank]`), `b` the up-projection
/// (`[rank × fan_out]`).
#[derive(Debug, Clone)]
pub struct AdapterPair {
    /// Down-projection, row-major `[fan_in × rank]`.
    pub a: Vec<f32>,
    /// Up-projection, row-major `[rank × fan_out]`.
    pub b: Vec<f32>,
}

/// One tenant's full adapter set: per layer, per projection site.
struct Adapter {
    /// `sites[layer][Proj::site_index()]` — `None` off the placement.
    sites: Vec<[Option<AdapterPair>; 7]>,
}

/// Measured adapter-serving statistics: task-switch traffic against
/// the tiered memory model plus the MACs actually executed at adapter
/// sites. Counters are lifetime-accumulated (like the KV store's);
/// [`LoraServeStats::since`] extracts a per-trace delta.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LoraServeStats {
    /// Sequences bound to an adapter (one per adapter-carrying
    /// request; base-model sequences do not count).
    pub binds: u64,
    /// Binds that found the adapter non-resident and streamed its
    /// quantized weights over the external interface.
    pub cold_loads: u64,
    /// Bytes streamed by cold loads.
    pub bytes_streamed: u64,
    /// Energy of the cold-load streaming (J, external-DRAM reads).
    pub stream_energy_j: f64,
    /// Low-rank correction MACs executed (`fan_in·r + r·fan_out` per
    /// activation row per site).
    pub adapter_macs: u64,
    /// Base-projection MACs executed at the same sites for the same
    /// rows (`fan_in·fan_out` each) — the denominator of the paper's
    /// "0.7% of their corresponding projection layers" claim.
    pub base_macs: u64,
    /// Activation rows that passed through at least one adapter site.
    pub adapter_rows: u64,
}

impl LoraServeStats {
    /// Measured per-token adapter op overhead: adapter MACs as a
    /// fraction of the base MACs of the projections they attach to —
    /// the executed twin of
    /// [`LoraConfig::op_overhead_vs_host_projections`].
    pub fn measured_op_overhead(&self) -> f64 {
        if self.base_macs == 0 {
            0.0
        } else {
            self.adapter_macs as f64 / self.base_macs as f64
        }
    }

    /// Counter delta since the `start` snapshot (per-trace reporting).
    pub fn since(&self, start: &Self) -> Self {
        LoraServeStats {
            binds: self.binds.saturating_sub(start.binds),
            cold_loads: self.cold_loads.saturating_sub(start.cold_loads),
            bytes_streamed: self.bytes_streamed.saturating_sub(start.bytes_streamed),
            stream_energy_j: (self.stream_energy_j - start.stream_energy_j).max(0.0),
            adapter_macs: self.adapter_macs.saturating_sub(start.adapter_macs),
            base_macs: self.base_macs.saturating_sub(start.base_macs),
            adapter_rows: self.adapter_rows.saturating_sub(start.adapter_rows),
        }
    }
}

/// Seeded, deterministic multi-tenant adapter store (module docs).
/// Weights are immutable after fabrication; residency and MAC
/// accounting live behind `Mutex`es because the serving API hands out
/// `&self` and partition stages may execute on worker threads
/// (DESIGN.md §12). Each op's tally is merged in one brief critical
/// section, and every counter is a commutative sum (residency flips
/// once, monotonically), so totals are bit-identical at any thread
/// count.
pub struct AdapterRegistry {
    model: ModelConfig,
    lora: LoraConfig,
    alpha: f32,
    adapters: Vec<Adapter>,
    dram: DramParams,
    resident: Mutex<Vec<bool>>,
    stats: Mutex<LoraServeStats>,
}

impl AdapterRegistry {
    /// Fabricate `n_adapters` deterministic tenant adapters for
    /// `model` at `lora`'s rank/placement. Factor entries are
    /// gaussians scaled `0.5/√fan_in` (A) and `0.5/√rank` (B), so the
    /// applied delta perturbs projections strongly enough to
    /// specialize generation without destabilizing it. α follows the
    /// common 2·rank convention.
    pub fn fabricate(
        model: &ModelConfig,
        lora: &LoraConfig,
        n_adapters: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(n_adapters >= 1, "need at least one adapter");
        anyhow::ensure!(lora.rank >= 1, "adapter rank must be >= 1");
        anyhow::ensure!(!lora.placement.is_empty(), "empty adapter placement");
        let mut rng = Rng::new(seed);
        let adapters = (0..n_adapters)
            .map(|_| {
                let sites = (0..model.n_layers)
                    .map(|_| {
                        let mut layer: [Option<AdapterPair>; 7] = std::array::from_fn(|_| None);
                        for &p in &lora.placement {
                            let (fi, fo) = p.dims(model);
                            let sa = 0.5 / (fi as f64).sqrt();
                            let sb = 0.5 / (lora.rank as f64).sqrt();
                            let a = (0..fi * lora.rank)
                                .map(|_| (rng.normal() * sa) as f32)
                                .collect();
                            let b = (0..lora.rank * fo)
                                .map(|_| (rng.normal() * sb) as f32)
                                .collect();
                            layer[p.site_index()] = Some(AdapterPair { a, b });
                        }
                        layer
                    })
                    .collect();
                Adapter { sites }
            })
            .collect();
        Ok(AdapterRegistry {
            model: model.clone(),
            lora: lora.clone(),
            alpha: 2.0 * lora.rank as f32,
            adapters,
            dram: DramParams::default(),
            resident: Mutex::new(vec![false; n_adapters]),
            stats: Mutex::new(LoraServeStats::default()),
        })
    }

    /// Tenant adapters loaded.
    pub fn n_adapters(&self) -> usize {
        self.adapters.len()
    }

    /// The rank/placement/quantization configuration.
    pub fn lora(&self) -> &LoraConfig {
        &self.lora
    }

    /// LoRA scaling factor α (the delta is scaled α/rank).
    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    /// The architecture the adapters were fabricated for.
    pub fn model(&self) -> &ModelConfig {
        &self.model
    }

    /// Check the registry fits `model`'s projection shapes (a backend
    /// constructor precondition).
    pub fn compatible_with(&self, model: &ModelConfig) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.model.n_layers == model.n_layers
                && self.model.d_model == model.d_model
                && self.model.kv_dim() == model.kv_dim()
                && self.model.d_ff == model.d_ff,
            "adapter registry fabricated for {:?} does not fit model {:?}",
            self.model.name,
            model.name
        );
        Ok(())
    }

    /// The factor pair at (`adapter`, `layer`, `proj`), if that site
    /// carries one.
    pub fn site(&self, adapter: u32, layer: usize, proj: Proj) -> Option<&AdapterPair> {
        self.adapters.get(adapter as usize)?.sites.get(layer)?[proj.site_index()].as_ref()
    }

    /// Bind `adapter` to a sequence: validates the id, counts the
    /// task switch, and streams the adapter's quantized bytes on-die
    /// if this is its first use (cold load). Resident adapters bind
    /// for free — no weights move.
    pub fn bind(&self, adapter: u32) -> anyhow::Result<()> {
        let idx = adapter as usize;
        anyhow::ensure!(
            idx < self.adapters.len(),
            "adapter {adapter} out of range ({} loaded)",
            self.adapters.len()
        );
        let mut stats = self.stats.lock().expect("adapter stats poisoned");
        stats.binds += 1;
        let mut resident = self.resident.lock().expect("adapter residency poisoned");
        if !resident[idx] {
            resident[idx] = true;
            let bytes = self.adapter_bytes();
            stats.cold_loads += 1;
            stats.bytes_streamed += bytes;
            stats.stream_energy_j += bytes as f64 * self.dram.read_pj_per_byte * 1e-12;
        }
        Ok(())
    }

    /// Record the MACs of applying one adapter site to `rows`
    /// activation rows (called by the backend at the point of
    /// execution, so the measured overhead reflects the sites actually
    /// wired in). One brief lock per op — the per-op tally commutes,
    /// so totals are thread-count-invariant.
    pub fn record_site_macs(&self, rows: u64, fan_in: usize, fan_out: usize) {
        let r = self.lora.rank as u64;
        let mut stats = self.stats.lock().expect("adapter stats poisoned");
        stats.adapter_macs += rows * (fan_in as u64 * r + r * fan_out as u64);
        stats.base_macs += rows * fan_in as u64 * fan_out as u64;
        stats.adapter_rows += rows;
    }

    /// Snapshot of the accumulated statistics.
    pub fn stats(&self) -> LoraServeStats {
        self.stats.lock().expect("adapter stats poisoned").clone()
    }

    /// Quantized storage of ONE tenant adapter (what a cold task
    /// switch streams).
    pub fn adapter_bytes(&self) -> u64 {
        self.lora.storage_bytes(&self.model)
    }

    /// On-die bytes currently held by resident adapters.
    pub fn resident_bytes(&self) -> u64 {
        let resident = self.resident.lock().expect("adapter residency poisoned");
        let n = resident.iter().filter(|&&r| r).count();
        n as u64 * self.adapter_bytes()
    }

    /// What a full weight reload would move instead: every ROM-held
    /// ternary parameter at the 1.6 b/trit packed encoding.
    pub fn full_reload_bytes(&self) -> u64 {
        Self::full_reload_bytes_for(&self.model)
    }

    /// [`Self::full_reload_bytes`] for any architecture (no registry
    /// needed).
    pub fn full_reload_bytes_for(model: &ModelConfig) -> u64 {
        (model.rom_param_count() + 4) / 5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::sim_tiny()
    }

    fn paper() -> LoraConfig {
        LoraConfig::paper()
    }

    #[test]
    fn fabrication_is_deterministic_per_seed() {
        let m = tiny();
        let a = AdapterRegistry::fabricate(&m, &paper(), 2, 7).unwrap();
        let b = AdapterRegistry::fabricate(&m, &paper(), 2, 7).unwrap();
        let c = AdapterRegistry::fabricate(&m, &paper(), 2, 8).unwrap();
        let pa = a.site(1, 3, Proj::O).unwrap();
        let pb = b.site(1, 3, Proj::O).unwrap();
        let pc = c.site(1, 3, Proj::O).unwrap();
        assert_eq!(pa.a, pb.a);
        assert_eq!(pa.b, pb.b);
        assert_ne!(pa.a, pc.a);
        // distinct tenants get distinct weights
        let p0 = a.site(0, 3, Proj::O).unwrap();
        assert_ne!(pa.a, p0.a);
    }

    #[test]
    fn sites_follow_the_placement() {
        let m = tiny();
        let reg = AdapterRegistry::fabricate(&m, &paper(), 1, 1).unwrap();
        for li in 0..m.n_layers {
            for p in Proj::ALL {
                let on = paper().placement.contains(&p);
                assert_eq!(reg.site(0, li, p).is_some(), on, "{p:?} layer {li}");
            }
        }
        // shapes match the model's projection dims
        let (fi, fo) = Proj::Down.dims(&m);
        let pair = reg.site(0, 0, Proj::Down).unwrap();
        assert_eq!(pair.a.len(), fi * 16);
        assert_eq!(pair.b.len(), 16 * fo);
        // out-of-range lookups are None, not panics
        assert!(reg.site(1, 0, Proj::Down).is_none());
        assert!(reg.site(0, m.n_layers, Proj::Down).is_none());
    }

    #[test]
    fn bind_streams_once_then_switches_free() {
        let reg = AdapterRegistry::fabricate(&tiny(), &paper(), 3, 2).unwrap();
        reg.bind(1).unwrap();
        reg.bind(1).unwrap();
        reg.bind(2).unwrap();
        let s = reg.stats();
        assert_eq!(s.binds, 3);
        assert_eq!(s.cold_loads, 2);
        assert_eq!(s.bytes_streamed, 2 * reg.adapter_bytes());
        assert!(s.stream_energy_j > 0.0);
        assert_eq!(reg.resident_bytes(), 2 * reg.adapter_bytes());
        assert!(reg.bind(3).is_err(), "id past the registry must fail");
    }

    #[test]
    fn mac_accounting_matches_the_analytic_overhead() {
        let m = tiny();
        let lora = paper();
        let reg = AdapterRegistry::fabricate(&m, &lora, 1, 3).unwrap();
        // apply every placement site of every layer to 5 rows, as one
        // served token round does
        for _li in 0..m.n_layers {
            for &p in &lora.placement {
                let (fi, fo) = p.dims(&m);
                reg.record_site_macs(5, fi, fo);
            }
        }
        let s = reg.stats();
        assert_eq!(s.adapter_rows, 5 * (m.n_layers * lora.placement.len()) as u64);
        let analytic = lora.op_overhead_vs_host_projections(&m);
        let measured = s.measured_op_overhead();
        assert!(
            (measured - analytic).abs() < 1e-12,
            "measured {measured} vs analytic {analytic}"
        );
    }

    #[test]
    fn stats_delta_since_snapshot() {
        let reg = AdapterRegistry::fabricate(&tiny(), &paper(), 2, 4).unwrap();
        reg.bind(0).unwrap();
        let snap = reg.stats();
        reg.bind(0).unwrap();
        reg.record_site_macs(1, 8, 4);
        let d = reg.stats().since(&snap);
        assert_eq!(d.binds, 1);
        assert_eq!(d.cold_loads, 0, "adapter 0 was already resident");
        assert_eq!(d.bytes_streamed, 0);
        assert_eq!(d.adapter_rows, 1);
    }

    #[test]
    fn switch_bytes_are_a_small_fraction_of_a_full_reload() {
        // the reload-vs-switch claim at the paper's deployment target:
        // a cold task switch streams the 6-bit VOD r16 adapter, ~1.7%
        // of re-loading the packed ternary mask set
        let falcon = ModelConfig::falcon3_1b();
        let adapter = LoraConfig::paper().storage_bytes(&falcon);
        let reload = AdapterRegistry::full_reload_bytes_for(&falcon);
        let ratio = adapter as f64 / reload as f64;
        assert!(ratio < 0.05, "adapter/reload ratio {ratio}");
        // even on the tiny sim model (rank 16 is huge next to d=128)
        // the switch stays well under a reload
        let reg = AdapterRegistry::fabricate(&tiny(), &paper(), 1, 5).unwrap();
        assert!(reg.adapter_bytes() * 2 < reg.full_reload_bytes());
    }

    #[test]
    fn registry_rejects_degenerate_configs() {
        let m = tiny();
        assert!(AdapterRegistry::fabricate(&m, &paper(), 0, 1).is_err());
        let mut zero_rank = paper();
        zero_rank.rank = 0;
        assert!(AdapterRegistry::fabricate(&m, &zero_rank, 1, 1).is_err());
        let mut nowhere = paper();
        nowhere.placement.clear();
        assert!(AdapterRegistry::fabricate(&m, &nowhere, 1, 1).is_err());
        // model-shape mismatch is caught by compatible_with
        let reg = AdapterRegistry::fabricate(&m, &paper(), 1, 1).unwrap();
        assert!(reg.compatible_with(&ModelConfig::falcon3_1b()).is_err());
        assert!(reg.compatible_with(&m).is_ok());
    }
}
