//! BiROMA — the Bidirectional ROM Array (paper §III-B2, Fig 4).
//!
//! Each single-transistor cell stores two ternary weights by connecting
//! its source/drain to one of three signal-line levels per side
//! (½VDD → '0', ¼VDD → '+1', VSS → '−1'). The even (E) and odd (O)
//! signal-line sides are fully symmetric: either side can act as the
//! source lines (drive) while the other develops bitline readout —
//! *bidirectional operation*, which is what doubles the density.
//!
//! The simulator stores the cell codes exactly as the mask would fix
//! them and models readout at trit granularity, counting every read.
//! Contents are immutable after construction — this is ROM; there is
//! deliberately NO write method.

use crate::bitnet::pack::{cell_decode, cell_encode};
use crate::bitnet::{BitplaneMatrix, Trit};

/// Which signal-line side is being read out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    /// Even signal lines act as bitlines.
    Even,
    /// Odd signal lines act as bitlines.
    Odd,
}

/// One BiROMA array: single-transistor cells storing two trits each
/// (paper §III-B1).
#[derive(Debug, Clone)]
pub struct Biroma {
    rows: usize,
    cols: usize,
    /// Cell codes, row-major; code ∈ [0, 8] encodes (even, odd) trits.
    cells: Vec<u8>,
}

impl Biroma {
    /// "Fabricate" an array from per-cell (even, odd) trit pairs.
    /// `pairs` is row-major, `rows * cols` entries.
    pub fn fabricate(rows: usize, cols: usize, pairs: &[(Trit, Trit)]) -> Self {
        assert_eq!(pairs.len(), rows * cols, "cell count mismatch");
        let cells = pairs.iter().map(|&(e, o)| cell_encode(e, o)).collect();
        Biroma { rows, cols, cells }
    }

    /// Fabricate an all-zero (erased mask) array.
    pub fn blank(rows: usize, cols: usize) -> Self {
        Biroma {
            rows,
            cols,
            cells: vec![cell_encode(0, 0); rows * cols],
        }
    }

    /// Fabricate from per-output-channel weight rows in the *blocked*
    /// layout: input `i < cols` is stored on the even side of cell `i`;
    /// input `i >= cols` on the odd side of cell `i - cols`. Small
    /// fan-in channels therefore need only the even-side readout pass —
    /// half the cycles. Unprogrammed cells hold 0.
    pub fn fabricate_rows(rows: usize, cols: usize, row_trits: &[Vec<Trit>]) -> Self {
        assert!(row_trits.len() <= rows, "too many rows");
        let mut cells = vec![cell_encode(0, 0); rows * cols];
        for (r, trits) in row_trits.iter().enumerate() {
            assert!(trits.len() <= 2 * cols, "row {r} too wide");
            for c in 0..cols {
                let e = trits.get(c).copied().unwrap_or(0);
                let o = trits.get(cols + c).copied().unwrap_or(0);
                cells[r * cols + c] = cell_encode(e, o);
            }
        }
        Biroma { rows, cols, cells }
    }

    /// Fabricate from a weight matrix's bitplane view — the same
    /// blocked layout as [`Biroma::fabricate_rows`] (plane column `c` =
    /// output channel = wordline row; input `i < cols` on the even
    /// side, `i ≥ cols` on the odd side) but WITHOUT materializing a
    /// `Vec<Trit>` per channel: cells are written straight off the
    /// plane words. Unprogrammed cells hold 0.
    pub fn fabricate_from_planes(rows: usize, cols: usize, planes: &BitplaneMatrix) -> Self {
        assert!(planes.cols() <= rows, "too many rows");
        assert!(planes.rows() <= 2 * cols, "rows too wide");
        let mut cells = vec![cell_encode(0, 0); rows * cols];
        let fan_in = planes.rows();
        for r in 0..planes.cols() {
            for c in 0..cols {
                let e = if c < fan_in { planes.get(c, r) } else { 0 };
                let o = if cols + c < fan_in { planes.get(cols + c, r) } else { 0 };
                cells[r * cols + c] = cell_encode(e, o);
            }
        }
        Biroma { rows, cols, cells }
    }

    /// Wordlines in the array.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Cells per wordline (each stores two trits).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Read one trit: activate WL `row`, configure `side`'s lines as
    /// bitlines, select cell column `col`. Returns the stored trit.
    #[inline]
    pub fn read(&self, row: usize, col: usize, side: Side) -> Trit {
        assert!(row < self.rows && col < self.cols, "read OOB ({row},{col})");
        let (e, o) = cell_decode(self.cells[row * self.cols + col]);
        match side {
            Side::Even => e,
            Side::Odd => o,
        }
    }

    /// Read a whole row on one side (one WL activation; `cols` trits).
    pub fn read_row(&self, row: usize, side: Side) -> Vec<Trit> {
        (0..self.cols).map(|c| self.read(row, c, side)).collect()
    }

    /// Logical input weight `i` of output-channel `row`, using the
    /// blocked even/odd layout of `fabricate_rows`.
    #[inline]
    pub fn weight(&self, row: usize, i: usize) -> Trit {
        let (side, col) = if i < self.cols {
            (Side::Even, i)
        } else {
            (Side::Odd, i - self.cols)
        };
        self.read(row, col, side)
    }

    /// Total ternary weights stored.
    pub fn capacity_weights(&self) -> usize {
        self.rows * self.cols * 2
    }

    /// Zero fraction over the whole array.
    pub fn sparsity(&self) -> f64 {
        let zeros: usize = self
            .cells
            .iter()
            .map(|&c| {
                let (e, o) = cell_decode(c);
                (e == 0) as usize + (o == 0) as usize
            })
            .sum();
        zeros as f64 / self.capacity_weights() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn fabricate_and_read_both_sides() {
        let pairs = vec![(1i8, -1i8), (0, 1), (-1, 0), (1, 1)];
        let b = Biroma::fabricate(2, 2, &pairs);
        assert_eq!(b.read(0, 0, Side::Even), 1);
        assert_eq!(b.read(0, 0, Side::Odd), -1);
        assert_eq!(b.read(1, 0, Side::Even), -1);
        assert_eq!(b.read(1, 1, Side::Odd), 1);
    }

    #[test]
    fn sides_are_independent_property() {
        check(0xB1120, 100, |g| {
            let rows = g.size(16);
            let cols = g.size(16);
            let pairs: Vec<(i8, i8)> = (0..rows * cols)
                .map(|_| (g.trit(0.3), g.trit(0.3)))
                .collect();
            let b = Biroma::fabricate(rows, cols, &pairs);
            for r in 0..rows {
                for c in 0..cols {
                    prop_assert_eq!(b.read(r, c, Side::Even), pairs[r * cols + c].0);
                    prop_assert_eq!(b.read(r, c, Side::Odd), pairs[r * cols + c].1);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn row_layout_is_blocked_even_then_odd() {
        let row: Vec<i8> = vec![1, -1, 0, 1]; // inputs 0..4, cols=2
        let b = Biroma::fabricate_rows(1, 2, &[row.clone()]);
        for (i, &t) in row.iter().enumerate() {
            assert_eq!(b.weight(0, i), t, "input {i}");
        }
        // inputs 0,1 live on the even side; 2,3 on the odd side
        assert_eq!(b.read(0, 0, Side::Even), 1);
        assert_eq!(b.read(0, 1, Side::Even), -1);
        assert_eq!(b.read(0, 0, Side::Odd), 0);
        assert_eq!(b.read(0, 1, Side::Odd), 1);
    }

    #[test]
    fn plane_fabrication_equals_row_fabrication_property() {
        use crate::bitnet::TernaryMatrix;
        check(0xB1FA, 80, |g| {
            let cols = g.size(16);
            let rows = g.size(16);
            let fan_in = g.usize(1, 2 * cols);
            let fan_out = g.usize(1, rows);
            let trits = g.vec_trits(fan_in * fan_out, 0.3);
            let w = TernaryMatrix::from_trits(fan_in, fan_out, &trits, 1.0);
            let via_rows: Vec<Vec<i8>> = (0..w.cols).map(|c| w.col_trits(c)).collect();
            let a = Biroma::fabricate_rows(rows, cols, &via_rows);
            let b = Biroma::fabricate_from_planes(rows, cols, w.bitplanes());
            for r in 0..rows {
                for c in 0..cols {
                    prop_assert_eq!(a.read(r, c, Side::Even), b.read(r, c, Side::Even));
                    prop_assert_eq!(a.read(r, c, Side::Odd), b.read(r, c, Side::Odd));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn short_rows_pad_with_zero() {
        let b = Biroma::fabricate_rows(2, 4, &[vec![1, 1, 1]]);
        assert_eq!(b.weight(0, 3), 0);
        assert_eq!(b.weight(0, 7), 0); // odd side empty
        assert_eq!(b.weight(1, 0), 0); // unprogrammed row
    }

    #[test]
    fn read_row_matches_point_reads() {
        let pairs: Vec<(i8, i8)> = (0..12).map(|i| ((i % 3) as i8 - 1, 1)).collect();
        let b = Biroma::fabricate(3, 4, &pairs);
        for r in 0..3 {
            let row = b.read_row(r, Side::Even);
            for c in 0..4 {
                assert_eq!(row[c], b.read(r, c, Side::Even));
            }
        }
    }

    #[test]
    fn sparsity_counts_both_sides() {
        let b = Biroma::fabricate(1, 2, &[(0, 1), (0, 0)]);
        assert!((b.sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "OOB")]
    fn out_of_bounds_read_panics() {
        Biroma::blank(2, 2).read(2, 0, Side::Even);
    }
}
