//! Energy/activity event counters — the interface between the circuit
//! simulators and the analytical energy model.

/// Counts of every energy-bearing event during macro execution.
/// The `energy` module multiplies these by the per-event constants in
/// `config::EnergyParams` (scaled by voltage) to obtain joules.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EventCounters {
    /// Ternary weights read from the BiROMA (BL precharge + develop).
    pub weight_reads: u64,
    /// TriMLA local accumulates actually performed (EN high).
    pub accums: u64,
    /// TriMLA cycles skipped because the weight was zero (EN gated).
    /// Costs no accumulate energy — the sparsity win.
    pub skips: u64,
    /// Global adder-tree passes.
    pub tree_passes: u64,
    /// Array clock cycles (column-select steps × sides × serial passes,
    /// all TriMLAs operating in parallel per cycle). Tracked by the
    /// macro, not by individual TriMLAs.
    pub mac_cycles: u64,
    /// Extra cycles incurred by 8-bit bit-serial mode.
    pub bitserial_cycles: u64,
    /// TriMLA 8-bit accumulator saturations (must stay 0 in-spec).
    pub saturations: u64,
    /// MAC operations completed (multiply-accumulate pairs, for TOPS).
    pub macs: u64,
}

impl EventCounters {
    /// All-zero counters.
    pub fn new() -> Self {
        Default::default()
    }

    /// Accumulate another trace's counts into this one.
    pub fn merge(&mut self, other: &EventCounters) {
        self.weight_reads += other.weight_reads;
        self.accums += other.accums;
        self.skips += other.skips;
        self.tree_passes += other.tree_passes;
        self.mac_cycles += other.mac_cycles;
        self.bitserial_cycles += other.bitserial_cycles;
        self.saturations += other.saturations;
        self.macs += other.macs;
    }

    /// Observed zero-skip rate.
    pub fn skip_rate(&self) -> f64 {
        let total = self.accums + self.skips;
        if total == 0 {
            0.0
        } else {
            self.skips as f64 / total as f64
        }
    }

    /// Arithmetic operations for TOPS accounting (2 ops per MAC —
    /// multiply + add — the convention used by the paper's TOPS/W).
    pub fn ops(&self) -> u64 {
        2 * self.macs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_fields() {
        let mut a = EventCounters {
            weight_reads: 1,
            accums: 2,
            skips: 3,
            tree_passes: 4,
            mac_cycles: 5,
            bitserial_cycles: 6,
            saturations: 0,
            macs: 7,
        };
        a.merge(&a.clone());
        assert_eq!(a.weight_reads, 2);
        assert_eq!(a.macs, 14);
        assert_eq!(a.ops(), 28);
    }

    #[test]
    fn skip_rate() {
        let c = EventCounters {
            accums: 70,
            skips: 30,
            ..Default::default()
        };
        assert!((c.skip_rate() - 0.3).abs() < 1e-12);
        assert_eq!(EventCounters::new().skip_rate(), 0.0);
    }
}
