//! TriMLA — the Tri-Mode Local Accumulator (paper §III-B2/B3, Fig 4).
//!
//! The prefetched ternary weight drives two comparators against the
//! 1/8·VDD and 3/8·VDD references; their outputs form the (MSB, LSB)
//! mode code of the truth table:
//!
//! | weight | MSB (≠0?) | LSB (sign) | mode     |
//! |--------|-----------|------------|----------|
//! |   0    |     0     |     ×      | **skip** (EN low — no toggle) |
//! |  +1    |     1     |     0      | **add**  |
//! |  −1    |     1     |     1      | **sub**  |
//!
//! The local accumulator is 8-bit signed; the simulator saturates and
//! *counts* any saturation event so the paper's "8-bit output width is
//! sufficient to avoid overflow" claim is checked, not assumed.

use crate::bitnet::Trit;

use super::events::EventCounters;

/// Decoded operating mode (the comparator outputs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrimlaMode {
    /// Zero weight: EN gated low, no accumulate energy.
    Skip,
    /// +1 weight: add the activation.
    Add,
    /// −1 weight: subtract the activation.
    Sub,
}

impl TrimlaMode {
    /// The Fig 4 truth table.
    #[inline]
    pub fn decode(w: Trit) -> TrimlaMode {
        match w {
            0 => TrimlaMode::Skip,
            1 => TrimlaMode::Add,
            -1 => TrimlaMode::Sub,
            _ => panic!("non-ternary weight {w}"),
        }
    }

    /// (MSB, LSB) comparator bits for this mode.
    pub fn comparator_bits(self) -> (bool, bool) {
        match self {
            TrimlaMode::Skip => (false, false),
            TrimlaMode::Add => (true, false),
            TrimlaMode::Sub => (true, true),
        }
    }
}

/// One local accumulator instance.
#[derive(Debug, Clone)]
pub struct Trimla {
    acc: i32,
    out_bits: u32,
}

impl Trimla {
    /// Accumulator with an `out_bits`-wide saturating register.
    pub fn new(out_bits: usize) -> Self {
        Trimla {
            acc: 0,
            out_bits: out_bits as u32,
        }
    }

    /// Clear the accumulator for the next channel pass.
    pub fn reset(&mut self) {
        self.acc = 0;
    }

    /// One MAC cycle: weight-mode decode + gated accumulate of a 4-bit
    /// activation digit (in [-8, 15]: signed int4 or a bit-serial
    /// nibble). Saturates at the accumulator width and records events.
    #[inline]
    pub fn step(&mut self, w: Trit, x_digit: i32, ev: &mut EventCounters) {
        debug_assert!(
            (-8..=15).contains(&x_digit),
            "activation digit {x_digit} exceeds the 4-bit datapath"
        );
        ev.weight_reads += 1;
        ev.macs += 1;
        match TrimlaMode::decode(w) {
            TrimlaMode::Skip => {
                // EN low: accumulator clock-gated, no energy event.
                ev.skips += 1;
            }
            TrimlaMode::Add => {
                ev.accums += 1;
                self.accumulate(x_digit, ev);
            }
            TrimlaMode::Sub => {
                ev.accums += 1;
                self.accumulate(-x_digit, ev);
            }
        }
    }

    #[inline]
    fn accumulate(&mut self, delta: i32, ev: &mut EventCounters) {
        let max = (1i32 << (self.out_bits - 1)) - 1;
        let min = -(1i32 << (self.out_bits - 1));
        let next = self.acc + delta;
        if next > max || next < min {
            ev.saturations += 1;
            self.acc = next.clamp(min, max);
        } else {
            self.acc = next;
        }
    }

    /// The local partial sum handed to the adder tree.
    pub fn output(&self) -> i32 {
        self.acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn truth_table_exact() {
        assert_eq!(TrimlaMode::decode(0), TrimlaMode::Skip);
        assert_eq!(TrimlaMode::decode(1), TrimlaMode::Add);
        assert_eq!(TrimlaMode::decode(-1), TrimlaMode::Sub);
        assert_eq!(TrimlaMode::Skip.comparator_bits(), (false, false));
        assert_eq!(TrimlaMode::Add.comparator_bits(), (true, false));
        assert_eq!(TrimlaMode::Sub.comparator_bits(), (true, true));
    }

    #[test]
    fn accumulates_add_sub_skip() {
        let mut t = Trimla::new(8);
        let mut ev = EventCounters::new();
        t.step(1, 5, &mut ev); // +5
        t.step(-1, 3, &mut ev); // -3
        t.step(0, 7, &mut ev); // skip
        assert_eq!(t.output(), 2);
        assert_eq!(ev.accums, 2);
        assert_eq!(ev.skips, 1);
        assert_eq!(ev.macs, 3);
        assert_eq!(ev.saturations, 0);
    }

    #[test]
    fn eight_products_of_nibbles_never_saturate() {
        // The paper's claim: 8 columns per TriMLA, 4-bit digits →
        // worst case |Σ| = 8·15 = 120 < 127. Exhaustive worst cases:
        let mut ev = EventCounters::new();
        for digit in [15, -8] {
            let mut t = Trimla::new(8);
            for _ in 0..8 {
                t.step(1, digit, &mut ev);
            }
            assert_eq!(t.output(), 8 * digit);
        }
        for digit in [15, -8] {
            let mut t = Trimla::new(8);
            for _ in 0..8 {
                t.step(-1, digit, &mut ev);
            }
            assert_eq!(t.output(), -8 * digit);
        }
        assert_eq!(ev.saturations, 0);
    }

    #[test]
    fn saturation_detected_beyond_spec() {
        // 9+ max-magnitude products CAN overflow — the simulator must
        // detect it (this is exactly why the group size is 8).
        let mut t = Trimla::new(8);
        let mut ev = EventCounters::new();
        for _ in 0..9 {
            t.step(1, 15, &mut ev);
        }
        assert!(ev.saturations > 0);
        assert_eq!(t.output(), 127); // clamped
    }

    #[test]
    fn matches_plain_arithmetic_property() {
        check(0x7215, 200, |g| {
            let n = g.usize(1, 8);
            let mut t = Trimla::new(8);
            let mut ev = EventCounters::new();
            let mut expect = 0i32;
            for _ in 0..n {
                let w = g.trit(0.3);
                let x = g.rng.i64(-8, 15) as i32;
                t.step(w, x, &mut ev);
                expect += w as i32 * x;
            }
            prop_assert_eq!(t.output(), expect);
            prop_assert_eq!(ev.saturations, 0);
            prop_assert_eq!(ev.accums + ev.skips, n as u64);
            Ok(())
        });
    }

    #[test]
    fn reset_clears_state() {
        let mut t = Trimla::new(8);
        let mut ev = EventCounters::new();
        t.step(1, 7, &mut ev);
        t.reset();
        assert_eq!(t.output(), 0);
    }

    #[test]
    #[should_panic(expected = "non-ternary")]
    fn rejects_non_ternary_weight() {
        let mut t = Trimla::new(8);
        let mut ev = EventCounters::new();
        t.step(2, 1, &mut ev);
    }
}
