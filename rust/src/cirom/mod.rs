//! Bit-accurate, cycle-approximate simulators of the BitROM macro
//! circuits (paper §III-B, Fig 4).
//!
//! Microarchitecture reconstructed from the paper text:
//!
//! * The **BiROMA** array is 2048 rows × 1024 single-transistor cells;
//!   each cell stores TWO ternary weights (even-side + odd-side). One
//!   wordline = one *output channel*: its row holds up to 2048 input
//!   weights (1024 per side, read in two bidirectional passes).
//! * Each **TriMLA** serves a group of 8 adjacent columns via the column
//!   selector: per cycle it receives one prefetched ternary weight and
//!   the matching 4-bit activation digit, and — per the Fig 4 truth
//!   table — either skips (w = 0, EN gated by the MSB comparator), adds
//!   (w = +1) or subtracts (w = −1). Its local accumulator is 8-bit;
//!   with 8 products of 4-bit digits the worst case |Σ| ≤ 8·15 = 120,
//!   which is why the paper's "8-bit output width is sufficient" —
//!   the simulator *checks* this instead of assuming it.
//! * After the 8 column-select cycles (per side), the shared **adder
//!   tree** performs the single global summation over all 128 TriMLA
//!   partials ("local-then-global accumulation").
//! * 8-bit activations run **bit-serial**: low nibble pass then high
//!   nibble pass, recombined as 16·hi + lo.
//!
//! Every weight read, accumulate, skip and tree pass increments
//! [`EventCounters`]; the `energy` module turns those counts into
//! joules, which is where the TOPS/W numbers come from.

mod adder_tree;
mod bank;
mod biroma;
mod events;
mod macro_sim;
mod trimla;

pub use adder_tree::AdderTree;
pub use bank::MacroBank;
pub use biroma::{Biroma, Side};
pub use events::EventCounters;
pub use macro_sim::BitRomMacro;
pub use trimla::{Trimla, TrimlaMode};
