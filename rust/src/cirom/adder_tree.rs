//! The shared global adder tree (paper §III-B2).
//!
//! Prior digital CiROM gives each small cell group its own adder tree —
//! the dominant area cost. BitROM's local-then-global schedule lets ONE
//! tree serve the whole 2048×1024 array: it fires once per channel pass,
//! after all TriMLAs have finished their local accumulation. The
//! simulator models the reduction exactly (binary tree, wide enough to
//! be overflow-free by construction) and counts passes for the energy
//! model.

use super::events::EventCounters;

/// The shared global adder tree (one per macro; module docs).
#[derive(Debug, Clone)]
pub struct AdderTree {
    fan_in: usize,
}

impl AdderTree {
    /// Tree with the given (power-of-two) fan-in.
    pub fn new(fan_in: usize) -> Self {
        assert!(fan_in.is_power_of_two(), "tree fan-in must be 2^k");
        AdderTree { fan_in }
    }

    /// Inputs the tree reduces per pass.
    pub fn fan_in(&self) -> usize {
        self.fan_in
    }

    /// Tree depth in adder stages (= log2 fan-in).
    pub fn depth(&self) -> u32 {
        self.fan_in.trailing_zeros()
    }

    /// Output width needed for `in_bits`-wide inputs: one extra bit per
    /// stage. 128 × 8b → 15b, comfortably inside the 32-bit model.
    pub fn out_bits(&self, in_bits: u32) -> u32 {
        in_bits + self.depth()
    }

    /// One global accumulation pass over the TriMLA partials.
    /// Reduction order is the physical pairwise tree (exact in integer
    /// arithmetic regardless of order — asserted in tests).
    pub fn reduce(&self, partials: &[i32], ev: &mut EventCounters) -> i64 {
        assert_eq!(
            partials.len(),
            self.fan_in,
            "tree fed {} partials, fan-in {}",
            partials.len(),
            self.fan_in
        );
        ev.tree_passes += 1;
        let mut level: Vec<i64> = partials.iter().map(|&p| p as i64).collect();
        while level.len() > 1 {
            level = level.chunks(2).map(|c| c[0] + c[1]).collect();
        }
        level[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn reduces_exactly() {
        let t = AdderTree::new(8);
        let mut ev = EventCounters::new();
        let sum = t.reduce(&[1, -2, 3, -4, 5, -6, 7, -8], &mut ev);
        assert_eq!(sum, -4);
        assert_eq!(ev.tree_passes, 1);
    }

    #[test]
    fn matches_linear_sum_property() {
        check(0xADD, 200, |g| {
            let fan_in = 1usize << g.usize(0, 8);
            let t = AdderTree::new(fan_in);
            let partials: Vec<i32> = (0..fan_in)
                .map(|_| g.rng.i64(-128, 127) as i32)
                .collect();
            let mut ev = EventCounters::new();
            let got = t.reduce(&partials, &mut ev);
            let want: i64 = partials.iter().map(|&p| p as i64).sum();
            prop_assert_eq!(got, want);
            Ok(())
        });
    }

    #[test]
    fn depth_and_width() {
        let t = AdderTree::new(128);
        assert_eq!(t.depth(), 7);
        assert_eq!(t.out_bits(8), 15);
    }

    #[test]
    #[should_panic(expected = "fan-in")]
    fn wrong_partial_count_panics() {
        let t = AdderTree::new(4);
        let mut ev = EventCounters::new();
        t.reduce(&[1, 2, 3], &mut ev);
    }

    #[test]
    #[should_panic(expected = "2^k")]
    fn non_pow2_fan_in_rejected() {
        AdderTree::new(12);
    }
}
