//! The complete BitROM macro: BiROMA + 128 TriMLAs + shared adder tree
//! executing the local-then-global accumulation schedule (paper Fig 4).
//!
//! `gemv` is bit-exact against `bitnet::ref_gemv` (tested) while
//! counting every circuit event — the simulator is simultaneously the
//! functional model and the activity trace the energy model consumes.

use std::sync::OnceLock;

use crate::bitnet::{BitplaneMatrix, QuantizedActs, TernaryMatrix};
use crate::config::MacroGeometry;

use super::adder_tree::AdderTree;
use super::biroma::{Biroma, Side};
use super::events::EventCounters;
use super::trimla::Trimla;

/// Bit-accurate simulator of one BitROM macro: a BiROMA array, its
/// TriMLAs and the shared adder tree (paper Fig 3).
#[derive(Debug, Clone)]
pub struct BitRomMacro {
    geom: MacroGeometry,
    array: Biroma,
    tree: AdderTree,
    /// Bitplane twin of the programmed weights — the functional
    /// (non-event) compute path; bit-identical to the circuit model.
    /// Built lazily from the array on first use, so macros that only
    /// ever run the event path (e.g. `MacroBank` tiles, whose bank
    /// holds a full-matrix plane view of its own) never pay for it.
    planes: OnceLock<BitplaneMatrix>,
    /// Dimensions of the weight matrix programmed at fabrication.
    fan_in: usize,
    fan_out: usize,
    scale: f32,
}

impl BitRomMacro {
    /// "Fabricate" a macro holding `w` ([fan_in × fan_out], column = one
    /// output channel = one wordline row).
    pub fn fabricate(geom: MacroGeometry, w: &TernaryMatrix) -> Self {
        let m = Self::fabricate_view(geom, w.bitplanes(), w.scale);
        // seed the functional twin from the view we already have, so a
        // standalone macro's first gemv_functional() doesn't have to
        // reconstruct it from the array (bank tiles stay lazy)
        let _ = m.planes.set(w.bitplanes().clone());
        m
    }

    /// Fabricate straight from a bitplane view (the `MacroBank` tiling
    /// path — no intermediate packed matrix per tile).
    pub fn fabricate_view(geom: MacroGeometry, planes: &BitplaneMatrix, scale: f32) -> Self {
        assert!(
            planes.cols() <= geom.rows,
            "fan_out {} exceeds array rows {}",
            planes.cols(),
            geom.rows
        );
        assert!(
            planes.rows() <= 2 * geom.cols,
            "fan_in {} exceeds 2x array cols {}",
            planes.rows(),
            2 * geom.cols
        );
        let array = Biroma::fabricate_from_planes(geom.rows, geom.cols, planes);
        let tree = AdderTree::new(geom.n_trimla().next_power_of_two());
        BitRomMacro {
            fan_in: planes.rows(),
            fan_out: planes.cols(),
            scale,
            geom,
            array,
            tree,
            planes: OnceLock::new(),
        }
    }

    /// The lazily-built bitplane twin (reconstructed from the ROM
    /// array's blocked layout: logical input `i` of channel `ch` is
    /// `array.weight(ch, i)`).
    fn planes(&self) -> &BitplaneMatrix {
        self.planes.get_or_init(|| {
            let mut trits = vec![0i8; self.fan_in * self.fan_out];
            for ch in 0..self.fan_out {
                for i in 0..self.fan_in {
                    trits[i * self.fan_out + ch] = self.array.weight(ch, i);
                }
            }
            BitplaneMatrix::from_trits(self.fan_in, self.fan_out, &trits)
        })
    }

    /// Input features this macro accepts.
    pub fn fan_in(&self) -> usize {
        self.fan_in
    }

    /// Output channels this macro produces.
    pub fn fan_out(&self) -> usize {
        self.fan_out
    }

    /// Weight dequantization scale.
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Zero-weight fraction of the stored tile.
    pub fn sparsity(&self) -> f64 {
        self.array.sparsity()
    }

    /// Integer GEMV through the full circuit model.
    ///
    /// `acts.bits` selects the datapath mode: 4-bit runs each side in a
    /// single pass; 8-bit runs the two-cycle bit-serial schedule
    /// (low nibble then high digit, recombined as 16·hi + lo).
    pub fn gemv(&self, acts: &QuantizedActs, ev: &mut EventCounters) -> Vec<i64> {
        assert_eq!(acts.values.len(), self.fan_in, "gemv dim mismatch");
        assert!(
            acts.bits == 4 || acts.bits == 8,
            "TriMLA supports 4b/8b activations, got {}b",
            acts.bits
        );
        let mut out = Vec::with_capacity(self.fan_out);
        match acts.bits {
            4 => {
                for row in 0..self.fan_out {
                    out.push(self.channel_pass(row, &acts.values, ev));
                }
            }
            _ => {
                // bit-serial: x = 16*hi + lo
                let digits = acts.bit_serial_digits();
                let lo: Vec<i32> = digits.iter().map(|d| d.1).collect();
                let hi: Vec<i32> = digits.iter().map(|d| d.0).collect();
                for row in 0..self.fan_out {
                    let lo_sum = self.channel_pass(row, &lo, ev);
                    let cyc_before = ev.mac_cycles;
                    let hi_sum = self.channel_pass(row, &hi, ev);
                    ev.bitserial_cycles += ev.mac_cycles - cyc_before;
                    // shift-and-accumulate in the (wide) output register
                    out.push(16 * hi_sum + lo_sum);
                }
            }
        }
        out
    }

    /// Dequantized GEMV (applies activation + weight scales).
    pub fn gemv_f32(&self, acts: &QuantizedActs, ev: &mut EventCounters) -> Vec<f32> {
        self.gemv(acts, ev)
            .into_iter()
            .map(|v| v as f32 * acts.scale * self.scale)
            .collect()
    }

    /// Functional (non-event) GEMV on the word-parallel bitplane twin:
    /// the same integers [`Self::gemv`] produces (tested), for callers
    /// that need the macro's *result* but not its activity trace —
    /// orders of magnitude faster than stepping every TriMLA.
    pub fn gemv_functional(&self, acts: &QuantizedActs) -> Vec<i64> {
        assert_eq!(acts.values.len(), self.fan_in, "gemv dim mismatch");
        self.planes().gemv(&acts.values)
    }

    /// Batched functional GEMM on the bitplane twin.
    pub fn gemm_functional<X: AsRef<[i32]> + Sync>(&self, batch: &[X]) -> Vec<Vec<i64>> {
        self.planes().gemm(batch)
    }

    /// One full local-then-global pass for one output channel with
    /// single-digit activations: for each populated side, 8
    /// column-select cycles of parallel TriMLA accumulation, then ONE
    /// adder-tree pass; sides accumulate into the (wide) channel
    /// register.
    fn channel_pass(&self, row: usize, x: &[i32], ev: &mut EventCounters) -> i64 {
        let n_tr = self.geom.n_trimla();
        let cpt = self.geom.cols_per_trimla;
        let mut channel_total = 0i64;

        for (side_idx, side) in [Side::Even, Side::Odd].into_iter().enumerate() {
            let base = side_idx * self.geom.cols;
            if base >= self.fan_in {
                // side holds no weights for this matrix: the voltage
                // supply control never precharges it — zero cycles.
                continue;
            }
            let mut trimlas: Vec<Trimla> =
                (0..n_tr).map(|_| Trimla::new(self.geom.trimla_out_bits)).collect();

            // 8 column-select cycles; all TriMLAs step in parallel.
            for c in 0..cpt {
                ev.mac_cycles += 1;
                for (j, t) in trimlas.iter_mut().enumerate() {
                    let input = base + j * cpt + c;
                    if input >= self.fan_in {
                        continue; // column group beyond fan_in: gated off
                    }
                    let w = self.array.read(row, j * cpt + c, side);
                    t.step(w, x[input], ev);
                }
            }

            // one-shot global accumulation over all TriMLA partials
            let mut partials: Vec<i32> = trimlas.iter().map(|t| t.output()).collect();
            partials.resize(self.tree.fan_in(), 0);
            channel_total += self.tree.reduce(&partials, ev);
        }
        channel_total
    }

    /// Array cycles needed for one full GEMV (throughput model).
    pub fn cycles_per_gemv(&self, act_bits: usize) -> u64 {
        let sides = if self.fan_in > self.geom.cols { 2 } else { 1 };
        let serial = if act_bits == 8 { 2 } else { 1 };
        (self.fan_out * sides * self.geom.cols_per_trimla * serial) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitnet::{absmax_quantize, ref_gemv};
    use crate::util::check::check;
    use crate::util::rng::Rng;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    fn small_geom() -> MacroGeometry {
        MacroGeometry {
            rows: 32,
            cols: 16,
            cols_per_trimla: 8,
            ..Default::default()
        }
    }

    fn random_acts(rng: &mut Rng, n: usize, bits: usize) -> QuantizedActs {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        absmax_quantize(&x, bits)
    }

    #[test]
    fn gemv_matches_golden_reference_4bit() {
        check(0x6433, 60, |g| {
            let geom = small_geom();
            let fan_in = g.usize(1, 2 * geom.cols);
            let fan_out = g.usize(1, geom.rows);
            let trits = g.vec_trits(fan_in * fan_out, 0.3);
            let w = TernaryMatrix::from_trits(fan_in, fan_out, &trits, 1.0);
            let m = BitRomMacro::fabricate(geom, &w);
            let acts = random_acts(&mut g.rng, fan_in, 4);
            let mut ev = EventCounters::new();
            let got = m.gemv(&acts, &mut ev);
            let want = ref_gemv(&acts.values, &w);
            prop_assert_eq!(got, want);
            prop_assert_eq!(ev.saturations, 0);
            Ok(())
        });
    }

    #[test]
    fn gemv_matches_golden_reference_8bit_bitserial() {
        check(0x6488, 60, |g| {
            let geom = small_geom();
            let fan_in = g.usize(1, 2 * geom.cols);
            let fan_out = g.usize(1, geom.rows);
            let trits = g.vec_trits(fan_in * fan_out, 0.3);
            let w = TernaryMatrix::from_trits(fan_in, fan_out, &trits, 1.0);
            let m = BitRomMacro::fabricate(geom, &w);
            let acts = random_acts(&mut g.rng, fan_in, 8);
            let mut ev = EventCounters::new();
            let got = m.gemv(&acts, &mut ev);
            let want = ref_gemv(&acts.values, &w);
            prop_assert_eq!(got, want);
            prop_assert_eq!(ev.saturations, 0);
            Ok(())
        });
    }

    #[test]
    fn functional_path_matches_event_path_property() {
        check(0x64FA, 60, |g| {
            let geom = small_geom();
            let fan_in = g.usize(1, 2 * geom.cols);
            let fan_out = g.usize(1, geom.rows);
            let trits = g.vec_trits(fan_in * fan_out, g.f64());
            let w = TernaryMatrix::from_trits(fan_in, fan_out, &trits, 1.0);
            let m = BitRomMacro::fabricate(geom, &w);
            let bits = if g.rng.bool(0.5) { 4 } else { 8 };
            let acts = random_acts(&mut g.rng, fan_in, bits);
            let mut ev = EventCounters::new();
            let via_circuit = m.gemv(&acts, &mut ev);
            prop_assert_eq!(m.gemv_functional(&acts), via_circuit);
            prop_assert_eq!(
                m.gemm_functional(&[acts.values.clone()]),
                vec![ref_gemv(&acts.values, &w)]
            );
            Ok(())
        });
    }

    #[test]
    fn full_size_macro_single_channel() {
        // default 2048×1024 geometry, one output channel, fan_in 2048
        let geom = MacroGeometry::default();
        let mut rng = Rng::new(42);
        let w = TernaryMatrix::random(2048, 1, 0.3, &mut rng);
        let m = BitRomMacro::fabricate(geom, &w);
        let acts = random_acts(&mut rng, 2048, 8);
        let mut ev = EventCounters::new();
        let got = m.gemv(&acts, &mut ev);
        assert_eq!(got, ref_gemv(&acts.values, &w));
        assert_eq!(ev.saturations, 0);
        // 2 sides × 8 col-selects × 2 serial passes = 32 cycles
        assert_eq!(ev.mac_cycles, 32);
        assert_eq!(ev.bitserial_cycles, 16);
        // 2048 weights read twice (lo + hi pass)
        assert_eq!(ev.weight_reads, 4096);
        assert_eq!(ev.tree_passes, 4);
    }

    #[test]
    fn sparsity_shows_up_as_skips() {
        let geom = small_geom();
        let mut rng = Rng::new(7);
        let w = TernaryMatrix::random(32, 32, 0.5, &mut rng);
        let m = BitRomMacro::fabricate(geom, &w);
        let acts = random_acts(&mut rng, 32, 4);
        let mut ev = EventCounters::new();
        m.gemv(&acts, &mut ev);
        let rate = ev.skip_rate();
        assert!((rate - 0.5).abs() < 0.12, "skip rate {rate}");
        // dense weights → zero skips
        let wd = TernaryMatrix::from_trits(4, 4, &[1; 16], 1.0);
        let md = BitRomMacro::fabricate(small_geom(), &wd);
        let mut evd = EventCounters::new();
        md.gemv(&random_acts(&mut rng, 4, 4), &mut evd);
        assert_eq!(evd.skips, 0);
    }

    #[test]
    fn small_fan_in_uses_single_side() {
        let geom = small_geom(); // cols = 16
        let mut rng = Rng::new(9);
        let w = TernaryMatrix::random(16, 8, 0.3, &mut rng); // fits even side
        let m = BitRomMacro::fabricate(geom, &w);
        let acts = random_acts(&mut rng, 16, 4);
        let mut ev = EventCounters::new();
        m.gemv(&acts, &mut ev);
        // 8 channels × 1 side × 8 col-selects
        assert_eq!(ev.mac_cycles, 64);
        assert_eq!(ev.tree_passes, 8); // one per channel, single side
        assert_eq!(m.cycles_per_gemv(4), 64);
    }

    #[test]
    fn dequantized_gemv_applies_scales() {
        let geom = small_geom();
        let w = TernaryMatrix::from_trits(2, 1, &[1, 1], 0.5);
        let m = BitRomMacro::fabricate(geom, &w);
        let acts = QuantizedActs {
            values: vec![3, 4],
            scale: 2.0,
            bits: 4,
        };
        let mut ev = EventCounters::new();
        let y = m.gemv_f32(&acts, &mut ev);
        assert_eq!(y, vec![7.0 * 2.0 * 0.5]);
    }

    #[test]
    fn cycles_model_matches_simulation() {
        let geom = small_geom();
        let mut rng = Rng::new(11);
        for (fan_in, bits) in [(16, 4), (32, 4), (16, 8), (32, 8)] {
            let w = TernaryMatrix::random(fan_in, 8, 0.3, &mut rng);
            let m = BitRomMacro::fabricate(geom.clone(), &w);
            let acts = random_acts(&mut rng, fan_in, bits);
            let mut ev = EventCounters::new();
            m.gemv(&acts, &mut ev);
            assert_eq!(
                ev.mac_cycles,
                m.cycles_per_gemv(bits),
                "fan_in {fan_in} bits {bits}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "fan_out")]
    fn oversize_matrix_rejected() {
        let geom = small_geom();
        let w = TernaryMatrix::from_trits(1, 33, &[0; 33], 1.0);
        BitRomMacro::fabricate(geom, &w);
    }
}
