//! MacroBank — shards an arbitrary-size ternary weight matrix across
//! multiple BitROM macros (the paper maps Falcon3-1B onto ~340 macros
//! grouped into 6 partitions; this is the intra-partition tiling).
//!
//! fan_out tiles map to macro wordline rows (≤ `geom.rows` channels per
//! macro); fan_in tiles map to the two signal-line sides (≤ 2·cols per
//! macro). Partial sums across fan_in tiles accumulate in the wide
//! output registers (exact integer arithmetic).

use std::sync::Arc;

use crate::bitnet::{BitplaneMatrix, QuantizedActs, TernaryMatrix};
use crate::config::MacroGeometry;

use super::events::EventCounters;
use super::macro_sim::BitRomMacro;

/// A weight matrix tiled across BitROM macros (the multi-macro
/// compute unit one projection maps onto).
#[derive(Debug, Clone)]
pub struct MacroBank {
    geom: MacroGeometry,
    /// Tiles indexed `[fan_in_tile][fan_out_tile]`.
    tiles: Vec<Vec<BitRomMacro>>,
    /// Bitplane view of the FULL weight matrix — the functional
    /// (non-event) compute path, bit-identical to tiling + accumulating
    /// through every macro (tested). Shared with the source
    /// `TernaryMatrix`'s cache, not copied.
    planes: Arc<BitplaneMatrix>,
    fan_in: usize,
    fan_out: usize,
    scale: f32,
}

impl MacroBank {
    /// Tile `w` into macros of the given geometry.
    pub fn fabricate(geom: MacroGeometry, w: &TernaryMatrix) -> Self {
        let planes = w.bitplanes_arc();
        let in_tile = 2 * geom.cols;
        let out_tile = geom.rows;
        let n_in = (w.rows + in_tile - 1) / in_tile;
        let n_out = (w.cols + out_tile - 1) / out_tile;
        let mut tiles = Vec::with_capacity(n_in);
        for ti in 0..n_in {
            let r0 = ti * in_tile;
            let r1 = (r0 + in_tile).min(w.rows);
            let mut row_tiles = Vec::with_capacity(n_out);
            for tj in 0..n_out {
                let c0 = tj * out_tile;
                let c1 = (c0 + out_tile).min(w.cols);
                // tile extraction is plane-to-plane (word-wise bit
                // tests) — no per-trit base-3 decode, no intermediate
                // packed matrix
                let sub = planes.submatrix(r0, r1, c0, c1);
                row_tiles.push(BitRomMacro::fabricate_view(geom.clone(), &sub, w.scale));
            }
            tiles.push(row_tiles);
        }
        MacroBank {
            geom,
            tiles,
            planes,
            fan_in: w.rows,
            fan_out: w.cols,
            scale: w.scale,
        }
    }

    /// Macros in the bank.
    pub fn n_macros(&self) -> usize {
        self.tiles.iter().map(|r| r.len()).sum()
    }

    /// Input features of the tiled matrix.
    pub fn fan_in(&self) -> usize {
        self.fan_in
    }

    /// Output features of the tiled matrix.
    pub fn fan_out(&self) -> usize {
        self.fan_out
    }

    /// Full integer GEMV across all tiles.
    pub fn gemv(&self, acts: &QuantizedActs, ev: &mut EventCounters) -> Vec<i64> {
        assert_eq!(acts.values.len(), self.fan_in, "bank gemv dim mismatch");
        let in_tile = 2 * self.geom.cols;
        let mut y = vec![0i64; self.fan_out];
        for (ti, row_tiles) in self.tiles.iter().enumerate() {
            let r0 = ti * in_tile;
            let r1 = (r0 + in_tile).min(self.fan_in);
            let sub_acts = QuantizedActs {
                values: acts.values[r0..r1].to_vec(),
                scale: acts.scale,
                bits: acts.bits,
            };
            let mut col0 = 0;
            for m in row_tiles {
                let part = m.gemv(&sub_acts, ev);
                for (i, v) in part.into_iter().enumerate() {
                    y[col0 + i] += v;
                }
                col0 += m.fan_out();
            }
        }
        y
    }

    /// [`Self::gemv`] with the activation/weight scales applied.
    pub fn gemv_f32(&self, acts: &QuantizedActs, ev: &mut EventCounters) -> Vec<f32> {
        self.gemv(acts, ev)
            .into_iter()
            .map(|v| v as f32 * acts.scale * self.scale)
            .collect()
    }

    /// Functional (non-event) GEMV across the whole bank on the
    /// word-parallel bitplane view — same integers as [`Self::gemv`]
    /// without instantiating per-tile circuit activity.
    pub fn gemv_functional(&self, acts: &QuantizedActs) -> Vec<i64> {
        assert_eq!(acts.values.len(), self.fan_in, "bank gemv dim mismatch");
        self.planes.gemv(&acts.values)
    }

    /// Batched functional GEMM across the whole bank.
    pub fn gemm_functional<X: AsRef<[i32]> + Sync>(&self, batch: &[X]) -> Vec<Vec<i64>> {
        self.planes.gemm(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitnet::{absmax_quantize, ref_gemv};
    use crate::util::check::check;
    use crate::util::rng::Rng;
    use crate::{prop_assert, prop_assert_eq};

    fn small_geom() -> MacroGeometry {
        MacroGeometry {
            rows: 16,
            cols: 8,
            cols_per_trimla: 8,
            ..Default::default()
        }
    }

    #[test]
    fn bank_matches_reference_across_tilings() {
        check(0xBA2C, 40, |g| {
            let geom = small_geom();
            // force multi-tile shapes: up to 4 tiles each way
            let fan_in = g.usize(1, 4 * 2 * geom.cols);
            let fan_out = g.usize(1, 4 * geom.rows);
            let trits = g.vec_trits(fan_in * fan_out, 0.3);
            let w = TernaryMatrix::from_trits(fan_in, fan_out, &trits, 1.0);
            let bank = MacroBank::fabricate(geom, &w);
            let x: Vec<f32> = g.vec_f32(fan_in);
            let acts = absmax_quantize(&x, if g.rng.bool(0.5) { 4 } else { 8 });
            let mut ev = EventCounters::new();
            let got = bank.gemv(&acts, &mut ev);
            prop_assert_eq!(bank.gemv_functional(&acts), got.clone());
            prop_assert_eq!(got, ref_gemv(&acts.values, &w));
            prop_assert_eq!(ev.saturations, 0);
            Ok(())
        });
    }

    #[test]
    fn functional_gemm_matches_per_row_reference() {
        let geom = small_geom();
        let mut rng = Rng::new(13);
        let w = TernaryMatrix::random(40, 21, 0.35, &mut rng);
        let bank = MacroBank::fabricate(geom, &w);
        let batch: Vec<Vec<i32>> = (0..4)
            .map(|_| (0..40).map(|_| rng.i64(-127, 127) as i32).collect())
            .collect();
        let got = bank.gemm_functional(&batch);
        for (x, y) in batch.iter().zip(&got) {
            assert_eq!(y, &ref_gemv(x, &w));
        }
    }

    #[test]
    fn tile_count_matches_geometry() {
        let geom = small_geom(); // 16 out × 16 in per macro
        let mut rng = Rng::new(1);
        let w = TernaryMatrix::random(33, 17, 0.3, &mut rng);
        let bank = MacroBank::fabricate(geom, &w);
        // fan_in 33 → 3 in-tiles (16 each); fan_out 17 → 2 out-tiles
        assert_eq!(bank.n_macros(), 6);
    }

    #[test]
    fn scales_applied_in_f32_path() {
        let geom = small_geom();
        let w = TernaryMatrix::from_trits(1, 1, &[-1], 0.25);
        let bank = MacroBank::fabricate(geom, &w);
        let acts = QuantizedActs {
            values: vec![8],
            scale: 0.5,
            bits: 4,
        };
        let mut ev = EventCounters::new();
        assert_eq!(bank.gemv_f32(&acts, &mut ev), vec![-8.0 * 0.5 * 0.25]);
    }
}
