//! External DRAM traffic model — the baseline the paper's 43.6%
//! reduction claim is measured against.
//!
//! The model is a counter set with per-access energy/latency constants
//! (LPDDR-class, documented in DESIGN.md §5); the KV-cache manager
//! routes accesses here or to the DR eDRAM and the ratio of the two is
//! the Fig 5(b) result.

/// LPDDR-class external memory parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct DramParams {
    /// Read energy per byte (pJ).
    pub read_pj_per_byte: f64,
    /// Write energy per byte (pJ).
    pub write_pj_per_byte: f64,
    /// Access latency (ns).
    pub latency_ns: f64,
    /// Peak interface bandwidth (GB/s).
    pub bandwidth_gb_s: f64,
}

impl Default for DramParams {
    fn default() -> Self {
        DramParams {
            // ~6 pJ/bit LPDDR4-class interface + array
            read_pj_per_byte: 48.0,
            write_pj_per_byte: 52.0,
            latency_ns: 100.0,
            bandwidth_gb_s: 8.5,
        }
    }
}

/// Access counters for one simulation run.
#[derive(Debug, Clone, Default)]
pub struct ExternalDram {
    /// Interface parameters.
    pub params: DramParams,
    /// Read transactions issued.
    pub reads: u64,
    /// Write transactions issued.
    pub writes: u64,
    /// Bytes read.
    pub read_bytes: u64,
    /// Bytes written.
    pub write_bytes: u64,
}

impl ExternalDram {
    /// Zeroed counters over `params`.
    pub fn new(params: DramParams) -> Self {
        ExternalDram {
            params,
            reads: 0,
            writes: 0,
            read_bytes: 0,
            write_bytes: 0,
        }
    }

    /// Count one read of `bytes`.
    pub fn read(&mut self, bytes: u64) {
        self.reads += 1;
        self.read_bytes += bytes;
    }

    /// Count one write of `bytes`.
    pub fn write(&mut self, bytes: u64) {
        self.writes += 1;
        self.write_bytes += bytes;
    }

    /// Total transactions.
    pub fn accesses(&self) -> u64 {
        self.reads + self.writes
    }

    /// Total bytes moved.
    pub fn total_bytes(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    /// Interface energy spent so far (J).
    pub fn energy_j(&self) -> f64 {
        (self.read_bytes as f64 * self.params.read_pj_per_byte
            + self.write_bytes as f64 * self.params.write_pj_per_byte)
            * 1e-12
    }

    /// Transfer time at the configured bandwidth (s).
    pub fn transfer_time_s(&self) -> f64 {
        self.total_bytes() as f64 / (self.params.bandwidth_gb_s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut d = ExternalDram::new(DramParams::default());
        d.read(64);
        d.read(64);
        d.write(128);
        assert_eq!(d.accesses(), 3);
        assert_eq!(d.total_bytes(), 256);
    }

    #[test]
    fn energy_scales_with_bytes() {
        let mut d = ExternalDram::new(DramParams::default());
        d.read(1000);
        let e1 = d.energy_j();
        d.read(1000);
        assert!((d.energy_j() - 2.0 * e1).abs() < 1e-18);
        assert!(e1 > 0.0);
    }

    #[test]
    fn transfer_time_uses_bandwidth() {
        let mut d = ExternalDram::new(DramParams {
            bandwidth_gb_s: 1.0,
            ..DramParams::default()
        });
        d.write(1_000_000_000);
        assert!((d.transfer_time_s() - 1.0).abs() < 1e-9);
    }
}
