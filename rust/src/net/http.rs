//! Minimal HTTP/1.1 framing over generic `Read`/`Write` (DESIGN.md §14).
//!
//! Just enough of the protocol for the serving plane: one request per
//! connection (`Connection: close`), `Content-Length` bodies on the way
//! in, fixed or chunked (`Transfer-Encoding: chunked`) bodies on the
//! way out. Being generic over the transport keeps every parsing and
//! framing path unit-testable without sockets; `net::server` plugs in
//! `TcpStream`, the tests plug in cursors and vectors.
//!
//! Streaming responses flush after every chunk: a token frame must hit
//! the wire the moment the decode round produces it, not when a buffer
//! happens to fill.

use std::io::{Read, Write};

use anyhow::{Context, Result};

/// Cap on the request head (request line + headers) in bytes.
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// One parsed HTTP request.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpRequest {
    /// Request method (`GET`, `POST`, ...), as sent.
    pub method: String,
    /// Request target (path plus any query string), as sent.
    pub path: String,
    /// Header `(name, value)` pairs in wire order, values trimmed.
    pub headers: Vec<(String, String)>,
    /// Request body (`Content-Length` bytes; empty when absent).
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header value matching `name`, case-insensitively.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Offset of the byte *after* the `\r\n\r\n` head terminator, if any.
fn head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Read one request from `r`. Returns `Ok(None)` on a clean EOF before
/// any bytes arrive (peer closed an idle connection); errors on a
/// truncated or malformed request, or a body larger than
/// `max_body_bytes`.
pub fn read_request<R: Read>(r: &mut R, max_body_bytes: usize) -> Result<Option<HttpRequest>> {
    let mut buf = Vec::new();
    let mut scratch = [0u8; 4096];
    let split = loop {
        if let Some(end) = head_end(&buf) {
            break end;
        }
        anyhow::ensure!(buf.len() <= MAX_HEAD_BYTES, "http: request head exceeds cap");
        let n = r.read(&mut scratch).context("http: read")?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            anyhow::bail!("http: connection closed mid-request-head");
        }
        buf.extend_from_slice(&scratch[..n]);
    };
    let head = std::str::from_utf8(&buf[..split - 4]).context("http: non-UTF-8 request head")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if !m.is_empty() && !p.is_empty() => {
            (m.to_string(), p.to_string(), v)
        }
        _ => anyhow::bail!("http: malformed request line {request_line:?}"),
    };
    anyhow::ensure!(
        version.starts_with("HTTP/1."),
        "http: unsupported protocol version {version:?}"
    );
    let mut headers = Vec::new();
    for line in lines {
        let (name, value) = line
            .split_once(':')
            .with_context(|| format!("http: malformed header line {line:?}"))?;
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }
    let req = HttpRequest {
        method,
        path,
        headers,
        body: Vec::new(),
    };
    let body_len = match req.header("content-length") {
        Some(v) => v
            .parse::<usize>()
            .with_context(|| format!("http: bad Content-Length {v:?}"))?,
        None => 0,
    };
    anyhow::ensure!(
        body_len <= max_body_bytes,
        "http: body of {body_len} bytes exceeds the {max_body_bytes}-byte cap"
    );
    let mut body = buf.split_off(split);
    anyhow::ensure!(body.len() <= body_len, "http: more body bytes than Content-Length");
    while body.len() < body_len {
        let n = r.read(&mut scratch).context("http: read body")?;
        anyhow::ensure!(n > 0, "http: connection closed mid-body");
        body.extend_from_slice(&scratch[..n]);
        anyhow::ensure!(body.len() <= body_len, "http: more body bytes than Content-Length");
    }
    Ok(Some(HttpRequest { body, ..req }))
}

/// Reason phrase for the status codes the serving plane emits.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response (always `Connection: close`).
/// `extra_headers` lets callers attach e.g. `Retry-After` on a 429.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> Result<()> {
    write!(w, "HTTP/1.1 {status} {}\r\n", reason(status))?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    for (name, value) in extra_headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"Connection: close\r\n\r\n")?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Chunked-transfer streaming response writer. Each [`chunk`] is one
/// `Transfer-Encoding: chunked` frame, flushed immediately so tokens
/// reach the client as they decode; [`finish`] writes the terminal
/// zero-length chunk.
///
/// [`chunk`]: ChunkedWriter::chunk
/// [`finish`]: ChunkedWriter::finish
#[derive(Debug)]
pub struct ChunkedWriter<W: Write> {
    w: W,
}

impl<W: Write> ChunkedWriter<W> {
    /// Write the response head and return the chunk writer.
    pub fn start(mut w: W, status: u16, content_type: &str) -> Result<Self> {
        write!(w, "HTTP/1.1 {status} {}\r\n", reason(status))?;
        write!(w, "Content-Type: {content_type}\r\n")?;
        w.write_all(b"Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n")?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    /// Emit one chunk and flush it to the transport.
    pub fn chunk(&mut self, data: &[u8]) -> Result<()> {
        if data.is_empty() {
            return Ok(()); // a zero-length chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()?;
        Ok(())
    }

    /// Terminate the stream with the zero-length chunk.
    pub fn finish(mut self) -> Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()?;
        Ok(())
    }
}

/// Decode a complete chunked-transfer body back into its byte stream
/// (test/client helper — the inverse of [`ChunkedWriter`]).
pub fn decode_chunked(mut body: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    loop {
        let line_end = body
            .windows(2)
            .position(|w| w == b"\r\n")
            .context("http: chunk stream truncated before a size line")?;
        let size_line =
            std::str::from_utf8(&body[..line_end]).context("http: non-UTF-8 chunk size")?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .with_context(|| format!("http: bad chunk size {size_line:?}"))?;
        body = &body[line_end + 2..];
        if size == 0 {
            return Ok(out);
        }
        anyhow::ensure!(body.len() >= size + 2, "http: chunk stream truncated mid-chunk");
        out.extend_from_slice(&body[..size]);
        anyhow::ensure!(&body[size..size + 2] == b"\r\n", "http: chunk missing terminator");
        body = &body[size + 2..];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reader that yields its input a few bytes at a time, exercising
    /// the split-across-reads paths the way a real socket would.
    struct Trickle<'a> {
        data: &'a [u8],
        at: usize,
        step: usize,
    }

    impl Read for Trickle<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.step.min(self.data.len() - self.at).min(buf.len());
            buf[..n].copy_from_slice(&self.data[self.at..self.at + n]);
            self.at += n;
            Ok(n)
        }
    }

    #[test]
    fn parses_a_post_with_body_split_across_reads() {
        let wire =
            b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\nhello world";
        for step in [1, 3, 7, wire.len()] {
            let mut r = Trickle { data: wire, at: 0, step };
            let req = read_request(&mut r, 1024).unwrap().unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/v1/completions");
            assert_eq!(req.body, b"hello world");
        }
    }

    #[test]
    fn header_lookup_is_case_insensitive() {
        let wire = b"GET /healthz HTTP/1.1\r\nX-Tenant-Id: 3\r\n\r\n";
        let mut r = Trickle { data: wire, at: 0, step: 64 };
        let req = read_request(&mut r, 0).unwrap().unwrap();
        assert_eq!(req.header("x-tenant-id"), Some("3"));
        assert_eq!(req.header("X-TENANT-ID"), Some("3"));
        assert_eq!(req.header("absent"), None);
        assert!(req.body.is_empty());
    }

    #[test]
    fn clean_eof_before_any_bytes_is_none() {
        let mut r = Trickle { data: b"", at: 0, step: 64 };
        assert!(read_request(&mut r, 0).unwrap().is_none());
    }

    #[test]
    fn truncated_head_and_body_are_errors() {
        let mut r = Trickle { data: b"GET / HTTP", at: 0, step: 64 };
        assert!(read_request(&mut r, 0).is_err());
        let wire = b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort";
        let mut r = Trickle { data: wire, at: 0, step: 64 };
        assert!(read_request(&mut r, 1024).is_err());
    }

    #[test]
    fn oversized_bodies_are_rejected_up_front() {
        let wire = b"POST / HTTP/1.1\r\nContent-Length: 1000000\r\n\r\n";
        let mut r = Trickle { data: wire, at: 0, step: 64 };
        let e = read_request(&mut r, 1024).unwrap_err().to_string();
        assert!(e.contains("cap"), "{e}");
    }

    #[test]
    fn malformed_request_lines_are_errors() {
        for wire in [&b"GARBAGE\r\n\r\n"[..], b"GET /\r\n\r\n", b"GET / SPDY/3\r\n\r\n"] {
            let mut r = Trickle { data: wire, at: 0, step: 64 };
            assert!(read_request(&mut r, 0).is_err(), "{wire:?}");
        }
    }

    #[test]
    fn fixed_responses_carry_length_and_extra_headers() {
        let mut out = Vec::new();
        write_response(
            &mut out,
            429,
            "application/json",
            &[("Retry-After", "2".to_string())],
            b"{\"error\":\"rate-limit\"}",
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Content-Length: 22\r\n"));
        assert!(text.contains("Retry-After: 2\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"error\":\"rate-limit\"}"));
    }

    #[test]
    fn chunked_stream_round_trips() {
        let mut out = Vec::new();
        let mut w = ChunkedWriter::start(&mut out, 200, "application/x-ndjson").unwrap();
        w.chunk(b"{\"token\":1}\n").unwrap();
        w.chunk(b"").unwrap(); // ignored, must not terminate
        w.chunk(b"{\"token\":2}\n").unwrap();
        w.finish().unwrap();
        let text = String::from_utf8(out.clone()).unwrap();
        let head_end = text.find("\r\n\r\n").unwrap() + 4;
        assert!(text.contains("Transfer-Encoding: chunked\r\n"));
        let body = decode_chunked(&out[head_end..]).unwrap();
        assert_eq!(body, b"{\"token\":1}\n{\"token\":2}\n");
    }

    #[test]
    fn chunk_decoder_rejects_truncation() {
        assert!(decode_chunked(b"c\r\n{\"token\":1}\n").is_err());
        assert!(decode_chunked(b"zz\r\n").is_err());
    }
}
