//! The HTTP/1.1 front door of the streaming serving plane (DESIGN.md
//! §14): `std::net::TcpListener` + worker threads, no async runtime.
//!
//! Three endpoints:
//! * `POST /v1/completions` — submit one request (the
//!   [`crate::trace::Request`] wire object) and stream its tokens back
//!   incrementally, NDJSON by default or SSE via `?format=sse` /
//!   `Accept: text/event-stream`. Every frame is flushed the round the
//!   coordinator decodes it.
//! * `GET /healthz` — liveness (`200 ok`, `503 draining` once
//!   shutdown begins).
//! * `GET /metrics` — Prometheus text exposition of the live
//!   [`ServeMetrics`] snapshot, fault/shed counters included.
//!
//! One OS thread per connection, one request per connection
//! (`Connection: close`): serving-plane concurrency is bounded by the
//! *coordinator's* slots and the ingress queue, not by connection
//! count, so the plain threaded model is the simplest thing that is
//! honest about where the real backpressure lives. Admission policy
//! (per-tenant FIFO, token buckets, queue depth, prompt caps) is all
//! [`Ingress`]; transport limits (body size, read timeout) come from
//! [`NetConfig`].

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{NetConfig, ServeConfig};
use crate::coordinator::{
    CompletedRequest, FailReason, Ingress, Reject, ServeMetrics, Server, TokenSink,
};
use crate::runtime::InferenceBackend;
use crate::trace::Request;
use crate::util::json::Json;

use super::http::{read_request, write_response, ChunkedWriter, HttpRequest};
use super::jsonframe::{EventEncoder, StreamFormat};

/// First id assigned to submissions that carry none — far above any
/// trace id, so replayed traces (which carry their own ids for
/// invariant-10 twin comparisons) never collide with anonymous ones.
const ANON_ID_BASE: u64 = 1 << 32;

/// What one decode event becomes on its way from the coordinator's
/// [`TokenSink`] call to the connection thread that owns the socket.
enum SinkEvent {
    /// One streamed token.
    Token {
        /// Request id.
        id: u64,
        /// Token id.
        tok: i32,
    },
    /// The sequence completed.
    Done(CompletedRequest),
    /// The sequence was shed with a typed reason.
    Shed {
        /// Request id.
        id: u64,
        /// Why it was shed.
        reason: FailReason,
    },
}

/// [`TokenSink`] bridging the coordinator to a connection thread over
/// an mpsc channel. The *channel* is the liveness signal: when the
/// connection thread hits a dead socket it drops its receiver, the
/// next `on_token` send fails, and the coordinator sheds the sequence
/// as [`FailReason::Disconnect`].
struct HttpSink {
    tx: mpsc::Sender<SinkEvent>,
}

impl TokenSink for HttpSink {
    fn on_token(&mut self, id: u64, tok: i32) -> bool {
        self.tx.send(SinkEvent::Token { id, tok }).is_ok()
    }

    fn on_complete(&mut self, done: &CompletedRequest) {
        let _ = self.tx.send(SinkEvent::Done(done.clone()));
    }

    fn on_shed(&mut self, id: u64, reason: FailReason) {
        let _ = self.tx.send(SinkEvent::Shed { id, reason });
    }
}

/// State shared by every connection thread.
struct Shared {
    ingress: Arc<Ingress>,
    metrics: Arc<Mutex<ServeMetrics>>,
    /// The serving wall clock's epoch: submissions are stamped with
    /// seconds since here (the same clock feeds the rate buckets).
    epoch: Instant,
    next_anon_id: AtomicU64,
    net: NetConfig,
}

/// The online serving front door. [`NetServer::start`] spawns the
/// coordinator and accept threads and returns a [`NetHandle`]; the
/// server then runs until [`NetHandle::shutdown`].
pub struct NetServer;

impl NetServer {
    /// Bind `net.listen`, start the coordinator loop on `backend`, and
    /// begin accepting connections. Fails synchronously on a bad
    /// config or an unbindable address; after that every failure is
    /// per-connection.
    pub fn start<B>(backend: B, serve: ServeConfig, net: NetConfig) -> Result<NetHandle>
    where
        B: InferenceBackend + Send + Sync + 'static,
        B::State: Send,
        B::Hidden: Send,
    {
        net.validate()?;
        let mut server = Server::new(backend, serve.clone())?;
        // oversized prompts are rejected at the edge: a prompt past the
        // prefill bucket that reached the backend would fail the loop
        let ingress = Arc::new(Ingress::new(net.max_queue, net.rate_limit, serve.prefill_len));
        let metrics = Arc::new(Mutex::new(ServeMetrics::new()));
        let listener =
            TcpListener::bind(&net.listen).with_context(|| format!("binding {}", net.listen))?;
        let addr = listener.local_addr().context("reading bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let coord_ingress = ingress.clone();
        let coord_metrics = metrics.clone();
        let coord =
            std::thread::spawn(move || server.run_ingress(coord_ingress, Some(coord_metrics)));

        let shared = Arc::new(Shared {
            ingress: ingress.clone(),
            metrics: metrics.clone(),
            epoch: Instant::now(),
            next_anon_id: AtomicU64::new(ANON_ID_BASE),
            net,
        });
        let accept_stop = stop.clone();
        let accept_conns = conns.clone();
        let accept = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                let conn_shared = shared.clone();
                let h = std::thread::spawn(move || handle_connection(stream, &conn_shared));
                accept_conns.lock().unwrap_or_else(|p| p.into_inner()).push(h);
            }
        });

        Ok(NetHandle {
            addr,
            ingress,
            metrics,
            stop,
            accept,
            conns,
            coord,
        })
    }
}

/// Handle on a running [`NetServer`]: the bound address, the shared
/// admission funnel, live metrics, and the graceful-shutdown path.
pub struct NetHandle {
    addr: SocketAddr,
    ingress: Arc<Ingress>,
    metrics: Arc<Mutex<ServeMetrics>>,
    stop: Arc<AtomicBool>,
    accept: JoinHandle<()>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    coord: JoinHandle<Result<(Vec<CompletedRequest>, ServeMetrics)>>,
}

impl NetHandle {
    /// The actually-bound listen address (resolves port `0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared admission funnel (tests pause/resume it to replay
    /// closed-batch admission order; the CLI reports its queue depth).
    pub fn ingress(&self) -> &Arc<Ingress> {
        &self.ingress
    }

    /// A snapshot of the live serving metrics (what `/metrics` serves).
    pub fn metrics_snapshot(&self) -> ServeMetrics {
        self.metrics.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Graceful shutdown: stop admitting, let every in-flight sequence
    /// finish (queued ones are shed as [`FailReason::Shutdown`] — never
    /// a mid-token truncation), close the listener, join every thread,
    /// and return the completed requests + final metrics. Blocks until
    /// the drain finishes (stalled client sockets hold their
    /// connection threads up to the configured read timeout).
    pub fn shutdown(self) -> Result<(Vec<CompletedRequest>, ServeMetrics)> {
        self.ingress.shutdown();
        self.stop.store(true, Ordering::SeqCst);
        // poke the blocking accept() so it observes the stop flag
        let _ = TcpStream::connect(self.addr);
        self.accept
            .join()
            .map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.conns.lock().unwrap_or_else(|p| p.into_inner()));
        for h in handles {
            let _ = h.join();
        }
        self.coord
            .join()
            .map_err(|_| anyhow::anyhow!("coordinator thread panicked"))?
    }
}

/// Serve one connection: parse the request, route, respond, close.
fn handle_connection(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs_f64(shared.net.read_timeout_s)));
    // token frames must hit the wire per round, not per segment
    let _ = stream.set_nodelay(true);
    let req = match read_request(&mut stream, shared.net.max_body_bytes) {
        Ok(Some(r)) => r,
        Ok(None) => return,
        Err(e) => {
            let msg = format!("{e:#}");
            let status = if msg.contains("cap") { 413 } else { 400 };
            respond_error(&mut stream, status, &msg, &[]);
            return;
        }
    };
    let path = req.path.split('?').next().unwrap_or("");
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            let (status, body) = if shared.ingress.is_shutdown() {
                (503, "draining\n")
            } else {
                (200, "ok\n")
            };
            let _ = write_response(
                &mut stream,
                status,
                "text/plain; charset=utf-8",
                &[],
                body.as_bytes(),
            );
        }
        ("GET", "/metrics") => {
            let mut snap = shared.metrics.lock().unwrap_or_else(|p| p.into_inner()).clone();
            let text = snap.prometheus();
            let _ = write_response(
                &mut stream,
                200,
                "text/plain; version=0.0.4",
                &[],
                text.as_bytes(),
            );
        }
        ("POST", "/v1/completions") => handle_completion(&mut stream, shared, &req),
        (_, "/healthz" | "/metrics" | "/v1/completions") => {
            respond_error(&mut stream, 405, "method not allowed", &[]);
        }
        _ => respond_error(&mut stream, 404, "no such endpoint", &[]),
    }
}

/// Parse + admit one completion request and stream its tokens.
fn handle_completion(stream: &mut TcpStream, shared: &Shared, http: &HttpRequest) {
    let body = match std::str::from_utf8(&http.body) {
        Ok(b) => b,
        Err(_) => return respond_error(stream, 400, "request body must be UTF-8", &[]),
    };
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return respond_error(stream, 400, &format!("request body: {e}"), &[]),
    };
    let mut req = match Request::from_json(&parsed) {
        Ok(r) => r,
        Err(e) => return respond_error(stream, 400, &format!("{e:#}"), &[]),
    };
    if parsed.get("id").is_none() {
        req.id = shared.next_anon_id.fetch_add(1, Ordering::SeqCst);
    }
    let now_s = shared.epoch.elapsed().as_secs_f64();
    // the wire arrival_s (a trace replay artifact) is discarded: live
    // requests arrive when they arrive
    req.arrival_s = now_s;
    let format = if wants_sse(http) {
        StreamFormat::Sse
    } else {
        StreamFormat::Ndjson
    };
    let (tx, rx) = mpsc::channel();
    if let Err(reject) = shared.ingress.submit_at(req, Box::new(HttpSink { tx }), now_s) {
        return respond_reject(stream, &reject);
    }
    stream_events(stream, format, &rx);
}

/// `?format=sse` or an SSE `Accept` header selects SSE framing.
fn wants_sse(http: &HttpRequest) -> bool {
    let query = http.path.split_once('?').map(|(_, q)| q).unwrap_or("");
    query.split('&').any(|kv| kv == "format=sse")
        || http
            .header("accept")
            .is_some_and(|a| a.contains("text/event-stream"))
}

/// Stream sink events to the socket as chunked NDJSON/SSE frames until
/// the sequence completes or is shed. A failed chunk write ends the
/// loop and drops `rx` — the disconnect signal the coordinator sheds
/// on.
fn stream_events(stream: &mut TcpStream, format: StreamFormat, rx: &mpsc::Receiver<SinkEvent>) {
    let mut enc = EventEncoder::new(format);
    let mut cw = match ChunkedWriter::start(&mut *stream, 200, enc.content_type()) {
        Ok(c) => c,
        Err(_) => return,
    };
    let mut index = 0u64;
    loop {
        let event = match rx.recv() {
            Ok(e) => e,
            // the coordinator dropped the sink without a final event
            // (fatal serving error): terminate the stream cleanly
            Err(_) => {
                let _ = cw.finish();
                return;
            }
        };
        let frame = match event {
            SinkEvent::Token { id, tok } => {
                let f = enc.frame(&Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("token", Json::num(tok as f64)),
                    ("index", Json::num(index as f64)),
                ]));
                index += 1;
                if cw.chunk(f.as_bytes()).is_err() {
                    return;
                }
                continue;
            }
            SinkEvent::Done(done) => enc.frame(&Json::obj(vec![
                ("id", Json::num(done.id as f64)),
                ("done", Json::Bool(true)),
                ("n", Json::num(done.tokens.len() as f64)),
                (
                    "tokens",
                    Json::Arr(done.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
                ),
                ("ttft_s", Json::num(done.ttft_s)),
                ("latency_s", Json::num(done.latency_s)),
            ])),
            SinkEvent::Shed { id, reason } => enc.frame(&Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("error", Json::str(reason.to_string())),
            ])),
        };
        let _ = cw.chunk(frame.as_bytes());
        let _ = cw.finish();
        return;
    }
}

/// Write a JSON error body with the given status.
fn respond_error(w: &mut TcpStream, status: u16, msg: &str, extra: &[(&str, String)]) {
    let body = Json::obj(vec![("error", Json::str(msg))]).to_string_compact();
    let _ = write_response(w, status, "application/json", extra, body.as_bytes());
}

/// Map an admission rejection to its HTTP status (backpressure is
/// `429` with a `Retry-After` hint; draining is `503`).
fn respond_reject(stream: &mut TcpStream, reject: &Reject) {
    let msg = reject.to_string();
    match reject {
        Reject::RateLimit { retry_after_s } => {
            let secs = retry_after_s.ceil().max(1.0) as u64;
            respond_error(stream, 429, &msg, &[("Retry-After", secs.to_string())]);
        }
        Reject::QueueFull => {
            respond_error(stream, 429, &msg, &[("Retry-After", "1".to_string())]);
        }
        Reject::ShuttingDown => respond_error(stream, 503, &msg, &[]),
        Reject::DuplicateId | Reject::Invalid(_) => respond_error(stream, 400, &msg, &[]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::runtime::HostBackend;
    use std::io::{Read as _, Write as _};

    fn micro() -> ModelConfig {
        ModelConfig {
            name: "host-micro".into(),
            n_layers: 2,
            d_model: 32,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 64,
            vocab_size: 64,
            max_seq: 32,
            n_partitions: 2,
            act_bits: 8,
        }
    }

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn health_metrics_routing_and_clean_shutdown_over_loopback() {
        let backend = HostBackend::new(micro(), 1).unwrap();
        let serve = ServeConfig {
            max_batches: 1,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            ..ServeConfig::default()
        };
        let net = NetConfig {
            listen: "127.0.0.1:0".into(),
            ..NetConfig::default()
        };
        let handle = NetServer::start(backend, serve, net).unwrap();
        let addr = handle.addr();

        let health = get(addr, "/healthz");
        assert!(health.starts_with("HTTP/1.1 200"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");

        let m = get(addr, "/metrics");
        assert!(m.contains("bitrom_requests_done_total 0"), "{m}");
        assert!(m.contains("bitrom_faults_shed_total{reason=\"overload\"} 0"), "{m}");

        assert!(get(addr, "/nope").starts_with("HTTP/1.1 404"));
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "DELETE /metrics HTTP/1.1\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 405"), "{out}");

        let (done, metrics) = handle.shutdown().unwrap();
        assert!(done.is_empty());
        assert_eq!(metrics.requests_done, 0);
    }
}
