//! The streaming serving plane's network layer (DESIGN.md §14).
//!
//! Std-only — `std::net` sockets and OS threads, no async runtime:
//!
//! * [`http`] — minimal HTTP/1.1 request reader and response/chunked
//!   writers (exactly what the front door needs, nothing more).
//! * [`jsonframe`] — incremental JSON: a push-parser that re-frames
//!   values split across arbitrary read boundaries, and the
//!   NDJSON/SSE event encoder.
//! * [`NetServer`] — the front door itself: `POST /v1/completions`
//!   streaming tokens the round they decode, `GET /healthz`,
//!   `GET /metrics`, per-tenant backpressure via
//!   [`crate::coordinator::Ingress`].
//!
//! Invariant 10 (DESIGN.md §14): tokens served over loopback HTTP are
//! bit-identical to the offline [`crate::coordinator::Server::run_trace`]
//! twin on the same seeded request set — the wire is an observation
//! channel, never part of the math.

use std::sync::atomic::{AtomicBool, Ordering};

pub mod http;
pub mod jsonframe;
mod server;

pub use server::{NetHandle, NetServer};

/// Process-wide SIGINT latch for `bitrom serve --listen`.
static SIGINT: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" {
    fn signal(signum: i32, handler: usize) -> usize;
}

#[cfg(unix)]
extern "C" fn sigint_latch(_: i32) {
    SIGINT.store(true, Ordering::SeqCst);
}

/// Install a SIGINT handler that flips the returned latch instead of
/// killing the process, so the CLI can drain in-flight sequences
/// through [`NetHandle::shutdown`] (finish or typed-shed, never a
/// mid-token truncation). Idempotent; on non-unix targets the latch is
/// returned uninstalled and never flips.
pub fn install_sigint_latch() -> &'static AtomicBool {
    #[cfg(unix)]
    // SAFETY: `signal(2)` with a signal-safe handler that only does an
    // atomic store; std links libc on unix so the symbol resolves.
    unsafe {
        signal(2, sigint_latch as usize);
    }
    &SIGINT
}
