//! Incremental JSON framing for the streaming wire (DESIGN.md §14).
//!
//! The serving plane speaks newline-delimited JSON (NDJSON) and SSE.
//! Bytes arrive from sockets in arbitrary fragments — a value may be
//! split mid-string, mid-number, or mid-UTF-8-sequence across reads —
//! so decoding is a push parser: [`FrameDecoder::push`] consumes a
//! fragment and returns every *complete* top-level value it finished,
//! buffering the rest. Framing is structural (string/escape state plus
//! container depth), not line-based, so pretty-printed client bodies
//! split across lines still decode.
//!
//! Two modes (`jsonmodem`-style discipline):
//! * [`DecodeMode::Strict`] — any garbage between values, invalid
//!   UTF-8, or malformed value is a hard error (and poisons the
//!   decoder; the caller should drop the connection).
//! * [`DecodeMode::Lenient`] — garbage bytes are skipped until the
//!   next plausible value start, invalid UTF-8 is replaced, and
//!   malformed values are dropped; both are counted so callers can
//!   still observe the damage.
//!
//! Encoding is the exact inverse: [`EventEncoder`] renders one frame
//! per event through the crate JSON writer (escaping-correct by
//! construction), as NDJSON lines or `data:` SSE frames.

use anyhow::Result;

use crate::util::json::Json;

/// How [`FrameDecoder`] treats malformed input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    /// Reject garbage, invalid UTF-8 and malformed values (poisons the
    /// decoder — wire corruption is terminal for a connection).
    Strict,
    /// Skip garbage / replace invalid UTF-8 / drop malformed values,
    /// counting what was lost.
    Lenient,
}

/// Incremental push-parser over a byte stream of concatenated JSON
/// values (NDJSON or any whitespace-separated top-level sequence).
#[derive(Debug)]
pub struct FrameDecoder {
    mode: DecodeMode,
    buf: Vec<u8>,
    /// Scan cursor into `buf` (everything before it is classified).
    pos: usize,
    /// Start offset of the value currently being scanned.
    start: usize,
    in_value: bool,
    in_string: bool,
    escape: bool,
    depth: usize,
    /// Bytes consumed before the current `buf` (for error offsets).
    consumed: u64,
    poisoned: bool,
    max_value_bytes: usize,
    values_decoded: u64,
    bytes_skipped: u64,
    values_dropped: u64,
}

/// Default cap on one buffered value (a streaming peer should never
/// need megabyte frames; the cap bounds memory per connection).
pub const MAX_VALUE_BYTES: usize = 1 << 20;

impl FrameDecoder {
    /// Decoder in the given mode with the default value-size cap.
    pub fn new(mode: DecodeMode) -> Self {
        Self::with_limit(mode, MAX_VALUE_BYTES)
    }

    /// Decoder with an explicit per-value size cap in bytes.
    pub fn with_limit(mode: DecodeMode, max_value_bytes: usize) -> Self {
        FrameDecoder {
            mode,
            buf: Vec::new(),
            pos: 0,
            start: 0,
            in_value: false,
            in_string: false,
            escape: false,
            depth: 0,
            consumed: 0,
            poisoned: false,
            max_value_bytes,
            values_decoded: 0,
            bytes_skipped: 0,
            values_dropped: 0,
        }
    }

    /// Complete values decoded so far.
    pub fn values_decoded(&self) -> u64 {
        self.values_decoded
    }

    /// Garbage bytes skipped (lenient mode only; strict never skips).
    pub fn bytes_skipped(&self) -> u64 {
        self.bytes_skipped
    }

    /// Malformed values dropped (lenient mode only).
    pub fn values_dropped(&self) -> u64 {
        self.values_dropped
    }

    /// Bytes buffered awaiting the rest of a split value.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len() - if self.in_value { self.start } else { self.pos }
    }

    fn err(&mut self, msg: &str) -> anyhow::Error {
        self.poisoned = true;
        let off = self.consumed + self.pos as u64;
        anyhow::anyhow!("jsonframe: {msg} at stream offset {off}")
    }

    /// Would byte `b` start a JSON value?
    fn is_value_start(b: u8) -> bool {
        matches!(b, b'{' | b'[' | b'"' | b'-' | b'0'..=b'9' | b't' | b'f' | b'n')
    }

    fn is_ws(b: u8) -> bool {
        matches!(b, b' ' | b'\t' | b'\n' | b'\r')
    }

    /// Parse one completed slice according to the mode. `Ok(None)` =
    /// lenient drop.
    fn finish_value(&mut self, end: usize) -> Result<Option<Json>> {
        let slice = &self.buf[self.start..end];
        let text: std::borrow::Cow<'_, str> = match std::str::from_utf8(slice) {
            Ok(s) => s.into(),
            Err(_) => match self.mode {
                DecodeMode::Strict => return Err(self.err("invalid UTF-8 in value")),
                DecodeMode::Lenient => String::from_utf8_lossy(slice),
            },
        };
        match Json::parse(&text) {
            Ok(v) => {
                self.values_decoded += 1;
                Ok(Some(v))
            }
            Err(e) => match self.mode {
                DecodeMode::Strict => Err(self.err(&format!("malformed value ({e})"))),
                DecodeMode::Lenient => {
                    self.values_dropped += 1;
                    Ok(None)
                }
            },
        }
    }

    /// Feed one fragment; returns every value completed by it. Values
    /// split across fragments are buffered until their closing byte
    /// arrives (including multi-byte UTF-8 sequences split mid-char).
    pub fn push(&mut self, bytes: &[u8]) -> Result<Vec<Json>> {
        anyhow::ensure!(!self.poisoned, "jsonframe: decoder poisoned by an earlier error");
        self.buf.extend_from_slice(bytes);
        let mut out = Vec::new();
        while self.pos < self.buf.len() {
            let b = self.buf[self.pos];
            if !self.in_value {
                if Self::is_ws(b) {
                    self.pos += 1;
                    continue;
                }
                if Self::is_value_start(b) {
                    self.in_value = true;
                    self.in_string = false;
                    self.escape = false;
                    self.depth = 0;
                    self.start = self.pos;
                    continue;
                }
                match self.mode {
                    DecodeMode::Strict => {
                        return Err(self.err(&format!("unexpected byte {b:#04x} between values")))
                    }
                    DecodeMode::Lenient => {
                        self.bytes_skipped += 1;
                        self.pos += 1;
                        continue;
                    }
                }
            }
            // inside a value
            if self.pos - self.start > self.max_value_bytes {
                return Err(self.err("value exceeds the frame size cap"));
            }
            if self.in_string {
                if self.escape {
                    self.escape = false;
                } else if b == b'\\' {
                    self.escape = true;
                } else if b == b'"' {
                    self.in_string = false;
                    if self.depth == 0 {
                        // a bare top-level string just closed
                        self.pos += 1;
                        let v = self.finish_value(self.pos)?;
                        self.in_value = false;
                        out.extend(v);
                        continue;
                    }
                }
                self.pos += 1;
                continue;
            }
            match b {
                b'"' => {
                    self.in_string = true;
                    self.pos += 1;
                }
                b'{' | b'[' => {
                    self.depth += 1;
                    self.pos += 1;
                }
                b'}' | b']' => {
                    if self.depth == 0 {
                        // a closer with nothing open: the scalar before
                        // it (if any) ends here, the byte itself is
                        // garbage
                        match self.mode {
                            DecodeMode::Strict => {
                                return Err(self.err("unmatched closing bracket"))
                            }
                            DecodeMode::Lenient => {
                                let v = self.finish_value(self.pos)?;
                                self.in_value = false;
                                out.extend(v);
                                continue;
                            }
                        }
                    }
                    self.depth -= 1;
                    self.pos += 1;
                    if self.depth == 0 {
                        let v = self.finish_value(self.pos)?;
                        self.in_value = false;
                        out.extend(v);
                    }
                }
                b if self.depth == 0 && Self::is_ws(b) => {
                    // whitespace terminates a top-level scalar
                    let v = self.finish_value(self.pos)?;
                    self.in_value = false;
                    out.extend(v);
                }
                _ => {
                    self.pos += 1;
                }
            }
        }
        // drop the classified prefix so long streams stay O(value)
        let keep_from = if self.in_value { self.start } else { self.pos };
        if keep_from > 0 {
            self.buf.drain(..keep_from);
            self.consumed += keep_from as u64;
            self.pos -= keep_from;
            self.start = self.start.saturating_sub(keep_from);
        }
        Ok(out)
    }

    /// Signal end-of-stream. A pending top-level scalar (a number with
    /// no trailing newline) completes here; a pending container or
    /// string is truncation — an error in strict mode, a counted drop
    /// in lenient mode.
    pub fn finish(&mut self) -> Result<Option<Json>> {
        anyhow::ensure!(!self.poisoned, "jsonframe: decoder poisoned by an earlier error");
        if !self.in_value {
            return Ok(None);
        }
        self.in_value = false;
        if self.in_string || self.depth > 0 {
            self.in_string = false;
            self.depth = 0;
            return match self.mode {
                DecodeMode::Strict => Err(self.err("stream truncated inside a value")),
                DecodeMode::Lenient => {
                    self.values_dropped += 1;
                    self.buf.clear();
                    self.pos = 0;
                    self.start = 0;
                    Ok(None)
                }
            };
        }
        let end = self.buf.len();
        let v = self.finish_value(end)?;
        self.buf.clear();
        self.pos = 0;
        self.start = 0;
        Ok(v)
    }
}

/// Output framing for streamed events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamFormat {
    /// One compact JSON value per `\n`-terminated line.
    Ndjson,
    /// Server-sent events: `data: <compact json>\n\n` per event.
    Sse,
}

/// Stateful frame encoder: one event in, one wire frame out. Escaping
/// runs through the crate JSON writer, so any token payload — control
/// characters, quotes, non-ASCII — round-trips through
/// [`FrameDecoder`].
#[derive(Debug)]
pub struct EventEncoder {
    format: StreamFormat,
    events: u64,
}

impl EventEncoder {
    /// Encoder for the given wire format.
    pub fn new(format: StreamFormat) -> Self {
        EventEncoder { format, events: 0 }
    }

    /// The `Content-Type` this encoder's frames should be served under.
    pub fn content_type(&self) -> &'static str {
        match self.format {
            StreamFormat::Ndjson => "application/x-ndjson",
            StreamFormat::Sse => "text/event-stream",
        }
    }

    /// Render one event as a complete wire frame.
    pub fn frame(&mut self, event: &Json) -> String {
        self.events += 1;
        match self.format {
            StreamFormat::Ndjson => format!("{}\n", event.to_string_compact()),
            StreamFormat::Sse => format!("data: {}\n\n", event.to_string_compact()),
        }
    }

    /// Frames emitted so far.
    pub fn events(&self) -> u64 {
        self.events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;
    use crate::{prop_assert, prop_assert_eq};

    fn decode_all(mode: DecodeMode, chunks: &[&[u8]]) -> Result<Vec<Json>> {
        let mut d = FrameDecoder::new(mode);
        let mut out = Vec::new();
        for c in chunks {
            out.extend(d.push(c)?);
        }
        out.extend(d.finish()?);
        Ok(out)
    }

    /// Golden catalog: (name, input chunks, expected decoded values as
    /// canonical compact JSON). Every case runs in BOTH modes — strict
    /// and lenient must agree on well-formed input.
    const GOLDEN_OK: &[(&str, &[&[u8]], &[&str])] = &[
        ("single object", &[b"{\"a\":1}\n"], &["{\"a\":1}"]),
        ("two per chunk", &[b"{\"a\":1}\n{\"b\":2}\n"], &["{\"a\":1}", "{\"b\":2}"]),
        (
            "value split across reads",
            &[b"{\"tok", b"en\":4", b"2}\n"],
            &["{\"token\":42}"],
        ),
        (
            "split inside escape",
            &[b"{\"s\":\"a\\", b"\"b\"}\n"],
            &["{\"s\":\"a\\\"b\"}"],
        ),
        (
            "split inside multi-byte utf8",
            &[b"{\"s\":\"h\xc3", b"\xa9llo\"}\n"],
            &["{\"s\":\"h\u{e9}llo\"}"],
        ),
        ("nested containers", &[b"[{\"a\":[1,[2]]}]"], &["[{\"a\":[1,[2]]}]"]),
        (
            "brace inside string is not structure",
            &[b"{\"s\":\"}{\"}\n"],
            &["{\"s\":\"}{\"}"],
        ),
        ("bare string value", &[b"\"hi\"\n"], &["\"hi\""]),
        ("bare number needs a delimiter", &[b"42\n7\n"], &["42", "7"]),
        ("trailing number completes at finish", &[b"42\n", b"1.5"], &["42", "1.5"]),
        ("literals", &[b"true\nfalse\nnull\n"], &["true", "false", "null"]),
        ("crlf framing", &[b"{\"a\":1}\r\n{\"b\":2}\r\n"], &["{\"a\":1}", "{\"b\":2}"]),
        ("pretty-printed across lines", &[b"{\n  \"a\": 1\n}\n"], &["{\"a\":1}"]),
        ("empty chunks are harmless", &[b"", b"{\"a\":1}", b"", b"\n"], &["{\"a\":1}"]),
        (
            "byte-at-a-time",
            &[b"{", b"\"", b"a", b"\"", b":", b"1", b"}", b"\n"],
            &["{\"a\":1}"],
        ),
    ];

    #[test]
    fn golden_catalog_decodes_in_both_modes() {
        for &(name, chunks, want) in GOLDEN_OK {
            for mode in [DecodeMode::Strict, DecodeMode::Lenient] {
                let got = decode_all(mode, chunks)
                    .unwrap_or_else(|e| panic!("{name} ({mode:?}): {e}"));
                let got: Vec<String> = got.iter().map(|v| v.to_string_compact()).collect();
                assert_eq!(got, want, "{name} ({mode:?})");
            }
        }
    }

    /// Golden error catalog: inputs strict must reject.
    const GOLDEN_STRICT_ERR: &[(&str, &[&[u8]])] = &[
        ("garbage between values", &[b"{\"a\":1}\nxyz#\n"]),
        ("truncated object at eof", &[b"{\"a\":"]),
        ("truncated string at eof", &[b"\"unterminated"]),
        ("unmatched closer", &[b"]\n"]),
        ("invalid utf8 in string", &[b"{\"s\":\"\xff\xfe\"}\n"]),
        ("malformed value", &[b"{\"a\":}\n"]),
        ("comma between top-level values", &[b"{\"a\":1},{\"b\":2}\n"]),
    ];

    #[test]
    fn golden_catalog_strict_rejects_corruption() {
        for &(name, chunks) in GOLDEN_STRICT_ERR {
            let r = decode_all(DecodeMode::Strict, chunks);
            assert!(r.is_err(), "{name}: strict must reject");
        }
    }

    #[test]
    fn lenient_skips_garbage_and_keeps_decoding() {
        let mut d = FrameDecoder::new(DecodeMode::Lenient);
        let got = d.push(b"#!wire noise\n{\"a\":1}\n???{\"b\":2}\n").unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].to_string_compact(), "{\"a\":1}");
        assert_eq!(got[1].to_string_compact(), "{\"b\":2}");
        assert!(d.bytes_skipped() > 0);
        assert_eq!(d.values_decoded(), 2);
    }

    #[test]
    fn lenient_drops_malformed_values_and_counts_them() {
        let mut d = FrameDecoder::new(DecodeMode::Lenient);
        let got = d.push(b"{\"a\":}\n{\"b\":2}\n").unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].to_string_compact(), "{\"b\":2}");
        assert_eq!(d.values_dropped(), 1);
    }

    #[test]
    fn lenient_replaces_invalid_utf8() {
        let mut d = FrameDecoder::new(DecodeMode::Lenient);
        let got = d.push(b"{\"s\":\"a\xffb\"}\n").unwrap();
        assert_eq!(got.len(), 1);
        let s = got[0].get("s").unwrap().as_str().unwrap().to_string();
        assert!(s.starts_with('a') && s.ends_with('b'), "{s:?}");
    }

    #[test]
    fn strict_decoder_is_poisoned_after_an_error() {
        let mut d = FrameDecoder::new(DecodeMode::Strict);
        assert!(d.push(b"garbage").is_err());
        assert!(d.push(b"{\"a\":1}\n").is_err(), "poisoned decoders stay dead");
    }

    #[test]
    fn value_size_cap_is_enforced() {
        let mut d = FrameDecoder::with_limit(DecodeMode::Strict, 8);
        assert!(d.push(b"{\"aaaaaaaaaaaaaaaa\":1}\n").is_err());
    }

    #[test]
    fn pending_bytes_tracks_split_values() {
        let mut d = FrameDecoder::new(DecodeMode::Strict);
        assert_eq!(d.push(b"{\"a\"").unwrap().len(), 0);
        assert_eq!(d.pending_bytes(), 4);
        assert_eq!(d.push(b":1}\n").unwrap().len(), 1);
        assert_eq!(d.pending_bytes(), 0);
    }

    #[test]
    fn encoder_frames_round_trip_through_the_decoder() {
        let mut enc = EventEncoder::new(StreamFormat::Ndjson);
        let nasty = Json::obj(vec![
            ("text", Json::str("line\nbreak \"quoted\" \\ slash \t héllo ✓ \u{1}")),
            ("token", Json::num(42.0)),
        ]);
        let wire = enc.frame(&nasty);
        let mut d = FrameDecoder::new(DecodeMode::Strict);
        let got = d.push(wire.as_bytes()).unwrap();
        assert_eq!(got, vec![nasty]);
        assert_eq!(enc.events(), 1);
        assert_eq!(enc.content_type(), "application/x-ndjson");
    }

    #[test]
    fn sse_frames_carry_the_data_prefix() {
        let mut enc = EventEncoder::new(StreamFormat::Sse);
        let f = enc.frame(&Json::obj(vec![("token", Json::num(7.0))]));
        assert_eq!(f, "data: {\"token\":7}\n\n");
        assert_eq!(enc.content_type(), "text/event-stream");
    }

    /// Random JSON value, depth-bounded (strings avoid the full char
    /// space — escaping edge cases are pinned by the golden catalog and
    /// the dedicated round-trip test above).
    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        let pick = rng.usize(0, if depth == 0 { 3 } else { 5 });
        match pick {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.i64(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => {
                let len = rng.usize(0, 12);
                let s: String = (0..len)
                    .map(|_| {
                        const ALPH: &[char] =
                            &['a', 'Z', '9', '"', '\\', '\n', '\t', ' ', 'é', '✓', '𝕊', '\u{7}'];
                        ALPH[rng.usize(0, ALPH.len() - 1)]
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.usize(0, 4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn fuzz_random_values_split_at_random_boundaries_round_trip() {
        // encode a random NDJSON stream, shatter it at random byte
        // boundaries (splitting strings, escapes and UTF-8 sequences),
        // and require byte-exact value recovery in both modes
        check(0x77F3, 64, |g| {
            let n_values = g.usize(1, 6);
            let values: Vec<Json> = (0..n_values).map(|_| gen_json(&mut g.rng, 3)).collect();
            let mut enc = EventEncoder::new(StreamFormat::Ndjson);
            let wire: String = values.iter().map(|v| enc.frame(v)).collect();
            let bytes = wire.as_bytes();
            for mode in [DecodeMode::Strict, DecodeMode::Lenient] {
                let mut d = FrameDecoder::new(mode);
                let mut got = Vec::new();
                let mut at = 0usize;
                while at < bytes.len() {
                    let step = g.rng.usize(1, 7).min(bytes.len() - at);
                    got.extend(
                        d.push(&bytes[at..at + step])
                            .map_err(|e| format!("{mode:?}: {e}"))?,
                    );
                    at += step;
                }
                got.extend(d.finish().map_err(|e| format!("{mode:?} finish: {e}"))?);
                prop_assert_eq!(got, values.clone());
            }
            Ok(())
        });
    }

    #[test]
    fn fuzz_lenient_survives_injected_garbage() {
        // valid values interleaved with garbage bytes: lenient must
        // recover every value whose own bytes are intact
        check(0x77F4, 48, |g| {
            let n_values = g.usize(1, 5);
            let values: Vec<Json> = (0..n_values).map(|_| gen_json(&mut g.rng, 2)).collect();
            let mut wire = Vec::new();
            for v in &values {
                let junk_len = g.usize(0, 5);
                for _ in 0..junk_len {
                    // bytes that can't start a JSON value
                    const JUNK: &[u8] = b"#@!?;|%^&*\xff";
                    wire.push(JUNK[g.rng.usize(0, JUNK.len() - 1)]);
                }
                wire.extend_from_slice(format!("{}\n", v.to_string_compact()).as_bytes());
            }
            let mut d = FrameDecoder::new(DecodeMode::Lenient);
            let mut got = d.push(&wire).map_err(|e| e.to_string())?;
            got.extend(d.finish().map_err(|e| e.to_string())?);
            prop_assert_eq!(got, values.clone());
            prop_assert!(
                d.values_decoded() == n_values as u64,
                "decoded {} of {n_values}",
                d.values_decoded()
            );
            Ok(())
        });
    }
}
