//! `bitrom` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   serve      run a request trace through the partition pipeline
//!   generate   single-prompt greedy generation (sanity path)
//!   report     regenerate paper tables/figures from the simulators
//!   verify     check the runtime against the python golden trace
//!   info       print artifact/config summary

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::Duration;

use anyhow::Context;
use bitrom::config::{HardwareConfig, ModelConfig, NetConfig, ServeConfig};
use bitrom::coordinator::{CompletedRequest, ServeMetrics, Server};
use bitrom::lora::AdapterRegistry;
use bitrom::net::{install_sigint_latch, NetServer};
use bitrom::report::{
    fig1a_report, fig5a_report, fig5b_report, fig5b_serving_report, gemv_perf_report,
    lora_serving_report, prefix_serving_report, table3_report,
};
use bitrom::runtime::{HostBackend, InferenceBackend, Manifest, ServeTuning, ShardedBackend};
#[cfg(feature = "pjrt")]
use bitrom::runtime::ModelExecutor;
use bitrom::trace::{generate, TraceConfig};
use bitrom::util::args::{ArgParser, Args};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() {
        "help".to_string()
    } else {
        argv.remove(0)
    };
    let code = match cmd.as_str() {
        "serve" => cmd_serve(argv),
        "generate" => cmd_generate(argv),
        "report" => cmd_report(argv),
        "verify" => cmd_verify(argv),
        "info" => cmd_info(argv),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    }
    .map_or_else(
        |e: anyhow::Error| {
            eprintln!("error: {e:#}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn print_help() {
    println!(
        "bitrom — weight reload-free CiROM serving for 1.58-bit LLMs\n\n\
         USAGE: bitrom <command> [options]\n\n\
         COMMANDS:\n\
         \x20 serve     run a synthetic request trace through the partition pipeline\n\
         \x20           (--host serves offline on the fabricated HostBackend;\n\
         \x20           --adapters N serves N tenant LoRA adapters reload-free;\n\
         \x20           --prefix-cache shares prompt-prefix KV blocks by content\n\
         \x20           hash; --priority N + --preempt-policy reload|recompute\n\
         \x20           schedule by class under memory pressure;\n\
         \x20           --listen ADDR opens the streaming HTTP front door —\n\
         \x20           POST /v1/completions streams tokens as NDJSON/SSE,\n\
         \x20           Ctrl-C drains in-flight sequences gracefully)\n\
         \x20 generate  greedy-generate from a prompt (token ids; --host = offline;\n\
         \x20           --adapter K binds tenant K's adapter)\n\
         \x20 report    print paper tables/figures (--table3 --fig1a --fig5a --fig5b\n\
         \x20           --fig5b-serving = Fig 5(b) measured on a real served trace;\n\
         \x20           --lora-serving = adapter overhead + reload-vs-switch;\n\
         \x20           --prefix-serving = shared-prefix reduction vs private twin)\n\
         \x20 verify    replay the python golden trace and compare\n\
         \x20 info      artifact + config summary\n\n\
         Artifacts default to ./artifacts (override with BITROM_ARTIFACTS\n\
         or --artifacts). Build them with `make artifacts`. The --host\n\
         paths need neither artifacts nor the `pjrt` feature."
    );
}

fn artifacts_dir(args: &bitrom::util::args::Args) -> PathBuf {
    match args.get("artifacts") {
        Some(p) if !p.is_empty() => PathBuf::from(p),
        _ => Manifest::default_dir(),
    }
}

fn serve_trace_cfg(args: &Args, vocab: usize, n_adapters: usize) -> TraceConfig {
    TraceConfig {
        n_requests: args.usize("requests"),
        gen_len_min: args.usize("gen").min(8),
        gen_len_max: args.usize("gen"),
        arrival_rate: args.f64("rate"),
        burst_p: args.f64("burst-p"),
        shared_prefix_len: args.usize("shared-prefix"),
        turn_p: args.f64("turn-p"),
        priority_classes: args.usize("priority"),
        seed: args.u64("seed"),
        vocab_size: vocab,
        n_adapters,
        ..TraceConfig::default()
    }
}

fn serve_cfg(args: &Args) -> ServeConfig {
    ServeConfig {
        max_batches: args.usize("batches"),
        threads: args.usize("threads"),
        seed: args.u64("seed"),
        fault_seed: args.u64("fault-plan"),
        fault_storm_p: args.f64("storm-p"),
        fault_transient_p: args.f64("transient-p"),
        fault_clock_skip_s: args.f64("clock-skip"),
        retry_max: args.usize("retry-max"),
        admit_pressure: args.f64("admit-pressure"),
        preempt_under_pressure: args.flag("preempt"),
        shed_after_s: args.f64("shed-after"),
        prefix_cache: args.flag("prefix-cache"),
        shards: args.usize("shards"),
        preempt_policy: args.str("preempt-policy").to_string(),
        fused_decode: !args.flag("unfused-decode"),
        kernel_path: args.str("kernel-path").to_string(),
        ..ServeConfig::default()
    }
}

/// Resolve the model config for a `--host` invocation. `max_context`
/// caps the model's sequence length at what the invocation can
/// actually use: KV pages are allocated on demand in the tiered store,
/// but the serving config's `max_seq` must fit inside the model's, and
/// a smaller context keeps the early-token placement meaningful for
/// short runs.
fn host_model(args: &Args, max_context: usize) -> anyhow::Result<ModelConfig> {
    let mut model = ModelConfig::named(args.str("model"))
        .ok_or_else(|| anyhow::anyhow!("unknown model {:?}", args.str("model")))?
        .with_divisible_partitions();
    model.max_seq = model.max_seq.min(max_context.max(1));
    Ok(model)
}

/// Fabricate the offline backend for a `--host` invocation (shared by
/// `serve` and `generate`), with `n_adapters` tenant adapters when
/// requested (rank/placement from `serve`'s adapter knobs).
fn host_backend(
    args: &Args,
    max_context: usize,
    serve: &ServeConfig,
) -> anyhow::Result<HostBackend> {
    let model = host_model(args, max_context)?;
    let seed = args.u64("seed");
    match serve.lora_config()? {
        Some(lora) => {
            let registry =
                AdapterRegistry::fabricate(&model, &lora, serve.n_adapters, seed ^ 0xADA9)?;
            HostBackend::with_adapters(model, seed, registry)
        }
        None => HostBackend::new(model, seed),
    }
}

fn print_serve_outcome(done: &[CompletedRequest], metrics: &mut ServeMetrics, verbose: bool) {
    if verbose {
        for r in done {
            println!(
                "req {:>3}: prompt {:>2} tokens -> {} generated \
                 (ttft {:.1} ms, latency {:.1} ms)",
                r.id,
                r.prompt_len,
                r.tokens.len(),
                r.ttft_s * 1e3,
                r.latency_s * 1e3,
            );
        }
    }
    // the report includes the measured KV-tier line when the backend
    // serves through the tiered store
    println!("{}", metrics.report());
    if metrics.kv.is_none() {
        println!("KV tier stats: n/a (device-side KV is opaque to the host)");
    }
    println!(
        "compute: prefill mean {:.3} ms/req | decode mean {:.4} ms/tok",
        metrics.prefill_time.mean() * 1e3,
        metrics.decode_time.mean() * 1e3,
    );
}

fn cmd_serve(argv: Vec<String>) -> anyhow::Result<()> {
    let p = ArgParser::new("bitrom serve", "run a request trace through the pipeline")
        .opt("artifacts", "", "artifact directory (PJRT path)")
        .opt("requests", "12", "number of requests")
        .opt("batches", "6", "max in-flight batches")
        .opt("gen", "32", "max new tokens per request")
        .opt("rate", "0", "arrival rate (req/s, 0 = closed batch)")
        .opt("seed", "1", "trace seed")
        .opt("model", "sim-tiny", "model config for --host")
        .opt("adapters", "0", "tenant LoRA adapters to serve (--host; 0 = off)")
        .opt("adapter-rank", "16", "adapter rank (with --adapters)")
        .opt("placements", "VOD", "adapter placement sites (letters from QKVOGUD)")
        .opt("threads", "0", "worker threads (0 = BITROM_THREADS or serial; width-invariant tokens)")
        .opt("shards", "1", "model shards (--host; per-shard KV tiers, tokens invariant; §16)")
        .opt("fault-plan", "0", "deterministic fault-injection seed (0 = off; DESIGN.md §13)")
        .opt("storm-p", "0.25", "per-round retention-storm probability (with --fault-plan)")
        .opt("transient-p", "0.05", "per-slot transient-fault probability (with --fault-plan)")
        .opt("clock-skip", "0.1", "retention clock skip per storm, seconds (with --fault-plan)")
        .opt("retry-max", "3", "transient retries / recomputes per request before shedding")
        .opt("admit-pressure", "0", "defer admission above this on-die KV occupancy (0 = off)")
        .opt("shed-after", "0", "shed queued requests waiting longer than this (s; 0 = never)")
        .opt("burst-p", "0", "trace burst probability (arrival ties; stresses admission)")
        .opt("shared-prefix", "0", "shared system-prompt tokens in the trace (0 = off)")
        .opt("turn-p", "0", "multi-turn follow-up probability in the trace (0 = off)")
        .opt("priority", "0", "trace priority classes (0 = off; higher class admits first)")
        .opt("preempt-policy", "reload", "preemption KV policy: reload (swap out) or recompute")
        .opt("listen", "", "serve live over HTTP on this address (needs --host; e.g. 127.0.0.1:8080)")
        .opt("max-queue", "64", "admission queue depth before HTTP 429 (with --listen)")
        .opt("rate-limit", "0", "per-tenant request rate limit, req/s (with --listen; 0 = off)")
        .opt("trace-out", "", "export the request trace as NDJSON wire format to this file")
        .opt("trace-in", "", "replay requests from an NDJSON wire-format file instead of generating")
        .opt("kernel-path", "auto", "bitplane path: auto, scalar or bitserial (tokens invariant)")
        .flag("unfused-decode", "per-slot decode rounds instead of one fused partition walk")
        .flag("preempt", "preempt the lowest-priority slot under pressure (with --admit-pressure)")
        .flag("prefix-cache", "share full prompt-prefix KV blocks by content hash (DESIGN.md §15)")
        .flag("host", "serve on the offline HostBackend (no artifacts/PJRT needed)")
        .flag("verbose", "per-request output");
    let args = p.parse_from(argv).map_err(anyhow::Error::msg)?;

    if args.flag("host") {
        let mut serve = serve_cfg(&args);
        serve.n_adapters = args.usize("adapters");
        serve.adapter_rank = args.usize("adapter-rank");
        serve.adapter_placement = args.str("placements").to_string();
        let backend = host_backend(&args, serve.max_seq, &serve)?;
        println!(
            "fabricated host model {} ({} params, {} partitions, ROM sparsity {:.1}%, \
             {} worker thread(s))",
            backend.model().name,
            backend.model().param_count(),
            backend.model().n_partitions,
            backend.rom_sparsity() * 100.0,
            serve.resolved_threads(),
        );
        if let Some(reg) = backend.adapters() {
            println!(
                "serving {} tenant adapters (rank {} on {}, {} B each quantized; \
                 full weight reload would be {} B)",
                reg.n_adapters(),
                reg.lora().rank,
                reg.lora().placement_str(),
                reg.adapter_bytes(),
                reg.full_reload_bytes(),
            );
        }
        if serve.fault_seed != 0 {
            println!(
                "fault plan: seed {} (storm p={} skip={}s, transient p={}, retry budget {})",
                serve.fault_seed,
                serve.fault_storm_p,
                serve.fault_clock_skip_s,
                serve.fault_transient_p,
                serve.retry_max,
            );
        }
        if !args.str("listen").is_empty() {
            anyhow::ensure!(
                serve.shards <= 1,
                "--listen serves a single-shard deployment; drop --shards for the HTTP front door"
            );
            return serve_http(&args, backend, serve);
        }
        let trace = serve_trace_cfg(&args, backend.model().vocab_size, serve.n_adapters);
        let reqs = match args.str("trace-in") {
            "" => generate(&trace),
            path => {
                let text =
                    std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
                bitrom::trace::import_ndjson(&text)?
            }
        };
        let out = args.str("trace-out");
        if !out.is_empty() {
            std::fs::write(out, bitrom::trace::export_ndjson(&reqs))
                .with_context(|| format!("writing {out}"))?;
            println!("wrote {} requests to {out} (NDJSON wire format)", reqs.len());
        }
        if serve.shards > 1 {
            // grow the already-fabricated backend into a same-seed
            // fleet: each shard owns a contiguous partition range and
            // its own KV store; tokens are invariant to the shard
            // count (DESIGN.md §16, invariant 12)
            let mut fleet = vec![backend];
            for _ in 1..serve.shards {
                fleet.push(host_backend(&args, serve.max_seq, &serve)?);
            }
            let sharded = ShardedBackend::from_shards(fleet)?;
            println!(
                "sharded across {} backend instances (partition plan {:?}; \
                 tokens invariant to shard count)",
                sharded.n_shards(),
                sharded.partition_plan().ranges(),
            );
            let mut server = Server::new(sharded, serve)?;
            let (done, mut metrics) = server.run_trace(reqs)?;
            print_serve_outcome(&done, &mut metrics, args.flag("verbose"));
            return Ok(());
        }
        let mut server = Server::new(backend, serve)?;
        let (done, mut metrics) = server.run_trace(reqs)?;
        print_serve_outcome(&done, &mut metrics, args.flag("verbose"));
        return Ok(());
    }
    anyhow::ensure!(
        args.str("listen").is_empty(),
        "--listen needs --host: the streaming front door serves the offline backend"
    );
    serve_pjrt(&args)
}

/// `bitrom serve --host --listen ADDR`: open the streaming HTTP front
/// door and serve until SIGINT, then drain gracefully and print the
/// final serving report (DESIGN.md §14).
fn serve_http(args: &Args, backend: HostBackend, serve: ServeConfig) -> anyhow::Result<()> {
    let net = NetConfig {
        listen: args.str("listen").to_string(),
        max_queue: args.usize("max-queue"),
        rate_limit: args.f64("rate-limit"),
        ..NetConfig::default()
    };
    let sigint = install_sigint_latch();
    let handle = NetServer::start(backend, serve, net)?;
    println!(
        "listening on http://{} — POST /v1/completions (NDJSON; ?format=sse), \
         GET /healthz, GET /metrics",
        handle.addr()
    );
    println!("Ctrl-C drains in-flight sequences and prints the final serving report");
    while !sigint.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(200));
    }
    println!("SIGINT — draining in-flight sequences (queued requests shed as \"shutdown\")");
    let (done, mut metrics) = handle.shutdown()?;
    print_serve_outcome(&done, &mut metrics, args.flag("verbose"));
    Ok(())
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.usize("adapters") == 0,
        "--adapters needs --host: the PJRT executor serves no adapter registry"
    );
    let exec = ModelExecutor::load(&artifacts_dir(args))?;
    println!(
        "loaded {} artifacts in {:.2}s (model {}, {} partitions)",
        exec.manifest.artifacts.len(),
        exec.load_time_s,
        exec.manifest.model.name,
        exec.n_partitions()
    );
    let trace = serve_trace_cfg(args, exec.manifest.model.vocab_size, 0);
    let mut server = Server::new(exec, serve_cfg(args))?;
    let (done, mut metrics) = server.run_trace(generate(&trace))?;
    print_serve_outcome(&done, &mut metrics, args.flag("verbose"));
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(_args: &Args) -> anyhow::Result<()> {
    anyhow::bail!(
        "`bitrom serve` without --host needs the PJRT runtime — rebuild with \
         `cargo build --release --features pjrt` (and a real xla binding), \
         or pass --host to serve on the offline backend"
    )
}

fn cmd_generate(argv: Vec<String>) -> anyhow::Result<()> {
    let p = ArgParser::new("bitrom generate", "greedy generation from a token-id prompt")
        .opt("artifacts", "", "artifact directory (PJRT path)")
        .opt("prompt", "1,5,17,42", "comma-separated token ids")
        .opt("n", "16", "tokens to generate")
        .opt("model", "sim-tiny", "model config for --host")
        .opt("seed", "1", "weight seed for --host")
        .opt("adapter", "", "tenant adapter id to bind (--host; empty = base model)")
        .opt("adapters", "4", "tenant adapters fabricated when --adapter is set")
        .opt("threads", "0", "kernel worker threads (0 = BITROM_THREADS or serial)")
        .opt("kernel-path", "auto", "bitplane engine path: auto, scalar or bitserial")
        .flag("host", "generate on the offline HostBackend");
    let args = p.parse_from(argv).map_err(anyhow::Error::msg)?;
    let prompt: Vec<i32> = args
        .str("prompt")
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()?;
    let adapter: Option<u32> = match args.str("adapter") {
        "" => None,
        s => Some(s.parse()?),
    };

    if args.flag("host") {
        let mut serve = ServeConfig::default();
        if adapter.is_some() {
            // fabricate enough tenants to cover the requested id
            serve.n_adapters = args.usize("adapters").max(adapter.unwrap_or(0) as usize + 1);
        }
        let backend = host_backend(&args, prompt.len() + args.usize("n"), &serve)?;
        backend.set_threads(args.usize("threads"));
        let path = bitrom::bitnet::KernelPath::parse(args.str("kernel-path")).ok_or_else(|| {
            anyhow::anyhow!("unknown kernel path {:?}", args.str("kernel-path"))
        })?;
        backend.set_kernel_path(path);
        let out = backend.generate_greedy_bound(&prompt, args.usize("n"), adapter)?;
        println!("prompt:    {prompt:?}");
        if let Some(id) = adapter {
            println!("adapter:   tenant {id} (task switch without weight reload)");
        }
        println!("generated: {out:?}");
        return Ok(());
    }
    anyhow::ensure!(adapter.is_none(), "--adapter needs --host");
    generate_pjrt(&args, &prompt)
}

#[cfg(feature = "pjrt")]
fn generate_pjrt(args: &Args, prompt: &[i32]) -> anyhow::Result<()> {
    let exec = ModelExecutor::load(&artifacts_dir(args))?;
    let out = exec.generate_greedy(prompt, args.usize("n"))?;
    println!("prompt:    {prompt:?}");
    println!("generated: {out:?}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn generate_pjrt(_args: &Args, _prompt: &[i32]) -> anyhow::Result<()> {
    anyhow::bail!(
        "`bitrom generate` without --host needs the PJRT runtime — rebuild with \
         `cargo build --release --features pjrt`, or pass --host"
    )
}

fn cmd_report(argv: Vec<String>) -> anyhow::Result<()> {
    let p = ArgParser::new("bitrom report", "regenerate paper tables/figures")
        .opt("artifacts", "", "artifact directory (for measured sparsity)")
        .opt("sparsity", "0.30", "ROM sparsity for the energy model")
        .flag("table3", "Table III comparison")
        .flag("fig1a", "Fig 1(a) area sweep")
        .flag("fig5a", "Fig 5(a) KV access analysis")
        .flag("fig5b", "Fig 5(b) DRAM reduction grid (analytic)")
        .flag("fig5b-serving", "Fig 5(b) measured end-to-end on a served trace")
        .flag("lora-serving", "multi-tenant adapter overhead + reload-vs-switch, measured")
        .flag("prefix-serving", "shared-prefix KV reduction vs private twin, measured")
        .flag("gemv", "host bitplane-vs-reference GEMV perf (timed, not in --all)")
        .flag("all", "everything except --gemv");
    let args = p.parse_from(argv).map_err(anyhow::Error::msg)?;
    let all = args.flag("all")
        || !(args.flag("table3")
            || args.flag("fig1a")
            || args.flag("fig5a")
            || args.flag("fig5b")
            || args.flag("fig5b-serving")
            || args.flag("lora-serving")
            || args.flag("prefix-serving")
            || args.flag("gemv"));

    // prefer the measured ROM sparsity if artifacts exist
    let sparsity = Manifest::load(&artifacts_dir(&args))
        .map(|m| m.rom_sparsity)
        .unwrap_or_else(|_| args.f64("sparsity"));

    if all || args.flag("table3") {
        println!("{}", table3_report(sparsity));
    }
    if all || args.flag("fig1a") {
        println!("{}", fig1a_report(&HardwareConfig::default()));
    }
    if all || args.flag("fig5a") {
        println!("{}", fig5a_report(16));
    }
    if all || args.flag("fig5b") {
        println!("{}", fig5b_report());
    }
    if all || args.flag("fig5b-serving") {
        println!("{}", fig5b_serving_report());
    }
    if all || args.flag("lora-serving") {
        println!("{}", lora_serving_report());
    }
    if all || args.flag("prefix-serving") {
        println!("{}", prefix_serving_report());
    }
    if args.flag("gemv") {
        // timed study — explicit opt-in only (quick mode)
        println!("{}", gemv_perf_report(true));
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_verify(_argv: Vec<String>) -> anyhow::Result<()> {
    anyhow::bail!(
        "`bitrom verify` needs the PJRT runtime — rebuild with \
         `cargo build --release --features pjrt` (and a real xla binding)"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_verify(argv: Vec<String>) -> anyhow::Result<()> {
    let p = ArgParser::new("bitrom verify", "replay the python golden trace")
        .opt("artifacts", "", "artifact directory");
    let args = p.parse_from(argv).map_err(anyhow::Error::msg)?;
    let exec = ModelExecutor::load(&artifacts_dir(&args))?;
    let golden = exec
        .manifest
        .golden
        .clone()
        .ok_or_else(|| anyhow::anyhow!("manifest has no golden trace"))?;

    let (_, logits) = exec.prefill(&golden.prompt)?;
    let mut max_err = 0f32;
    for (a, b) in logits.data.iter().zip(&golden.prefill_last_logits) {
        max_err = max_err.max((a - b).abs());
    }
    println!("prefill logits max |err| vs python: {max_err:.2e}");
    anyhow::ensure!(max_err < 2e-3, "prefill logits diverge from python");

    let got = exec.generate_greedy(&golden.prompt, golden.generated.len())?;
    println!("python tokens: {:?}", golden.generated);
    println!("rust tokens:   {got:?}");
    anyhow::ensure!(got == golden.generated, "golden token mismatch");
    println!("verify OK — rust runtime reproduces the python model exactly");
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> anyhow::Result<()> {
    let p = ArgParser::new("bitrom info", "artifact + config summary")
        .opt("artifacts", "", "artifact directory");
    let args = p.parse_from(argv).map_err(anyhow::Error::msg)?;
    let m = Manifest::load(&artifacts_dir(&args))?;
    println!("model:        {}", m.model.name);
    println!("parameters:   {}", m.model.param_count());
    println!("partitions:   {} x {} layers", m.model.n_partitions, m.model.layers_per_partition());
    println!("prefill len:  {}", m.prefill_len);
    println!("max seq:      {}", m.model.max_seq);
    println!("ROM sparsity: {:.2}%", m.rom_sparsity * 100.0);
    println!("pallas:       {}", m.pallas_kernel);
    println!("trained ckpt: {}", m.trained_checkpoint);
    println!("artifacts:    {}", m.artifacts.len());
    let hw = HardwareConfig::default();
    println!(
        "bit density:  {:.0} kb/mm2 @65nm ({} macros for falcon3-1b)",
        hw.geometry.bit_density_kb_mm2(bitrom::config::TechNode::N65),
        hw.macros_for_weights(bitrom::config::ModelConfig::falcon3_1b().rom_param_count()),
    );
    Ok(())
}
