//! Self-contained substrates the coordinator is built on.
//!
//! This repository builds fully offline with only the `xla` and `anyhow`
//! crates available, so the usual ecosystem pieces (serde, clap, rand,
//! criterion, proptest, rayon) are implemented here from scratch — each
//! module is small, tested, and exactly as capable as this project
//! needs. [`pool`] is the crate-wide parallel execution substrate
//! (DESIGN.md §12).

pub mod args;
pub mod bench;
pub mod check;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod table;
