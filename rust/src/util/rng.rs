//! Deterministic PRNG (xoshiro256++) — no `rand` crate offline.
//!
//! Used by the workload generator, the property-test harness and the
//! macro simulator's synthetic inputs. Seeded runs are fully
//! reproducible across platforms (pure integer arithmetic).

/// xoshiro256++ generator state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator (splitmix64 state expansion).
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed, per the xoshiro reference.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi] inclusive. Panics if lo > hi.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range({lo}, {hi})");
        let span = hi - lo + 1;
        // Lemire-style rejection-free-enough reduction (bias < 2^-64·span,
        // negligible for simulation purposes).
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Uniform i64 in `[lo, hi]` inclusive.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.range(0, (hi - lo) as u64) as i64
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniformly pick one element.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Random ternary weight with the given zero probability — matches
    /// the sparsity statistics of absmean-quantized gaussian weights.
    pub fn trit(&mut self, p_zero: f64) -> i8 {
        if self.bool(p_zero) {
            0
        } else if self.bool(0.5) {
            1
        } else {
            -1
        }
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize(0, i);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_covers_endpoints() {
        let mut r = Rng::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            match r.range(0, 3) {
                0 => saw_lo = true,
                3 => saw_hi = true,
                1 | 2 => {}
                _ => panic!("out of range"),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn trit_sparsity_matches() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let zeros = (0..n).filter(|_| r.trit(0.3) == 0).count();
        let frac = zeros as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.01, "zero fraction {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
