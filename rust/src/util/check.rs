//! Mini property-based testing harness (no proptest offline).
//!
//! `check(seed, cases, |g| { ... })` runs a closure over `cases`
//! generated inputs; on failure it retries with progressively simpler
//! generator bounds (a lightweight stand-in for shrinking) and reports
//! the failing seed so the case replays deterministically.

use super::rng::Rng;

/// Generator handle passed to property closures.
pub struct Gen {
    /// Deterministic source driving the case.
    pub rng: Rng,
    /// Simplification level 0 (full size) ..= 3 (tiny). Generators are
    /// expected to scale their output size down with this.
    pub level: u32,
    /// Seed that replays this exact case.
    pub case_seed: u64,
}

impl Gen {
    /// Size helper: scales `max` down at higher simplification levels.
    pub fn size(&mut self, max: usize) -> usize {
        let max = (max >> self.level).max(1);
        self.rng.usize(1, max)
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize(lo, hi)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// Random trit with the given zero probability.
    pub fn trit(&mut self, p_zero: f64) -> i8 {
        self.rng.trit(p_zero)
    }

    /// Random trit vector.
    pub fn vec_trits(&mut self, len: usize, p_zero: f64) -> Vec<i8> {
        (0..len).map(|_| self.rng.trit(p_zero)).collect()
    }

    /// Random i8 vector in `[lo, hi]`.
    pub fn vec_i8(&mut self, len: usize, lo: i8, hi: i8) -> Vec<i8> {
        (0..len)
            .map(|_| self.rng.i64(lo as i64, hi as i64) as i8)
            .collect()
    }

    /// Standard-normal f32 vector.
    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal() as f32).collect()
    }
}

/// Outcome of a property: Ok(()) or an explanation of the violation.
pub type Prop = Result<(), String>;

/// Run `prop` over `cases` generated inputs. Panics (test failure) with
/// the failing case's seed and message.
pub fn check<F: FnMut(&mut Gen) -> Prop>(seed: u64, cases: u64, mut prop: F) {
    let mut meta = Rng::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        if let Err(msg) = run_case(case_seed, 0, &mut prop) {
            // try simpler levels to report the smallest reproduction
            for level in 1..=3 {
                if let Err(smaller) = run_case(case_seed, level, &mut prop) {
                    panic!(
                        "property failed (case {case}, seed {case_seed:#x}, \
                         simplification level {level}): {smaller}"
                    );
                }
            }
            panic!("property failed (case {case}, seed {case_seed:#x}): {msg}");
        }
    }
}

fn run_case<F: FnMut(&mut Gen) -> Prop>(case_seed: u64, level: u32, prop: &mut F) -> Prop {
    let mut g = Gen {
        rng: Rng::new(case_seed),
        level,
        case_seed,
    };
    prop(&mut g)
}

/// Assertion helpers producing `Prop`-friendly errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// `prop_assert!` for equality, reporting both operands on failure.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 50, |g| {
            let n = g.size(100);
            prop_assert!(n >= 1, "size must be positive, got {n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(2, 50, |g| {
            let n = g.usize(0, 10);
            prop_assert!(n < 10, "hit the bound: {n}");
            Ok(())
        });
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut collected = Vec::new();
        check(3, 5, |g| {
            collected.push(g.case_seed);
            Ok(())
        });
        let mut again = Vec::new();
        check(3, 5, |g| {
            again.push(g.case_seed);
            Ok(())
        });
        assert_eq!(collected, again);
    }

    #[test]
    fn size_scales_down_with_level() {
        let mut g = Gen {
            rng: Rng::new(1),
            level: 3,
            case_seed: 1,
        };
        for _ in 0..100 {
            assert!(g.size(64) <= 8);
        }
    }
}
