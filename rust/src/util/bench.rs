//! Micro-benchmark harness (no criterion offline).
//!
//! Warms up, then runs timed batches until a target measurement time is
//! reached; reports mean / median / p95 per-iteration latency and
//! throughput. Every `rust/benches/*.rs` target is built on this.

use std::time::{Duration, Instant};

use super::stats::Percentiles;

/// One benchmark's measured latency distribution.
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Mean per-iteration latency (ns).
    pub mean_ns: f64,
    /// Median latency (ns).
    pub median_ns: f64,
    /// 95th-percentile latency (ns).
    pub p95_ns: f64,
    /// Fastest observed iteration (ns).
    pub min_ns: f64,
}

impl BenchResult {
    /// Iterations per second at the mean latency.
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} iters  mean {:>12}  median {:>12}  p95 {:>12}  ({:.1}/s)",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.per_sec(),
        )
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner configuration (warmup + measurement budget).
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    max_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 1_000_000,
        }
    }
}

impl Bench {
    /// Short-budget configuration (CI smoke mode).
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            max_iters: 100_000,
        }
    }

    /// Override the measurement budget.
    pub fn with_measure(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    /// Benchmark `f`, which performs ONE iteration of the workload and
    /// returns a value that is black-boxed to prevent dead-code elision.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            black_box(f());
        }
        // measure
        let mut samples = Percentiles::new();
        let mut total = Duration::ZERO;
        let mut iters = 0u64;
        while total < self.measure && iters < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            let dt = t0.elapsed();
            samples.add(dt.as_nanos() as f64);
            total += dt;
            iters += 1;
        }
        let mean_ns = total.as_nanos() as f64 / iters.max(1) as f64;
        BenchResult {
            name: name.to_string(),
            iters,
            mean_ns,
            median_ns: samples.median(),
            p95_ns: samples.pct(95.0),
            min_ns: samples.pct(0.0),
        }
    }
}

/// Opaque value sink (stable `std::hint::black_box`).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Shared entry-point glue for bench binaries: honors BITROM_BENCH_QUICK
/// for fast CI runs.
pub fn bench_config() -> Bench {
    if std::env::var("BITROM_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

/// Where a bench binary should write its JSON record: BITROM_BENCH_OUT
/// if set, else `file` at the repository root (cargo runs benches with
/// cwd = the package root `rust/`, one level below it), else the
/// current directory. Shared by every record-emitting bench target.
pub fn bench_out_path(file: &str) -> std::path::PathBuf {
    if let Ok(p) = std::env::var("BITROM_BENCH_OUT") {
        return std::path::PathBuf::from(p);
    }
    if std::path::Path::new("../ROADMAP.md").exists() {
        std::path::PathBuf::from("..").join(file)
    } else {
        std::path::PathBuf::from(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let r = b.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.iters > 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p95_ns * 1.0001);
    }

    #[test]
    fn report_formats() {
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean_ns: 1500.0,
            median_ns: 1400.0,
            p95_ns: 2000.0,
            min_ns: 1000.0,
        };
        let s = r.report();
        assert!(s.contains("µs"), "{s}");
    }

    #[test]
    fn ns_formatting_ranges() {
        assert!(fmt_ns(10.0).contains("ns"));
        assert!(fmt_ns(10_000.0).contains("µs"));
        assert!(fmt_ns(10_000_000.0).contains("ms"));
        assert!(fmt_ns(10_000_000_000.0).contains(" s"));
    }
}
