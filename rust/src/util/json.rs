//! Minimal but complete JSON parser + writer (RFC 8259 subset: no
//! surrogate-pair escapes beyond \uXXXX handling, numbers as f64).
//!
//! Used for `artifacts/manifest.json`, experiment result files and
//! config load/save. Built from scratch because no serde is available
//! offline (DESIGN.md §3 `util/`).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value (numbers are f64, objects are ordered maps).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys for stable output).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with its byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset of the failure.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors -----------------------------------------------------

    /// Object field lookup (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["config", "d_model"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Number coerced to usize, if representable.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Number coerced to i64, if representable.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders ------------------------------------------------------

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a number.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Build a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a number array.
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    // ---- parsing -------------------------------------------------------

    /// Parse a complete JSON document.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Read and parse a JSON file.
    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // ---- writing --------------------------------------------------------

    /// Render with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Render without whitespace.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        format!("{}", n as i64)
    } else {
        format!("{}", n)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.at(&["c"]).unwrap().as_str(), Some("x"));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":-7}}"#;
        let j = Json::parse(src).unwrap();
        for text in [j.to_string_pretty(), j.to_string_compact()] {
            assert_eq!(Json::parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(2.5).to_string_compact(), "2.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap().to_string_compact(), "[]");
    }

    #[test]
    fn path_access_misses_return_none() {
        let j = Json::parse(r#"{"a":{"b":1}}"#).unwrap();
        assert!(j.at(&["a", "b"]).is_some());
        assert!(j.at(&["a", "c"]).is_none());
        assert!(j.at(&["x"]).is_none());
    }
}
