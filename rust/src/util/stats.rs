//! Descriptive statistics and latency histograms for the serving
//! metrics path (TTFT / TBT / throughput percentiles).

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty accumulator.
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold in one sample.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest sample (0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Exact-percentile reservoir (stores all samples; fine at serving scale).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    /// Empty reservoir.
    pub fn new() -> Self {
        Default::default()
    }

    /// Record one sample.
    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    /// Samples recorded.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn pct(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let n = self.samples.len();
        let rank = (q / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi.min(n - 1)] * frac
    }

    /// The 50th percentile.
    pub fn median(&mut self) -> f64 {
        self.pct(50.0)
    }
}

/// Fixed-bucket histogram for quick distribution summaries.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    /// Histogram over `[lo, hi)` with `n_buckets` equal buckets.
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(hi > lo && n_buckets > 0);
        Histogram {
            lo,
            hi,
            buckets: vec![0; n_buckets],
            underflow: 0,
            overflow: 0,
        }
    }

    /// Record one sample into its bucket.
    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo) * self.buckets.len() as f64)
                as usize;
            let last = self.buckets.len() - 1;
            self.buckets[idx.min(last)] += 1;
        }
    }

    /// Per-bucket counts (underflow/overflow excluded).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// All samples recorded, including under/overflow.
    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Render as a text sparkline for report output.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&c| BARS[(c * 7 / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_var() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn summary_empty_is_sane() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.pct(0.0) - 1.0).abs() < 1e-9);
        assert!((p.pct(100.0) - 100.0).abs() < 1e-9);
        assert!((p.median() - 50.5).abs() < 1e-9);
        assert!((p.pct(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn percentiles_single_sample() {
        let mut p = Percentiles::new();
        p.add(42.0);
        assert_eq!(p.pct(50.0), 42.0);
        assert_eq!(p.pct(99.0), 42.0);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.7, 9.9, -1.0, 100.0] {
            h.add(x);
        }
        assert_eq!(h.bucket_counts()[0], 1);
        assert_eq!(h.bucket_counts()[1], 2);
        assert_eq!(h.bucket_counts()[9], 1);
        assert_eq!(h.total(), 6);
        assert_eq!(h.sparkline().chars().count(), 10);
    }
}
