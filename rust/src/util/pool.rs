//! Scoped-thread worker pool — the crate-wide parallel execution
//! substrate (std-only, no new dependencies).
//!
//! A [`Pool`] is a *width*, not a set of live threads: every
//! fork-join call spawns `width` scoped workers (`std::thread::scope`),
//! splits the task range into contiguous chunks in index order, and
//! reassembles results in that same order. Scoped threads let workers
//! borrow the caller's data (weight planes, activation rows, per-slot
//! KV states) with no `Arc` cloning and no `'static` bounds, and the
//! static in-order chunking makes the decomposition deterministic: a
//! task's results never depend on which worker ran it or when.
//!
//! Width resolution: `BITROM_THREADS` (read once per process) is the
//! default everywhere; serving overrides it per deployment through
//! `ServeConfig::threads` / `--threads`. Width 1 is *exactly* the
//! serial path — no scope, no spawn, the closure runs inline on the
//! caller's thread — so single-threaded behavior is byte-for-byte the
//! pre-pool code path.
//!
//! Nesting is legal: a worker may itself fork a pool (the serving loop
//! shards slots across workers whose kernel calls shard columns). Each
//! fork is an independent `thread::scope`, so nested use cannot
//! deadlock — the cost is only transient oversubscription, which the
//! kernel-side work cutoffs keep small.
//!
//! Determinism contract (DESIGN.md §12): the pool itself never
//! reorders results. Callers keep bit-identity across widths by
//! ensuring each task's computation is independent of the others —
//! the bitplane kernels (per-column exact i64 accumulation) and the
//! serving loop (per-slot sequence state, coordinator-side KV
//! placement) both do.

use std::sync::OnceLock;

/// Process-wide default worker count: `BITROM_THREADS` if set to a
/// positive integer, else 1 (serial). Read once and cached — changing
/// the variable after the first call has no effect.
pub fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("BITROM_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1)
    })
}

/// The contiguous sub-range of `0..n` that worker `w` of `width` owns
/// (`[w·n/width, (w+1)·n/width)` — covers `0..n` exactly, near-even,
/// in index order).
pub fn chunk_bounds(n: usize, width: usize, w: usize) -> (usize, usize) {
    debug_assert!(w < width);
    (w * n / width, (w + 1) * n / width)
}

/// A fork-join worker pool of a fixed width (module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool of `threads` workers (0 is clamped to 1 = serial).
    pub fn new(threads: usize) -> Self {
        Pool {
            threads: threads.max(1),
        }
    }

    /// Pool at the process default width ([`env_threads`]).
    pub fn from_env() -> Self {
        Pool::new(env_threads())
    }

    /// The always-serial pool (width 1, inline execution).
    pub fn serial() -> Self {
        Pool::new(1)
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when calls run inline on the caller's thread.
    pub fn is_serial(&self) -> bool {
        self.threads == 1
    }

    /// Run `f(0), f(1), …, f(tasks-1)` across the pool and return the
    /// results in task order. Tasks are split into contiguous chunks
    /// (one per worker); width 1 or `tasks <= 1` runs inline.
    ///
    /// A panicking task propagates the panic to the caller after the
    /// scope joins every worker.
    pub fn run<T, F>(&self, tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let width = self.threads.min(tasks);
        if width <= 1 {
            return (0..tasks).map(f).collect();
        }
        let chunked: Vec<Vec<T>> = std::thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = (0..width)
                .map(|w| {
                    let (lo, hi) = chunk_bounds(tasks, width, w);
                    scope.spawn(move || (lo..hi).map(f).collect::<Vec<T>>())
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect()
        });
        chunked.into_iter().flatten().collect()
    }

    /// Map `f` over owned `items` across the pool, returning results
    /// in item order. Like [`Pool::run`] but each task consumes its
    /// item — the serving loop uses this to hand each worker exclusive
    /// `&mut` access to one slot's sequence state.
    pub fn map<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send,
        T: Send,
        F: Fn(I) -> T + Sync,
    {
        let n = items.len();
        let width = self.threads.min(n);
        if width <= 1 {
            return items.into_iter().map(f).collect();
        }
        // split into in-order chunks of owned items, one per worker
        let mut chunks: Vec<Vec<I>> = Vec::with_capacity(width);
        let mut items = items.into_iter();
        for w in 0..width {
            let (lo, hi) = chunk_bounds(n, width, w);
            chunks.push(items.by_ref().take(hi - lo).collect());
        }
        let chunked: Vec<Vec<T>> = std::thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = chunks
                .into_iter()
                .map(|chunk| scope.spawn(move || chunk.into_iter().map(f).collect::<Vec<T>>()))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect()
        });
        chunked.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_is_clamped_to_at_least_one() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert!(Pool::new(0).is_serial());
        assert_eq!(Pool::serial().threads(), 1);
        assert!(Pool::new(4).threads() == 4 && !Pool::new(4).is_serial());
    }

    #[test]
    fn chunks_cover_the_range_in_order() {
        for n in [0usize, 1, 5, 7, 64, 100] {
            for width in [1usize, 2, 3, 4, 7, 13] {
                let mut next = 0;
                for w in 0..width {
                    let (lo, hi) = chunk_bounds(n, width, w);
                    assert_eq!(lo, next, "gap at n={n} width={width} w={w}");
                    assert!(hi >= lo);
                    next = hi;
                }
                assert_eq!(next, n, "range not covered at n={n} width={width}");
            }
        }
    }

    #[test]
    fn run_preserves_task_order_at_every_width() {
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        for threads in [1usize, 2, 4, 7, 128] {
            let got = Pool::new(threads).run(100, |i| i * i);
            assert_eq!(got, want, "threads {threads}");
        }
    }

    #[test]
    fn run_handles_degenerate_task_counts() {
        let p = Pool::new(4);
        assert!(p.run(0, |i| i).is_empty());
        assert_eq!(p.run(1, |i| i + 10), vec![10]);
        // more workers than tasks: width collapses to the task count
        assert_eq!(Pool::new(64).run(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn map_consumes_items_in_order() {
        let items: Vec<String> = (0..17).map(|i| format!("it{i}")).collect();
        let want: Vec<String> = items.iter().map(|s| format!("{s}!")).collect();
        for threads in [1usize, 2, 4, 7] {
            let got = Pool::new(threads).map(items.clone(), |s| format!("{s}!"));
            assert_eq!(got, want, "threads {threads}");
        }
        assert!(Pool::new(3).map(Vec::<u8>::new(), |b| b).is_empty());
    }

    #[test]
    fn map_supports_exclusive_mutable_items() {
        // the serving-loop pattern: each item carries &mut to disjoint
        // state; workers mutate concurrently without any locking
        let mut cells = vec![0u64; 9];
        let items: Vec<(usize, &mut u64)> = cells.iter_mut().enumerate().collect();
        Pool::new(4).map(items, |(i, c)| *c = i as u64 + 1);
        assert_eq!(cells, (1..=9).collect::<Vec<u64>>());
    }

    #[test]
    fn nested_forks_join_cleanly() {
        // a worker may fork its own pool (parallel serve step calling
        // sharded kernels): results stay ordered at both levels
        let got = Pool::new(4).run(6, |outer| {
            let inner = Pool::new(3).run(5, |i| (outer * 10 + i) as u64);
            inner.iter().sum::<u64>()
        });
        let want: Vec<u64> = (0..6)
            .map(|o| (0..5).map(|i| (o * 10 + i) as u64).sum())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn worker_panic_propagates_to_the_caller() {
        let res = std::panic::catch_unwind(|| {
            Pool::new(2).run(4, |i| {
                if i == 3 {
                    panic!("boom");
                }
                i
            })
        });
        assert!(res.is_err(), "panic must not be swallowed");
    }

    #[test]
    fn env_default_is_serial_when_unset() {
        // the test environment does not set BITROM_THREADS; the cached
        // default must then be the serial width (and from_env agrees)
        if std::env::var("BITROM_THREADS").is_err() {
            assert_eq!(env_threads(), 1);
            assert!(Pool::from_env().is_serial());
        }
    }
}
