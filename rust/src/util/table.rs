//! Aligned text tables for report output (paper tables/figures are
//! regenerated as text rows that mirror the published layout).

/// Builder for an aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with a title line.
    pub fn new(title: &str) -> Self {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    /// Set the header row.
    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Append a data row.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a data row of string slices.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        self.rows
            .push(cells.iter().map(|s| s.to_string()).collect());
        self
    }

    /// Data rows added so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render title + aligned rows as text.
    pub fn render(&self) -> String {
        let n_cols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; n_cols];
        let measure = |widths: &mut Vec<usize>, row: &[String]| {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        };
        measure(&mut widths, &self.header);
        for r in &self.rows {
            measure(&mut widths, r);
        }

        let fmt_row = |row: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let cell = row.get(i).map(|s| s.as_str()).unwrap_or("");
                let pad = w - cell.chars().count();
                s.push(' ');
                s.push_str(cell);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let sep = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('|');
            }
            s
        };

        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &widths));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format helpers used across reports.
pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

/// Format a fraction as a percentage like `43.6%`.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

/// Format with an SI magnitude suffix (k/M/G/T).
pub fn fmt_si(x: f64) -> String {
    let (val, suffix) = if x.abs() >= 1e12 {
        (x / 1e12, "T")
    } else if x.abs() >= 1e9 {
        (x / 1e9, "G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "k")
    } else {
        (x, "")
    };
    format!("{val:.2}{suffix}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["name", "value"]);
        t.row_str(&["a", "1"]);
        t.row_str(&["longer", "22"]);
        let s = t.render();
        assert!(s.contains("## demo"));
        let lines: Vec<&str> = s.lines().collect();
        // all body lines equal width
        let w = lines[1].len();
        assert!(lines[2..].iter().all(|l| l.len() == w), "{s}");
    }

    #[test]
    fn handles_ragged_rows() {
        let mut t = Table::new("").header(&["a", "b", "c"]);
        t.row_str(&["1"]);
        let s = t.render();
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn si_format() {
        assert_eq!(fmt_si(20.8e12), "20.80T");
        assert_eq!(fmt_si(4967e3), "4.97M");
        assert_eq!(fmt_si(5.0), "5.00");
    }

    #[test]
    fn pct_format() {
        assert_eq!(fmt_pct(0.436), "43.6%");
    }
}
