//! Tiny declarative CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! per-command help text. Used by `main.rs`, examples and benches.

use std::collections::BTreeMap;

/// One declared option or flag.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    /// Option name (without the `--`).
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// Default value (None for flags).
    pub default: Option<&'static str>,
    /// True for boolean flags.
    pub is_flag: bool,
}

/// Parsed argument values.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declarative parser: declare options/flags, then parse.
#[derive(Debug)]
pub struct ArgParser {
    program: &'static str,
    about: &'static str,
    specs: Vec<ArgSpec>,
}

impl ArgParser {
    /// Parser for `program` with a one-line description.
    pub fn new(program: &'static str, about: &'static str) -> Self {
        ArgParser {
            program,
            about,
            specs: Vec::new(),
        }
    }

    /// Declare a `--name <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Render the help text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let left = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <value>", spec.name)
            };
            s.push_str(&format!("{left:<28}{}", spec.help));
            if let Some(d) = spec.default {
                s.push_str(&format!(" [default: {d}]"));
            }
            s.push('\n');
        }
        s
    }

    /// Parse an iterator of argument strings (exclude argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} requires a value"))?,
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments, exiting with usage on error.
    pub fn parse_env(&self) -> Args {
        // skip argv[0]; examples under `cargo run --example` see clean argv
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Value of `--key` (panics if undeclared).
    pub fn str(&self, key: &str) -> &str {
        self.get(key)
            .unwrap_or_else(|| panic!("missing option --{key} (no default)"))
    }

    /// `--key` parsed as usize.
    pub fn usize(&self, key: &str) -> usize {
        self.str(key)
            .parse()
            .unwrap_or_else(|e| panic!("--{key}: {e}"))
    }

    /// `--key` parsed as u64.
    pub fn u64(&self, key: &str) -> u64 {
        self.str(key)
            .parse()
            .unwrap_or_else(|e| panic!("--{key}: {e}"))
    }

    /// `--key` parsed as f64.
    pub fn f64(&self, key: &str) -> f64 {
        self.str(key)
            .parse()
            .unwrap_or_else(|e| panic!("--{key}: {e}"))
    }

    /// True when `--key` was passed.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Positional (non-option) arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> ArgParser {
        ArgParser::new("t", "test")
            .opt("count", "4", "how many")
            .opt("name", "x", "a name")
            .flag("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Result<Args, String> {
        parser().parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.usize("count"), 4);
        assert_eq!(a.str("name"), "x");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parse(&["--count", "7", "--name=zed"]).unwrap();
        assert_eq!(a.usize("count"), 7);
        assert_eq!(a.str("name"), "zed");
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["pos1", "--verbose", "pos2"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse(&["--nope"]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&["--count"]).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(parse(&["--verbose=1"]).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = parse(&["--help"]).unwrap_err();
        assert!(err.contains("--count"));
        assert!(err.contains("how many"));
    }
}
