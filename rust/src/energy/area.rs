//! Silicon-area estimation (Fig 1a) and bit-density computations.

use crate::config::{HardwareConfig, ModelConfig, TechNode, BITS_PER_CELL};

/// Bit density of the prior digital CiROM generation (DCiROM [1],
/// ASPDAC'25: 487 kb/mm² at 65nm — dominated by its per-group adder
/// trees). Fig 1(a)'s "existing CiROM cannot hold an LLM" baseline.
pub const PRIOR_DIGITAL_CIROM_KB_MM2: f64 = 487.0;

/// One point on the Fig 1(a) sweep.
#[derive(Debug, Clone)]
pub struct ModelPoint {
    /// Display name.
    pub name: String,
    /// Weight parameter count.
    pub params: u64,
    /// Bits per weight as stored (16 = fp16 CiROM baseline, 8/4 =
    /// quantized baselines, log2(3) = ternary BitROM).
    pub bits_per_weight: f64,
    /// true → placed in BitROM's BiROMA fabric (two trits/transistor);
    /// false → placed at prior digital CiROM density.
    pub bitrom_fabric: bool,
}

impl ModelPoint {
    /// An fp16 baseline point (prior CiROM fabric).
    pub fn fp16(name: &str, params: u64) -> Self {
        ModelPoint {
            name: name.into(),
            params,
            bits_per_weight: 16.0,
            bitrom_fabric: false,
        }
    }

    /// A 1.58-bit point on the BitROM fabric.
    pub fn ternary(name: &str, params: u64) -> Self {
        ModelPoint {
            name: name.into(),
            params,
            bits_per_weight: BITS_PER_CELL / 2.0, // one trit
            bitrom_fabric: true,
        }
    }

    /// A point taken from a [`ModelConfig`]'s parameter count.
    pub fn from_model(cfg: &ModelConfig, bits_per_weight: f64, bitrom: bool) -> Self {
        ModelPoint {
            name: cfg.name.clone(),
            params: cfg.param_count(),
            bits_per_weight,
            bitrom_fabric: bitrom,
        }
    }
}

/// Area result for a (model, node) pair.
#[derive(Debug, Clone)]
pub struct AreaEstimate {
    /// Model name the estimate is for.
    pub name: String,
    /// Technology node.
    pub node: TechNode,
    /// ROM area in mm².
    pub rom_mm2: f64,
    /// ROM area in cm² (the Fig 1(a) axis).
    pub rom_cm2: f64,
    /// Macros required (0 for non-BitROM fabrics).
    pub n_macros: u64,
}

/// Estimate CiROM silicon area for a model at a node.
///
/// BitROM-fabric points use the calibrated BiROMA density (two ternary
/// weights per transistor + 4.8% periphery); baseline points use the
/// prior digital CiROM density, both spatially scaled with the node.
/// This reproduces the Fig 1(a) shape: fp16 LLaMA-7B-class models need
/// >10³ cm² of prior CiROM at 65nm and >10² cm² even at 14nm, while
/// ternary BitNet-1B on BitROM drops to single-digit cm² at 65nm.
pub fn area_estimate(hw: &HardwareConfig, model: &ModelPoint, node: TechNode) -> AreaEstimate {
    let g = &hw.geometry;
    let bits = model.params as f64 * model.bits_per_weight;
    let density_bits_mm2 = if model.bitrom_fabric {
        g.bit_density_kb_mm2(node) * 1e3
    } else {
        PRIOR_DIGITAL_CIROM_KB_MM2 * 1e3 * node.density_scale_vs_65()
    };
    let rom_mm2 = bits / density_bits_mm2;
    let per_macro_bits = g.bits_per_macro();
    AreaEstimate {
        name: model.name.clone(),
        node,
        rom_mm2,
        rom_cm2: rom_mm2 / 100.0,
        n_macros: (bits / per_macro_bits).ceil() as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HardwareConfig {
        HardwareConfig::default()
    }

    #[test]
    fn llama7b_fp16_is_impractical() {
        // Fig 1(a): the motivating claim — LLaMA-7B on existing CiROM
        // exceeds 1,000 cm² (we measure >2,000 at 65nm and >100 even
        // with ideal 14nm scaling).
        let m = ModelPoint::fp16("llama-7b", 6_738_000_000);
        let a65 = area_estimate(&hw(), &m, TechNode::N65);
        assert!(a65.rom_cm2 > 1000.0, "65nm: {} cm²", a65.rom_cm2);
        let a14 = area_estimate(&hw(), &m, TechNode::N14);
        assert!(a14.rom_cm2 > 100.0, "14nm: {} cm²", a14.rom_cm2);
        assert!(a65.rom_cm2 > a14.rom_cm2 * 20.0);
    }

    #[test]
    fn bitnet_1b_is_single_digit_cm2_on_bitrom() {
        // Fig 1(a): ternary + BiROMA closes the gap.
        let cfg = ModelConfig::falcon3_1b();
        let m = ModelPoint::ternary("falcon3-1b", cfg.param_count());
        let a65 = area_estimate(&hw(), &m, TechNode::N65);
        assert!(
            (1.0..20.0).contains(&a65.rom_cm2),
            "65nm: {} cm²",
            a65.rom_cm2
        );
        let a14 = area_estimate(&hw(), &m, TechNode::N14);
        assert!(a14.rom_cm2 < 1.0, "14nm: {} cm²", a14.rom_cm2);
    }

    #[test]
    fn bitrom_fabric_vs_prior_cirom_is_10x_per_bit() {
        // same bit count placed on both fabrics: BitROM's density win.
        let m_prior = ModelPoint {
            name: "x".into(),
            params: 1_000_000_000,
            bits_per_weight: 1.0,
            bitrom_fabric: false,
        };
        let m_bitrom = ModelPoint {
            name: "x".into(),
            params: 1_000_000_000,
            bits_per_weight: 1.0,
            bitrom_fabric: true,
        };
        let a_prior = area_estimate(&hw(), &m_prior, TechNode::N65);
        let a_bitrom = area_estimate(&hw(), &m_bitrom, TechNode::N65);
        let ratio = a_prior.rom_mm2 / a_bitrom.rom_mm2;
        assert!(ratio > 10.0, "ratio {ratio:.1}");
    }

    #[test]
    fn node_scaling_is_spatial() {
        let m = ModelPoint::ternary("t", 1_000_000_000);
        let a65 = area_estimate(&hw(), &m, TechNode::N65);
        let a28 = area_estimate(&hw(), &m, TechNode::N28);
        let want = (65.0f64 / 28.0).powi(2);
        assert!((a65.rom_mm2 / a28.rom_mm2 - want).abs() < 1e-9);
    }

    #[test]
    fn macro_count_for_falcon3_rom() {
        let cfg = ModelConfig::falcon3_1b();
        let m = ModelPoint::ternary("f1b", cfg.rom_param_count());
        let a = area_estimate(&hw(), &m, TechNode::N65);
        assert_eq!(a.n_macros, hw().macros_for_weights(cfg.rom_param_count()));
    }
}
