//! Adapter task-switch energy: the joule face of the reload-free
//! claim. Switching a sequence to another tenant's LoRA adapter costs
//! at most one cold stream of that adapter's quantized bytes over the
//! external interface (and nothing once resident); a weight-loaded
//! accelerator would instead re-read its entire parameter set. This
//! type extracts the measured switch energy from a [`LoraServeStats`]
//! snapshot and prices the hypothetical reload on the same interface,
//! so `report::lora_serving` can show both next to each other.

use crate::dram::DramParams;
use crate::lora::LoraServeStats;

/// Joule breakdown of a trace's adapter-switch traffic.
#[derive(Debug, Clone, Default)]
pub struct AdapterEnergy {
    /// Energy spent streaming adapter weights on cold loads (J).
    pub stream_j: f64,
    /// Bytes streamed by those cold loads.
    pub bytes_streamed: u64,
    /// Cold loads that caused the streaming.
    pub cold_loads: u64,
}

impl AdapterEnergy {
    /// Extract the switch energy from a registry's measured statistics.
    pub fn from_stats(stats: &LoraServeStats) -> Self {
        AdapterEnergy {
            stream_j: stats.stream_energy_j,
            bytes_streamed: stats.bytes_streamed,
            cold_loads: stats.cold_loads,
        }
    }

    /// Mean energy of one cold task switch, J.
    pub fn per_cold_load_j(&self) -> f64 {
        if self.cold_loads == 0 {
            0.0
        } else {
            self.stream_j / self.cold_loads as f64
        }
    }

    /// What a full weight reload of `reload_bytes` would cost on the
    /// same external interface — the price BitROM's fixed mask set
    /// never pays.
    pub fn reload_j(reload_bytes: u64, dram: &DramParams) -> f64 {
        reload_bytes as f64 * dram.read_pj_per_byte * 1e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::lora::{AdapterRegistry, LoraConfig};

    #[test]
    fn extracts_the_measured_switch_traffic() {
        let stats = LoraServeStats {
            binds: 5,
            cold_loads: 2,
            bytes_streamed: 2048,
            stream_energy_j: 4e-8,
            ..LoraServeStats::default()
        };
        let e = AdapterEnergy::from_stats(&stats);
        assert_eq!(e.bytes_streamed, 2048);
        assert!((e.per_cold_load_j() - 2e-8).abs() < 1e-20);
        assert_eq!(AdapterEnergy::from_stats(&LoraServeStats::default()).per_cold_load_j(), 0.0);
    }

    #[test]
    fn cold_switch_is_far_cheaper_than_a_full_reload() {
        // the paper's deployment target: streaming the 6-bit VOD r16
        // adapter vs re-reading the whole packed ternary mask set over
        // the same LPDDR-class interface
        let falcon = ModelConfig::falcon3_1b();
        let dram = DramParams::default();
        let switch_j =
            LoraConfig::paper().storage_bytes(&falcon) as f64 * dram.read_pj_per_byte * 1e-12;
        let reload_j =
            AdapterEnergy::reload_j(AdapterRegistry::full_reload_bytes_for(&falcon), &dram);
        assert!(
            switch_j * 10.0 < reload_j,
            "switch {switch_j} J vs reload {reload_j} J"
        );
    }
}
