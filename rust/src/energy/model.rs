//! Event-count → energy conversion and TOPS/W computation.

use crate::cirom::EventCounters;
use crate::config::{HardwareConfig, ModelConfig};

/// Joule breakdown of a macro workload.
#[derive(Debug, Clone, Default)]
pub struct EnergyBreakdown {
    /// BiROMA read energy (J).
    pub read_j: f64,
    /// TriMLA accumulate energy (J).
    pub accum_j: f64,
    /// Global adder-tree energy (J).
    pub tree_j: f64,
    /// Control/clock/comparator overhead (J).
    pub ctrl_j: f64,
}

impl EnergyBreakdown {
    /// Sum of all terms (J).
    pub fn total_j(&self) -> f64 {
        self.read_j + self.accum_j + self.tree_j + self.ctrl_j
    }
}

/// The analytical model bound to a hardware config (node + voltage).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// The hardware configuration (node + voltage) being modeled.
    pub hw: HardwareConfig,
}

impl EnergyModel {
    /// Model bound to `hw`.
    pub fn new(hw: HardwareConfig) -> Self {
        EnergyModel { hw }
    }

    /// Convert an activity trace to joules at the config's voltage.
    ///
    /// Control energy is charged per TriMLA-cycle *slot* (active or
    /// skipped — the comparators and column selectors toggle either
    /// way), which is `weight_reads`; the zero-skip saving applies only
    /// to the accumulate term, exactly as in the circuit.
    pub fn energy(&self, ev: &EventCounters) -> EnergyBreakdown {
        let e = &self.hw.energy;
        let vs = e.v_scale(self.hw.vdd);
        let fj = 1e-15;
        EnergyBreakdown {
            read_j: ev.weight_reads as f64 * e.read_fj * vs * fj,
            accum_j: ev.accums as f64 * e.accum_fj * vs * fj,
            tree_j: ev.tree_passes as f64 * e.tree_pass_fj * vs * fj,
            ctrl_j: ev.weight_reads as f64 * e.ctrl_fj * vs * fj,
        }
    }

    /// TOPS/W for an activity trace: ops / joules / 1e12.
    pub fn tops_per_watt(&self, ev: &EventCounters) -> f64 {
        let j = self.energy(ev).total_j();
        if j == 0.0 {
            return 0.0;
        }
        ev.ops() as f64 / j / 1e12
    }

    /// Closed-form TOPS/W for a workload with the given zero-weight
    /// fraction and activation bits — the design-point calculator used
    /// by Table III (agrees with the simulator, see tests).
    pub fn tops_per_watt_analytic(&self, sparsity: f64, act_bits: usize) -> f64 {
        let e = &self.hw.energy;
        let vs = e.v_scale(self.hw.vdd);
        let serial = if act_bits == 8 { 2.0 } else { 1.0 };
        // per-MAC slot events: `serial` reads/ctrl slots, accum on
        // non-zero weights per pass, amortized tree share
        let g = &self.hw.geometry;
        let macs_per_tree = (g.n_trimla() * g.cols_per_trimla) as f64;
        let per_mac_fj = serial * (e.read_fj + e.ctrl_fj)
            + serial * (1.0 - sparsity) * e.accum_fj
            + serial * e.tree_pass_fj / macs_per_tree;
        2.0 / (per_mac_fj * vs * 1e-15) / 1e12
    }

    /// End-to-end per-token performance estimate for a model mapped on
    /// this hardware (paper §V-B style): all linear projections run on
    /// macros; embeddings/attention/softmax on the auxiliary processor
    /// are excluded from the TOPS/W metric, as in the paper.
    pub fn per_token(&self, model: &ModelConfig, sparsity: f64) -> PerfEstimate {
        let macs = model.rom_param_count() as f64;
        let e = &self.hw.energy;
        let vs = e.v_scale(self.hw.vdd);
        let serial = if model.act_bits == 8 { 2.0 } else { 1.0 };
        let g = &self.hw.geometry;
        let macs_per_tree = (g.n_trimla() * g.cols_per_trimla) as f64;
        let per_mac_fj = serial * (e.read_fj + e.ctrl_fj)
            + serial * (1.0 - sparsity) * e.accum_fj
            + serial * e.tree_pass_fj / macs_per_tree;
        let energy_j = macs * per_mac_fj * vs * 1e-15;

        // throughput: macros operate in parallel; each macro retires
        // n_trimla MACs per cycle (one column-select step).
        let n_macros = self.hw.macros_for_weights(model.rom_param_count()) as f64;
        let macs_per_cycle = n_macros * g.n_trimla() as f64;
        let cycles = macs * serial / macs_per_cycle;
        let latency_s = cycles / e.clk_hz(self.hw.vdd);

        PerfEstimate {
            energy_per_token_j: energy_j,
            latency_per_token_s: latency_s,
            tokens_per_s: 1.0 / latency_s,
            avg_power_w: energy_j / latency_s,
            n_macros: n_macros as u64,
        }
    }
}

/// Per-token performance summary.
#[derive(Debug, Clone)]
pub struct PerfEstimate {
    /// Projection energy per generated token (J).
    pub energy_per_token_j: f64,
    /// Token latency (s).
    pub latency_per_token_s: f64,
    /// Decode throughput (1 / latency).
    pub tokens_per_s: f64,
    /// Average power draw (W).
    pub avg_power_w: f64,
    /// Macros the model maps onto.
    pub n_macros: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitnet::{absmax_quantize, TernaryMatrix};
    use crate::cirom::{BitRomMacro, EventCounters};
    use crate::config::TechNode;
    use crate::util::rng::Rng;

    /// Nominal BitNet sparsity used for the Table III design point
    /// (≈ absmean-ternarized gaussian weights; our Falcon3-tiny ROM
    /// measures 0.31).
    const NOMINAL_SPARSITY: f64 = 0.30;

    #[test]
    fn table3_energy_point_0v6() {
        // Paper Table III "This Work": 20.8 TOPS/W at 0.6 V, 4b acts.
        let m = EnergyModel::new(HardwareConfig::default());
        let t = m.tops_per_watt_analytic(NOMINAL_SPARSITY, 4);
        assert!((t - 20.8).abs() < 0.15, "got {t:.2} TOPS/W");
    }

    #[test]
    fn table3_energy_point_1v2_follows_cv2() {
        // 5.2 TOPS/W at 1.2 V — zero extra degrees of freedom.
        let m = EnergyModel::new(HardwareConfig::default().at_voltage(1.2));
        let t = m.tops_per_watt_analytic(NOMINAL_SPARSITY, 4);
        assert!((t - 5.2).abs() < 0.05, "got {t:.2} TOPS/W");
    }

    #[test]
    fn analytic_agrees_with_simulator() {
        // The closed form and the event-counting simulator must agree.
        let mut rng = Rng::new(17);
        let geom = crate::config::MacroGeometry::default();
        let w = TernaryMatrix::random(2048, 4, NOMINAL_SPARSITY, &mut rng);
        let mac = BitRomMacro::fabricate(geom, &w);
        let x: Vec<f32> = (0..2048).map(|_| rng.normal() as f32).collect();
        let acts = absmax_quantize(&x, 4);
        let mut ev = EventCounters::new();
        mac.gemv(&acts, &mut ev);
        let m = EnergyModel::new(HardwareConfig::default());
        let sim = m.tops_per_watt(&ev);
        let ana = m.tops_per_watt_analytic(w.sparsity(), 4);
        let rel = (sim - ana).abs() / ana;
        assert!(rel < 0.02, "sim {sim:.2} vs analytic {ana:.2}");
    }

    #[test]
    fn sparsity_improves_efficiency() {
        let m = EnergyModel::new(HardwareConfig::default());
        let dense = m.tops_per_watt_analytic(0.0, 4);
        let sparse = m.tops_per_watt_analytic(0.5, 4);
        assert!(sparse > dense * 1.15, "dense {dense:.1} sparse {sparse:.1}");
    }

    #[test]
    fn bit_serial_8b_costs_about_half() {
        let m = EnergyModel::new(HardwareConfig::default());
        let t4 = m.tops_per_watt_analytic(NOMINAL_SPARSITY, 4);
        let t8 = m.tops_per_watt_analytic(NOMINAL_SPARSITY, 8);
        let ratio = t4 / t8;
        assert!((1.8..2.2).contains(&ratio), "4b/8b ratio {ratio:.2}");
    }

    #[test]
    fn falcon3_1b_per_token_budget() {
        // §V-B deployment sanity: TBT far below the 64 ms eDRAM tREF —
        // the premise of the refresh-on-read argument.
        let m = EnergyModel::new(HardwareConfig::default());
        let p = m.per_token(&ModelConfig::falcon3_1b(), NOMINAL_SPARSITY);
        assert!(p.latency_per_token_s < 0.064, "TBT {}", p.latency_per_token_s);
        assert!(p.n_macros > 250 && p.n_macros < 300);
        // edge power envelope: sub-watt at 0.6V
        assert!(p.avg_power_w < 1.0, "power {}", p.avg_power_w);
    }

    #[test]
    fn node_does_not_change_tops_per_watt_model() {
        // our first-order model scales only area with node (the paper's
        // Table III normalization handles energy); TOPS/W is reported
        // at the implementation node.
        let a = EnergyModel::new(HardwareConfig::default());
        let b = EnergyModel::new(HardwareConfig::default().at_node(TechNode::N28));
        assert_eq!(
            a.tops_per_watt_analytic(0.3, 4),
            b.tops_per_watt_analytic(0.3, 4)
        );
    }
}
