//! Analytical energy / area / performance model.
//!
//! Converts `cirom::EventCounters` activity into joules using the
//! calibrated per-event constants (`config::EnergyParams`), computes
//! TOPS/W at any operating voltage, bit density and silicon area at any
//! node — the machinery behind Table III and Fig 1(a). See
//! `config::hardware` module docs for exactly which constants are
//! fitted vs derived. [`KvEnergy`] adds the memory side: the measured
//! KV-cache energy of a served trace, split by tier (the energy face
//! of the Fig 5(b) claim). [`AdapterEnergy`] prices tenant task
//! switches (cold adapter streams vs the full weight reload they
//! replace — the energy face of the reload-free claim).

mod area;
mod kv;
mod lora;
mod model;

pub use area::{area_estimate, AreaEstimate, ModelPoint};
pub use kv::KvEnergy;
pub use lora::AdapterEnergy;
pub use model::{EnergyBreakdown, EnergyModel, PerfEstimate};
