//! Analytical energy / area / performance model.
//!
//! Converts `cirom::EventCounters` activity into joules using the
//! calibrated per-event constants (`config::EnergyParams`), computes
//! TOPS/W at any operating voltage, bit density and silicon area at any
//! node — the machinery behind Table III and Fig 1(a). See
//! `config::hardware` module docs for exactly which constants are
//! fitted vs derived.

mod area;
mod model;

pub use area::{area_estimate, AreaEstimate, ModelPoint};
pub use model::{EnergyBreakdown, EnergyModel, PerfEstimate};
