//! KV-memory energy: joules of a served trace's KV traffic, split by
//! tier — the energy face of the Fig 5(b) access-reduction claim.
//!
//! The per-byte costs live in the tier models themselves
//! (`EdramParams` / `DramParams`: on-die eDRAM is ~15x cheaper per
//! byte than the LPDDR-class external interface), and the store
//! integrates them as traffic happens. This type extracts the result
//! from a [`KvStoreStats`] snapshot so serving reports and the
//! Fig 5(b) end-to-end reproduction can show energy next to access
//! counts.

use crate::kvcache::KvStoreStats;

/// Joule breakdown of a trace's KV-cache traffic by tier.
#[derive(Debug, Clone, Default)]
pub struct KvEnergy {
    /// DR-eDRAM (on-die tier) energy, J.
    pub ondie_j: f64,
    /// External-DRAM energy, J — eviction/spill traffic included.
    pub external_j: f64,
}

impl KvEnergy {
    /// Extract the tier energies from a store's measured statistics.
    pub fn from_stats(kv: &KvStoreStats) -> Self {
        KvEnergy {
            ondie_j: kv.edram_energy_j,
            external_j: kv.dram_energy_j,
        }
    }

    /// Total KV memory energy, J.
    pub fn total_j(&self) -> f64 {
        self.ondie_j + self.external_j
    }

    /// Fraction of the KV energy spent on the external interface —
    /// the quantity the paper's early-token buffering attacks.
    pub fn external_fraction(&self) -> f64 {
        let t = self.total_j();
        if t == 0.0 {
            0.0
        } else {
            self.external_j / t
        }
    }

    /// Mean KV memory energy per token, J.
    pub fn per_token_j(&self, tokens: u64) -> f64 {
        if tokens == 0 {
            0.0
        } else {
            self.total_j() / tokens as f64
        }
    }

    /// Fraction of the baseline's external-interface energy this run
    /// avoided (the energy face of a traffic-reduction claim — used by
    /// the shared-prefix serving ledger to compare against its
    /// private-KV twin). 0 when the baseline spent nothing.
    pub fn external_savings_vs(&self, baseline: &KvEnergy) -> f64 {
        if baseline.external_j == 0.0 {
            0.0
        } else {
            1.0 - self.external_j / baseline.external_j
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EdramParams, ModelConfig};
    use crate::dram::DramParams;
    use crate::kvcache::{KvQuant, KvStore, KvStoreConfig};
    use crate::util::rng::Rng;

    /// Decode `s` tokens through a store with `b` on-die tokens and
    /// return the KV energy.
    fn run(s: usize, b: usize) -> KvEnergy {
        let model = ModelConfig::sim_tiny();
        let mut store = KvStore::new(KvStoreConfig {
            kv_dim: model.kv_dim(),
            n_layers: 1,
            block_tokens: 8,
            ondie_tokens: b,
            quant: KvQuant::Q8,
            edram: EdramParams::default(),
            dram: DramParams::default(),
        });
        let mut seq = store.new_seq();
        let mut rng = Rng::new(9);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        for t in 0..s {
            store.set_now(t as f64 * 0.005);
            let row: Vec<f32> = (0..model.kv_dim()).map(|_| rng.normal() as f32).collect();
            store.append(&mut seq, 0, &row, &row);
            store.gather(&seq, 0, t + 1, true, &mut k, &mut v).unwrap();
        }
        KvEnergy::from_stats(&store.stats())
    }

    #[test]
    fn tier_split_matches_store_counters() {
        let e = run(64, 16);
        assert!(e.ondie_j > 0.0 && e.external_j > 0.0);
        assert!((e.total_j() - (e.ondie_j + e.external_j)).abs() < 1e-18);
        assert!(e.per_token_j(64) > 0.0);
        assert!((0.0..=1.0).contains(&e.external_fraction()));
    }

    #[test]
    fn buffering_early_tokens_cuts_external_energy() {
        // the energy twin of Fig 5(b): the same decode with 32 tokens
        // buffered on-die spends far less on the external interface
        // than with none, and external DRAM dominates when unbuffered
        // (it is ~15x more expensive per byte)
        let none = run(128, 0);
        let buffered = run(128, 32);
        assert_eq!(none.ondie_j, 0.0);
        assert!(buffered.external_j < none.external_j * 0.62);
        assert!(none.external_fraction() > 0.99);
        assert!(buffered.external_fraction() < 1.0);
        // cheaper on-die bytes: total energy drops too
        assert!(buffered.total_j() < none.total_j());
        // and the savings comparator agrees with the raw joules
        let s = buffered.external_savings_vs(&none);
        assert!(s > 0.38 && s < 1.0, "savings {s}");
        assert_eq!(KvEnergy::default().external_savings_vs(&KvEnergy::default()), 0.0);
    }
}
