//! Rust mirror of the python quantizers (`compile/quant.py`).
//!
//! The macro simulator quantizes its own inputs so it can be exercised
//! without the python stack; the cross-language tests pin both sides to
//! the same arithmetic.

use super::Trit;

/// BitNet b1.58 absmean ternary quantization.
/// Returns `(trits, scale)` with `w ≈ trit * scale`.
pub fn absmean_ternary(w: &[f32]) -> (Vec<Trit>, f32) {
    let n = w.len().max(1);
    let scale = w.iter().map(|x| x.abs()).sum::<f32>() / n as f32 + 1e-8;
    let trits = w
        .iter()
        .map(|&x| (x / scale).round().clamp(-1.0, 1.0) as i8)
        .collect();
    (trits, scale)
}

/// Per-vector absmax quantization to `bits` bits.
#[derive(Debug, Clone)]
pub struct QuantizedActs {
    /// Exact integers in [-qmax, qmax].
    pub values: Vec<i32>,
    /// Dequantization scale (`x ≈ value * scale`).
    pub scale: f32,
    /// Quantization width in bits.
    pub bits: usize,
}

/// Absmax-quantize an activation vector to `bits` bits.
pub fn absmax_quantize(x: &[f32], bits: usize) -> QuantizedActs {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = amax.max(1e-8) / qmax;
    let values = x
        .iter()
        .map(|&v| (v / scale).round().clamp(-qmax, qmax) as i32)
        .collect();
    QuantizedActs {
        values,
        scale,
        bits,
    }
}

impl QuantizedActs {
    /// Reconstruct the (lossy) float vector.
    pub fn dequant(&self) -> Vec<f32> {
        self.values.iter().map(|&v| v as f32 * self.scale).collect()
    }

    /// Split each int value into (hi, lo) 4-bit digits: v = 16*hi + lo,
    /// lo in [0, 15] — the TriMLA bit-serial decomposition (must match
    /// `kernels/ref.bit_serial_split`).
    pub fn bit_serial_digits(&self) -> Vec<(i32, i32)> {
        self.values
            .iter()
            .map(|&v| {
                let hi = (v as f64 / 16.0).floor() as i32;
                let lo = v - hi * 16;
                (hi, lo)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn absmean_scale_matches_hand_example() {
        // same example as the python test: mean |[1,-2,3,-4]| = 2.5
        let (trits, scale) = absmean_ternary(&[1.0, -2.0, 3.0, -4.0]);
        assert!((scale - 2.5).abs() < 1e-6);
        assert_eq!(trits, vec![0, -1, 1, -1]);
    }

    #[test]
    fn absmean_outputs_are_trits() {
        check(0xAB5, 100, |g| {
            let n = g.size(256);
            let w = g.vec_f32(n);
            let (trits, scale) = absmean_ternary(&w);
            prop_assert!(scale > 0.0, "scale {scale}");
            prop_assert!(
                trits.iter().all(|&t| super::super::is_trit(t)),
                "non-trit output"
            );
            // sign preservation on non-zeros
            for (t, x) in trits.iter().zip(&w) {
                if *t != 0 {
                    prop_assert!(
                        (*t as f32) * x >= 0.0,
                        "sign flip: trit {t} for {x}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn absmax_integer_range_property() {
        check(0xA3A, 100, |g| {
            let n = g.size(256);
            let x = g.vec_f32(n);
            for bits in [4usize, 8] {
                let q = absmax_quantize(&x, bits);
                let qmax = (1i32 << (bits - 1)) - 1;
                prop_assert!(
                    q.values.iter().all(|&v| v.abs() <= qmax),
                    "out of range for {bits} bits"
                );
                // reconstruction error ≤ scale/2
                for (v, orig) in q.values.iter().zip(&x) {
                    let err = (*v as f32 * q.scale - orig).abs();
                    prop_assert!(
                        err <= q.scale * 0.5 + 1e-6,
                        "err {err} > half-step {}",
                        q.scale * 0.5
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bit_serial_digits_recompose() {
        check(0xB17, 100, |g| {
            let n = g.size(128);
            let x = g.vec_f32(n);
            let q = absmax_quantize(&x, 8);
            for ((hi, lo), v) in q.bit_serial_digits().iter().zip(&q.values) {
                prop_assert_eq!(16 * hi + lo, *v);
                prop_assert!((0..=15).contains(lo), "lo digit {lo}");
                prop_assert!((-8..=8).contains(hi), "hi digit {hi}");
            }
            Ok(())
        });
    }

    #[test]
    fn zero_vector_quantizes_to_zero() {
        let q = absmax_quantize(&[0.0; 8], 8);
        assert!(q.values.iter().all(|&v| v == 0));
        let (t, _) = absmean_ternary(&[0.0; 8]);
        assert!(t.iter().all(|&v| v == 0));
    }
}
