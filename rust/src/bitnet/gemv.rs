//! Golden ternary GEMV/GEMM — the reference the `cirom::Macro`
//! simulator is bit-checked against. `ref_gemv` stays the slow,
//! obviously-correct oracle; production host compute goes through the
//! cached word-parallel [`BitplaneMatrix`] view (`gemv`/`gemm`), which
//! is property-tested to be bit-identical.

use std::sync::{Arc, OnceLock};

use super::bitplane::BitplaneMatrix;
use super::pack::PackedTrits;
use super::Trit;

/// A ternary weight matrix in packed storage, row-major
/// `[rows (fan_in) × cols (fan_out)]` with a per-tensor scale.
///
/// `PackedTrits` (1.6 bits/trit) remains the storage format; the
/// bitplane compute view is built lazily on first use and cached for
/// the life of the matrix (ROM weights never change, so the cache
/// never invalidates).
#[derive(Debug, Clone)]
pub struct TernaryMatrix {
    /// Fan-in (input features).
    pub rows: usize,
    /// Fan-out (output features).
    pub cols: usize,
    packed: PackedTrits,
    /// Per-tensor dequantization scale (`w ≈ trit * scale`).
    pub scale: f32,
    /// Arc so long-lived consumers (`cirom::MacroBank`) share one copy
    /// instead of deep-cloning the plane words.
    planes: OnceLock<Arc<BitplaneMatrix>>,
}

impl TernaryMatrix {
    /// Build from explicit trits (row-major `[rows × cols]`).
    pub fn from_trits(rows: usize, cols: usize, trits: &[Trit], scale: f32) -> Self {
        assert_eq!(trits.len(), rows * cols, "trit count mismatch");
        TernaryMatrix {
            rows,
            cols,
            packed: PackedTrits::from_trits(trits),
            scale,
            planes: OnceLock::new(),
        }
    }

    /// Quantize a float matrix (row-major [rows × cols]).
    pub fn quantize(rows: usize, cols: usize, w: &[f32]) -> Self {
        let (trits, scale) = super::quant::absmean_ternary(w);
        Self::from_trits(rows, cols, &trits, scale)
    }

    /// Random ternary matrix with given zero probability (sparsity).
    pub fn random(rows: usize, cols: usize, p_zero: f64, rng: &mut crate::util::rng::Rng) -> Self {
        let trits: Vec<Trit> = (0..rows * cols).map(|_| rng.trit(p_zero)).collect();
        Self::from_trits(rows, cols, &trits, 1.0)
    }

    /// The trit at `(row, col)`.
    #[inline]
    pub fn get(&self, row: usize, col: usize) -> Trit {
        self.packed.get(row * self.cols + col)
    }

    fn init_planes(&self) -> &Arc<BitplaneMatrix> {
        self.planes.get_or_init(|| {
            Arc::new(BitplaneMatrix::from_packed(self.rows, self.cols, &self.packed))
        })
    }

    /// The cached word-parallel compute view (built on first use).
    pub fn bitplanes(&self) -> &BitplaneMatrix {
        &**self.init_planes()
    }

    /// Shared handle to the cached view — lets long-lived consumers
    /// keep it alive without copying the plane words.
    pub fn bitplanes_arc(&self) -> Arc<BitplaneMatrix> {
        self.init_planes().clone()
    }

    /// Integer GEMV on the bitplane kernel — bit-identical to
    /// [`ref_gemv`] and the kernel every functional (non-event) host
    /// path uses. Runs a process-default
    /// [`KernelCtx`](super::KernelCtx) (`BITROM_THREADS`, serial by
    /// default, auto path); callers that pick a pool, path, or tile
    /// build their own context and pass [`Self::bitplanes`].
    pub fn gemv(&self, x: &[i32]) -> Vec<i64> {
        self.bitplanes().gemv(x)
    }

    /// Batched integer GEMM on the bitplane kernel — bit-identical to
    /// mapping [`ref_gemv`] over the batch. Accepts any borrowable
    /// activation rows (`&[Vec<i32>]`, `&[&[i32]]`, …) — no copies.
    /// Same process-default context as [`Self::gemv`].
    pub fn gemm<X: AsRef<[i32]> + Sync>(&self, xs: &[X]) -> Vec<Vec<i64>> {
        self.bitplanes().gemm(xs)
    }

    /// One column (an output channel's fan-in weights), extracted from
    /// the bitplane view rather than per-trit base-3 decode.
    pub fn col_trits(&self, col: usize) -> Vec<Trit> {
        self.bitplanes().col_trits(col)
    }

    /// Extract a sub-matrix (the `cirom::MacroBank` tiling path).
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> TernaryMatrix {
        let trits = self.bitplanes().submatrix_trits(r0, r1, c0, c1);
        TernaryMatrix::from_trits(r1 - r0, c1 - c0, &trits, self.scale)
    }

    /// Zero-weight fraction — O(1) (precomputed at pack time).
    pub fn sparsity(&self) -> f64 {
        self.packed.sparsity()
    }

    /// Packed-storage footprint in bytes (1.6 bits/trit).
    pub fn storage_bytes(&self) -> usize {
        self.packed.bytes()
    }
}

/// Integer ternary GEMV: `y[c] = Σ_r x[r] * w[r][c]` — exact i64
/// accumulation (the hardware's error-free digital computation).
/// `x` are quantized activation integers.
pub fn ref_gemv(x: &[i32], w: &TernaryMatrix) -> Vec<i64> {
    assert_eq!(x.len(), w.rows, "gemv dim mismatch");
    let mut y = vec![0i64; w.cols];
    for r in 0..w.rows {
        let xv = x[r] as i64;
        if xv == 0 {
            continue;
        }
        for c in 0..w.cols {
            match w.get(r, c) {
                0 => {}
                1 => y[c] += xv,
                -1 => y[c] -= xv,
                _ => unreachable!(),
            }
        }
    }
    y
}

/// Integer ternary GEMM over a batch of activation rows.
pub fn ref_gemm(xs: &[Vec<i32>], w: &TernaryMatrix) -> Vec<Vec<i64>> {
    xs.iter().map(|x| ref_gemv(x, w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn gemv_hand_example() {
        // w = [[1, -1], [0, 1], [-1, 0]], x = [2, 3, 5]
        let w = TernaryMatrix::from_trits(3, 2, &[1, -1, 0, 1, -1, 0], 1.0);
        let y = ref_gemv(&[2, 3, 5], &w);
        assert_eq!(y, vec![2 - 5, -2 + 3]);
    }

    #[test]
    fn gemv_matches_dense_float_property() {
        check(0x6E34, 100, |g| {
            let rows = g.size(64);
            let cols = g.size(32);
            let trits = g.vec_trits(rows * cols, 0.3);
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let x: Vec<i32> = (0..rows)
                .map(|_| g.rng.i64(-127, 127) as i32)
                .collect();
            let y = ref_gemv(&x, &w);
            // dense float recomputation
            for c in 0..cols {
                let mut acc = 0f64;
                for r in 0..rows {
                    acc += x[r] as f64 * trits[r * cols + c] as f64;
                }
                prop_assert_eq!(y[c], acc as i64);
            }
            Ok(())
        });
    }

    #[test]
    fn zero_activation_rows_are_skipped_consistently() {
        let w = TernaryMatrix::from_trits(2, 2, &[1, 1, -1, -1], 1.0);
        assert_eq!(ref_gemv(&[0, 0], &w), vec![0, 0]);
    }

    #[test]
    fn quantize_then_gemv_tracks_float_product() {
        let mut rng = Rng::new(3);
        let (rows, cols) = (48, 24);
        let wf: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let w = TernaryMatrix::quantize(rows, cols, &wf);
        assert!(w.sparsity() > 0.05 && w.sparsity() < 0.8);
        let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
        let y = ref_gemv(&x, &w);
        // sanity: result magnitudes bounded by rows * 127
        assert!(y.iter().all(|&v| v.abs() <= (rows as i64) * 127));
    }

    #[test]
    fn random_matrix_sparsity_tracks_p_zero() {
        let mut rng = Rng::new(5);
        let w = TernaryMatrix::random(100, 100, 0.4, &mut rng);
        assert!((w.sparsity() - 0.4).abs() < 0.05);
    }

    #[test]
    fn storage_is_packed() {
        let w = TernaryMatrix::from_trits(10, 10, &[0; 100], 1.0);
        assert_eq!(w.storage_bytes(), 20); // 100 trits / 5 per byte
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_panics() {
        let w = TernaryMatrix::from_trits(2, 2, &[0, 0, 0, 0], 1.0);
        ref_gemv(&[1], &w);
    }

    #[test]
    fn bitplane_view_matches_reference_property() {
        check(0xF00D, 80, |g| {
            let rows = g.size(150);
            let cols = g.size(40);
            let trits = g.vec_trits(rows * cols, g.f64());
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let x: Vec<i32> = (0..rows).map(|_| g.rng.i64(-127, 127) as i32).collect();
            prop_assert_eq!(w.gemv(&x), ref_gemv(&x, &w));
            let xs = vec![x.clone(), x.iter().map(|v| -v).collect()];
            prop_assert_eq!(w.gemm(&xs), ref_gemm(&xs, &w));
            Ok(())
        });
    }

    #[test]
    fn submatrix_preserves_weights_and_scale() {
        let mut rng = Rng::new(21);
        let w = TernaryMatrix::random(70, 9, 0.3, &mut rng);
        let sub = w.submatrix(10, 70, 2, 8);
        assert_eq!((sub.rows, sub.cols), (60, 6));
        assert_eq!(sub.scale, w.scale);
        for r in 0..60 {
            for c in 0..6 {
                assert_eq!(sub.get(r, c), w.get(r + 10, c + 2), "({r},{c})");
            }
        }
    }

    #[test]
    fn clone_preserves_cached_planes() {
        let mut rng = Rng::new(22);
        let w = TernaryMatrix::random(65, 5, 0.3, &mut rng);
        let x: Vec<i32> = (0..65).map(|_| rng.i64(-9, 9) as i32).collect();
        let before = w.gemv(&x); // forces plane construction
        let cloned = w.clone();
        assert_eq!(cloned.gemv(&x), before);
    }

    #[test]
    fn sparsity_is_constant_time_and_exact() {
        // a matrix big enough that a rescan would be noticeable is not
        // needed for correctness — just pin the precomputed value
        let w = TernaryMatrix::from_trits(2, 3, &[0, 1, -1, 0, 0, 1], 1.0);
        assert!((w.sparsity() - 0.5).abs() < 1e-12);
    }
}
