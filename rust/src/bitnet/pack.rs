//! Packed ternary storage.
//!
//! Two packings exist in the system:
//!
//! * **BiROMA cell packing** (`pack_trits` pairs): two trits per
//!   single-transistor cell, base-3 pair code in [0, 8] — the physical
//!   layout of the ROM array, mirrored by
//!   `python/compile/quant.pack_trits_base3` (round-trip tested on both
//!   sides).
//! * **Dense base-3 packing** (`PackedTrits`): five trits per byte
//!   (3^5 = 243 ≤ 256) — the minimal-footprint host representation used
//!   to hold large ROM images in memory; 1.6 bits/trit, within 1% of
//!   the information-theoretic 1.585.

use super::Trit;

/// Encode a pair of trits into a BiROMA cell code in [0, 8].
#[inline]
pub fn cell_encode(even: Trit, odd: Trit) -> u8 {
    debug_assert!(super::is_trit(even) && super::is_trit(odd));
    ((even + 1) * 3 + (odd + 1)) as u8
}

/// Decode a BiROMA cell code back to (even, odd) trits.
#[inline]
pub fn cell_decode(code: u8) -> (Trit, Trit) {
    debug_assert!(code <= 8);
    ((code / 3) as i8 - 1, (code % 3) as i8 - 1)
}

/// Pack a trit slice into cell codes (pads odd lengths with 0).
pub fn pack_trits(trits: &[Trit]) -> Vec<u8> {
    trits
        .chunks(2)
        .map(|c| cell_encode(c[0], if c.len() > 1 { c[1] } else { 0 }))
        .collect()
}

/// Unpack cell codes to `n` trits.
pub fn unpack_trits(cells: &[u8], n: usize) -> Vec<Trit> {
    let mut out = Vec::with_capacity(n);
    for &c in cells {
        let (e, o) = cell_decode(c);
        out.push(e);
        if out.len() < n {
            out.push(o);
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

/// Dense base-3 packed trit vector: 5 trits per byte.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedTrits {
    data: Vec<u8>,
    len: usize,
    /// Zero-trit count, computed once at pack time (keeps `sparsity()`
    /// O(1) instead of re-decoding the whole tensor).
    zeros: usize,
}

impl PackedTrits {
    /// Pack a trit slice (5 trits per byte, base-3).
    pub fn from_trits(trits: &[Trit]) -> Self {
        let mut data = Vec::with_capacity((trits.len() + 4) / 5);
        let mut zeros = 0usize;
        for chunk in trits.chunks(5) {
            let mut code = 0u16;
            // little-endian base-3 digits
            for (i, &t) in chunk.iter().enumerate() {
                debug_assert!(super::is_trit(t));
                code += (t + 1) as u16 * POW3[i];
                zeros += (t == 0) as usize;
            }
            data.push(code as u8);
        }
        PackedTrits {
            data,
            len: trits.len(),
            zeros,
        }
    }

    /// Number of stored trits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no trits are stored.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Packed size in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// The trit at `idx`.
    #[inline]
    pub fn get(&self, idx: usize) -> Trit {
        assert!(idx < self.len, "trit index {idx} out of bounds {}", self.len);
        // table lookup instead of a base-3 division + modulo per access
        DECODE5[self.data[idx / 5] as usize][idx % 5]
    }

    /// Decode the 5-trit group holding byte `chunk` — the bulk-decode
    /// primitive `to_trits`/`iter` run on (one table lookup per FIVE
    /// trits instead of one div/mod each). NOTE: positions past `len`
    /// in the final partial chunk decode as −1 (absent base-3 digits
    /// are zero, and digit 0 means trit −1) — callers must truncate,
    /// which is why this stays crate-private.
    #[inline]
    pub(crate) fn chunk(&self, chunk: usize) -> &'static [Trit; 5] {
        &DECODE5[self.data[chunk] as usize]
    }

    /// Decode every trit (bulk table-lookup path).
    pub fn to_trits(&self) -> Vec<Trit> {
        let mut out = Vec::with_capacity(self.len);
        for c in 0..self.data.len() {
            out.extend_from_slice(self.chunk(c));
        }
        out.truncate(self.len);
        out
    }

    /// Iterate all trits in order (bulk table decode, no div/mod).
    pub fn iter(&self) -> impl Iterator<Item = Trit> + '_ {
        self.data
            .iter()
            .flat_map(|&b| DECODE5[b as usize].iter().copied())
            .take(self.len)
    }

    /// Effective storage density in bits per trit.
    pub fn bits_per_trit(&self) -> f64 {
        self.data.len() as f64 * 8.0 / self.len as f64
    }

    /// Zero-trit count (precomputed at pack time).
    pub fn zero_count(&self) -> usize {
        self.zeros
    }

    /// Fraction of zero trits (TriMLA skip rate of this tensor) — O(1).
    pub fn sparsity(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.zeros as f64 / self.len as f64
    }
}

const POW3: [u16; 5] = [1, 3, 9, 27, 81];

/// All 243 valid pack bytes decoded to their 5 trits, built at compile
/// time. Indexed `[code][digit]`; codes ≥ 243 never occur (packing
/// caps at 3^5 − 1 = 242), but the table is sized 256 so indexing with
/// a raw byte needs no bounds trickery.
static DECODE5: [[Trit; 5]; 256] = build_decode5();

const fn build_decode5() -> [[Trit; 5]; 256] {
    let mut table = [[0i8; 5]; 256];
    let mut code = 0usize;
    while code < 243 {
        let mut rem = code;
        let mut digit = 0usize;
        while digit < 5 {
            table[code][digit] = (rem % 3) as i8 - 1;
            rem /= 3;
            digit += 1;
        }
        code += 1;
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn cell_codes_cover_all_pairs() {
        let mut seen = [false; 9];
        for e in -1..=1i8 {
            for o in -1..=1i8 {
                let c = cell_encode(e, o);
                assert!(c <= 8);
                assert!(!seen[c as usize], "duplicate code {c}");
                seen[c as usize] = true;
                assert_eq!(cell_decode(c), (e, o));
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn pack_unpack_roundtrip_property() {
        check(0xB17B0A, 200, |g| {
            let n = g.size(512);
            let trits = g.vec_trits(n, 0.3);
            let cells = pack_trits(&trits);
            prop_assert_eq!(cells.len(), (n + 1) / 2);
            let back = unpack_trits(&cells, n);
            prop_assert_eq!(back, trits);
            Ok(())
        });
    }

    #[test]
    fn dense_pack_roundtrip_property() {
        check(0xDE45E, 200, |g| {
            let n = g.size(1000);
            let trits = g.vec_trits(n, 0.4);
            let packed = PackedTrits::from_trits(&trits);
            prop_assert_eq!(packed.to_trits(), trits);
            prop_assert!(
                packed.bytes() == (n + 4) / 5,
                "bytes {} for {} trits",
                packed.bytes(),
                n
            );
            Ok(())
        });
    }

    #[test]
    fn dense_density_close_to_entropy() {
        let trits: Vec<Trit> = (0..10_000).map(|i| ((i % 3) as i8) - 1).collect();
        let p = PackedTrits::from_trits(&trits);
        let bpt = p.bits_per_trit();
        assert!(bpt < 1.61, "bits/trit {bpt}"); // vs 1.585 ideal
    }

    #[test]
    fn random_access_matches_sequential() {
        let trits: Vec<Trit> = vec![1, -1, 0, 0, 1, -1, -1, 1, 0, 1, 1];
        let p = PackedTrits::from_trits(&trits);
        for (i, &t) in trits.iter().enumerate() {
            assert_eq!(p.get(i), t, "index {i}");
        }
    }

    #[test]
    fn sparsity_counts_zeros() {
        let p = PackedTrits::from_trits(&[0, 0, 1, -1]);
        assert!((p.sparsity() - 0.5).abs() < 1e-12);
        assert_eq!(p.zero_count(), 2);
    }

    #[test]
    fn decode_table_matches_base3_arithmetic() {
        // exhaustive: every valid code, every digit position
        for code in 0u16..243 {
            for digit in 0..5usize {
                let want = ((code / POW3[digit]) % 3) as i8 - 1;
                assert_eq!(DECODE5[code as usize][digit], want, "code {code} digit {digit}");
            }
        }
    }

    #[test]
    fn iter_and_chunks_match_indexed_gets() {
        check(0x17E2, 100, |g| {
            let n = g.size(400);
            let trits = g.vec_trits(n, 0.3);
            let p = PackedTrits::from_trits(&trits);
            let via_iter: Vec<Trit> = p.iter().collect();
            prop_assert_eq!(via_iter, trits.clone());
            let via_get: Vec<Trit> = (0..n).map(|i| p.get(i)).collect();
            prop_assert_eq!(via_get, trits.clone());
            prop_assert_eq!(
                p.zero_count(),
                trits.iter().filter(|&&t| t == 0).count()
            );
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_get_panics() {
        PackedTrits::from_trits(&[1]).get(1);
    }
}
