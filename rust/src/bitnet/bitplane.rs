//! Ternary bitplane storage — the compute view the kernel engine
//! ([`KernelCtx`](super::KernelCtx), DESIGN.md §17) runs on.
//!
//! A ternary matrix decomposes into two bitplanes (the same sign/zero
//! decomposition the TriMLA comparators produce in silicon, paper Fig 4):
//! a *plus* mask (bit set ⇔ weight = +1) and a *minus* mask (bit set ⇔
//! weight = −1). Zero weights set no bit in either plane, so sparsity
//! is skipped for free — the software twin of the TriMLA zero-skip.
//!
//! Storage is per-column: column `c` (one output channel / one BiROMA
//! wordline row) owns `words_per_col` contiguous u64 words per plane,
//! rows blocked 64 to a word. The accumulation loops themselves live
//! in [`kernel`](super::kernel); this type only owns the planes plus
//! the fabrication/extraction primitives (`get`, `col_trits_into`,
//! `submatrix`). The `gemv`/`gemm` methods here are conveniences that
//! run a process-default [`KernelCtx`](super::KernelCtx) — callers
//! that pick a pool, path, or tile go through the context directly.
//!
//! Accumulation is exact i64, so results are bit-identical to
//! [`ref_gemv`](super::ref_gemv) (property-tested across shapes,
//! sparsities, paths, and negative/zero activations). `PackedTrits`
//! remains the minimal-footprint storage format; a `BitplaneMatrix` is
//! the compute view constructed from it once and reused.

use super::kernel::KernelCtx;
use super::pack::PackedTrits;
use super::Trit;

/// A ternary weight matrix decomposed into per-column sign bitplanes.
#[derive(Debug, Clone, PartialEq)]
pub struct BitplaneMatrix {
    rows: usize,
    cols: usize,
    /// u64 words per column (`ceil(rows / 64)`).
    words_per_col: usize,
    /// Plus-plane, column-major: column `c` is
    /// `plus[c * words_per_col .. (c + 1) * words_per_col]`; bit `r % 64`
    /// of word `r / 64` covers row `r`.
    plus: Vec<u64>,
    /// Minus-plane, same layout.
    minus: Vec<u64>,
    /// Total non-zero weights (popcount of both planes).
    nonzeros: u64,
}

impl BitplaneMatrix {
    /// Build from row-major packed trits (`rows × cols`, the layout
    /// `TernaryMatrix` stores).
    pub fn from_packed(rows: usize, cols: usize, packed: &PackedTrits) -> Self {
        assert_eq!(packed.len(), rows * cols, "packed length mismatch");
        Self::build(rows, cols, packed.iter())
    }

    /// Build directly from a trit slice (row-major) — no base-3
    /// roundtrip.
    pub fn from_trits(rows: usize, cols: usize, trits: &[Trit]) -> Self {
        assert_eq!(trits.len(), rows * cols, "trit count mismatch");
        Self::build(rows, cols, trits.iter().copied())
    }

    fn build(rows: usize, cols: usize, trits: impl Iterator<Item = Trit>) -> Self {
        let words_per_col = (rows + 63) / 64;
        let mut plus = vec![0u64; cols * words_per_col];
        let mut minus = vec![0u64; cols * words_per_col];
        let mut nonzeros = 0u64;
        // Sequential source decode; the scattered plane writes hit
        // `cols` cache lines round-robin, which is fine for a one-time
        // construction pass.
        let mut r = 0usize;
        let mut c = 0usize;
        for t in trits {
            if t != 0 {
                nonzeros += 1;
                let word = c * words_per_col + (r >> 6);
                let bit = 1u64 << (r & 63);
                if t > 0 {
                    plus[word] |= bit;
                } else {
                    minus[word] |= bit;
                }
            }
            c += 1;
            if c == cols {
                c = 0;
                r += 1;
            }
        }
        BitplaneMatrix {
            rows,
            cols,
            words_per_col,
            plus,
            minus,
            nonzeros,
        }
    }

    /// Fan-in (input features).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Fan-out (output features).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Non-zero weight count (one popcount reduction, precomputed).
    pub fn nonzeros(&self) -> u64 {
        self.nonzeros
    }

    /// Zero-weight fraction — O(1).
    pub fn sparsity(&self) -> f64 {
        let n = (self.rows * self.cols) as u64;
        if n == 0 {
            return 0.0;
        }
        1.0 - self.nonzeros as f64 / n as f64
    }

    /// Plane storage in bytes (the compute view's footprint).
    pub fn storage_bytes(&self) -> usize {
        (self.plus.len() + self.minus.len()) * 8
    }

    /// The plus/minus plane words of column `c` — the kernel engine's
    /// readout primitive.
    #[inline]
    pub(crate) fn col_words(&self, c: usize) -> (&[u64], &[u64]) {
        let base = c * self.words_per_col;
        (
            &self.plus[base..base + self.words_per_col],
            &self.minus[base..base + self.words_per_col],
        )
    }

    /// Single weight readout from the planes.
    #[inline]
    pub fn get(&self, row: usize, col: usize) -> Trit {
        assert!(row < self.rows && col < self.cols, "get OOB ({row},{col})");
        let w = col * self.words_per_col + (row >> 6);
        let bit = row & 63;
        ((self.plus[w] >> bit) & 1) as i8 - ((self.minus[w] >> bit) & 1) as i8
    }

    /// Materialize one column (an output channel's fan-in weights) into
    /// a caller buffer of length `rows` — the fabrication path the
    /// `cirom` layer uses instead of per-trit base-3 decode, without a
    /// per-call allocation on repeat extraction.
    pub fn col_trits_into(&self, col: usize, out: &mut [Trit]) {
        assert!(col < self.cols, "column {col} out of bounds {}", self.cols);
        assert_eq!(out.len(), self.rows, "col_trits_into buffer length");
        out.fill(0);
        let (pcol, mcol) = self.col_words(col);
        for (wi, (&p, &m)) in pcol.iter().zip(mcol).enumerate() {
            let mut bits = p | m;
            while bits != 0 {
                let i = bits.trailing_zeros() as usize;
                let r = (wi << 6) | i;
                out[r] = ((p >> i) & 1) as i8 - ((m >> i) & 1) as i8;
                bits &= bits - 1;
            }
        }
    }

    /// Allocating twin of [`Self::col_trits_into`] (one-shot callers).
    pub fn col_trits(&self, col: usize) -> Vec<Trit> {
        let mut out = vec![0i8; self.rows];
        self.col_trits_into(col, &mut out);
        out
    }

    /// Integer GEMV, bit-identical to `ref_gemv`: `y[c] = Σ_r x[r]·w[r][c]`
    /// with exact i64 accumulation, on a process-default
    /// [`KernelCtx`](super::KernelCtx) (serial unless `BITROM_THREADS`
    /// is set, auto path). Callers that pick a pool/path/tile build
    /// their own context.
    pub fn gemv(&self, x: &[i32]) -> Vec<i64> {
        KernelCtx::from_env().gemv(self, x)
    }

    /// Batched integer GEMM over activation rows, bit-identical to
    /// mapping `ref_gemv` over `xs`, on a process-default
    /// [`KernelCtx`](super::KernelCtx). The batched kernel decodes
    /// each weight word once and replays it across the whole batch;
    /// the decode hot loop uses the flat-output variant
    /// ([`KernelCtx::gemm_flat`](super::KernelCtx::gemm_flat)) instead.
    pub fn gemm<X: AsRef<[i32]> + Sync>(&self, xs: &[X]) -> Vec<Vec<i64>> {
        KernelCtx::from_env().gemm(self, xs)
    }

    /// Extract a sub-matrix's trits (row-major, `[r0, r1) × [c0, c1)`) —
    /// the tiling primitive `cirom::MacroBank` shards with.
    pub fn submatrix_trits(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Vec<Trit> {
        assert!(r0 <= r1 && r1 <= self.rows, "row range {r0}..{r1}");
        assert!(c0 <= c1 && c1 <= self.cols, "col range {c0}..{c1}");
        let (h, w) = (r1 - r0, c1 - c0);
        let mut out = vec![0i8; h * w];
        if h == 0 || w == 0 {
            return out;
        }
        for (j, c) in (c0..c1).enumerate() {
            let base = c * self.words_per_col;
            for wi in (r0 >> 6)..=((r1 - 1) >> 6) {
                let (p, m) = (self.plus[base + wi], self.minus[base + wi]);
                let mut bits = p | m;
                while bits != 0 {
                    let i = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let r = (wi << 6) | i;
                    if r < r0 || r >= r1 {
                        continue;
                    }
                    out[(r - r0) * w + j] = ((p >> i) & 1) as i8 - ((m >> i) & 1) as i8;
                }
            }
        }
        out
    }

    /// Plane-level submatrix (`[r0, r1) × [c0, c1)`) — word-wise bit
    /// extraction straight into a new plane view, no base-3 roundtrip
    /// (the `cirom::MacroBank` tiling path).
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> BitplaneMatrix {
        BitplaneMatrix::from_trits(r1 - r0, c1 - c0, &self.submatrix_trits(r0, r1, c0, c1))
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ref_gemv, TernaryMatrix};
    use super::*;
    use crate::util::check::check;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    fn random_case(g: &mut crate::util::check::Gen) -> (usize, usize, Vec<Trit>, Vec<i32>) {
        // shapes deliberately straddle the 64-row word boundary
        let rows = g.size(200);
        let cols = g.size(48);
        let p_zero = g.f64(); // full sparsity range 0.0..1.0
        let trits = g.vec_trits(rows * cols, p_zero);
        // negative, zero, and large activations all exercised
        let x: Vec<i32> = (0..rows)
            .map(|_| {
                if g.rng.bool(0.15) {
                    0
                } else {
                    g.rng.i64(-127, 127) as i32
                }
            })
            .collect();
        (rows, cols, trits, x)
    }

    #[test]
    fn gemv_bit_identical_to_reference_property() {
        check(0xB17A, 150, |g| {
            let (rows, cols, trits, x) = random_case(g);
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            prop_assert_eq!(plane.gemv(&x), ref_gemv(&x, &w));
            Ok(())
        });
    }

    #[test]
    fn gemv_exact_at_word_boundaries() {
        // rows exactly at, one under, and one over multiples of 64
        let mut rng = crate::util::rng::Rng::new(0xB0);
        for rows in [1usize, 63, 64, 65, 127, 128, 129, 192] {
            let cols = 7;
            let trits: Vec<Trit> = (0..rows * cols).map(|_| rng.trit(0.3)).collect();
            let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            assert_eq!(plane.gemv(&x), ref_gemv(&x, &w), "rows {rows}");
        }
    }

    #[test]
    fn gemv_covers_both_density_paths() {
        // all-dense (sparsity 0) forces the whole-word path; high
        // sparsity forces bit iteration; both must agree with ref.
        let mut rng = crate::util::rng::Rng::new(0xD3);
        for p_zero in [0.0, 0.05, 0.5, 0.95, 1.0] {
            let (rows, cols) = (130, 9);
            let trits: Vec<Trit> = (0..rows * cols).map(|_| rng.trit(p_zero)).collect();
            let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            assert_eq!(plane.gemv(&x), ref_gemv(&x, &w), "p_zero {p_zero}");
        }
    }

    #[test]
    fn gemm_bit_identical_to_mapped_reference_property() {
        check(0x6E44, 80, |g| {
            let (rows, cols, trits, _) = random_case(g);
            let batch = g.usize(1, 6);
            let xs: Vec<Vec<i32>> = (0..batch)
                .map(|_| (0..rows).map(|_| g.rng.i64(-127, 127) as i32).collect())
                .collect();
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            let want: Vec<Vec<i64>> = xs.iter().map(|x| ref_gemv(x, &w)).collect();
            prop_assert_eq!(plane.gemm(&xs), want);
            Ok(())
        });
    }

    #[test]
    fn gemm_empty_batch() {
        let plane = BitplaneMatrix::from_trits(4, 4, &[1i8; 16]);
        assert!(plane.gemm::<Vec<i32>>(&[]).is_empty());
    }

    #[test]
    fn gemm_accepts_borrowed_rows() {
        let plane = BitplaneMatrix::from_trits(3, 2, &[1, -1, 0, 1, -1, 0]);
        let x = [2i32, 3, 5];
        let borrowed: Vec<&[i32]> = vec![&x];
        assert_eq!(plane.gemm(&borrowed), vec![vec![2 - 5, -2 + 3]]);
    }

    #[test]
    fn get_and_col_trits_match_source() {
        check(0xC01, 100, |g| {
            let rows = g.size(150);
            let cols = g.size(20);
            let trits = g.vec_trits(rows * cols, 0.4);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            let mut buf = vec![7i8; rows]; // stale junk must be overwritten
            for c in 0..cols {
                let col = plane.col_trits(c);
                plane.col_trits_into(c, &mut buf);
                for r in 0..rows {
                    prop_assert_eq!(col[r], trits[r * cols + c]);
                    prop_assert_eq!(buf[r], trits[r * cols + c]);
                }
            }
            let r = g.usize(0, rows - 1);
            let c = g.usize(0, cols - 1);
            prop_assert_eq!(plane.get(r, c), trits[r * cols + c]);
            Ok(())
        });
    }

    #[test]
    fn submatrix_extraction_matches_source() {
        check(0x5AB, 100, |g| {
            let rows = g.size(180);
            let cols = g.size(24);
            let trits = g.vec_trits(rows * cols, 0.3);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            let r0 = g.usize(0, rows);
            let r1 = g.usize(r0, rows);
            let c0 = g.usize(0, cols);
            let c1 = g.usize(c0, cols);
            let sub = plane.submatrix_trits(r0, r1, c0, c1);
            for r in r0..r1 {
                for c in c0..c1 {
                    prop_assert_eq!(
                        sub[(r - r0) * (c1 - c0) + (c - c0)],
                        trits[r * cols + c]
                    );
                }
            }
            // the plane-level submatrix is the same data as a plane
            // built from the extracted trits
            let sub_plane = plane.submatrix(r0, r1, c0, c1);
            prop_assert_eq!(
                sub_plane,
                BitplaneMatrix::from_trits(r1 - r0, c1 - c0, &sub)
            );
            Ok(())
        });
    }

    #[test]
    fn popcount_sparsity_is_exact() {
        check(0x90C, 60, |g| {
            let rows = g.size(100);
            let cols = g.size(30);
            let trits = g.vec_trits(rows * cols, g.f64());
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            let zeros = trits.iter().filter(|&&t| t == 0).count();
            prop_assert!(
                (plane.sparsity() - zeros as f64 / trits.len() as f64).abs() < 1e-15,
                "sparsity mismatch"
            );
            prop_assert_eq!(plane.nonzeros(), (trits.len() - zeros) as u64);
            Ok(())
        });
    }

    #[test]
    fn storage_is_two_bits_per_weight_plus_padding() {
        let plane = BitplaneMatrix::from_trits(128, 16, &[1i8; 128 * 16]);
        // 2 words per column per plane × 16 cols × 2 planes × 8 bytes
        assert_eq!(plane.storage_bytes(), 2 * 16 * 2 * 8);
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_panics() {
        BitplaneMatrix::from_trits(2, 2, &[0; 4]).gemv(&[1]);
    }
}
