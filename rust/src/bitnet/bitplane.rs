//! Word-parallel ternary kernel engine — the host compute path.
//!
//! A ternary matrix decomposes into two bitplanes (the same sign/zero
//! decomposition the TriMLA comparators produce in silicon, paper Fig 4):
//! a *plus* mask (bit set ⇔ weight = +1) and a *minus* mask (bit set ⇔
//! weight = −1). Zero weights set no bit in either plane, so sparsity
//! is skipped for free — the software twin of the TriMLA zero-skip.
//!
//! Storage is per-column: column `c` (one output channel / one BiROMA
//! wordline row) owns `words_per_col` contiguous u64 words per plane,
//! rows blocked 64 to a word. A GEMV walks each column's words once:
//! sparse words iterate set bits (`trailing_zeros`), dense words run a
//! straight sign-select pass over all 64 lanes — either way there is no
//! per-trit base-3 decode, no division, no modulo on the hot path.
//!
//! Accumulation is exact i64, so results are bit-identical to
//! [`ref_gemv`](super::ref_gemv) (property-tested across shapes,
//! sparsities, and negative/zero activations). `PackedTrits` remains
//! the minimal-footprint storage format; a `BitplaneMatrix` is the
//! compute view constructed from it once and reused.

use super::pack::PackedTrits;
use super::Trit;
use crate::util::pool::{chunk_bounds, Pool};

/// Above this many populated lanes in a 64-row word, a straight
/// whole-word sign-select pass beats per-set-bit iteration (the
/// bit-iteration loop costs ~2 dependent ops per set bit; the dense
/// pass streams all lanes branch-free).
const DENSE_WORD_CUTOVER: u32 = 32;

/// Below this many weights a kernel stays serial no matter what width
/// the caller's pool requests: a `thread::scope` fork costs tens of
/// microseconds, which dwarfs a small GEMV. The cutoff only affects
/// speed — sharding is bit-identical at any width (each output column
/// is always accumulated whole, in row order, by exactly one worker).
const PAR_MIN_WEIGHTS: usize = 64 * 1024;

/// A ternary weight matrix decomposed into per-column sign bitplanes.
#[derive(Debug, Clone, PartialEq)]
pub struct BitplaneMatrix {
    rows: usize,
    cols: usize,
    /// u64 words per column (`ceil(rows / 64)`).
    words_per_col: usize,
    /// Plus-plane, column-major: column `c` is
    /// `plus[c * words_per_col .. (c + 1) * words_per_col]`; bit `r % 64`
    /// of word `r / 64` covers row `r`.
    plus: Vec<u64>,
    /// Minus-plane, same layout.
    minus: Vec<u64>,
    /// Total non-zero weights (popcount of both planes).
    nonzeros: u64,
}

impl BitplaneMatrix {
    /// Build from row-major packed trits (`rows × cols`, the layout
    /// `TernaryMatrix` stores).
    pub fn from_packed(rows: usize, cols: usize, packed: &PackedTrits) -> Self {
        assert_eq!(packed.len(), rows * cols, "packed length mismatch");
        Self::build(rows, cols, packed.iter())
    }

    /// Build directly from a trit slice (row-major) — no base-3
    /// roundtrip.
    pub fn from_trits(rows: usize, cols: usize, trits: &[Trit]) -> Self {
        assert_eq!(trits.len(), rows * cols, "trit count mismatch");
        Self::build(rows, cols, trits.iter().copied())
    }

    fn build(rows: usize, cols: usize, trits: impl Iterator<Item = Trit>) -> Self {
        let words_per_col = (rows + 63) / 64;
        let mut plus = vec![0u64; cols * words_per_col];
        let mut minus = vec![0u64; cols * words_per_col];
        let mut nonzeros = 0u64;
        // Sequential source decode; the scattered plane writes hit
        // `cols` cache lines round-robin, which is fine for a one-time
        // construction pass.
        let mut r = 0usize;
        let mut c = 0usize;
        for t in trits {
            if t != 0 {
                nonzeros += 1;
                let word = c * words_per_col + (r >> 6);
                let bit = 1u64 << (r & 63);
                if t > 0 {
                    plus[word] |= bit;
                } else {
                    minus[word] |= bit;
                }
            }
            c += 1;
            if c == cols {
                c = 0;
                r += 1;
            }
        }
        BitplaneMatrix {
            rows,
            cols,
            words_per_col,
            plus,
            minus,
            nonzeros,
        }
    }

    /// Fan-in (input features).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Fan-out (output features).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Non-zero weight count (one popcount reduction, precomputed).
    pub fn nonzeros(&self) -> u64 {
        self.nonzeros
    }

    /// Zero-weight fraction — O(1).
    pub fn sparsity(&self) -> f64 {
        let n = (self.rows * self.cols) as u64;
        if n == 0 {
            return 0.0;
        }
        1.0 - self.nonzeros as f64 / n as f64
    }

    /// Plane storage in bytes (the compute view's footprint).
    pub fn storage_bytes(&self) -> usize {
        (self.plus.len() + self.minus.len()) * 8
    }

    /// Single weight readout from the planes.
    #[inline]
    pub fn get(&self, row: usize, col: usize) -> Trit {
        assert!(row < self.rows && col < self.cols, "get OOB ({row},{col})");
        let w = col * self.words_per_col + (row >> 6);
        let bit = row & 63;
        ((self.plus[w] >> bit) & 1) as i8 - ((self.minus[w] >> bit) & 1) as i8
    }

    /// Materialize one column (an output channel's fan-in weights) —
    /// the fabrication path the `cirom` layer uses instead of per-trit
    /// base-3 decode.
    pub fn col_trits(&self, col: usize) -> Vec<Trit> {
        assert!(col < self.cols, "column {col} out of bounds {}", self.cols);
        let base = col * self.words_per_col;
        let mut out = vec![0i8; self.rows];
        for wi in 0..self.words_per_col {
            let (p, m) = (self.plus[base + wi], self.minus[base + wi]);
            let mut bits = p | m;
            while bits != 0 {
                let i = bits.trailing_zeros() as usize;
                let r = (wi << 6) | i;
                out[r] = ((p >> i) & 1) as i8 - ((m >> i) & 1) as i8;
                bits &= bits - 1;
            }
        }
        out
    }

    /// Integer GEMV, bit-identical to `ref_gemv`: `y[c] = Σ_r x[r]·w[r][c]`
    /// with exact i64 accumulation. Shards output columns across the
    /// process-default pool ([`Pool::from_env`], serial unless
    /// `BITROM_THREADS` is set).
    pub fn gemv(&self, x: &[i32]) -> Vec<i64> {
        self.gemv_with(x, &Pool::from_env())
    }

    /// [`Self::gemv`] on an explicit pool. Each worker owns a
    /// contiguous column range; a column's i64 accumulation is always
    /// performed whole and in row order by one worker, so the result
    /// is bit-identical at every width (tested at 1/2/4/7 threads).
    pub fn gemv_with(&self, x: &[i32], pool: &Pool) -> Vec<i64> {
        let mut y = vec![0i64; self.cols];
        self.gemv_into_with(x, &mut y, pool);
        y
    }

    /// GEMV into a caller-provided output buffer (overwrites `y`).
    pub fn gemv_into(&self, x: &[i32], y: &mut [i64]) {
        self.gemv_into_with(x, y, &Pool::from_env());
    }

    /// [`Self::gemv_into`] on an explicit pool: the output slice is
    /// split into per-worker column chunks (disjoint `&mut` views into
    /// the same buffer — no copies, no stitching).
    pub fn gemv_into_with(&self, x: &[i32], y: &mut [i64], pool: &Pool) {
        assert_eq!(x.len(), self.rows, "gemv dim mismatch");
        assert_eq!(y.len(), self.cols, "gemv output dim mismatch");
        let width = self.shard_width(pool);
        if width <= 1 {
            self.gemv_cols(x, 0, self.cols, y);
            return;
        }
        let cols = self.cols;
        std::thread::scope(|scope| {
            let mut rest: &mut [i64] = y;
            for w in 0..width {
                let (lo, hi) = chunk_bounds(cols, width, w);
                let (chunk, tail) = rest.split_at_mut(hi - lo);
                rest = tail;
                scope.spawn(move || self.gemv_cols(x, lo, hi, chunk));
            }
        });
    }

    /// Serial GEMV over columns `[c0, c1)` into `out` (`out[c - c0]` =
    /// column `c`) — the one accumulation loop every GEMV path runs.
    fn gemv_cols(&self, x: &[i32], c0: usize, c1: usize, out: &mut [i64]) {
        debug_assert_eq!(out.len(), c1 - c0);
        let wpc = self.words_per_col;
        for (c, out) in (c0..c1).zip(out.iter_mut()) {
            let base = c * wpc;
            let pcol = &self.plus[base..base + wpc];
            let mcol = &self.minus[base..base + wpc];
            let mut acc = 0i64;
            for (wi, (&p, &m)) in pcol.iter().zip(mcol).enumerate() {
                let both = p | m;
                if both == 0 {
                    continue;
                }
                let row0 = wi << 6;
                if both.count_ones() >= DENSE_WORD_CUTOVER {
                    // dense word: stream every resident lane, branch-free
                    // sign select (+1 / −1 / 0 as a two-bit difference)
                    let lanes = &x[row0..(row0 + 64).min(self.rows)];
                    for (i, &xv) in lanes.iter().enumerate() {
                        let sign = ((p >> i) & 1) as i64 - ((m >> i) & 1) as i64;
                        acc += sign * xv as i64;
                    }
                } else {
                    // sparse word: touch only the set bits
                    let mut pp = p;
                    while pp != 0 {
                        acc += x[row0 + pp.trailing_zeros() as usize] as i64;
                        pp &= pp - 1;
                    }
                    let mut mm = m;
                    while mm != 0 {
                        acc -= x[row0 + mm.trailing_zeros() as usize] as i64;
                        mm &= mm - 1;
                    }
                }
            }
            *out = acc;
        }
    }

    /// Effective shard width for this matrix on `pool`: serial below
    /// [`PAR_MIN_WEIGHTS`], else capped at one column per worker.
    fn shard_width(&self, pool: &Pool) -> usize {
        if self.rows * self.cols < PAR_MIN_WEIGHTS {
            return 1;
        }
        pool.threads().min(self.cols).max(1)
    }

    /// Batched integer GEMM over activation rows, bit-identical to
    /// mapping `ref_gemv` over `xs`. Shards output columns across the
    /// process-default pool ([`Pool::from_env`]).
    ///
    /// The win over repeated `gemv` calls: each column word's bit
    /// pattern is decoded ONCE into (row, sign) pairs and replayed
    /// across the whole batch, so mask iteration amortizes over the
    /// batch dimension (the LoRA merge, report, and KV-study paths all
    /// push multiple activation rows through the same weights).
    pub fn gemm<X: AsRef<[i32]> + Sync>(&self, xs: &[X]) -> Vec<Vec<i64>> {
        self.gemm_with(xs, &Pool::from_env())
    }

    /// [`Self::gemm`] on an explicit pool. Workers own contiguous
    /// column ranges of every batch row; per-column accumulation order
    /// is exactly the serial kernel's, so results are bit-identical at
    /// every width (tested at 1/2/4/7 threads).
    pub fn gemm_with<X: AsRef<[i32]> + Sync>(&self, xs: &[X], pool: &Pool) -> Vec<Vec<i64>> {
        for x in xs {
            assert_eq!(x.as_ref().len(), self.rows, "gemm dim mismatch");
        }
        if xs.is_empty() {
            return Vec::new();
        }
        let width = self.shard_width(pool);
        if width <= 1 {
            return self.gemm_cols(xs, 0, self.cols);
        }
        let cols = self.cols;
        let parts = pool.run(width, |w| {
            let (lo, hi) = chunk_bounds(cols, width, w);
            self.gemm_cols(xs, lo, hi)
        });
        // stitch the per-worker column chunks back into full rows
        let mut ys: Vec<Vec<i64>> = (0..xs.len()).map(|_| Vec::with_capacity(cols)).collect();
        for part in parts {
            for (y, chunk) in ys.iter_mut().zip(part) {
                y.extend(chunk);
            }
        }
        ys
    }

    /// Serial batched GEMM over columns `[c0, c1)`: returns
    /// `[batch][c1 - c0]` partial rows — the one accumulation loop
    /// every GEMM path runs.
    fn gemm_cols<X: AsRef<[i32]>>(&self, xs: &[X], c0: usize, c1: usize) -> Vec<Vec<i64>> {
        let mut ys = vec![vec![0i64; c1 - c0]; xs.len()];
        let wpc = self.words_per_col;
        // decoded (row, sign) scratch for one 64-row word
        let mut rows_buf = [0usize; 64];
        let mut sign_buf = [0i64; 64];
        for c in c0..c1 {
            let base = c * wpc;
            let pcol = &self.plus[base..base + wpc];
            let mcol = &self.minus[base..base + wpc];
            for (wi, (&p, &m)) in pcol.iter().zip(mcol).enumerate() {
                let both = p | m;
                if both == 0 {
                    continue;
                }
                let row0 = wi << 6;
                if both.count_ones() >= DENSE_WORD_CUTOVER {
                    let hi = (row0 + 64).min(self.rows);
                    for (b, x) in xs.iter().enumerate() {
                        let x = x.as_ref();
                        let mut acc = 0i64;
                        for (i, &xv) in x[row0..hi].iter().enumerate() {
                            let sign = ((p >> i) & 1) as i64 - ((m >> i) & 1) as i64;
                            acc += sign * xv as i64;
                        }
                        ys[b][c - c0] += acc;
                    }
                } else {
                    let mut n = 0usize;
                    let mut bits = both;
                    while bits != 0 {
                        let i = bits.trailing_zeros() as usize;
                        rows_buf[n] = row0 + i;
                        sign_buf[n] = ((p >> i) & 1) as i64 - ((m >> i) & 1) as i64;
                        n += 1;
                        bits &= bits - 1;
                    }
                    for (b, x) in xs.iter().enumerate() {
                        let x = x.as_ref();
                        let mut acc = 0i64;
                        for k in 0..n {
                            acc += sign_buf[k] * x[rows_buf[k]] as i64;
                        }
                        ys[b][c - c0] += acc;
                    }
                }
            }
        }
        ys
    }

    /// Extract a sub-matrix's trits (row-major, `[r0, r1) × [c0, c1)`) —
    /// the tiling primitive `cirom::MacroBank` shards with.
    pub fn submatrix_trits(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Vec<Trit> {
        assert!(r0 <= r1 && r1 <= self.rows, "row range {r0}..{r1}");
        assert!(c0 <= c1 && c1 <= self.cols, "col range {c0}..{c1}");
        let (h, w) = (r1 - r0, c1 - c0);
        let mut out = vec![0i8; h * w];
        if h == 0 || w == 0 {
            return out;
        }
        for (j, c) in (c0..c1).enumerate() {
            let base = c * self.words_per_col;
            for wi in (r0 >> 6)..=((r1 - 1) >> 6) {
                let (p, m) = (self.plus[base + wi], self.minus[base + wi]);
                let mut bits = p | m;
                while bits != 0 {
                    let i = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let r = (wi << 6) | i;
                    if r < r0 || r >= r1 {
                        continue;
                    }
                    out[(r - r0) * w + j] = ((p >> i) & 1) as i8 - ((m >> i) & 1) as i8;
                }
            }
        }
        out
    }

    /// Plane-level submatrix (`[r0, r1) × [c0, c1)`) — word-wise bit
    /// extraction straight into a new plane view, no base-3 roundtrip
    /// (the `cirom::MacroBank` tiling path).
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> BitplaneMatrix {
        BitplaneMatrix::from_trits(r1 - r0, c1 - c0, &self.submatrix_trits(r0, r1, c0, c1))
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ref_gemv, TernaryMatrix};
    use super::*;
    use crate::util::check::check;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    fn random_case(g: &mut crate::util::check::Gen) -> (usize, usize, Vec<Trit>, Vec<i32>) {
        // shapes deliberately straddle the 64-row word boundary
        let rows = g.size(200);
        let cols = g.size(48);
        let p_zero = g.f64(); // full sparsity range 0.0..1.0
        let trits = g.vec_trits(rows * cols, p_zero);
        // negative, zero, and large activations all exercised
        let x: Vec<i32> = (0..rows)
            .map(|_| {
                if g.rng.bool(0.15) {
                    0
                } else {
                    g.rng.i64(-127, 127) as i32
                }
            })
            .collect();
        (rows, cols, trits, x)
    }

    #[test]
    fn gemv_bit_identical_to_reference_property() {
        check(0xB17A, 150, |g| {
            let (rows, cols, trits, x) = random_case(g);
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            prop_assert_eq!(plane.gemv(&x), ref_gemv(&x, &w));
            Ok(())
        });
    }

    #[test]
    fn gemv_exact_at_word_boundaries() {
        // rows exactly at, one under, and one over multiples of 64
        let mut rng = crate::util::rng::Rng::new(0xB0);
        for rows in [1usize, 63, 64, 65, 127, 128, 129, 192] {
            let cols = 7;
            let trits: Vec<Trit> = (0..rows * cols).map(|_| rng.trit(0.3)).collect();
            let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            assert_eq!(plane.gemv(&x), ref_gemv(&x, &w), "rows {rows}");
        }
    }

    #[test]
    fn gemv_covers_both_density_paths() {
        // all-dense (sparsity 0) forces the whole-word path; high
        // sparsity forces bit iteration; both must agree with ref.
        let mut rng = crate::util::rng::Rng::new(0xD3);
        for p_zero in [0.0, 0.05, 0.5, 0.95, 1.0] {
            let (rows, cols) = (130, 9);
            let trits: Vec<Trit> = (0..rows * cols).map(|_| rng.trit(p_zero)).collect();
            let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            assert_eq!(plane.gemv(&x), ref_gemv(&x, &w), "p_zero {p_zero}");
        }
    }

    #[test]
    fn gemm_bit_identical_to_mapped_reference_property() {
        check(0x6E44, 80, |g| {
            let (rows, cols, trits, _) = random_case(g);
            let batch = g.usize(1, 6);
            let xs: Vec<Vec<i32>> = (0..batch)
                .map(|_| (0..rows).map(|_| g.rng.i64(-127, 127) as i32).collect())
                .collect();
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            let want: Vec<Vec<i64>> = xs.iter().map(|x| ref_gemv(x, &w)).collect();
            prop_assert_eq!(plane.gemm(&xs), want);
            Ok(())
        });
    }

    #[test]
    fn gemm_empty_batch() {
        let plane = BitplaneMatrix::from_trits(4, 4, &[1i8; 16]);
        assert!(plane.gemm::<Vec<i32>>(&[]).is_empty());
    }

    #[test]
    fn gemm_accepts_borrowed_rows() {
        let plane = BitplaneMatrix::from_trits(3, 2, &[1, -1, 0, 1, -1, 0]);
        let x = [2i32, 3, 5];
        let borrowed: Vec<&[i32]> = vec![&x];
        assert_eq!(plane.gemm(&borrowed), vec![vec![2 - 5, -2 + 3]]);
    }

    #[test]
    fn gemv_into_reuses_buffer() {
        let plane = BitplaneMatrix::from_trits(3, 2, &[1, -1, 0, 1, -1, 0]);
        let mut y = vec![99i64; 2];
        plane.gemv_into(&[2, 3, 5], &mut y);
        assert_eq!(y, vec![2 - 5, -2 + 3]);
    }

    #[test]
    fn get_and_col_trits_match_source() {
        check(0xC01, 100, |g| {
            let rows = g.size(150);
            let cols = g.size(20);
            let trits = g.vec_trits(rows * cols, 0.4);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            for c in 0..cols {
                let col = plane.col_trits(c);
                for r in 0..rows {
                    prop_assert_eq!(col[r], trits[r * cols + c]);
                }
            }
            let r = g.usize(0, rows - 1);
            let c = g.usize(0, cols - 1);
            prop_assert_eq!(plane.get(r, c), trits[r * cols + c]);
            Ok(())
        });
    }

    #[test]
    fn submatrix_extraction_matches_source() {
        check(0x5AB, 100, |g| {
            let rows = g.size(180);
            let cols = g.size(24);
            let trits = g.vec_trits(rows * cols, 0.3);
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            let r0 = g.usize(0, rows);
            let r1 = g.usize(r0, rows);
            let c0 = g.usize(0, cols);
            let c1 = g.usize(c0, cols);
            let sub = plane.submatrix_trits(r0, r1, c0, c1);
            for r in r0..r1 {
                for c in c0..c1 {
                    prop_assert_eq!(
                        sub[(r - r0) * (c1 - c0) + (c - c0)],
                        trits[r * cols + c]
                    );
                }
            }
            // the plane-level submatrix is the same data as a plane
            // built from the extracted trits
            let sub_plane = plane.submatrix(r0, r1, c0, c1);
            prop_assert_eq!(
                sub_plane,
                BitplaneMatrix::from_trits(r1 - r0, c1 - c0, &sub)
            );
            Ok(())
        });
    }

    #[test]
    fn popcount_sparsity_is_exact() {
        check(0x90C, 60, |g| {
            let rows = g.size(100);
            let cols = g.size(30);
            let trits = g.vec_trits(rows * cols, g.f64());
            let plane = BitplaneMatrix::from_trits(rows, cols, &trits);
            let zeros = trits.iter().filter(|&&t| t == 0).count();
            prop_assert!(
                (plane.sparsity() - zeros as f64 / trits.len() as f64).abs() < 1e-15,
                "sparsity mismatch"
            );
            prop_assert_eq!(plane.nonzeros(), (trits.len() - zeros) as u64);
            Ok(())
        });
    }

    #[test]
    fn storage_is_two_bits_per_weight_plus_padding() {
        let plane = BitplaneMatrix::from_trits(128, 16, &[1i8; 128 * 16]);
        // 2 words per column per plane × 16 cols × 2 planes × 8 bytes
        assert_eq!(plane.storage_bytes(), 2 * 16 * 2 * 8);
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_panics() {
        BitplaneMatrix::from_trits(2, 2, &[0; 4]).gemv(&[1]);
    }

    /// A shape big enough (≥ PAR_MIN_WEIGHTS) that the pooled paths
    /// genuinely fork workers instead of hitting the serial cutoff.
    fn parallel_case() -> (BitplaneMatrix, Vec<i32>, Vec<Vec<i32>>) {
        let mut rng = crate::util::rng::Rng::new(0x7AE);
        let (rows, cols) = (1031, 130); // >64k weights, ∤64 rows, odd cols
        let trits: Vec<Trit> = (0..rows * cols).map(|_| rng.trit(0.3)).collect();
        let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
        let xs: Vec<Vec<i32>> = (0..5)
            .map(|_| (0..rows).map(|_| rng.i64(-127, 127) as i32).collect())
            .collect();
        (BitplaneMatrix::from_trits(rows, cols, &trits), x, xs)
    }

    #[test]
    fn sharded_gemv_is_bit_identical_at_every_width() {
        // DESIGN.md §12: each output column is accumulated whole by one
        // worker, so sharding cannot change a single bit
        let (plane, x, _) = parallel_case();
        let serial = plane.gemv_with(&x, &Pool::serial());
        for threads in [2usize, 4, 7, 64] {
            let got = plane.gemv_with(&x, &Pool::new(threads));
            assert_eq!(got, serial, "gemv diverged at {threads} threads");
        }
        // the into-buffer variant shards the same way
        let mut y = vec![0i64; plane.cols()];
        plane.gemv_into_with(&x, &mut y, &Pool::new(4));
        assert_eq!(y, serial);
    }

    #[test]
    fn sharded_gemm_is_bit_identical_at_every_width() {
        let (plane, _, xs) = parallel_case();
        let serial = plane.gemm_with(&xs, &Pool::serial());
        for threads in [2usize, 4, 7] {
            let got = plane.gemm_with(&xs, &Pool::new(threads));
            assert_eq!(got, serial, "gemm diverged at {threads} threads");
        }
    }

    #[test]
    fn sharded_kernels_handle_degenerate_shapes() {
        let pool = Pool::new(7);
        // 0-row matrix: every column accumulates nothing
        let zero_rows = BitplaneMatrix::from_trits(0, 5, &[]);
        assert_eq!(zero_rows.gemv_with(&[], &pool), vec![0i64; 5]);
        // 0-column matrix: empty output
        let zero_cols = BitplaneMatrix::from_trits(4, 0, &[]);
        assert!(zero_cols.gemv_with(&[1, 2, 3, 4], &pool).is_empty());
        // 1-row matrix with far more workers than rows or columns
        let one_row = BitplaneMatrix::from_trits(1, 3, &[1, -1, 0]);
        assert_eq!(one_row.gemv_with(&[5], &pool), vec![5, -5, 0]);
        assert_eq!(
            one_row.gemm_with(&[vec![2], vec![-3]], &Pool::new(64)),
            vec![vec![2, -2, 0], vec![-3, 3, 0]]
        );
    }

    #[test]
    fn small_matrices_stay_on_the_serial_path() {
        // below PAR_MIN_WEIGHTS the pooled call must not fork (perf
        // guard); behaviorally it is indistinguishable — assert the
        // results anyway so the cutoff can never change semantics
        let plane = BitplaneMatrix::from_trits(3, 2, &[1, -1, 0, 1, -1, 0]);
        assert_eq!(plane.shard_width(&Pool::new(8)), 1);
        assert_eq!(plane.gemv_with(&[2, 3, 5], &Pool::new(8)), plane.gemv(&[2, 3, 5]));
        let (big, _, _) = parallel_case();
        assert!(big.shard_width(&Pool::new(8)) > 1);
    }
}
