//! Kernel engine v2 — the unified entrypoint for every ternary
//! GEMV/GEMM in the crate (DESIGN.md §17).
//!
//! A [`KernelCtx`] bundles the three knobs a matmul call used to take
//! through six near-duplicate methods (`gemv`/`gemv_with`/`gemv_into`/
//! `gemv_into_with`/`gemm`/`gemm_with`): the worker [`Pool`], the
//! compute [`KernelPath`], and the column tile used by the batched
//! kernels. New paths extend the enum instead of multiplying the
//! method surface.
//!
//! Two compute paths, bit-identical by construction:
//!
//! * **Scalar** — the word-parallel sign-select loop: sparse words
//!   iterate set bits (`trailing_zeros`), dense words stream all 64
//!   lanes. The portable twin; also the fallback for activations that
//!   do not fit in 8 bits.
//! * **BitSerial** — SIMD-within-a-register over multiple u64 lanes:
//!   each 64-row activation word is transposed once into eight u64
//!   bit-lanes (two's-complement i8), and a dense weight word then
//!   reduces to 16 AND+POPCNT ops instead of 64 multiply-adds:
//!   `dot = Σ_b (popcnt(plus & lane_b) − popcnt(minus & lane_b)) · 2^b`
//!   with the sign bit subtracted (`b = 7` weighs −128). On x86-64 the
//!   hardware `popcnt` instruction is runtime-detected
//!   (`is_x86_feature_detected!`) and the same loop body is
//!   monomorphized behind `#[target_feature(enable = "popcnt")]`; the
//!   portable build uses the SWAR `u64::count_ones`. Sparse words keep
//!   the scalar set-bit iteration — zero-skip beats bit-slicing below
//!   [`BITSERIAL_WORD_CUTOVER`] resident lanes.
//!
//! Every path accumulates in exact i64, so results are bit-identical
//! to [`ref_gemv`](super::ref_gemv)/[`ref_gemm`](super::ref_gemm) and
//! to each other — kernel path changes throughput, never results
//! (property-tested across lane remainders, sparsities 0–1, and pool
//! widths). The batched kernels additionally offer a flat row-major
//! output ([`KernelCtx::gemm_flat`]) so the per-round decode hot loop
//! reuses one buffer instead of churning `Vec<Vec<i64>>`.

use super::bitplane::BitplaneMatrix;
use crate::util::pool::{chunk_bounds, Pool};

/// Above this many populated lanes in a 64-row word, the scalar dense
/// sign-select pass beats per-set-bit iteration.
const DENSE_WORD_CUTOVER: u32 = 32;

/// Above this many populated lanes, the bit-serial popcount reduction
/// (a fixed ~16 AND+POPCNT ops per word) beats set-bit iteration
/// (~2 dependent ops per set bit).
const BITSERIAL_WORD_CUTOVER: u32 = 12;

/// Below this many weights a kernel stays serial no matter what width
/// the caller's pool requests: a `thread::scope` fork costs tens of
/// microseconds, which dwarfs a small GEMV. The cutoff only affects
/// speed — sharding is bit-identical at any width.
const PAR_MIN_WEIGHTS: usize = 64 * 1024;

/// Default output-column tile of the batched kernels: 256 columns of
/// plane words (2 planes × words/col × 8 B) stay L1/L2-resident while
/// the whole batch streams through them.
const DEFAULT_COL_TILE: usize = 256;

/// Compute path selector for [`KernelCtx`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelPath {
    /// Pick per call: bit-serial when every activation fits in i8
    /// (the quantized `act_bits ≤ 8` serving path always does), the
    /// scalar twin otherwise.
    #[default]
    Auto,
    /// The portable word-parallel sign-select loop.
    Scalar,
    /// The multi-lane popcount engine (falls back to scalar when an
    /// activation exceeds the i8 range — results are identical either
    /// way, only throughput changes).
    BitSerial,
}

impl KernelPath {
    /// Parse a CLI/config spelling (`auto` | `scalar` | `bitserial`).
    pub fn parse(s: &str) -> Option<KernelPath> {
        match s {
            "auto" => Some(KernelPath::Auto),
            "scalar" => Some(KernelPath::Scalar),
            "bitserial" => Some(KernelPath::BitSerial),
            _ => None,
        }
    }

    /// The canonical config spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelPath::Auto => "auto",
            KernelPath::Scalar => "scalar",
            KernelPath::BitSerial => "bitserial",
        }
    }
}

/// The unified kernel entrypoint: pool width + compute path + column
/// tile, applied uniformly to every GEMV/GEMM (module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCtx {
    pool: Pool,
    path: KernelPath,
    col_tile: usize,
}

impl Default for KernelCtx {
    fn default() -> Self {
        KernelCtx::from_env()
    }
}

impl KernelCtx {
    /// Context on an explicit pool, auto path, default tile.
    pub fn new(pool: Pool) -> Self {
        KernelCtx {
            pool,
            path: KernelPath::Auto,
            col_tile: DEFAULT_COL_TILE,
        }
    }

    /// The always-serial context (width 1, auto path).
    pub fn serial() -> Self {
        KernelCtx::new(Pool::serial())
    }

    /// Context at the process-default width (`BITROM_THREADS`), on the
    /// path named by `BITROM_KERNEL_PATH` when set (auto otherwise;
    /// unknown names fall back to auto — an env twin must never turn a
    /// working process into an error).
    pub fn from_env() -> Self {
        let ctx = KernelCtx::new(Pool::from_env());
        match std::env::var("BITROM_KERNEL_PATH")
            .ok()
            .as_deref()
            .and_then(KernelPath::parse)
        {
            Some(path) => ctx.with_path(path),
            None => ctx,
        }
    }

    /// Select the compute path (builder style).
    pub fn with_path(mut self, path: KernelPath) -> Self {
        self.path = path;
        self
    }

    /// Override the batched kernels' column tile (clamped to ≥ 1;
    /// tiling never changes results, only cache behavior).
    pub fn with_col_tile(mut self, cols: usize) -> Self {
        self.col_tile = cols.max(1);
        self
    }

    /// The worker pool this context shards over.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// The configured compute path.
    pub fn path(&self) -> KernelPath {
        self.path
    }

    /// Integer GEMV `y[c] = Σ_r x[r]·w[r][c]`, exact i64 — bit-identical
    /// to [`ref_gemv`](super::ref_gemv) on every path and pool width.
    pub fn gemv(&self, w: &BitplaneMatrix, x: &[i32]) -> Vec<i64> {
        let mut y = vec![0i64; w.cols()];
        self.gemv_into(w, x, &mut y);
        y
    }

    /// [`Self::gemv`] into a caller-provided buffer (overwrites `y`).
    /// The output slice is split into per-worker column chunks —
    /// disjoint `&mut` views, no copies, no stitching.
    pub fn gemv_into(&self, w: &BitplaneMatrix, x: &[i32], y: &mut [i64]) {
        assert_eq!(x.len(), w.rows(), "gemv dim mismatch");
        assert_eq!(y.len(), w.cols(), "gemv output dim mismatch");
        let bitserial = self.use_bitserial(std::slice::from_ref(&x));
        let lanes = if bitserial { transpose_lanes(x) } else { Vec::new() };
        let width = shard_width(w, &self.pool);
        if width <= 1 {
            gemv_cols(w, x, &lanes, bitserial, 0, w.cols(), y);
            return;
        }
        let cols = w.cols();
        let lanes = &lanes;
        std::thread::scope(|scope| {
            let mut rest: &mut [i64] = y;
            for wk in 0..width {
                let (lo, hi) = chunk_bounds(cols, width, wk);
                let (chunk, tail) = rest.split_at_mut(hi - lo);
                rest = tail;
                scope.spawn(move || gemv_cols(w, x, lanes, bitserial, lo, hi, chunk));
            }
        });
    }

    /// Batched integer GEMM, bit-identical to mapping
    /// [`ref_gemv`](super::ref_gemv) over `xs`. Allocates one nested
    /// vector per batch row; the decode hot loop should prefer
    /// [`Self::gemm_flat`].
    pub fn gemm<X: AsRef<[i32]> + Sync>(&self, w: &BitplaneMatrix, xs: &[X]) -> Vec<Vec<i64>> {
        let mut flat = Vec::new();
        self.gemm_flat(w, xs, &mut flat);
        let mut rows: Vec<Vec<i64>> = flat
            .chunks(w.cols().max(1))
            .take(xs.len())
            .map(|r| r.to_vec())
            .collect();
        rows.resize(xs.len(), Vec::new()); // zero-column matrices: one empty row per batch entry
        rows
    }

    /// Batched integer GEMM into a flat row-major buffer:
    /// `out[b * w.cols() + c]` is batch row `b`, output column `c`.
    /// `out` is resized to `xs.len() × w.cols()` and overwritten — the
    /// per-round decode loop reuses one allocation across rounds.
    ///
    /// Workers own contiguous column ranges of every batch row
    /// (cache-tiled by [`Self::with_col_tile`]); each output element is
    /// accumulated in exact i64 by exactly one worker, so results are
    /// bit-identical at every width, path, and tile.
    pub fn gemm_flat<X: AsRef<[i32]> + Sync>(
        &self,
        w: &BitplaneMatrix,
        xs: &[X],
        out: &mut Vec<i64>,
    ) {
        for x in xs {
            assert_eq!(x.as_ref().len(), w.rows(), "gemm dim mismatch");
        }
        let cols = w.cols();
        out.clear();
        out.resize(xs.len() * cols, 0);
        if xs.is_empty() || cols == 0 {
            return;
        }
        let bitserial = self.use_bitserial(xs);
        let lanes: Vec<Vec<Lanes>> = if bitserial {
            xs.iter().map(|x| transpose_lanes(x.as_ref())).collect()
        } else {
            Vec::new()
        };
        let width = if w.rows() * cols * xs.len() < PAR_MIN_WEIGHTS {
            1
        } else {
            shard_width(w, &self.pool)
        };
        if width <= 1 {
            let mut views: Vec<&mut [i64]> = out.chunks_mut(cols).collect();
            gemm_cols(w, xs, &lanes, bitserial, 0, cols, self.col_tile, &mut views);
            return;
        }
        // split each row-major output row at the worker chunk bounds,
        // regrouping the disjoint &mut column views per worker
        let bounds: Vec<(usize, usize)> =
            (0..width).map(|wk| chunk_bounds(cols, width, wk)).collect();
        let mut per_worker: Vec<Vec<&mut [i64]>> =
            (0..width).map(|_| Vec::with_capacity(xs.len())).collect();
        for row in out.chunks_mut(cols) {
            let mut rest = row;
            for (wk, &(lo, hi)) in bounds.iter().enumerate() {
                let (chunk, tail) = rest.split_at_mut(hi - lo);
                rest = tail;
                per_worker[wk].push(chunk);
            }
        }
        let (lanes, tile) = (&lanes, self.col_tile);
        std::thread::scope(|scope| {
            for (wk, mut views) in per_worker.into_iter().enumerate() {
                let (lo, hi) = bounds[wk];
                scope.spawn(move || {
                    gemm_cols(w, xs, lanes, bitserial, lo, hi, tile, &mut views)
                });
            }
        });
    }

    /// True when this call runs the bit-serial engine: path says so
    /// (or Auto) and every activation of every row fits two's-complement
    /// i8 — the range the lane transpose encodes exactly.
    fn use_bitserial<X: AsRef<[i32]>>(&self, xs: &[X]) -> bool {
        match self.path {
            KernelPath::Scalar => false,
            KernelPath::Auto | KernelPath::BitSerial => xs
                .iter()
                .all(|x| x.as_ref().iter().all(|&v| (-128..=127).contains(&v))),
        }
    }
}

/// Effective shard width for `w` on `pool`: serial below
/// [`PAR_MIN_WEIGHTS`], else capped at one column per worker.
fn shard_width(w: &BitplaneMatrix, pool: &Pool) -> usize {
    if w.rows() * w.cols() < PAR_MIN_WEIGHTS {
        return 1;
    }
    pool.threads().min(w.cols()).max(1)
}

/// Eight u64 bit-lanes of one 64-row activation word: `0[b]` bit `r`
/// is bit `b` of `x[word*64 + r]` as two's-complement i8.
type Lanes = [u64; 8];

/// Transpose i8-range activations into per-word bit-lanes (done once
/// per activation row, amortized over every output column).
fn transpose_lanes(x: &[i32]) -> Vec<Lanes> {
    let words = (x.len() + 63) / 64;
    let mut out = vec![[0u64; 8]; words];
    for (r, &v) in x.iter().enumerate() {
        let mut byte = (v as i8) as u8;
        let bit = (r & 63) as u32;
        let lanes = &mut out[r >> 6];
        for lane in lanes.iter_mut() {
            *lane |= u64::from(byte & 1) << bit;
            byte >>= 1;
        }
    }
    out
}

/// Bit-serial dot product of one dense 64-row weight word against the
/// eight activation bit-lanes: popcount sign-select per lane, powers
/// of two recombined with the sign lane (`b = 7`) subtracted. Exact —
/// each popcount difference is in `[-64, 64]`, the weighted sum in
/// `[-2^14, 2^14]`.
#[inline(always)]
fn dot_word_lanes(p: u64, m: u64, lanes: &Lanes) -> i64 {
    let mut acc = 0i64;
    for (b, &lane) in lanes.iter().enumerate().take(7) {
        let d = (p & lane).count_ones() as i64 - (m & lane).count_ones() as i64;
        acc += d << b;
    }
    let d7 = (p & lanes[7]).count_ones() as i64 - (m & lanes[7]).count_ones() as i64;
    acc - (d7 << 7)
}

/// Serial GEMV over columns `[c0, c1)` into `out` — the one
/// accumulation loop every GEMV path runs. `lanes` is non-empty iff
/// `bitserial`.
fn gemv_cols(
    w: &BitplaneMatrix,
    x: &[i32],
    lanes: &[Lanes],
    bitserial: bool,
    c0: usize,
    c1: usize,
    out: &mut [i64],
) {
    debug_assert_eq!(out.len(), c1 - c0);
    #[cfg(target_arch = "x86_64")]
    if bitserial && std::arch::is_x86_feature_detected!("popcnt") {
        // SAFETY: the `popcnt` CPU feature was detected at runtime on
        // this exact machine; the callee only requires that feature.
        unsafe { gemv_cols_popcnt(w, x, lanes, c0, c1, out) };
        return;
    }
    gemv_cols_body(w, x, lanes, bitserial, c0, c1, out);
}

/// [`gemv_cols_body`] monomorphized with the hardware `popcnt`
/// instruction enabled (runtime-detected by the caller).
///
/// # Safety
/// The CPU must support the `popcnt` feature.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn gemv_cols_popcnt(
    w: &BitplaneMatrix,
    x: &[i32],
    lanes: &[Lanes],
    c0: usize,
    c1: usize,
    out: &mut [i64],
) {
    gemv_cols_body(w, x, lanes, true, c0, c1, out);
}

#[inline(always)]
fn gemv_cols_body(
    w: &BitplaneMatrix,
    x: &[i32],
    lanes: &[Lanes],
    bitserial: bool,
    c0: usize,
    c1: usize,
    out: &mut [i64],
) {
    let rows = w.rows();
    let dense_cutover = if bitserial {
        BITSERIAL_WORD_CUTOVER
    } else {
        DENSE_WORD_CUTOVER
    };
    for (c, out) in (c0..c1).zip(out.iter_mut()) {
        let (pcol, mcol) = w.col_words(c);
        let mut acc = 0i64;
        for (wi, (&p, &m)) in pcol.iter().zip(mcol).enumerate() {
            let both = p | m;
            if both == 0 {
                continue;
            }
            let row0 = wi << 6;
            if both.count_ones() >= dense_cutover {
                if bitserial {
                    acc += dot_word_lanes(p, m, &lanes[wi]);
                } else {
                    // dense word: stream every resident lane,
                    // branch-free sign select
                    let xw = &x[row0..(row0 + 64).min(rows)];
                    for (i, &xv) in xw.iter().enumerate() {
                        let sign = ((p >> i) & 1) as i64 - ((m >> i) & 1) as i64;
                        acc += sign * xv as i64;
                    }
                }
            } else {
                // sparse word: touch only the set bits
                let mut pp = p;
                while pp != 0 {
                    acc += x[row0 + pp.trailing_zeros() as usize] as i64;
                    pp &= pp - 1;
                }
                let mut mm = m;
                while mm != 0 {
                    acc -= x[row0 + mm.trailing_zeros() as usize] as i64;
                    mm &= mm - 1;
                }
            }
        }
        *out = acc;
    }
}

/// Serial batched GEMM over columns `[c0, c1)` into per-row column
/// views (`outs[b][c - c0]` = batch row `b`, column `c`) — the one
/// accumulation loop every GEMM path runs. Columns are walked in
/// `col_tile` blocks so a tile's plane words stay cache-resident while
/// the whole batch streams through them; each weight word is decoded
/// once and replayed across the batch.
#[allow(clippy::too_many_arguments)]
fn gemm_cols<X: AsRef<[i32]>>(
    w: &BitplaneMatrix,
    xs: &[X],
    lanes: &[Vec<Lanes>],
    bitserial: bool,
    c0: usize,
    c1: usize,
    col_tile: usize,
    outs: &mut [&mut [i64]],
) {
    debug_assert_eq!(outs.len(), xs.len());
    #[cfg(target_arch = "x86_64")]
    if bitserial && std::arch::is_x86_feature_detected!("popcnt") {
        // SAFETY: the `popcnt` CPU feature was detected at runtime on
        // this exact machine; the callee only requires that feature.
        unsafe { gemm_cols_popcnt(w, xs, lanes, c0, c1, col_tile, outs) };
        return;
    }
    gemm_cols_body(w, xs, lanes, bitserial, c0, c1, col_tile, outs);
}

/// [`gemm_cols_body`] monomorphized with the hardware `popcnt`
/// instruction enabled (runtime-detected by the caller).
///
/// # Safety
/// The CPU must support the `popcnt` feature.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn gemm_cols_popcnt<X: AsRef<[i32]>>(
    w: &BitplaneMatrix,
    xs: &[X],
    lanes: &[Vec<Lanes>],
    c0: usize,
    c1: usize,
    col_tile: usize,
    outs: &mut [&mut [i64]],
) {
    gemm_cols_body(w, xs, lanes, true, c0, c1, col_tile, outs);
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn gemm_cols_body<X: AsRef<[i32]>>(
    w: &BitplaneMatrix,
    xs: &[X],
    lanes: &[Vec<Lanes>],
    bitserial: bool,
    c0: usize,
    c1: usize,
    col_tile: usize,
    outs: &mut [&mut [i64]],
) {
    let rows = w.rows();
    let dense_cutover = if bitserial {
        BITSERIAL_WORD_CUTOVER
    } else {
        DENSE_WORD_CUTOVER
    };
    // decoded (row, sign) scratch for one 64-row word
    let mut rows_buf = [0usize; 64];
    let mut sign_buf = [0i64; 64];
    let mut tile0 = c0;
    while tile0 < c1 {
        let tile1 = (tile0 + col_tile).min(c1);
        for c in tile0..tile1 {
            let (pcol, mcol) = w.col_words(c);
            for (wi, (&p, &m)) in pcol.iter().zip(mcol).enumerate() {
                let both = p | m;
                if both == 0 {
                    continue;
                }
                let row0 = wi << 6;
                if both.count_ones() >= dense_cutover {
                    if bitserial {
                        for (b, out) in outs.iter_mut().enumerate() {
                            out[c - c0] += dot_word_lanes(p, m, &lanes[b][wi]);
                        }
                    } else {
                        let hi = (row0 + 64).min(rows);
                        for (b, out) in outs.iter_mut().enumerate() {
                            let x = xs[b].as_ref();
                            let mut acc = 0i64;
                            for (i, &xv) in x[row0..hi].iter().enumerate() {
                                let sign = ((p >> i) & 1) as i64 - ((m >> i) & 1) as i64;
                                acc += sign * xv as i64;
                            }
                            out[c - c0] += acc;
                        }
                    }
                } else {
                    // decode the word's (row, sign) pairs once, replay
                    // across the whole batch
                    let mut n = 0usize;
                    let mut bits = both;
                    while bits != 0 {
                        let i = bits.trailing_zeros() as usize;
                        rows_buf[n] = row0 + i;
                        sign_buf[n] = ((p >> i) & 1) as i64 - ((m >> i) & 1) as i64;
                        n += 1;
                        bits &= bits - 1;
                    }
                    for (b, out) in outs.iter_mut().enumerate() {
                        let x = xs[b].as_ref();
                        let mut acc = 0i64;
                        for k in 0..n {
                            acc += sign_buf[k] * x[rows_buf[k]] as i64;
                        }
                        out[c - c0] += acc;
                    }
                }
            }
        }
        tile0 = tile1;
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ref_gemm, ref_gemv, TernaryMatrix};
    use super::*;
    use crate::util::check::check;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    fn ctx(path: KernelPath) -> KernelCtx {
        KernelCtx::serial().with_path(path)
    }

    #[test]
    fn paths_parse_and_roundtrip() {
        for p in [KernelPath::Auto, KernelPath::Scalar, KernelPath::BitSerial] {
            assert_eq!(KernelPath::parse(p.as_str()), Some(p));
        }
        assert_eq!(KernelPath::parse("avx512"), None);
        assert_eq!(KernelPath::default(), KernelPath::Auto);
    }

    #[test]
    fn every_path_matches_reference_property() {
        // SIMD ≡ scalar ≡ ref across random shapes (straddling word
        // boundaries), full sparsity range, negative/zero activations
        check(0x51D0, 120, |g| {
            let rows = g.size(200);
            let cols = g.size(48);
            let trits = g.vec_trits(rows * cols, g.f64());
            let x: Vec<i32> = (0..rows).map(|_| g.rng.i64(-128, 127) as i32).collect();
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let want = ref_gemv(&x, &w);
            for path in [KernelPath::Auto, KernelPath::Scalar, KernelPath::BitSerial] {
                prop_assert_eq!(ctx(path).gemv(w.bitplanes(), &x), want.clone());
            }
            Ok(())
        });
    }

    #[test]
    fn bitserial_exact_at_lane_remainders_and_sparsities() {
        // rows exactly at, under, and over multiples of the 64-lane
        // word width; sparsities from all-dense to all-zero
        let mut rng = crate::util::rng::Rng::new(0xB5E);
        for rows in [1usize, 63, 64, 65, 127, 128, 129, 192, 200] {
            for p_zero in [0.0, 0.3, 0.7, 1.0] {
                let cols = 9;
                let trits: Vec<i8> = (0..rows * cols).map(|_| rng.trit(p_zero)).collect();
                let x: Vec<i32> = (0..rows).map(|_| rng.i64(-128, 127) as i32).collect();
                let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
                assert_eq!(
                    ctx(KernelPath::BitSerial).gemv(w.bitplanes(), &x),
                    ref_gemv(&x, &w),
                    "rows {rows} p_zero {p_zero}"
                );
            }
        }
    }

    #[test]
    fn bitserial_covers_extreme_i8_values() {
        // a fully dense 64-lane word (forced onto the popcount path)
        // with ±127 and −128 exercising every bit-lane incl. the sign
        let trits: Vec<i8> = (0..64).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let w = TernaryMatrix::from_trits(64, 1, &trits, 1.0);
        let vals = [-128i32, 127, -1, 0, 1, -64, 64, 5];
        let x: Vec<i32> = (0..64).map(|i| vals[i % vals.len()]).collect();
        assert_eq!(
            ctx(KernelPath::BitSerial).gemv(w.bitplanes(), &x),
            ref_gemv(&x, &w)
        );
    }

    #[test]
    fn out_of_range_activations_fall_back_to_scalar() {
        // the bit-serial request still computes the right answer for
        // activations outside i8 — via the scalar twin
        let mut rng = crate::util::rng::Rng::new(0xFA11);
        let trits: Vec<i8> = (0..96 * 5).map(|_| rng.trit(0.2)).collect();
        let w = TernaryMatrix::from_trits(96, 5, &trits, 1.0);
        let x: Vec<i32> = (0..96).map(|_| rng.i64(-4000, 4000) as i32).collect();
        for path in [KernelPath::Auto, KernelPath::BitSerial] {
            assert_eq!(ctx(path).gemv(w.bitplanes(), &x), ref_gemv(&x, &w));
        }
    }

    #[test]
    fn gemm_flat_matches_nested_and_reference_property() {
        check(0x6F1A, 80, |g| {
            let rows = g.size(150);
            let cols = g.size(40);
            let trits = g.vec_trits(rows * cols, g.f64());
            let batch = g.usize(1, 9);
            let xs: Vec<Vec<i32>> = (0..batch)
                .map(|_| (0..rows).map(|_| g.rng.i64(-128, 127) as i32).collect())
                .collect();
            let w = TernaryMatrix::from_trits(rows, cols, &trits, 1.0);
            let want = ref_gemm(&xs, &w);
            for path in [KernelPath::Scalar, KernelPath::BitSerial] {
                let k = ctx(path);
                prop_assert_eq!(k.gemm(w.bitplanes(), &xs), want.clone());
                let mut flat = Vec::new();
                k.gemm_flat(w.bitplanes(), &xs, &mut flat);
                prop_assert_eq!(flat.len(), batch * cols);
                for (b, row) in want.iter().enumerate() {
                    prop_assert_eq!(&flat[b * cols..(b + 1) * cols], &row[..]);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_flat_reuses_the_buffer_across_shapes() {
        let w1 = TernaryMatrix::from_trits(3, 2, &[1, -1, 0, 1, -1, 0], 1.0);
        let mut flat = vec![99i64; 17]; // stale junk from a prior round
        let k = KernelCtx::serial();
        k.gemm_flat(w1.bitplanes(), &[vec![2, 3, 5]], &mut flat);
        assert_eq!(flat, vec![2 - 5, -2 + 3]);
        // empty batch leaves an empty buffer
        k.gemm_flat(w1.bitplanes(), &Vec::<Vec<i32>>::new(), &mut flat);
        assert!(flat.is_empty());
    }

    /// A shape big enough (≥ PAR_MIN_WEIGHTS) that the pooled paths
    /// genuinely fork workers instead of hitting the serial cutoff.
    fn parallel_case() -> (TernaryMatrix, Vec<i32>, Vec<Vec<i32>>) {
        let mut rng = crate::util::rng::Rng::new(0x7AE);
        let (rows, cols) = (1031, 130); // >64k weights, ∤64 rows, odd cols
        let trits: Vec<i8> = (0..rows * cols).map(|_| rng.trit(0.3)).collect();
        let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
        let xs: Vec<Vec<i32>> = (0..5)
            .map(|_| (0..rows).map(|_| rng.i64(-127, 127) as i32).collect())
            .collect();
        (TernaryMatrix::from_trits(rows, cols, &trits, 1.0), x, xs)
    }

    #[test]
    fn pool_width_never_changes_results_on_any_path() {
        let (w, x, xs) = parallel_case();
        for path in [KernelPath::Scalar, KernelPath::BitSerial] {
            let serial = ctx(path);
            let want_v = serial.gemv(w.bitplanes(), &x);
            let mut want_m = Vec::new();
            serial.gemm_flat(w.bitplanes(), &xs, &mut want_m);
            for threads in [2usize, 4, 7, 64] {
                let k = KernelCtx::new(Pool::new(threads)).with_path(path);
                assert_eq!(k.gemv(w.bitplanes(), &x), want_v, "gemv {path:?} @ {threads}");
                let mut y = vec![0i64; w.cols];
                k.gemv_into(w.bitplanes(), &x, &mut y);
                assert_eq!(y, want_v);
                let mut flat = Vec::new();
                k.gemm_flat(w.bitplanes(), &xs, &mut flat);
                assert_eq!(flat, want_m, "gemm {path:?} @ {threads}");
            }
        }
    }

    #[test]
    fn col_tiles_never_change_results() {
        let (w, _, xs) = parallel_case();
        let want = KernelCtx::serial().gemm(w.bitplanes(), &xs);
        for tile in [1usize, 7, 64, 1000] {
            let k = KernelCtx::new(Pool::new(4)).with_col_tile(tile);
            assert_eq!(k.gemm(w.bitplanes(), &xs), want, "tile {tile}");
        }
        // tile 0 is clamped, not UB
        assert_eq!(
            KernelCtx::serial().with_col_tile(0).gemm(w.bitplanes(), &xs),
            want
        );
    }

    #[test]
    fn degenerate_shapes_on_every_path() {
        for path in [KernelPath::Scalar, KernelPath::BitSerial] {
            let k = KernelCtx::new(Pool::new(7)).with_path(path);
            let zero_rows = TernaryMatrix::from_trits(0, 5, &[], 1.0);
            assert_eq!(k.gemv(zero_rows.bitplanes(), &[]), vec![0i64; 5]);
            let zero_cols = TernaryMatrix::from_trits(4, 0, &[], 1.0);
            assert!(k.gemv(zero_cols.bitplanes(), &[1, 2, 3, 4]).is_empty());
            let one_row = TernaryMatrix::from_trits(1, 3, &[1, -1, 0], 1.0);
            assert_eq!(k.gemv(one_row.bitplanes(), &[5]), vec![5, -5, 0]);
            let mut flat = Vec::new();
            k.gemm_flat(one_row.bitplanes(), &[vec![2], vec![-3]], &mut flat);
            assert_eq!(flat, vec![2, -2, 0, -3, 3, 0]);
        }
    }

    #[test]
    fn lane_transpose_is_exact_two_s_complement() {
        let x: Vec<i32> = vec![-128, -127, -1, 0, 1, 127, 42, -42];
        let lanes = transpose_lanes(&x);
        assert_eq!(lanes.len(), 1);
        for (r, &v) in x.iter().enumerate() {
            let mut got = 0u8;
            for (b, &lane) in lanes[0].iter().enumerate() {
                got |= (((lane >> r) & 1) as u8) << b;
            }
            assert_eq!(got as i8 as i32, v, "row {r}");
        }
    }
}
