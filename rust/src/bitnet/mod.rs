//! BitNet ternary-weight substrate: trit types, packed storage, the
//! absmean/absmax quantizers (bit-identical to `python/compile/quant.py`),
//! the golden ternary GEMV the `cirom` macro simulator is verified
//! against, and the word-parallel [`BitplaneMatrix`] kernel engine the
//! host-side functional compute paths run on (bit-identical to
//! `ref_gemv`, property-tested).

mod bitplane;
mod gemv;
pub mod pack;
mod quant;

pub use bitplane::BitplaneMatrix;
pub use gemv::{ref_gemm, ref_gemv, TernaryMatrix};
pub use pack::{pack_trits, unpack_trits, PackedTrits};
pub use quant::{absmax_quantize, absmean_ternary, QuantizedActs};

/// A ternary weight: -1, 0 or +1, stored as i8.
pub type Trit = i8;

/// Validity check used across the module.
pub fn is_trit(v: i8) -> bool {
    (-1..=1).contains(&v)
}
