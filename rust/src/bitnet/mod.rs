//! BitNet ternary-weight substrate: trit types, packed storage, the
//! absmean/absmax quantizers (bit-identical to `python/compile/quant.py`),
//! the golden ternary GEMV the `cirom` macro simulator is verified
//! against, the [`BitplaneMatrix`] compute view, and the kernel engine
//! v2 behind [`KernelCtx`] — scalar and bit-serial popcount paths, all
//! bit-identical to `ref_gemv`/`ref_gemm` (property-tested); kernel
//! path changes throughput, never results.

mod bitplane;
mod gemv;
pub mod kernel;
pub mod pack;
mod quant;

pub use bitplane::BitplaneMatrix;
pub use gemv::{ref_gemm, ref_gemv, TernaryMatrix};
pub use kernel::{KernelCtx, KernelPath};
pub use pack::{pack_trits, unpack_trits, PackedTrits};
pub use quant::{absmax_quantize, absmean_ternary, QuantizedActs};

/// A ternary weight: -1, 0 or +1, stored as i8.
pub type Trit = i8;

/// Validity check used across the module.
pub fn is_trit(v: i8) -> bool {
    (-1..=1).contains(&v)
}
