//! DR (Decode-Refresh) eDRAM — the paper's §IV contribution.
//!
//! The key observation: once a token's KV is stored, it is read at
//! *every* subsequent decoding step, and a DRAM read inherently
//! refreshes the row (WL open → sense amplify → WL close). Therefore,
//! as long as the Token-Between-Token time stays below the cell
//! retention time (tREF = 64 ms per JESD79-5C), the KV-cache needs **no
//! explicit refresh management at all**.
//!
//! This simulator makes that argument *checkable* rather than assumed:
//! every row carries a retention deadline; reads and writes renew it;
//! reading an expired row is a hard `RetentionError`; and an optional
//! scrubber counts how many explicit refreshes would have been needed —
//! zero under a healthy decode loop (tested), nonzero if the loop
//! stalls past tREF.

use crate::config::EdramParams;

/// Error: a row was read after its retention deadline.
#[derive(Debug, Clone, PartialEq)]
pub struct RetentionError {
    /// The expired row.
    pub row: usize,
    /// Seconds past the retention deadline (∞ for never-written).
    pub expired_for_s: f64,
}

impl std::fmt::Display for RetentionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "eDRAM row {} read {:.3}s past its retention deadline",
            self.row, self.expired_for_s
        )
    }
}

impl std::error::Error for RetentionError {}

#[derive(Debug, Clone, Copy, Default)]
struct Row {
    /// Simulation time of the last operation that refreshed the cells
    /// (write, read, or explicit refresh). `None` = never written.
    last_refresh: Option<f64>,
}

/// The DR eDRAM array with access/energy counters.
#[derive(Debug, Clone)]
pub struct DrEdram {
    /// Array parameters (capacity, tREF, energies).
    pub params: EdramParams,
    rows: Vec<Row>,
    /// Successful row reads.
    pub reads: u64,
    /// Row writes.
    pub writes: u64,
    /// Explicit refreshes issued (0 under healthy decode-refresh).
    pub explicit_refreshes: u64,
    /// Bytes read.
    pub read_bytes: u64,
    /// Bytes written.
    pub write_bytes: u64,
    /// Reads attempted past the retention deadline.
    pub retention_failures: u64,
}

impl DrEdram {
    /// Blank array sized from `params`.
    pub fn new(params: EdramParams) -> Self {
        let n_rows = (params.capacity_bytes / params.row_bytes) as usize;
        DrEdram {
            params,
            rows: vec![Row::default(); n_rows],
            reads: 0,
            writes: 0,
            explicit_refreshes: 0,
            read_bytes: 0,
            write_bytes: 0,
            retention_failures: 0,
        }
    }

    /// Rows in the array.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Total capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.params.capacity_bytes
    }

    /// Write `bytes` into `row` at simulation time `now` (refreshes it).
    pub fn write(&mut self, row: usize, bytes: u64, now: f64) {
        assert!(row < self.rows.len(), "eDRAM row {row} out of range");
        self.rows[row].last_refresh = Some(now);
        self.writes += 1;
        self.write_bytes += bytes;
    }

    /// Read `bytes` from `row` at time `now`. A successful read
    /// automatically refreshes the row (the DR property). Reading an
    /// expired or never-written row fails.
    pub fn read(&mut self, row: usize, bytes: u64, now: f64) -> Result<(), RetentionError> {
        assert!(row < self.rows.len(), "eDRAM row {row} out of range");
        match self.rows[row].last_refresh {
            Some(t) if now - t <= self.params.t_ref_s => {
                self.rows[row].last_refresh = Some(now); // refresh-on-read
                self.reads += 1;
                self.read_bytes += bytes;
                Ok(())
            }
            Some(t) => {
                self.retention_failures += 1;
                Err(RetentionError {
                    row,
                    expired_for_s: now - t - self.params.t_ref_s,
                })
            }
            None => {
                self.retention_failures += 1;
                Err(RetentionError {
                    row,
                    expired_for_s: f64::INFINITY,
                })
            }
        }
    }

    /// Explicit refresh of one row (the fallback a conventional eDRAM
    /// controller would issue). Counted separately so experiments can
    /// show the DR scheme needs zero of these during healthy decoding.
    pub fn explicit_refresh(&mut self, row: usize, now: f64) {
        assert!(row < self.rows.len());
        self.rows[row].last_refresh = Some(now);
        self.explicit_refreshes += 1;
    }

    /// Scrub pass: explicitly refresh every live row whose deadline
    /// would expire before `now + horizon`. Returns how many refreshes
    /// were issued. A conventional controller runs this continuously;
    /// under DR decoding it should find nothing to do.
    pub fn scrub(&mut self, now: f64, horizon: f64) -> u64 {
        let mut issued = 0;
        for i in 0..self.rows.len() {
            if let Some(t) = self.rows[i].last_refresh {
                if now + horizon - t > self.params.t_ref_s {
                    self.explicit_refresh(i, now);
                    issued += 1;
                }
            }
        }
        issued
    }

    /// Seconds of retention slack remaining for `row` at `now`
    /// (negative = expired).
    pub fn slack(&self, row: usize, now: f64) -> Option<f64> {
        self.rows[row]
            .last_refresh
            .map(|t| self.params.t_ref_s - (now - t))
    }

    /// Array energy spent so far (J), explicit refreshes included.
    pub fn energy_j(&self) -> f64 {
        (self.read_bytes as f64 * self.params.read_pj_per_byte
            + self.write_bytes as f64 * self.params.write_pj_per_byte
            + self.explicit_refreshes as f64 * self.params.refresh_pj_per_row)
            * 1e-12
    }

    /// Reads + writes.
    pub fn accesses(&self) -> u64 {
        self.reads + self.writes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DrEdram {
        DrEdram::new(EdramParams {
            capacity_bytes: 64 * 16,
            row_bytes: 64,
            t_ref_s: 0.064,
            ..EdramParams::default()
        })
    }

    #[test]
    fn write_then_read_within_retention_ok() {
        let mut e = small();
        e.write(3, 64, 0.0);
        assert!(e.read(3, 64, 0.050).is_ok());
        assert_eq!(e.reads, 1);
        assert_eq!(e.writes, 1);
    }

    #[test]
    fn read_refreshes_the_row() {
        // chain of reads each 50ms apart stays alive indefinitely even
        // though total elapsed time >> tREF — the DR property.
        let mut e = small();
        e.write(0, 64, 0.0);
        for step in 1..=20 {
            let now = step as f64 * 0.050;
            assert!(e.read(0, 64, now).is_ok(), "step {step}");
        }
        assert_eq!(e.retention_failures, 0);
        assert_eq!(e.explicit_refreshes, 0);
    }

    #[test]
    fn expired_read_fails() {
        let mut e = small();
        e.write(1, 64, 0.0);
        let err = e.read(1, 64, 0.065).unwrap_err();
        assert_eq!(err.row, 1);
        assert!(err.expired_for_s > 0.0);
        assert_eq!(e.retention_failures, 1);
    }

    #[test]
    fn never_written_read_fails() {
        let mut e = small();
        assert!(e.read(2, 64, 0.0).is_err());
    }

    #[test]
    fn scrub_finds_nothing_under_healthy_cadence() {
        let mut e = small();
        e.write(0, 64, 0.0);
        e.write(1, 64, 0.0);
        let _ = e.read(0, 64, 0.030);
        let _ = e.read(1, 64, 0.030);
        assert_eq!(e.scrub(0.040, 0.010), 0);
    }

    #[test]
    fn scrub_rescues_stale_rows() {
        let mut e = small();
        e.write(0, 64, 0.0);
        let issued = e.scrub(0.060, 0.010); // would expire by 0.070
        assert_eq!(issued, 1);
        assert!(e.read(0, 64, 0.070).is_ok()); // rescued
        assert_eq!(e.explicit_refreshes, 1);
    }

    #[test]
    fn slack_decreases_with_time() {
        let mut e = small();
        e.write(0, 64, 0.0);
        let s1 = e.slack(0, 0.010).unwrap();
        let s2 = e.slack(0, 0.020).unwrap();
        assert!(s1 > s2 && s2 > 0.0);
        assert!(e.slack(1, 0.0).is_none());
    }

    #[test]
    fn energy_counts_refreshes_separately() {
        let mut e = small();
        e.write(0, 64, 0.0);
        let base = e.energy_j();
        e.explicit_refresh(0, 0.01);
        assert!(e.energy_j() > base);
    }

    #[test]
    fn capacity_rows() {
        let e = DrEdram::new(EdramParams::default());
        // 13.5 MB / 64 B rows
        assert_eq!(e.n_rows() as u64, 13_500_000 / 64);
    }
}
