//! The runtime KV-cache manager used by the serving coordinator: routes
//! every KV read/write to DR eDRAM (early tokens) or external DRAM
//! (late tokens), advancing the eDRAM retention clock with simulation
//! time so the refresh-on-read argument is continuously checked.

use crate::config::{EdramParams, ModelConfig, ServeConfig};
use crate::dram::{DramParams, ExternalDram};
use crate::edram::{DrEdram, RetentionError};

/// Aggregate access statistics for one run.
#[derive(Debug, Clone, Default)]
pub struct KvStats {
    /// (token, layer) reads served by DR eDRAM.
    pub ondie_reads: u64,
    /// (token, layer) writes into DR eDRAM.
    pub ondie_writes: u64,
    /// (token, layer) reads from external DRAM.
    pub external_reads: u64,
    /// (token, layer) writes to external DRAM.
    pub external_writes: u64,
}

impl KvStats {
    /// Accesses that hit the external interface.
    pub fn external_accesses(&self) -> u64 {
        self.external_reads + self.external_writes
    }

    /// Accesses across both tiers.
    pub fn total_accesses(&self) -> u64 {
        self.external_accesses() + self.ondie_reads + self.ondie_writes
    }

    /// Fraction of accesses kept off the external interface.
    pub fn external_reduction(&self) -> f64 {
        if self.total_accesses() == 0 {
            return 0.0;
        }
        1.0 - self.external_accesses() as f64 / self.total_accesses() as f64
    }
}

#[derive(Debug, Clone)]
struct SeqState {
    /// Tokens whose KV has been written (absolute count).
    len: usize,
    /// Shared-prefix binding: `(donor_slot, bound_tokens)`. Context
    /// reads of tokens below the bound route to the donor's rows —
    /// one physical copy, every reader refreshes it.
    shared: Option<(usize, usize)>,
}

/// KV-cache manager for up to `max_batches` concurrent sequences.
#[derive(Debug)]
pub struct KvCacheManager {
    n_layers: usize,
    /// K+V bytes per (token, layer).
    kv_bytes: u64,
    ondie_tokens: usize,
    max_seq: usize,
    rows_per_record: usize,
    edram: DrEdram,
    dram: ExternalDram,
    seqs: Vec<Option<SeqState>>,
    /// Accumulated access counts.
    pub stats: KvStats,
}

impl KvCacheManager {
    /// Manager sized for `serve` over `model` (asserts the on-die
    /// working set fits the eDRAM capacity).
    pub fn new(model: &ModelConfig, serve: &ServeConfig, edram_params: EdramParams) -> Self {
        // K + V, f32 entries (the simulation artifacts run f32; the
        // paper's silicon would use 8/16-bit KV — the *ratio* results
        // are byte-size independent).
        let kv_bytes = model.kv_bytes_per_token(4) / model.n_layers as u64;
        let rows_per_record =
            ((kv_bytes + edram_params.row_bytes - 1) / edram_params.row_bytes) as usize;
        let needed_rows =
            serve.max_batches * model.n_layers * serve.ondie_tokens * rows_per_record;
        assert!(
            (needed_rows as u64) * edram_params.row_bytes <= edram_params.capacity_bytes,
            "DR eDRAM capacity {} B cannot hold {} on-die tokens for {} slots",
            edram_params.capacity_bytes,
            serve.ondie_tokens,
            serve.max_batches,
        );
        KvCacheManager {
            n_layers: model.n_layers,
            kv_bytes,
            ondie_tokens: serve.ondie_tokens,
            max_seq: serve.max_seq,
            rows_per_record,
            edram: DrEdram::new(edram_params),
            dram: ExternalDram::new(DramParams::default()),
            seqs: vec![None; serve.max_batches],
            stats: KvStats::default(),
        }
    }

    fn row_base(&self, slot: usize, layer: usize, token: usize) -> usize {
        ((slot * self.n_layers + layer) * self.ondie_tokens + token) * self.rows_per_record
    }

    /// Begin a sequence in `slot` (frees any previous occupant).
    pub fn start_seq(&mut self, slot: usize) {
        assert!(slot < self.seqs.len(), "slot {slot} out of range");
        self.seqs[slot] = Some(SeqState { len: 0, shared: None });
    }

    /// Bind the first `bound` tokens of `slot` to the donor's already
    /// resident prefix — the analytic face of `KvStore::bind_prefix`
    /// (DESIGN.md §15). Binding records no writes; the bound tokens'
    /// context reads route to the donor's rows, so one physical copy
    /// serves every reader and each read refreshes it. Must be called
    /// on a freshly started, empty sequence.
    pub fn bind_prefix(&mut self, slot: usize, donor: usize, bound: usize) {
        assert!(slot != donor, "a sequence cannot donate to itself");
        let donor_len = self.seqs[donor].as_ref().expect("donor not started").len;
        assert!(bound <= donor_len, "donor holds only {donor_len} tokens");
        let st = self.seqs[slot].as_mut().expect("slot not started");
        assert!(st.len == 0, "bind_prefix before any writes");
        st.len = bound;
        st.shared = Some((donor, bound));
    }

    /// Finish the sequence in `slot`, freeing it.
    pub fn end_seq(&mut self, slot: usize) {
        self.seqs[slot] = None;
    }

    /// Tokens written for the sequence in `slot`.
    pub fn seq_len(&self, slot: usize) -> usize {
        self.seqs[slot].as_ref().map_or(0, |s| s.len)
    }

    /// Record the KV write of the next token (all layers) at time `now`.
    pub fn write_token(&mut self, slot: usize, now: f64) -> usize {
        let (ondie_tokens, n_layers, kv_bytes, rows_per_record) = (
            self.ondie_tokens,
            self.n_layers,
            self.kv_bytes,
            self.rows_per_record,
        );
        let token = {
            let st = self.seqs[slot].as_mut().expect("slot not started");
            let t = st.len;
            assert!(t < self.max_seq, "sequence overflow in slot {slot}");
            st.len += 1;
            t
        };
        for layer in 0..n_layers {
            if token < ondie_tokens {
                let base = self.row_base(slot, layer, token);
                for r in 0..rows_per_record {
                    self.edram
                        .write(base + r, kv_bytes / rows_per_record as u64, now);
                }
                self.stats.ondie_writes += 1;
            } else {
                self.dram.write(kv_bytes);
                self.stats.external_writes += 1;
            }
        }
        token
    }

    /// Record the attention reads of one decode step at time `now`: the
    /// KV of every *previous* token (the just-written token's KV feeds
    /// from the datapath registers). Returns a retention error if any
    /// on-die row expired — i.e. if the DR argument was violated.
    pub fn read_context(&mut self, slot: usize, now: f64) -> Result<(), RetentionError> {
        let (len, shared) = {
            let st = self.seqs[slot].as_ref().expect("slot not started");
            (st.len, st.shared)
        };
        for layer in 0..self.n_layers {
            for token in 0..len.saturating_sub(1) {
                if token < self.ondie_tokens {
                    // a bound token lives in the donor's rows: shared
                    // physical copy, refreshed by whichever reader
                    // touches it first each step
                    let home = match shared {
                        Some((donor, bound)) if token < bound => donor,
                        _ => slot,
                    };
                    let base = self.row_base(home, layer, token);
                    for r in 0..self.rows_per_record {
                        self.edram
                            .read(base + r, self.kv_bytes / self.rows_per_record as u64, now)?;
                    }
                    self.stats.ondie_reads += 1;
                } else {
                    self.dram.read(self.kv_bytes);
                    self.stats.external_reads += 1;
                }
            }
        }
        Ok(())
    }

    /// Prefill: write `n` prompt tokens at `now` (prefill attention
    /// reads stay in on-chip activation buffers — Fig 5(a) counts no
    /// memory reads for them).
    pub fn prefill(&mut self, slot: usize, n: usize, now: f64) {
        for _ in 0..n {
            self.write_token(slot, now);
        }
    }

    /// The on-die tier model.
    pub fn edram(&self) -> &DrEdram {
        &self.edram
    }

    /// The external tier model.
    pub fn dram(&self) -> &ExternalDram {
        &self.dram
    }

    /// Total external-DRAM energy spent on KV traffic so far.
    pub fn external_energy_j(&self) -> f64 {
        self.dram.energy_j()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> KvCacheManager {
        let model = ModelConfig::sim_tiny();
        let serve = ServeConfig::default();
        KvCacheManager::new(&model, &serve, EdramParams::default())
    }

    /// Drive one full sequence: prefill `p`, decode until `s` total.
    fn run_seq(m: &mut KvCacheManager, slot: usize, p: usize, s: usize, tbt: f64) {
        m.start_seq(slot);
        m.prefill(slot, p, 0.0);
        for step in 0..(s - p) {
            let now = (step + 1) as f64 * tbt;
            m.write_token(slot, now);
            m.read_context(slot, now).expect("retention violated");
        }
    }

    #[test]
    fn placement_splits_at_ondie_boundary() {
        let mut m = mk();
        run_seq(&mut m, 0, 8, 64, 0.005);
        // tokens 0..32 on-die, 32..64 external — writes per layer
        let l = ModelConfig::sim_tiny().n_layers as u64;
        assert_eq!(m.stats.ondie_writes, 32 * l);
        assert_eq!(m.stats.external_writes, 32 * l);
        assert!(m.stats.ondie_reads > 0 && m.stats.external_reads > 0);
    }

    #[test]
    fn healthy_decode_needs_no_explicit_refresh() {
        // TBT 5 ms << tREF 64 ms: the DR property must hold with zero
        // explicit refreshes and zero retention failures.
        let mut m = mk();
        run_seq(&mut m, 0, 8, 128, 0.005);
        assert_eq!(m.edram().explicit_refreshes, 0);
        assert_eq!(m.edram().retention_failures, 0);
    }

    #[test]
    fn stalled_decode_violates_retention() {
        let mut m = mk();
        m.start_seq(0);
        m.prefill(0, 4, 0.0);
        m.write_token(0, 0.001);
        assert!(m.read_context(0, 0.001).is_ok());
        // stall 100 ms > tREF, then resume
        m.write_token(0, 0.101);
        assert!(m.read_context(0, 0.101).is_err());
    }

    #[test]
    fn read_counts_match_fig5a_analysis() {
        // Fig 5(a): at the step producing token t (0-based), t prior
        // tokens are read, per layer.
        let mut m = mk();
        let l = ModelConfig::sim_tiny().n_layers as u64;
        m.start_seq(0);
        m.prefill(0, 1, 0.0);
        for step in 1..=10u64 {
            m.write_token(0, step as f64 * 0.005);
            m.read_context(0, step as f64 * 0.005).unwrap();
        }
        // reads per layer: Σ_{t=1..10} t = 55
        assert_eq!(m.stats.ondie_reads + m.stats.external_reads, 55 * l);
        // writes: 11 tokens per layer
        assert_eq!(m.stats.ondie_writes + m.stats.external_writes, 11 * l);
    }

    #[test]
    fn multiple_slots_do_not_collide() {
        let mut m = mk();
        run_seq(&mut m, 0, 4, 40, 0.005);
        run_seq(&mut m, 1, 4, 40, 0.005);
        assert_eq!(m.edram().retention_failures, 0);
    }

    #[test]
    fn slot_reuse_after_end() {
        let mut m = mk();
        run_seq(&mut m, 0, 4, 40, 0.005);
        m.end_seq(0);
        run_seq(&mut m, 0, 4, 40, 0.005);
        assert_eq!(m.edram().retention_failures, 0);
    }

    #[test]
    fn bound_prefix_skips_rewrites_and_reads_route_to_the_donor() {
        // donor runs a 17-token prompt to 64; a binder shares the
        // first 16 tokens (two full 8-token blocks): it writes only
        // the 48-token tail but still reads the full context
        let l = ModelConfig::sim_tiny().n_layers as u64;
        let mut m = mk();
        run_seq(&mut m, 0, 17, 64, 0.005);
        let donor_writes = m.stats.ondie_writes + m.stats.external_writes;
        assert_eq!(donor_writes, 64 * l);
        m.start_seq(1);
        m.bind_prefix(1, 0, 16);
        m.prefill(1, 1, 0.24); // the unshared last prompt token
        for step in 0..47 {
            let now = 0.24 + (step + 1) as f64 * 0.005;
            m.write_token(1, now);
            m.read_context(1, now).expect("retention violated");
        }
        assert_eq!(m.seq_len(1), 64);
        // the binder wrote exactly the unshared 48 tokens per layer
        let total_writes = m.stats.ondie_writes + m.stats.external_writes;
        assert_eq!(total_writes - donor_writes, 48 * l);
        // the binder's shared reads keep refreshing the donor's rows
        // after the donor went idle at t=0.235 — refresh-on-read works
        // across sequences exactly because the copy is shared
        assert_eq!(m.edram().retention_failures, 0);
    }

    #[test]
    #[should_panic(expected = "bind_prefix before any writes")]
    fn bind_after_writes_panics() {
        let mut m = mk();
        m.start_seq(0);
        m.prefill(0, 17, 0.0);
        m.start_seq(1);
        m.prefill(1, 1, 0.0);
        m.bind_prefix(1, 0, 16);
    }

    #[test]
    #[should_panic(expected = "donor holds only")]
    fn bind_past_the_donor_length_panics() {
        let mut m = mk();
        m.start_seq(0);
        m.prefill(0, 8, 0.0);
        m.start_seq(1);
        m.bind_prefix(1, 0, 16);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn sequence_overflow_panics() {
        let mut m = mk();
        m.start_seq(0);
        for i in 0..=128 {
            m.write_token(0, i as f64 * 0.001);
        }
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn oversubscribed_edram_rejected_at_construction() {
        let model = ModelConfig::falcon3_1b();
        let serve = ServeConfig {
            ondie_tokens: 4096,
            max_seq: 4096,
            prefill_len: 64,
            ..ServeConfig::default()
        };
        // 6 slots × 18 layers × 4096 tokens × 8 KiB ≫ 13.5 MB
        KvCacheManager::new(&model, &serve, EdramParams::default());
    }
}
