//! Paged, quantized, tiered KV-cache store — the serving data plane.
//!
//! Where [`KvCacheManager`](super::KvCacheManager) *models* the paper's
//! KV placement (it tallies hypothetical accesses next to the real
//! serving state), this store *is* the KV state: the host backend's
//! attention reads and writes go through it, so the Fig 5(b)
//! external-access reduction and the DR-eDRAM retention argument are
//! measured on the actual served bytes instead of being assumed.
//!
//! Layout: each sequence owns per-layer **block tables** (vLLM-style
//! paging) whose entries index a shared block slab. A block holds
//! `block_tokens` consecutive tokens' K and V rows, 8-bit quantized
//! (per-token absmax scale + i8 payload) or raw f32, and lives in one
//! of two tiers:
//!
//! * **DR eDRAM** — a capacity-bounded on-die tier backed by the
//!   [`DrEdram`] retention clock: every read refreshes the block's
//!   rows, and a decode stall past tREF surfaces as a hard
//!   [`RetentionError`] exactly as it would in silicon.
//! * **External DRAM** — unbounded spill tier ([`ExternalDram`]
//!   counters/energy).
//!
//! Placement follows the paper's early-token policy: a block whose
//! first token index is below `ondie_tokens` is placed on-die. When
//! the on-die tier is full, a resident block covering *later* tokens
//! than the incoming one is evicted to external DRAM (early tokens win
//! across all live sequences, since they are re-read the most —
//! Fig 5(a)); if no later block exists the incoming block spills. Tier
//! moves never change stored values, so placement is invisible to the
//! model's numerics.
//!
//! Every fallible store operation returns a typed [`KvError`] instead
//! of panicking: retention expiry, free-slab references, double
//! demotions, and row-accounting corruption are all classifiable by
//! the serving layer, which recovers or sheds one request instead of
//! tearing down the server (DESIGN.md §13).
//!
//! Quantization is per *token row*, not per whole block: a row's
//! stored value is fixed at append time and never revised, which keeps
//! dequantization time-invariant — prefill and chunked decode see
//! bit-identical KV (DESIGN.md invariant 4). A single running scale
//! per block would require requantizing earlier rows as the block's
//! absmax grows and would break that equivalence.

use std::collections::BTreeMap;

use crate::config::{EdramParams, ModelConfig, ServeConfig};
use crate::dram::{DramParams, ExternalDram};
use crate::edram::{DrEdram, RetentionError};

use super::KvStats;

/// Typed failure of a KV-store operation (DESIGN.md §13). Every
/// capacity/eviction edge that used to panic surfaces here instead, so
/// the serving layer can classify a failure (recover, retry, or shed
/// one request) without ever tearing down the whole server. The
/// variant survives `anyhow` wrapping — the host backend raises these
/// via `anyhow::Error::new`, and the coordinator gets the typed value
/// back with `downcast_ref::<KvError>()`.
#[derive(Debug, Clone, PartialEq)]
pub enum KvError {
    /// A DR-eDRAM row was read past its retention deadline — the
    /// stored KV is gone; the sequence must be recomputed or shed.
    Retention(RetentionError),
    /// A block table referenced a slab slot holding no block (retired
    /// or never-allocated page — e.g. a double-retire race).
    FreeBlock {
        /// The offending slab index.
        id: usize,
    },
    /// Asked to demote a block that already lives in external DRAM.
    EvictExternal {
        /// The offending slab index.
        id: usize,
    },
    /// Row accounting corrupted: an eviction freed no allocatable
    /// on-die range.
    RowAccounting {
        /// Rows one block needs.
        need_rows: usize,
    },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::Retention(e) => write!(f, "KV retention expiry: {e}"),
            KvError::FreeBlock { id } => write!(f, "KV block {id} is not mapped in the slab"),
            KvError::EvictExternal { id } => {
                write!(f, "KV block {id} is already in external DRAM")
            }
            KvError::RowAccounting { need_rows } => {
                write!(f, "KV eviction freed no {need_rows}-row eDRAM range")
            }
        }
    }
}

impl std::error::Error for KvError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            KvError::Retention(e) => Some(e),
            _ => None,
        }
    }
}

/// KV element encoding inside a block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvQuant {
    /// Raw f32 rows (lossless reference mode).
    F32,
    /// 8-bit rows: per-token absmax scale + i8 payload (the deployed
    /// mode; ~3.8x smaller than f32 including scales).
    Q8,
}

impl KvQuant {
    /// Parse from a serving config's `kv_quant_bits` field.
    pub fn from_bits(bits: usize) -> anyhow::Result<KvQuant> {
        match bits {
            8 => Ok(KvQuant::Q8),
            32 => Ok(KvQuant::F32),
            other => anyhow::bail!("kv_quant_bits must be 8 or 32, got {other}"),
        }
    }

    /// Bits per stored KV element (excluding per-token scales).
    pub fn bits(self) -> usize {
        match self {
            KvQuant::F32 => 32,
            KvQuant::Q8 => 8,
        }
    }
}

/// Static configuration of a [`KvStore`].
#[derive(Debug, Clone)]
pub struct KvStoreConfig {
    /// K (and V) row width in elements, `ModelConfig::kv_dim()`.
    pub kv_dim: usize,
    /// Transformer layers (each with its own block table per sequence).
    pub n_layers: usize,
    /// Tokens per block (page size of the store).
    pub block_tokens: usize,
    /// Early-token policy threshold: blocks starting below this token
    /// index are placed on-die (paper: 32 at seq 128).
    pub ondie_tokens: usize,
    /// Element encoding for stored rows.
    pub quant: KvQuant,
    /// DR-eDRAM tier parameters (capacity bounds the on-die tier).
    pub edram: EdramParams,
    /// External spill tier parameters.
    pub dram: DramParams,
}

impl KvStoreConfig {
    /// Default store for stand-alone backend use (single-stream
    /// generation outside a server): paper placement constants clamped
    /// to the model's context.
    pub fn for_model(model: &ModelConfig) -> Self {
        KvStoreConfig {
            kv_dim: model.kv_dim(),
            n_layers: model.n_layers,
            block_tokens: 8,
            ondie_tokens: 32.min(model.max_seq),
            quant: KvQuant::Q8,
            edram: EdramParams::default(),
            dram: DramParams::default(),
        }
    }

    /// Store for a serving deployment: placement and paging knobs come
    /// from the [`ServeConfig`].
    pub fn for_serve(model: &ModelConfig, serve: &ServeConfig) -> anyhow::Result<Self> {
        anyhow::ensure!(serve.kv_block_tokens >= 1, "kv_block_tokens must be >= 1");
        Ok(KvStoreConfig {
            kv_dim: model.kv_dim(),
            n_layers: model.n_layers,
            block_tokens: serve.kv_block_tokens,
            ondie_tokens: serve.ondie_tokens,
            quant: KvQuant::from_bits(serve.kv_quant_bits)?,
            edram: EdramParams {
                capacity_bytes: serve.kv_edram_bytes,
                ..EdramParams::default()
            },
            dram: DramParams::default(),
        })
    }

    /// Stored bytes per (token, layer): K + V payload plus per-token
    /// scales in Q8 mode.
    pub fn bytes_per_token(&self) -> u64 {
        match self.quant {
            KvQuant::F32 => 2 * self.kv_dim as u64 * 4,
            KvQuant::Q8 => 2 * (self.kv_dim as u64 + 4),
        }
    }

    /// Stored bytes per full block.
    pub fn block_bytes(&self) -> u64 {
        self.block_tokens as u64 * self.bytes_per_token()
    }

    /// eDRAM rows one on-die block occupies.
    pub fn rows_per_block(&self) -> usize {
        ((self.block_bytes() + self.edram.row_bytes - 1) / self.edram.row_bytes) as usize
    }
}

/// Measured counters of one store over its lifetime — what serving
/// metrics and the Fig 5(b) end-to-end reproduction report.
#[derive(Debug, Clone, Default)]
pub struct KvStoreStats {
    /// Token-granular access counts per tier (one count per (token,
    /// layer) read or write — directly comparable to the analytic
    /// [`simulate_reduction`](super::simulate_reduction) model).
    pub accesses: KvStats,
    /// On-die blocks demoted to external DRAM to make room for
    /// earlier-token blocks.
    pub evictions: u64,
    /// Early-token blocks that had to spill because the tier was full
    /// and held nothing later to evict.
    pub spilled_early_blocks: u64,
    /// eDRAM rows read past their retention deadline (must stay 0 for
    /// the DR argument to hold).
    pub retention_failures: u64,
    /// Explicit refreshes issued (always 0 under decode-refresh).
    pub explicit_refreshes: u64,
    /// Energy spent in the on-die tier so far (J).
    pub edram_energy_j: f64,
    /// Energy spent on the external interface so far (J), eviction
    /// traffic included.
    pub dram_energy_j: f64,
    /// On-die blocks currently resident.
    pub ondie_blocks_in_use: usize,
    /// On-die tier capacity in blocks.
    pub ondie_block_capacity: usize,
    /// Element encoding bits (8 or 32).
    pub quant_bits: usize,
    /// Page size in tokens.
    pub block_tokens: usize,
    /// Sequences that bound at least one shared full-prefix block
    /// instead of re-materializing it ([`KvStore::bind_prefix`]).
    pub prefix_hits: u64,
    /// Prompt tokens satisfied by binding shared prefix blocks (per
    /// sequence, not multiplied by layers).
    pub prefix_bound_tokens: u64,
    /// Copy-on-write forks: appends that landed on a block another
    /// sequence still references and copied it first.
    pub cow_forks: u64,
}

impl KvStoreStats {
    /// Fraction of token-granular accesses kept off the external
    /// interface — the measured Fig 5(b) quantity.
    pub fn external_reduction(&self) -> f64 {
        self.accesses.external_reduction()
    }

    /// Total KV memory energy (both tiers), J.
    pub fn kv_energy_j(&self) -> f64 {
        self.edram_energy_j + self.dram_energy_j
    }

    /// The counters accumulated since `earlier` (an older snapshot of
    /// the same store): lifetime counts and energies are subtracted,
    /// point-in-time gauges (resident blocks, capacity, config) keep
    /// this snapshot's values. This is how the serving loop turns the
    /// store's lifetime counters into per-trace metrics.
    pub fn since(&self, earlier: &KvStoreStats) -> KvStoreStats {
        KvStoreStats {
            accesses: KvStats {
                ondie_reads: self.accesses.ondie_reads - earlier.accesses.ondie_reads,
                ondie_writes: self.accesses.ondie_writes - earlier.accesses.ondie_writes,
                external_reads: self.accesses.external_reads - earlier.accesses.external_reads,
                external_writes: self.accesses.external_writes - earlier.accesses.external_writes,
            },
            evictions: self.evictions - earlier.evictions,
            spilled_early_blocks: self.spilled_early_blocks - earlier.spilled_early_blocks,
            retention_failures: self.retention_failures - earlier.retention_failures,
            explicit_refreshes: self.explicit_refreshes - earlier.explicit_refreshes,
            edram_energy_j: self.edram_energy_j - earlier.edram_energy_j,
            dram_energy_j: self.dram_energy_j - earlier.dram_energy_j,
            ondie_blocks_in_use: self.ondie_blocks_in_use,
            ondie_block_capacity: self.ondie_block_capacity,
            quant_bits: self.quant_bits,
            block_tokens: self.block_tokens,
            prefix_hits: self.prefix_hits - earlier.prefix_hits,
            prefix_bound_tokens: self.prefix_bound_tokens - earlier.prefix_bound_tokens,
            cow_forks: self.cow_forks - earlier.cow_forks,
        }
    }
}

/// Where a block's bytes currently live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Tier {
    /// Resident in DR eDRAM, occupying `rows_per_block` rows starting
    /// at this row index.
    OnDie { row_base: usize },
    /// Spilled to external DRAM.
    External,
}

/// Block payload: fixed-capacity K and V pages.
#[derive(Debug, Clone)]
enum BlockData {
    F32 { k: Vec<f32>, v: Vec<f32> },
    Q8 { k: Vec<i8>, v: Vec<i8>, k_scale: Vec<f32>, v_scale: Vec<f32> },
}

#[derive(Debug, Clone)]
struct KvBlock {
    first_token: usize,
    /// Token rows filled so far (append-only).
    len: usize,
    tier: Tier,
    /// Sequences referencing this block (shared-prefix binds and
    /// sequence forks raise it; the last release frees the block).
    refs: u32,
    data: BlockData,
}

/// One registered shareable prefix: the exact tokens it covers (hash
/// collisions are resolved by comparing these), the adapter they were
/// computed under, and the per-layer slab ids of its full blocks.
#[derive(Debug, Clone)]
struct PrefixEntry {
    adapter: Option<u32>,
    tokens: Vec<i32>,
    /// `blocks[layer]` = slab ids of the prefix's full blocks.
    blocks: Vec<Vec<usize>>,
}

/// FNV-1a over the adapter id and token ids — the content hash keying
/// the shared-prefix index. Collisions are harmless: entries store the
/// exact tokens and a bind verifies them before sharing anything.
fn prefix_hash(adapter: Option<u32>, tokens: &[i32]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    fn eat(h: &mut u64, bytes: [u8; 4]) {
        for b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(PRIME);
        }
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    eat(&mut h, adapter.map_or([0xff; 4], |a| a.to_le_bytes()));
    eat(&mut h, [adapter.is_some() as u8; 4]);
    for &t in tokens {
        eat(&mut h, t.to_le_bytes());
    }
    h
}

/// One sequence's handle into the store: per-layer block tables plus
/// per-layer append cursors. Created by [`KvStore::new_seq`], returned
/// to the store with [`KvStore::retire_seq`] (on-die pages are recycled
/// there — dropping a `KvSeq` without retiring leaks tier capacity).
#[derive(Debug, Default)]
pub struct KvSeq {
    /// `tables[layer]` = slab indices of this sequence's blocks.
    tables: Vec<Vec<usize>>,
    /// Tokens appended per layer.
    lens: Vec<usize>,
}

impl KvSeq {
    /// Tokens stored for `layer`.
    pub fn len(&self, layer: usize) -> usize {
        self.lens[layer]
    }

    /// True when nothing has been appended to any layer.
    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|&l| l == 0)
    }
}

/// The paged, quantized, tiered KV store (module docs have the full
/// design rationale).
#[derive(Debug)]
pub struct KvStore {
    cfg: KvStoreConfig,
    edram: DrEdram,
    dram: ExternalDram,
    /// Block slab; `None` entries are free (recycled via `free_ids`).
    blocks: Vec<Option<KvBlock>>,
    free_ids: Vec<usize>,
    /// Recycled on-die row-range starts (all ranges are
    /// `rows_per_block` long, so a free list of starts suffices).
    ondie_free: Vec<usize>,
    /// Bump allocator: next never-used range start.
    ondie_next: usize,
    ondie_in_use: usize,
    now: f64,
    stats: KvStats,
    evictions: u64,
    spilled_early_blocks: u64,
    /// Content-hash index of registered shareable prefixes
    /// (deterministically ordered; entries are purged when their last
    /// referencing block is freed).
    prefix_index: BTreeMap<u64, PrefixEntry>,
    prefix_hits: u64,
    prefix_bound_tokens: u64,
    cow_forks: u64,
}

impl KvStore {
    /// Build an empty store for `cfg`.
    pub fn new(cfg: KvStoreConfig) -> Self {
        let edram = DrEdram::new(cfg.edram.clone());
        let dram = ExternalDram::new(cfg.dram.clone());
        KvStore {
            edram,
            dram,
            blocks: Vec::new(),
            free_ids: Vec::new(),
            ondie_free: Vec::new(),
            ondie_next: 0,
            ondie_in_use: 0,
            now: 0.0,
            stats: KvStats::default(),
            evictions: 0,
            spilled_early_blocks: 0,
            prefix_index: BTreeMap::new(),
            prefix_hits: 0,
            prefix_bound_tokens: 0,
            cow_forks: 0,
            cfg,
        }
    }

    /// The configuration this store was built with.
    pub fn config(&self) -> &KvStoreConfig {
        &self.cfg
    }

    /// On-die tier capacity in blocks.
    pub fn ondie_block_capacity(&self) -> usize {
        self.edram.n_rows() / self.cfg.rows_per_block()
    }

    /// On-die blocks currently resident.
    pub fn ondie_blocks_in_use(&self) -> usize {
        self.ondie_in_use
    }

    /// Advance the retention clock (modeled hardware time, seconds —
    /// monotone non-decreasing; the serving loop calls this once per
    /// token round).
    pub fn set_now(&mut self, now: f64) {
        if now > self.now {
            self.now = now;
        }
    }

    /// Current retention-clock time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Fresh empty sequence handle.
    pub fn new_seq(&self) -> KvSeq {
        KvSeq {
            tables: vec![Vec::new(); self.cfg.n_layers],
            lens: vec![0; self.cfg.n_layers],
        }
    }

    /// Return a sequence's pages to the store. Each block loses one
    /// reference; a block's *last* reference frees it (on-die rows and
    /// slab slot recycled) and purges any shared-prefix index entries
    /// that pointed at it — so refcounts return to zero and nothing
    /// leaks no matter how blocks were shared.
    pub fn retire_seq(&mut self, seq: &mut KvSeq) {
        for li in 0..seq.tables.len() {
            let ids = std::mem::take(&mut seq.tables[li]);
            for id in ids {
                self.release_block(id);
            }
        }
        for l in &mut seq.lens {
            *l = 0;
        }
    }

    /// Publish a sequence's full prefix blocks for reuse: every
    /// block-aligned prefix of `tokens` (full blocks only — a partial
    /// tail is never shareable) is entered into the content-hash index
    /// keyed over (adapter, token ids). First writer wins: an
    /// already-registered prefix is left untouched, so a coordinator
    /// registering in slot order is deterministic at any thread width.
    /// Registration moves no data and counts nothing.
    pub fn register_prefix(&mut self, seq: &KvSeq, adapter: Option<u32>, tokens: &[i32]) {
        let bt = self.cfg.block_tokens;
        for k in 1..=tokens.len() / bt {
            let n = k * bt;
            if seq.lens.iter().any(|&l| l < n) || seq.tables.iter().any(|t| t.len() < k) {
                return; // the store never saw these tokens appended
            }
            let key = prefix_hash(adapter, &tokens[..n]);
            self.prefix_index.entry(key).or_insert_with(|| PrefixEntry {
                adapter,
                tokens: tokens[..n].to_vec(),
                blocks: seq.tables.iter().map(|t| t[..k].to_vec()).collect(),
            });
        }
    }

    /// Bind the longest registered shared prefix of `tokens` into an
    /// empty sequence: the matching full blocks are reference-counted
    /// into this sequence's block tables — no data moves and nothing
    /// is counted, because sharing changes placement bookkeeping,
    /// never values. Returns the number of tokens bound (0 on a miss).
    /// At most `tokens.len() - 1` tokens ever bind, so the caller
    /// always recomputes at least the last prompt token (the serving
    /// loop samples from its hidden state).
    pub fn bind_prefix(&mut self, seq: &mut KvSeq, adapter: Option<u32>, tokens: &[i32]) -> usize {
        assert!(seq.is_empty(), "bind_prefix requires a fresh sequence");
        let bt = self.cfg.block_tokens;
        if tokens.is_empty() {
            return 0;
        }
        for k in (1..=(tokens.len() - 1) / bt).rev() {
            let n = k * bt;
            let Some(entry) = self.prefix_index.get(&prefix_hash(adapter, &tokens[..n])) else {
                continue;
            };
            if entry.adapter != adapter || entry.tokens != tokens[..n] {
                continue; // hash collision: not actually this prefix
            }
            let blocks = entry.blocks.clone();
            for ids in &blocks {
                for &id in ids {
                    self.blocks[id]
                        .as_mut()
                        .expect("prefix index entries are purged when a block frees")
                        .refs += 1;
                }
            }
            for (layer, ids) in blocks.into_iter().enumerate() {
                seq.tables[layer] = ids;
                seq.lens[layer] = n;
            }
            self.prefix_hits += 1;
            self.prefix_bound_tokens += n as u64;
            return n;
        }
        0
    }

    /// Fork a sequence: the new handle shares every existing block
    /// (reference-counted, partial tail included) and diverges via
    /// copy-on-write on its first append into a shared block — the
    /// multi-turn primitive: turn N+1 continues from turn N's KV
    /// without copying anything up front.
    pub fn fork_seq(&mut self, seq: &KvSeq) -> KvSeq {
        for table in &seq.tables {
            for &id in table {
                if let Some(b) = self.blocks[id].as_mut() {
                    b.refs += 1;
                }
            }
        }
        KvSeq {
            tables: seq.tables.clone(),
            lens: seq.lens.clone(),
        }
    }

    /// Pre-allocate (and tier-place) the blocks covering the next
    /// `n_tokens` appends to `layer`. Placement — on-die vs spill,
    /// including any eviction — is decided *now*, so a serving
    /// coordinator that reserves every sequence's round in a fixed
    /// slot order makes block placement deterministic no matter how
    /// worker threads later interleave the actual [`Self::append`]
    /// calls (DESIGN.md §12). Reserving is idempotent for already-
    /// covered tokens and counts nothing: writes are accounted when
    /// the rows actually land.
    pub fn reserve(
        &mut self,
        seq: &mut KvSeq,
        layer: usize,
        n_tokens: usize,
    ) -> Result<(), KvError> {
        let bt = self.cfg.block_tokens;
        let need = (seq.lens[layer] + n_tokens).div_ceil(bt);
        for bi in seq.tables[layer].len()..need {
            let id = self.alloc_block(bi * bt)?;
            seq.tables[layer].push(id);
        }
        Ok(())
    }

    /// Append the next token's K/V rows for `layer` (token index =
    /// tokens appended to that layer so far). Counts one tier write at
    /// the current clock. Rows must be exactly `kv_dim` wide. Uses the
    /// block [`Self::reserve`] placed for this token if one exists;
    /// otherwise allocates (and places) the block here. Fails typed
    /// ([`KvError`]) on slab/placement corruption instead of panicking.
    pub fn append(
        &mut self,
        seq: &mut KvSeq,
        layer: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<(), KvError> {
        let d = self.cfg.kv_dim;
        assert_eq!(k_row.len(), d, "K row width {} != kv_dim {d}", k_row.len());
        assert_eq!(v_row.len(), d, "V row width {} != kv_dim {d}", v_row.len());
        let token = seq.lens[layer];
        let bt = self.cfg.block_tokens;
        let bi = token / bt;
        if seq.tables[layer].len() <= bi {
            let id = self.alloc_block(bi * bt)?;
            seq.tables[layer].push(id);
        }
        let mut id = seq.tables[layer][bi];
        // copy-on-write: never mutate a block another sequence still
        // references — fork a private copy first
        if self.blocks[id].as_ref().ok_or(KvError::FreeBlock { id })?.refs > 1 {
            id = self.fork_block(id)?;
            seq.tables[layer][bi] = id;
        }
        let block = self.blocks[id].as_mut().ok_or(KvError::FreeBlock { id })?;
        let slot = token - block.first_token;
        match &mut block.data {
            BlockData::F32 { k, v } => {
                k[slot * d..(slot + 1) * d].copy_from_slice(k_row);
                v[slot * d..(slot + 1) * d].copy_from_slice(v_row);
            }
            BlockData::Q8 { k, v, k_scale, v_scale } => {
                k_scale[slot] = quantize_row(k_row, &mut k[slot * d..(slot + 1) * d]);
                v_scale[slot] = quantize_row(v_row, &mut v[slot * d..(slot + 1) * d]);
            }
        }
        block.len += 1;
        let tier = block.tier;
        seq.lens[layer] = token + 1;
        // account the write on the block's tier
        let bytes = self.cfg.bytes_per_token();
        match tier {
            Tier::OnDie { row_base } => {
                self.write_token_rows(row_base, slot, bytes);
                self.stats.ondie_writes += 1;
            }
            Tier::External => {
                self.dram.write(bytes);
                self.stats.external_writes += 1;
            }
        }
        Ok(())
    }

    /// Dequantize tokens `0..n_ctx` of `layer` into `k_out`/`v_out`
    /// (row `t` at `t * kv_dim`, same layout the attention kernels
    /// expect).
    ///
    /// With `count_reads`, one tier read per (token, layer) is counted
    /// for every token except the newest (its KV feeds from the
    /// datapath registers — Fig 5(a) convention), and on-die rows pass
    /// through the DR-eDRAM retention check at the current clock:
    /// reading refreshes, a stall past tREF returns the row's expiry as
    /// [`KvError::Retention`]. Prefill attention reads on-chip
    /// activation buffers, so the serving path gathers with
    /// `count_reads = false` there.
    pub fn gather(
        &mut self,
        seq: &KvSeq,
        layer: usize,
        n_ctx: usize,
        count_reads: bool,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) -> Result<(), KvError> {
        let d = self.cfg.kv_dim;
        let bt = self.cfg.block_tokens;
        assert!(
            n_ctx <= seq.lens[layer],
            "gather {n_ctx} tokens but layer {layer} holds {}",
            seq.lens[layer]
        );
        k_out.clear();
        v_out.clear();
        k_out.reserve(n_ctx * d);
        v_out.reserve(n_ctx * d);
        let bytes = self.cfg.bytes_per_token();
        for t in 0..n_ctx {
            let id = seq.tables[layer][t / bt];
            let slot = t % bt;
            // newest token forwards from the datapath registers
            if count_reads && t + 1 < n_ctx {
                let tier = self.blocks[id].as_ref().ok_or(KvError::FreeBlock { id })?.tier;
                match tier {
                    Tier::OnDie { row_base } => {
                        self.read_token_rows(row_base, slot, bytes)
                            .map_err(KvError::Retention)?;
                        self.stats.ondie_reads += 1;
                    }
                    Tier::External => {
                        self.dram.read(bytes);
                        self.stats.external_reads += 1;
                    }
                }
            }
            let block = self.blocks[id].as_ref().ok_or(KvError::FreeBlock { id })?;
            match &block.data {
                BlockData::F32 { k, v } => {
                    k_out.extend_from_slice(&k[slot * d..(slot + 1) * d]);
                    v_out.extend_from_slice(&v[slot * d..(slot + 1) * d]);
                }
                BlockData::Q8 { k, v, k_scale, v_scale } => {
                    let (ks, vs) = (k_scale[slot], v_scale[slot]);
                    k_out.extend(k[slot * d..(slot + 1) * d].iter().map(|&q| q as f32 * ks));
                    v_out.extend(v[slot * d..(slot + 1) * d].iter().map(|&q| q as f32 * vs));
                }
            }
        }
        Ok(())
    }

    /// Counter snapshot for metrics and reports.
    pub fn stats(&self) -> KvStoreStats {
        KvStoreStats {
            accesses: self.stats.clone(),
            evictions: self.evictions,
            spilled_early_blocks: self.spilled_early_blocks,
            retention_failures: self.edram.retention_failures,
            explicit_refreshes: self.edram.explicit_refreshes,
            edram_energy_j: self.edram.energy_j(),
            dram_energy_j: self.dram.energy_j(),
            ondie_blocks_in_use: self.ondie_in_use,
            ondie_block_capacity: self.ondie_block_capacity(),
            quant_bits: self.cfg.quant.bits(),
            block_tokens: self.cfg.block_tokens,
            prefix_hits: self.prefix_hits,
            prefix_bound_tokens: self.prefix_bound_tokens,
            cow_forks: self.cow_forks,
        }
    }

    /// Live (allocated) blocks in the slab — returns to zero once
    /// every sequence is retired, however blocks were shared.
    pub fn live_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.is_some()).count()
    }

    /// Registered shared-prefix index entries (purged together with
    /// their last referencing block).
    pub fn prefix_entries(&self) -> usize {
        self.prefix_index.len()
    }

    /// Reference counts of a sequence's blocks, layer-major — the COW
    /// property harness inspects sharing through this.
    pub fn block_ref_counts(&self, seq: &KvSeq) -> Vec<u32> {
        seq.tables
            .iter()
            .flat_map(|t| {
                t.iter().map(|&id| self.blocks[id].as_ref().map_or(0, |b| b.refs))
            })
            .collect()
    }

    /// The on-die tier (for retention/energy inspection).
    pub fn edram(&self) -> &DrEdram {
        &self.edram
    }

    /// The external tier (for traffic/energy inspection).
    pub fn dram(&self) -> &ExternalDram {
        &self.dram
    }

    /// Swap a whole sequence out of the on-die tier: every resident
    /// block is demoted to external DRAM (counted as evictions, like
    /// capacity-driven demotions), freeing its eDRAM rows for other
    /// sequences. Already-external blocks are skipped, so demoting
    /// twice is a no-op. Returns the number of blocks demoted. Stored
    /// values are untouched — a swapped-out sequence reads back
    /// bit-identical KV (placement never changes numerics), which is
    /// what makes preemption recovery reload-free (DESIGN.md §13).
    pub fn demote_seq(&mut self, seq: &KvSeq) -> Result<u64, KvError> {
        let mut demoted = 0;
        for table in &seq.tables {
            for &id in table {
                let block = self.blocks[id].as_ref().ok_or(KvError::FreeBlock { id })?;
                if matches!(block.tier, Tier::OnDie { .. }) {
                    self.evict(id)?;
                    demoted += 1;
                }
            }
        }
        Ok(demoted)
    }

    // ---- internals ------------------------------------------------------

    /// Allocate a slab slot + tier placement for a block whose first
    /// token is `first_token`.
    fn alloc_block(&mut self, first_token: usize) -> Result<usize, KvError> {
        let tier = self.place(first_token)?;
        let bt = self.cfg.block_tokens;
        let d = self.cfg.kv_dim;
        let data = match self.cfg.quant {
            KvQuant::F32 => BlockData::F32 {
                k: vec![0.0; bt * d],
                v: vec![0.0; bt * d],
            },
            KvQuant::Q8 => BlockData::Q8 {
                k: vec![0; bt * d],
                v: vec![0; bt * d],
                k_scale: vec![0.0; bt],
                v_scale: vec![0.0; bt],
            },
        };
        let block = KvBlock {
            first_token,
            len: 0,
            tier,
            refs: 1,
            data,
        };
        Ok(self.insert_block(block))
    }

    /// Put a block into the slab, recycling a free slot if one exists.
    fn insert_block(&mut self, block: KvBlock) -> usize {
        match self.free_ids.pop() {
            Some(id) => {
                self.blocks[id] = Some(block);
                id
            }
            None => {
                self.blocks.push(Some(block));
                self.blocks.len() - 1
            }
        }
    }

    /// Drop one reference to a slab block; the last reference frees it
    /// (on-die rows and slab slot recycled) and purges shared-prefix
    /// index entries that pointed at it.
    fn release_block(&mut self, id: usize) {
        let Some(block) = self.blocks[id].as_mut() else {
            return;
        };
        if block.refs > 1 {
            block.refs -= 1;
            return;
        }
        let block = self.blocks[id].take().expect("checked live above");
        if let Tier::OnDie { row_base } = block.tier {
            self.ondie_free.push(row_base);
            self.ondie_in_use -= 1;
        }
        self.free_ids.push(id);
        self.prefix_index
            .retain(|_, e| e.blocks.iter().all(|layer| !layer.contains(&id)));
    }

    /// Copy-on-write fork: clone a shared block into a private one
    /// before a write lands. The copy traffic hits the destination
    /// tier's byte/energy counters (its rows are written once), but
    /// not the token-granular access stats — those count only
    /// model-level appends and gathers, so sharing never perturbs the
    /// Fig 5(b) accounting base.
    fn fork_block(&mut self, id: usize) -> Result<usize, KvError> {
        let (first_token, len, data) = {
            let b = self.blocks[id].as_ref().ok_or(KvError::FreeBlock { id })?;
            (b.first_token, b.len, b.data.clone())
        };
        let tier = self.place(first_token)?;
        let bytes = self.cfg.bytes_per_token();
        match tier {
            Tier::OnDie { row_base } => {
                for slot in 0..len {
                    self.write_token_rows(row_base, slot, bytes);
                }
            }
            Tier::External => self.dram.write(len as u64 * bytes),
        }
        let new_id = self.insert_block(KvBlock {
            first_token,
            len,
            tier,
            refs: 1,
            data,
        });
        self.blocks[id].as_mut().ok_or(KvError::FreeBlock { id })?.refs -= 1;
        self.cow_forks += 1;
        Ok(new_id)
    }

    /// Early-token-on-die placement with eviction on overflow.
    fn place(&mut self, first_token: usize) -> Result<Tier, KvError> {
        if first_token >= self.cfg.ondie_tokens {
            return Ok(Tier::External);
        }
        if let Some(row_base) = self.alloc_rows() {
            self.ondie_in_use += 1;
            return Ok(Tier::OnDie { row_base });
        }
        // Tier full: demote the resident block covering the latest
        // tokens, if it is later than the incoming block (early tokens
        // are re-read the most — they win across all live sequences).
        if let Some(victim) = self.latest_ondie_block(first_token) {
            self.evict(victim)?;
            let row_base = self.alloc_rows().ok_or(KvError::RowAccounting {
                need_rows: self.cfg.rows_per_block(),
            })?;
            self.ondie_in_use += 1;
            return Ok(Tier::OnDie { row_base });
        }
        self.spilled_early_blocks += 1;
        Ok(Tier::External)
    }

    fn alloc_rows(&mut self) -> Option<usize> {
        if let Some(base) = self.ondie_free.pop() {
            return Some(base);
        }
        let rows = self.cfg.rows_per_block();
        if self.ondie_next + rows <= self.edram.n_rows() {
            let base = self.ondie_next;
            self.ondie_next += rows;
            Some(base)
        } else {
            None
        }
    }

    /// Resident on-die block with the largest `first_token` strictly
    /// greater than `than`.
    fn latest_ondie_block(&self, than: usize) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None;
        for (id, b) in self.blocks.iter().enumerate() {
            if let Some(b) = b {
                if matches!(b.tier, Tier::OnDie { .. })
                    && b.first_token > than
                    && best.map_or(true, |(_, ft)| b.first_token > ft)
                {
                    best = Some((id, b.first_token));
                }
            }
        }
        best.map(|(id, _)| id)
    }

    /// Demote an on-die block to external DRAM: its filled rows are
    /// written out (external traffic + energy, tracked separately from
    /// the token-granular access stats), its eDRAM rows are freed. The
    /// stored values are untouched — placement never changes numerics.
    /// Fails typed on a free slab slot or an already-external block
    /// (double-evict), instead of panicking.
    fn evict(&mut self, id: usize) -> Result<(), KvError> {
        let (row_base, len) = {
            let b = self.blocks[id].as_ref().ok_or(KvError::FreeBlock { id })?;
            match b.tier {
                Tier::OnDie { row_base } => (row_base, b.len),
                Tier::External => return Err(KvError::EvictExternal { id }),
            }
        };
        self.dram.write(len as u64 * self.cfg.bytes_per_token());
        self.ondie_free.push(row_base);
        self.ondie_in_use -= 1;
        self.evictions += 1;
        self.blocks[id].as_mut().ok_or(KvError::FreeBlock { id })?.tier = Tier::External;
        Ok(())
    }

    /// eDRAM rows covering token `slot` of a block at `row_base`.
    fn token_rows(&self, row_base: usize, slot: usize) -> (usize, usize) {
        let bpt = self.cfg.bytes_per_token();
        let rb = self.cfg.edram.row_bytes;
        let off = slot as u64 * bpt;
        let first = row_base + (off / rb) as usize;
        let last = row_base + ((off + bpt - 1) / rb) as usize;
        (first, last)
    }

    fn write_token_rows(&mut self, row_base: usize, slot: usize, bytes: u64) {
        let (first, last) = self.token_rows(row_base, slot);
        let n = (last - first + 1) as u64;
        for (i, row) in (first..=last).enumerate() {
            // distribute the byte count across rows (remainder on the first)
            let b = bytes / n + if i == 0 { bytes % n } else { 0 };
            self.edram.write(row, b, self.now);
        }
    }

    fn read_token_rows(
        &mut self,
        row_base: usize,
        slot: usize,
        bytes: u64,
    ) -> Result<(), RetentionError> {
        let (first, last) = self.token_rows(row_base, slot);
        let n = (last - first + 1) as u64;
        for (i, row) in (first..=last).enumerate() {
            let b = bytes / n + if i == 0 { bytes % n } else { 0 };
            self.edram.read(row, b, self.now)?;
        }
        Ok(())
    }
}

/// Absmax-quantize one row to i8; returns the dequant scale. A zero
/// row quantizes to all-zeros with scale 0 (exact).
fn quantize_row(x: &[f32], out: &mut [i8]) -> f32 {
    let absmax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if absmax == 0.0 {
        out.fill(0);
        return 0.0;
    }
    let inv = 127.0 / absmax;
    for (q, &v) in out.iter_mut().zip(x) {
        *q = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    absmax / 127.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::simulate_reduction;
    use crate::util::rng::Rng;

    /// Small geometry: 8-wide rows, 2 layers, 4-token blocks, first 8
    /// tokens on-die.
    fn cfg() -> KvStoreConfig {
        KvStoreConfig {
            kv_dim: 8,
            n_layers: 2,
            block_tokens: 4,
            ondie_tokens: 8,
            quant: KvQuant::Q8,
            edram: EdramParams::default(),
            dram: DramParams::default(),
        }
    }

    fn rand_row(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    /// Append `n` tokens to every layer with seeded rows.
    fn fill(store: &mut KvStore, seq: &mut KvSeq, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let d = store.config().kv_dim;
        let layers = store.config().n_layers;
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for _ in 0..n {
            let (k, v) = (rand_row(&mut rng, d), rand_row(&mut rng, d));
            for layer in 0..layers {
                store.append(seq, layer, &k, &v).unwrap();
            }
            rows.push(k);
            rows.push(v);
        }
        rows
    }

    #[test]
    fn q8_roundtrip_within_half_ulp() {
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        let rows = fill(&mut store, &mut seq, 10, 42);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        store.gather(&seq, 0, 10, false, &mut k, &mut v).unwrap();
        for t in 0..10 {
            let (k_ref, v_ref) = (&rows[2 * t], &rows[2 * t + 1]);
            for (pair, got) in [(k_ref, &k), (v_ref, &v)] {
                let absmax = pair.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let tol = absmax * (0.5 / 127.0 + 1e-6);
                for (i, &r) in pair.iter().enumerate() {
                    let e = (r - got[t * 8 + i]).abs();
                    assert!(e <= tol, "token {t} elem {i}: err {e} > tol {tol}");
                }
            }
        }
    }

    #[test]
    fn f32_mode_is_lossless() {
        let mut store = KvStore::new(KvStoreConfig {
            quant: KvQuant::F32,
            ..cfg()
        });
        let mut seq = store.new_seq();
        let rows = fill(&mut store, &mut seq, 6, 7);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        store.gather(&seq, 1, 6, false, &mut k, &mut v).unwrap();
        for t in 0..6 {
            assert_eq!(&k[t * 8..(t + 1) * 8], rows[2 * t].as_slice());
            assert_eq!(&v[t * 8..(t + 1) * 8], rows[2 * t + 1].as_slice());
        }
    }

    #[test]
    fn zero_rows_roundtrip_exactly() {
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        let z = vec![0f32; 8];
        store.append(&mut seq, 0, &z, &z).unwrap();
        let (mut k, mut v) = (Vec::new(), Vec::new());
        store.gather(&seq, 0, 1, false, &mut k, &mut v).unwrap();
        assert!(k.iter().chain(&v).all(|&x| x == 0.0));
    }

    /// Decode-loop driver: append token t, then gather its full
    /// context with read counting — the measured twin of the analytic
    /// Fig 5(b) step model.
    fn decode_loop(store: &mut KvStore, seq: &mut KvSeq, s: usize, tbt: f64) {
        let d = store.config().kv_dim;
        let layers = store.config().n_layers;
        let mut rng = Rng::new(1);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        for t in 0..s {
            store.set_now(t as f64 * tbt);
            let (kr, vr) = (rand_row(&mut rng, d), rand_row(&mut rng, d));
            for layer in 0..layers {
                store.append(seq, layer, &kr, &vr).unwrap();
                store
                    .gather(seq, layer, t + 1, true, &mut k, &mut v)
                    .expect("retention violated");
            }
        }
    }

    #[test]
    fn measured_reduction_matches_analytic_model() {
        // block-aligned (8 on-die tokens, 4-token blocks): the store's
        // measured reduction must equal the closed-form Fig 5(b) value
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        decode_loop(&mut store, &mut seq, 32, 0.005);
        let stats = store.stats();
        let measured = stats.external_reduction();
        let analytic = simulate_reduction(32, 8);
        assert!(
            (measured - analytic).abs() < 1e-12,
            "measured {measured} vs analytic {analytic}"
        );
        assert_eq!(stats.retention_failures, 0);
        assert_eq!(stats.explicit_refreshes, 0);
        assert!(stats.edram_energy_j > 0.0 && stats.dram_energy_j > 0.0);
    }

    #[test]
    fn healthy_decode_cadence_never_expires() {
        // 64 steps at 5 ms TBT: total span 320 ms >> tREF 64 ms, but
        // refresh-on-read keeps every on-die row alive.
        let mut store = KvStore::new(KvStoreConfig {
            ondie_tokens: 64,
            ..cfg()
        });
        let mut seq = store.new_seq();
        decode_loop(&mut store, &mut seq, 64, 0.005);
        assert_eq!(store.stats().retention_failures, 0);
    }

    #[test]
    fn stalled_decode_trips_retention() {
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        fill(&mut store, &mut seq, 4, 3);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        store.set_now(0.05); // within tREF: ok + refresh
        store.gather(&seq, 0, 4, true, &mut k, &mut v).unwrap();
        store.set_now(0.05 + 0.1); // 100 ms stall > tREF
        let err = store.gather(&seq, 0, 4, true, &mut k, &mut v);
        assert!(
            matches!(err, Err(KvError::Retention(_))),
            "expired read must fail typed, got {err:?}"
        );
        assert_eq!(store.stats().retention_failures, 1);
    }

    /// eDRAM sized for exactly two blocks.
    fn two_block_cfg() -> KvStoreConfig {
        let base = cfg();
        let rows = base.rows_per_block() as u64;
        KvStoreConfig {
            ondie_tokens: 16,
            edram: EdramParams {
                capacity_bytes: 2 * rows * base.edram.row_bytes,
                ..base.edram.clone()
            },
            n_layers: 1,
            ..base
        }
    }

    #[test]
    fn overflow_spills_when_nothing_later_to_evict() {
        let mut store = KvStore::new(two_block_cfg());
        assert_eq!(store.ondie_block_capacity(), 2);
        let mut seq = store.new_seq();
        // 12 tokens = blocks [0..4) [4..8) on-die, [8..12) wants
        // on-die (8 < 16) but the tier is full and both residents are
        // earlier -> spill
        fill(&mut store, &mut seq, 12, 5);
        let stats = store.stats();
        assert_eq!(stats.spilled_early_blocks, 1);
        assert_eq!(stats.evictions, 0);
        assert_eq!(stats.ondie_blocks_in_use, 2);
        assert_eq!(stats.accesses.ondie_writes, 8);
        assert_eq!(stats.accesses.external_writes, 4);
    }

    #[test]
    fn overflow_evicts_later_block_for_earlier_tokens() {
        let mut store = KvStore::new(two_block_cfg());
        let mut seq_a = store.new_seq();
        let rows_a = fill(&mut store, &mut seq_a, 8, 5); // fills the tier
        let mut seq_b = store.new_seq();
        fill(&mut store, &mut seq_b, 4, 6); // token 0 beats A's block [4..8)
        let stats = store.stats();
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.ondie_blocks_in_use, 2);
        // eviction moved bytes but not values: A reads back exactly
        // what round-tripping its rows gives
        let (mut k, mut v) = (Vec::new(), Vec::new());
        store.gather(&seq_a, 0, 8, false, &mut k, &mut v).unwrap();
        for t in 0..8 {
            let absmax = rows_a[2 * t].iter().fold(0f32, |m, &x| m.max(x.abs()));
            let tol = absmax * (0.5 / 127.0 + 1e-6);
            for (i, &r) in rows_a[2 * t].iter().enumerate() {
                assert!((r - k[t * 8 + i]).abs() <= tol, "eviction corrupted token {t}");
            }
        }
    }

    #[test]
    fn retirement_recycles_ondie_blocks() {
        let mut store = KvStore::new(two_block_cfg());
        let mut seq = store.new_seq();
        fill(&mut store, &mut seq, 8, 5);
        assert_eq!(store.ondie_blocks_in_use(), 2);
        store.retire_seq(&mut seq);
        assert!(seq.is_empty());
        assert_eq!(store.ondie_blocks_in_use(), 0);
        // a new sequence reuses the freed pages: on-die again, no
        // eviction or spill needed
        let mut seq2 = store.new_seq();
        fill(&mut store, &mut seq2, 8, 9);
        let stats = store.stats();
        assert_eq!(stats.ondie_blocks_in_use, 2);
        assert_eq!(stats.evictions, 0);
        assert_eq!(stats.spilled_early_blocks, 0);
    }

    #[test]
    fn reserve_pins_placement_before_append() {
        // reservation allocates + places blocks without counting writes
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        store.reserve(&mut seq, 0, 10).unwrap(); // 3 blocks of 4 tokens
        assert_eq!(store.ondie_blocks_in_use(), 2, "tokens 0..8 on-die");
        assert_eq!(store.stats().accesses.ondie_writes, 0, "reserve writes nothing");
        // re-reserving covered tokens is a no-op
        store.reserve(&mut seq, 0, 4).unwrap();
        assert_eq!(store.ondie_blocks_in_use(), 2);
        // appends land in the reserved blocks and only then count
        let rows = fill(&mut store, &mut seq, 10, 21);
        let stats = store.stats();
        assert_eq!(stats.accesses.ondie_writes, 8 * 2, "both layers' early tokens");
        let (mut k, mut v) = (Vec::new(), Vec::new());
        store.gather(&seq, 0, 10, false, &mut k, &mut v).unwrap();
        let absmax = rows[0].iter().fold(0f32, |m, &x| m.max(x.abs()));
        assert!((rows[0][0] - k[0]).abs() <= absmax * (0.5 / 127.0 + 1e-6));
    }

    #[test]
    fn reserved_and_lazy_runs_place_blocks_identically() {
        // a reserve-then-append run and a plain append run must end in
        // the same tier state and counters — so the serving loop's
        // coordinator-side reservation is invisible to accounting
        let run = |reserve: bool| {
            let mut store = KvStore::new(two_block_cfg());
            let mut seq = store.new_seq();
            if reserve {
                store.reserve(&mut seq, 0, 12).unwrap();
            }
            fill(&mut store, &mut seq, 12, 5);
            let s = store.stats();
            (
                s.accesses.ondie_writes,
                s.accesses.external_writes,
                s.evictions,
                s.spilled_early_blocks,
                s.ondie_blocks_in_use,
            )
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn retirement_recycles_reserved_but_unused_blocks() {
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        store.reserve(&mut seq, 0, 8).unwrap();
        assert_eq!(store.ondie_blocks_in_use(), 2);
        store.retire_seq(&mut seq);
        assert_eq!(store.ondie_blocks_in_use(), 0, "unused reservations recycled");
    }

    #[test]
    fn gather_order_is_time_invariant() {
        // the dequantized view of early tokens must not change as
        // later tokens arrive (DESIGN.md invariant 4 depends on this)
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        fill(&mut store, &mut seq, 4, 11);
        let (mut k1, mut v1) = (Vec::new(), Vec::new());
        store.gather(&seq, 0, 4, false, &mut k1, &mut v1).unwrap();
        fill(&mut store, &mut seq, 8, 12); // 8 more tokens
        let (mut k2, mut v2) = (Vec::new(), Vec::new());
        store.gather(&seq, 0, 4, false, &mut k2, &mut v2).unwrap();
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn demote_seq_swaps_out_preserving_values() {
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        fill(&mut store, &mut seq, 8, 17);
        let (mut k1, mut v1) = (Vec::new(), Vec::new());
        store.gather(&seq, 0, 8, false, &mut k1, &mut v1).unwrap();
        let demoted = store.demote_seq(&seq).unwrap();
        assert!(demoted > 0, "early blocks were on-die");
        assert_eq!(store.ondie_blocks_in_use(), 0);
        // demoting again is a no-op (all blocks already external)
        assert_eq!(store.demote_seq(&seq).unwrap(), 0);
        // swap-out moved bytes but not values
        let (mut k2, mut v2) = (Vec::new(), Vec::new());
        store.gather(&seq, 0, 8, false, &mut k2, &mut v2).unwrap();
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
        assert_eq!(store.stats().evictions, demoted);
    }

    #[test]
    fn demoted_blocks_survive_a_retention_stall() {
        // a swapped-out sequence no longer depends on the retention
        // clock: external DRAM has no tREF
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        fill(&mut store, &mut seq, 4, 19);
        store.demote_seq(&seq).unwrap();
        store.set_now(10.0); // far past tREF
        let (mut k, mut v) = (Vec::new(), Vec::new());
        store.gather(&seq, 0, 4, true, &mut k, &mut v).unwrap();
        assert_eq!(store.stats().retention_failures, 0);
    }

    #[test]
    fn kv_errors_are_typed_and_printable() {
        let e = KvError::FreeBlock { id: 3 };
        assert!(e.to_string().contains('3'));
        let mut store = KvStore::new(cfg());
        let mut seq = store.new_seq();
        fill(&mut store, &mut seq, 4, 3);
        store.set_now(1.0); // stall past tREF
        let (mut k, mut v) = (Vec::new(), Vec::new());
        match store.gather(&seq, 0, 4, true, &mut k, &mut v) {
            Err(KvError::Retention(r)) => assert!(r.expired_for_s > 0.0),
            other => panic!("expected a typed retention error, got {other:?}"),
        }
    }

    #[test]
    fn bind_prefix_shares_full_blocks_without_traffic() {
        let mut store = KvStore::new(cfg()); // 4-token blocks, 2 layers
        let mut donor = store.new_seq();
        let prompt: Vec<i32> = (0..10).map(|t| (t * 3 + 1) as i32).collect();
        fill(&mut store, &mut donor, 10, 23); // 2 full blocks + a partial tail
        store.register_prefix(&donor, None, &prompt);
        let before = store.stats();
        let mut binder = store.new_seq();
        let bound = store.bind_prefix(&mut binder, None, &prompt);
        assert_eq!(bound, 8, "both full blocks bind; the tail recomputes");
        assert_eq!(binder.len(0), 8);
        let after = store.stats();
        assert_eq!(after.accesses.ondie_writes, before.accesses.ondie_writes);
        assert_eq!(after.accesses.external_writes, before.accesses.external_writes);
        assert_eq!(after.prefix_hits, 1);
        assert_eq!(after.prefix_bound_tokens, 8);
        // the binder reads exactly the donor's rows
        let (mut kd, mut vd) = (Vec::new(), Vec::new());
        store.gather(&donor, 0, 8, false, &mut kd, &mut vd).unwrap();
        let (mut kb, mut vb) = (Vec::new(), Vec::new());
        store.gather(&binder, 0, 8, false, &mut kb, &mut vb).unwrap();
        assert_eq!(kd, kb);
        assert_eq!(vd, vb);
        // the binder's tail appends land in a fresh private block
        fill(&mut store, &mut binder, 2, 91);
        assert_eq!(store.stats().cow_forks, 0, "block-aligned binds never fork");
        // retiring in either order frees everything and purges the index
        store.retire_seq(&mut donor);
        assert!(store.prefix_entries() > 0, "binder keeps shared blocks alive");
        store.retire_seq(&mut binder);
        assert_eq!(store.live_blocks(), 0);
        assert_eq!(store.prefix_entries(), 0);
        assert_eq!(store.ondie_blocks_in_use(), 0);
    }

    #[test]
    fn bind_prefix_always_leaves_the_last_prompt_token() {
        // a prompt that is exactly 2 full blocks binds only 1: the
        // caller must recompute at least the token it samples from
        let mut store = KvStore::new(cfg());
        let mut donor = store.new_seq();
        let prompt: Vec<i32> = (0..8).map(|t| t as i32).collect();
        fill(&mut store, &mut donor, 8, 5);
        store.register_prefix(&donor, None, &prompt);
        let mut binder = store.new_seq();
        assert_eq!(store.bind_prefix(&mut binder, None, &prompt), 4);
        // an adapter mismatch never shares
        let mut other = store.new_seq();
        assert_eq!(store.bind_prefix(&mut other, Some(1), &prompt), 0);
        store.retire_seq(&mut donor);
        store.retire_seq(&mut binder);
        store.retire_seq(&mut other);
        assert_eq!(store.live_blocks(), 0);
    }

    #[test]
    fn forked_append_never_mutates_a_shared_block() {
        let mut store = KvStore::new(cfg());
        let mut a = store.new_seq();
        fill(&mut store, &mut a, 6, 31); // block 0 full, block 1 half-filled
        let mut b = store.fork_seq(&a);
        assert!(store.block_ref_counts(&a).iter().all(|&r| r == 2));
        let (mut k1, mut v1) = (Vec::new(), Vec::new());
        store.gather(&a, 0, 6, false, &mut k1, &mut v1).unwrap();
        fill(&mut store, &mut b, 2, 77); // token 6 lands in shared block 1
        assert_eq!(
            store.stats().cow_forks,
            store.config().n_layers as u64,
            "one fork per layer, then the private copy absorbs the rest"
        );
        let (mut k2, mut v2) = (Vec::new(), Vec::new());
        store.gather(&a, 0, 6, false, &mut k2, &mut v2).unwrap();
        assert_eq!(k1, k2, "a forked write must not touch the original");
        assert_eq!(v1, v2);
        store.retire_seq(&mut a);
        store.retire_seq(&mut b);
        assert_eq!(store.live_blocks(), 0);
        assert_eq!(store.ondie_blocks_in_use(), 0);
    }

    #[test]
    fn eviction_of_a_shared_block_respects_refcounts() {
        let mut store = KvStore::new(cfg());
        let mut donor = store.new_seq();
        let prompt: Vec<i32> = (0..5).map(|t| t as i32).collect();
        fill(&mut store, &mut donor, 5, 41);
        store.register_prefix(&donor, None, &prompt);
        let mut binder = store.new_seq();
        assert_eq!(store.bind_prefix(&mut binder, None, &prompt), 4);
        let (mut k1, mut v1) = (Vec::new(), Vec::new());
        store.gather(&binder, 0, 4, false, &mut k1, &mut v1).unwrap();
        // demoting the donor demotes the shared block (tier move only):
        // the binder still reads identical values through it
        store.demote_seq(&donor).unwrap();
        let (mut k2, mut v2) = (Vec::new(), Vec::new());
        store.gather(&binder, 0, 4, false, &mut k2, &mut v2).unwrap();
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
        assert!(store.block_ref_counts(&binder).iter().all(|&r| r == 2));
        store.retire_seq(&mut donor);
        store.retire_seq(&mut binder);
        assert_eq!(store.live_blocks(), 0);
    }

    #[test]
    fn quant_parse_and_sizes() {
        assert_eq!(KvQuant::from_bits(8).unwrap(), KvQuant::Q8);
        assert_eq!(KvQuant::from_bits(32).unwrap(), KvQuant::F32);
        assert!(KvQuant::from_bits(4).is_err());
        let c = cfg();
        // Q8: 2 * (8 + 4 scale bytes) = 24 B/token vs f32 64 B/token
        assert_eq!(c.bytes_per_token(), 24);
        let f = KvStoreConfig {
            quant: KvQuant::F32,
            ..cfg()
        };
        assert_eq!(f.bytes_per_token(), 64);
        assert!(c.rows_per_block() >= 1);
    }
}
