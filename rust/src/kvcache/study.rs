//! Fig 5(b) study: external-DRAM access reduction as a function of
//! sequence length and the number of early tokens buffered on-die.
//!
//! Both a closed form and a step-by-step simulation are provided; they
//! must agree exactly (tested), and the simulation path is the same
//! accounting the full `KvCacheManager` performs.

/// Closed-form external-access reduction.
///
/// Model (paper Fig 5a, prompt handled as one pre-written token block):
/// a sequence of `s` total tokens is produced starting from a 1-token
/// prompt; at the step producing token `t` (0-based), the KV of tokens
/// `0..t` is read and token `t` is written. Buffering the first `b`
/// tokens on-die removes their writes and all their reads from the
/// external interface.
///
/// reduction = (saved reads + saved writes) / (total reads + writes)
///           = (Σ_{i<b}(s−1−i) + b) / (s(s−1)/2 + s)
pub fn closed_form_reduction(s: usize, b: usize) -> f64 {
    let s = s as f64;
    let b = (b.min(s as usize)) as f64;
    let total_reads = s * (s - 1.0) / 2.0;
    let total_writes = s;
    let saved_reads = b * (s - 1.0) - b * (b - 1.0) / 2.0;
    let saved_writes = b;
    (saved_reads + saved_writes) / (total_reads + total_writes)
}

/// Step-by-step simulation of the same quantity (token-granularity
/// counters, layer count cancels in the ratio).
pub fn simulate_reduction(s: usize, b: usize) -> f64 {
    let mut ext_reads = 0u64;
    let mut ext_writes = 0u64;
    let mut all_reads = 0u64;
    let mut all_writes = 0u64;
    for t in 0..s {
        // write token t
        all_writes += 1;
        if t >= b {
            ext_writes += 1;
        }
        // read tokens 0..t
        for i in 0..t {
            all_reads += 1;
            if i >= b {
                ext_reads += 1;
            }
        }
    }
    1.0 - (ext_reads + ext_writes) as f64 / (all_reads + all_writes) as f64
}

/// One point of the Fig 5(b) sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Total sequence length.
    pub seq_len: usize,
    /// Early tokens buffered on-die.
    pub ondie_tokens: usize,
    /// External-access reduction at this point.
    pub reduction: f64,
}

/// The full Fig 5(b) grid: seq 32–256 × buffered 4–64.
pub fn reduction_sweep(seq_lens: &[usize], buffers: &[usize]) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &s in seq_lens {
        for &b in buffers {
            out.push(SweepPoint {
                seq_len: s,
                ondie_tokens: b,
                reduction: simulate_reduction(s, b),
            });
        }
    }
    out
}

/// Sequence lengths of the published Fig 5(b) grid.
pub const PAPER_SEQ_LENS: [usize; 4] = [32, 64, 128, 256];
/// Buffer sizes of the published Fig 5(b) grid.
pub const PAPER_BUFFERS: [usize; 5] = [4, 8, 16, 32, 64];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    #[allow(unused_imports)]
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn fig5b_matches_paper_point() {
        // THE headline: 43.6% reduction at seq 128 with 32 buffered.
        let r = simulate_reduction(128, 32);
        assert!(
            (r - 0.436).abs() < 0.0005,
            "got {:.4}, paper reports 0.436",
            r
        );
    }

    #[test]
    fn quarter_buffered_halves_traffic_ish() {
        // Paper: "relocating only 1/4 of the early tokens … can reduce
        // the DRAM access rate by nearly half."
        for s in [64usize, 128, 256] {
            let r = simulate_reduction(s, s / 4);
            assert!((0.40..0.50).contains(&r), "s={s}: {r:.3}");
        }
    }

    #[test]
    fn closed_form_equals_simulation() {
        check(0xF165B, 200, |g| {
            let s = g.usize(2, 512);
            let b = g.usize(0, 600);
            let cf = closed_form_reduction(s, b);
            let sim = simulate_reduction(s, b);
            prop_assert!(
                (cf - sim).abs() < 1e-12,
                "s={s} b={b}: closed {cf} vs sim {sim}"
            );
            Ok(())
        });
    }

    #[test]
    fn monotone_in_buffer_size() {
        for s in [32usize, 128] {
            let mut prev = -1.0;
            for b in [0usize, 4, 8, 16, 32, 64] {
                let r = simulate_reduction(s, b);
                assert!(r >= prev, "s={s} b={b}");
                prev = r;
            }
        }
    }

    #[test]
    fn full_buffer_removes_all_traffic() {
        assert!((simulate_reduction(64, 64) - 1.0).abs() < 1e-12);
        assert!((simulate_reduction(64, 1000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_buffer_removes_nothing() {
        assert_eq!(simulate_reduction(128, 0), 0.0);
    }

    #[test]
    fn longer_sequences_dilute_fixed_buffer() {
        // a fixed 32-token buffer matters less as the sequence grows
        let r128 = simulate_reduction(128, 32);
        let r256 = simulate_reduction(256, 32);
        assert!(r256 < r128);
    }

    #[test]
    fn sweep_covers_grid() {
        let pts = reduction_sweep(&PAPER_SEQ_LENS, &PAPER_BUFFERS);
        assert_eq!(pts.len(), 20);
        assert!(pts.iter().all(|p| (0.0..=1.0).contains(&p.reduction)));
    }
}
