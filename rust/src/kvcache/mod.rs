//! Decoding-aware KV-cache management (paper §IV, Fig 5): the analytic
//! placement model and the real, serving-grade tiered store.
//!
//! Three layers:
//!
//! * [`KvStore`] — the serving **data plane**: a
//!   paged, block-based KV store with 8-bit quantization and tiered
//!   DR-eDRAM / external-DRAM placement. `runtime::HostBackend` keeps
//!   its per-sequence KV here, so serving *measures* the Fig 5(b)
//!   reduction on actual accesses instead of modeling it.
//! * [`KvCacheManager`] — the original accounting model: routes
//!   hypothetical per-token accesses by the early-token policy and
//!   advances the retention clock. Kept as the analytic twin the
//!   measured path is validated against.
//! * study helpers ([`closed_form_reduction`],
//!   [`simulate_reduction`], [`reduction_sweep`]) — the Fig 5(b) grid:
//!   KV entries of the first `ondie_tokens` of each sequence live in
//!   DR eDRAM, later tokens in external DRAM. Because early tokens are
//!   read at every subsequent step (token i is read S−1−i times in an
//!   S-token sequence), buffering a small prefix removes a
//!   disproportionate share of external traffic, with the paper's
//!   headline 43.6% at (S=128, B=32) reproduced exactly
//!   (`fig5b_matches_paper_point`) and re-measured end-to-end by
//!   `report::fig5b_serving_report`.

mod manager;
mod store;
mod study;

pub use manager::{KvCacheManager, KvStats};
pub use store::{KvError, KvQuant, KvSeq, KvStore, KvStoreConfig, KvStoreStats};
pub use study::{
    closed_form_reduction, reduction_sweep, simulate_reduction, SweepPoint, PAPER_BUFFERS,
    PAPER_SEQ_LENS,
};
