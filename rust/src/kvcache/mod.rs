//! Decoding-aware KV-cache management (paper §IV, Fig 5).
//!
//! The manager owns the *placement* decision: KV entries of the first
//! `ondie_tokens` of each sequence live in the DR eDRAM; later tokens
//! go to external DRAM. Because early tokens are read at every
//! subsequent step (token i is read S−1−i times in an S-token
//! sequence), buffering a small prefix removes a disproportionate share
//! of external traffic — the Fig 5(b) result, with the paper's
//! headline 43.6% at (S=128, B=32) reproduced exactly
//! (`fig5b_matches_paper_point`).

mod manager;
mod study;

pub use manager::{KvCacheManager, KvStats};
pub use study::{
    closed_form_reduction, reduction_sweep, simulate_reduction, SweepPoint, PAPER_BUFFERS,
    PAPER_SEQ_LENS,
};
