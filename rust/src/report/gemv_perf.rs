//! Host GEMV/GEMM kernel performance study: the per-trit base-3
//! reference (`ref_gemv`) vs the bitplane engine's paths (the `auto`
//! heuristic plus the explicit `scalar` sign-select and `bitserial`
//! popcount engines — DESIGN.md §17), at LLaMA-shaped projection sizes
//! across sparsities.
//!
//! This is the §Perf record for the host compute path (EXPERIMENTS.md):
//! `bench_gemv` runs the same study and emits `BENCH_gemv.json` so the
//! perf trajectory is tracked across PRs. Every timed point first
//! asserts bit-exact agreement between all kernels — a perf number
//! for a wrong result is worthless.

use crate::bitnet::{ref_gemv, KernelCtx, KernelPath, TernaryMatrix};
use crate::util::bench::{bench_config, Bench};
use crate::util::json::Json;
use crate::util::pool::Pool;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// One measured (shape × sparsity) point.
#[derive(Debug, Clone)]
pub struct GemvPerfPoint {
    /// Fan-in of the measured shape.
    pub rows: usize,
    /// Fan-out of the measured shape.
    pub cols: usize,
    /// Target zero fraction the weights were drawn at.
    pub sparsity: f64,
    /// Mean ns per reference GEMV.
    pub ref_ns: f64,
    /// Mean ns per bitplane GEMV (the `auto` engine path).
    pub plane_ns: f64,
    /// Mean ns per GEMV on the explicit scalar sign-select path.
    pub scalar_ns: f64,
    /// Mean ns per GEMV on the explicit bit-serial popcount path.
    pub bitserial_ns: f64,
    /// Batch size used for the GEMM measurement.
    pub gemm_batch: usize,
    /// Mean ns per row of the batched bitplane GEMM.
    pub gemm_row_ns: f64,
}

impl GemvPerfPoint {
    /// Bitplane GEMV speedup over the reference kernel.
    pub fn speedup(&self) -> f64 {
        self.ref_ns / self.plane_ns
    }

    /// Batched-GEMM per-row speedup over the reference kernel.
    pub fn gemm_speedup(&self) -> f64 {
        self.ref_ns / self.gemm_row_ns
    }

    /// Bit-serial popcount throughput relative to the scalar path
    /// (>1 where the popcount engine wins at this shape/sparsity).
    pub fn bitserial_vs_scalar(&self) -> f64 {
        self.scalar_ns / self.bitserial_ns
    }
}

/// The LLaMA-shaped projection sizes the study sweeps (d_model×d_model
/// attention and d_model×d_ff MLP shapes of a ~1B model).
const FULL_SHAPES: [(usize, usize); 2] = [(2048, 2048), (2048, 5632)];
const FULL_SPARSITIES: [f64; 4] = [0.0, 0.3, 0.5, 0.7];
const QUICK_SHAPES: [(usize, usize); 1] = [(512, 512)];
const QUICK_SPARSITIES: [f64; 2] = [0.0, 0.3];
const GEMM_BATCH: usize = 8;

/// Run the study. `quick` restricts to a small shape with short
/// measurement windows (the `bitrom report --gemv` path); the full
/// sweep honors `BITROM_BENCH_QUICK` like every bench binary.
pub fn gemv_perf_study(quick: bool) -> Vec<GemvPerfPoint> {
    let bench = if quick { Bench::quick() } else { bench_config() };
    let shapes: &[(usize, usize)] = if quick { &QUICK_SHAPES } else { &FULL_SHAPES };
    let sparsities: &[f64] = if quick { &QUICK_SPARSITIES } else { &FULL_SPARSITIES };
    let mut rng = Rng::new(0x6E3A);
    let mut out = Vec::new();
    for &(rows, cols) in shapes {
        for &s in sparsities {
            let w = TernaryMatrix::random(rows, cols, s, &mut rng);
            let x: Vec<i32> = (0..rows).map(|_| rng.i64(-127, 127) as i32).collect();
            let scalar = KernelCtx::serial().with_path(KernelPath::Scalar);
            let bitserial = KernelCtx::serial().with_path(KernelPath::BitSerial);
            // correctness gate before any timing: every engine path
            // agrees with the golden reference bit-exactly
            let want = ref_gemv(&x, &w);
            assert_eq!(w.gemv(&x), want, "auto diverged at {rows}x{cols} s={s}");
            assert_eq!(
                scalar.gemv(w.bitplanes(), &x),
                want,
                "scalar diverged at {rows}x{cols} s={s}"
            );
            assert_eq!(
                bitserial.gemv(w.bitplanes(), &x),
                want,
                "bitserial diverged at {rows}x{cols} s={s}"
            );
            let r_ref = bench.run("ref", || ref_gemv(&x, &w));
            let r_plane = bench.run("plane", || w.gemv(&x));
            let r_scalar = bench.run("scalar", || scalar.gemv(w.bitplanes(), &x));
            let r_bits = bench.run("bitserial", || bitserial.gemv(w.bitplanes(), &x));
            let batch: Vec<Vec<i32>> = (0..GEMM_BATCH)
                .map(|_| (0..rows).map(|_| rng.i64(-127, 127) as i32).collect())
                .collect();
            let r_gemm = bench.run("gemm", || w.gemm(&batch));
            out.push(GemvPerfPoint {
                rows,
                cols,
                sparsity: s,
                ref_ns: r_ref.mean_ns,
                plane_ns: r_plane.mean_ns,
                scalar_ns: r_scalar.mean_ns,
                bitserial_ns: r_bits.mean_ns,
                gemm_batch: GEMM_BATCH,
                gemm_row_ns: r_gemm.mean_ns / GEMM_BATCH as f64,
            });
        }
    }
    out
}

/// Render measured points as a table.
pub fn gemv_perf_table(points: &[GemvPerfPoint]) -> String {
    let mut t = Table::new("Host ternary GEMV — per-trit reference vs the bitplane engine paths")
        .header(&[
            "shape",
            "sparsity",
            "ref/gemv",
            "auto/gemv",
            "speedup",
            "scalar",
            "bitserial",
            "bits/scalar",
            "gemm/row (b=8)",
            "gemm speedup",
        ]);
    for p in points {
        t.row(&[
            format!("{}x{}", p.rows, p.cols),
            format!("{:.1}", p.sparsity),
            crate::util::bench::fmt_ns(p.ref_ns),
            crate::util::bench::fmt_ns(p.plane_ns),
            format!("{:.1}x", p.speedup()),
            crate::util::bench::fmt_ns(p.scalar_ns),
            crate::util::bench::fmt_ns(p.bitserial_ns),
            format!("{:.2}x", p.bitserial_vs_scalar()),
            crate::util::bench::fmt_ns(p.gemm_row_ns),
            format!("{:.1}x", p.gemm_speedup()),
        ]);
    }
    t.render()
}

/// Run the study and render it (the `bitrom report --gemv` entry).
pub fn gemv_perf_report(quick: bool) -> String {
    gemv_perf_table(&gemv_perf_study(quick))
}

/// One measured point of the kernel threads sweep: the batched GEMM at
/// a fixed LLaMA shape, sharded across `threads` pool workers.
#[derive(Debug, Clone)]
pub struct GemmThreadsPoint {
    /// Fan-in of the swept shape.
    pub rows: usize,
    /// Fan-out of the swept shape.
    pub cols: usize,
    /// Target zero fraction the weights were drawn at.
    pub sparsity: f64,
    /// Pool width the GEMM was sharded across (1 = the serial kernel).
    pub threads: usize,
    /// Mean ns per whole batched GEMM call at this width.
    pub gemm_ns: f64,
}

/// Thread widths the sweep measures (1 is the serial baseline; the
/// acceptance bar is >1.5× GEMM throughput at 4 threads on CI).
pub const THREADS_SWEEP: [usize; 3] = [1, 2, 4];

/// Kernel threads sweep (DESIGN.md §12, EXPERIMENTS.md §Threads): the
/// batched GEMM at 2048×2048 / 30% sparsity across [`THREADS_SWEEP`]
/// pool widths. The shape stays large even in quick mode so the fork
/// cost is amortized and the sweep measures sharding, not spawn
/// overhead. Every width is first asserted bit-identical to the serial
/// kernel.
pub fn gemm_threads_sweep(quick: bool) -> Vec<GemmThreadsPoint> {
    let bench = if quick { Bench::quick() } else { bench_config() };
    let (rows, cols, sparsity) = (2048usize, 2048usize, 0.3f64);
    let mut rng = Rng::new(0x6E3B);
    let w = TernaryMatrix::random(rows, cols, sparsity, &mut rng);
    let batch: Vec<Vec<i32>> = (0..GEMM_BATCH)
        .map(|_| (0..rows).map(|_| rng.i64(-127, 127) as i32).collect())
        .collect();
    let serial = KernelCtx::serial().gemm(w.bitplanes(), &batch);
    THREADS_SWEEP
        .iter()
        .map(|&threads| {
            let ctx = KernelCtx::new(Pool::new(threads));
            // correctness gate before any timing (invariant: sharding
            // is bit-identical at every width)
            assert_eq!(
                ctx.gemm(w.bitplanes(), &batch),
                serial,
                "sharded gemm diverged at {threads} threads"
            );
            let r = bench.run(&format!("gemm_t{threads}"), || ctx.gemm(w.bitplanes(), &batch));
            GemmThreadsPoint {
                rows,
                cols,
                sparsity,
                threads,
                gemm_ns: r.mean_ns,
            }
        })
        .collect()
}

/// Render the threads sweep as a table (speedups vs the width-1 row).
pub fn gemm_threads_table(points: &[GemmThreadsPoint]) -> String {
    let serial_ns = points
        .iter()
        .find(|p| p.threads == 1)
        .map(|p| p.gemm_ns)
        .unwrap_or(f64::NAN);
    let mut t = Table::new("Sharded GEMM — threads vs throughput (batch = 8)").header(&[
        "shape",
        "sparsity",
        "threads",
        "gemm",
        "speedup vs 1",
    ]);
    for p in points {
        t.row(&[
            format!("{}x{}", p.rows, p.cols),
            format!("{:.1}", p.sparsity),
            format!("{}", p.threads),
            crate::util::bench::fmt_ns(p.gemm_ns),
            format!("{:.2}x", serial_ns / p.gemm_ns),
        ]);
    }
    t.render()
}

/// The scale-free speedup of the `threads`-wide GEMM over the serial
/// one (the metric the CI perf gate tracks — machine-comparable,
/// unlike absolute ns).
pub fn threads_speedup(points: &[GemmThreadsPoint], threads: usize) -> Option<f64> {
    let serial = points.iter().find(|p| p.threads == 1)?.gemm_ns;
    let wide = points.iter().find(|p| p.threads == threads)?.gemm_ns;
    Some(serial / wide)
}

/// JSON record (the `BENCH_gemv.json` payload). `gates` holds the
/// scale-free higher-is-better metrics `ci/check_bench.py` compares
/// against the committed `BENCH_baseline/` snapshot.
pub fn gemv_perf_json(
    points: &[GemvPerfPoint],
    threads_points: &[GemmThreadsPoint],
    source: &str,
) -> Json {
    let mut gates: Vec<(String, Json)> = Vec::new();
    for p in points {
        gates.push((
            format!("speedup/{}x{}/{}", p.rows, p.cols, p.sparsity),
            Json::num(p.speedup()),
        ));
        gates.push((
            format!("gemm_speedup/{}x{}/{}", p.rows, p.cols, p.sparsity),
            Json::num(p.gemm_speedup()),
        ));
        gates.push((
            format!("bitserial_vs_scalar/{}x{}/{}", p.rows, p.cols, p.sparsity),
            Json::num(p.bitserial_vs_scalar()),
        ));
    }
    for &t in &THREADS_SWEEP[1..] {
        if let Some(s) = threads_speedup(threads_points, t) {
            gates.push((format!("gemm_threads_speedup_{t}v1"), Json::num(s)));
        }
    }
    let gates_obj = Json::Obj(gates.into_iter().collect());
    Json::obj(vec![
        ("bench", Json::str("gemv")),
        ("source", Json::str(source)),
        // short measurement windows are noisy; the CI gate widens its
        // tolerance when this flag is set
        ("quick", Json::Bool(std::env::var("BITROM_BENCH_QUICK").is_ok())),
        ("gemm_batch", Json::num(GEMM_BATCH as f64)),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("rows", Json::num(p.rows as f64)),
                            ("cols", Json::num(p.cols as f64)),
                            ("sparsity", Json::num(p.sparsity)),
                            ("ref_ns", Json::num(p.ref_ns)),
                            ("bitplane_ns", Json::num(p.plane_ns)),
                            ("scalar_ns", Json::num(p.scalar_ns)),
                            ("bitserial_ns", Json::num(p.bitserial_ns)),
                            ("bitserial_vs_scalar", Json::num(p.bitserial_vs_scalar())),
                            ("speedup", Json::num(p.speedup())),
                            ("gemm_row_ns", Json::num(p.gemm_row_ns)),
                            ("gemm_speedup", Json::num(p.gemm_speedup())),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "threads_sweep",
            Json::Arr(
                threads_points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("rows", Json::num(p.rows as f64)),
                            ("cols", Json::num(p.cols as f64)),
                            ("sparsity", Json::num(p.sparsity)),
                            ("threads", Json::num(p.threads as f64)),
                            ("gemm_ns", Json::num(p.gemm_ns)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("gates", gates_obj),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_point() -> GemvPerfPoint {
        GemvPerfPoint {
            rows: 2048,
            cols: 2048,
            sparsity: 0.3,
            ref_ns: 8_000_000.0,
            plane_ns: 500_000.0,
            scalar_ns: 600_000.0,
            bitserial_ns: 300_000.0,
            gemm_batch: 8,
            gemm_row_ns: 400_000.0,
        }
    }

    #[test]
    fn speedups_derive_from_means() {
        let p = fake_point();
        assert!((p.speedup() - 16.0).abs() < 1e-9);
        assert!((p.gemm_speedup() - 20.0).abs() < 1e-9);
        assert!((p.bitserial_vs_scalar() - 2.0).abs() < 1e-9);
    }

    fn fake_threads_sweep() -> Vec<GemmThreadsPoint> {
        THREADS_SWEEP
            .iter()
            .map(|&threads| GemmThreadsPoint {
                rows: 2048,
                cols: 2048,
                sparsity: 0.3,
                threads,
                gemm_ns: 8_000_000.0 / threads as f64,
            })
            .collect()
    }

    #[test]
    fn table_and_json_render() {
        let pts = vec![fake_point()];
        let table = gemv_perf_table(&pts);
        assert!(table.contains("2048x2048"));
        assert!(table.contains("16.0x"));
        let j = gemv_perf_json(&pts, &fake_threads_sweep(), "unit-test");
        assert_eq!(j.at(&["bench"]).unwrap().as_str(), Some("gemv"));
        let first = &j.get("points").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("rows").unwrap().as_usize(), Some(2048));
        assert!(first.get("speedup").unwrap().as_f64().unwrap() > 15.0);
        // the CI perf gate reads scale-free metrics from `gates`
        let gates = j.get("gates").unwrap();
        let g = gates.get("speedup/2048x2048/0.3").unwrap().as_f64().unwrap();
        assert!((g - 16.0).abs() < 1e-9);
        let bs = gates
            .get("bitserial_vs_scalar/2048x2048/0.3")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((bs - 2.0).abs() < 1e-9);
        let t4 = gates.get("gemm_threads_speedup_4v1").unwrap().as_f64().unwrap();
        assert!((t4 - 4.0).abs() < 1e-9, "ideal fake sweep scales linearly");
    }

    #[test]
    fn threads_sweep_table_and_speedup_derive() {
        let pts = fake_threads_sweep();
        assert_eq!(threads_speedup(&pts, 2), Some(2.0));
        assert_eq!(threads_speedup(&pts, 4), Some(4.0));
        assert_eq!(threads_speedup(&pts, 16), None, "unmeasured width");
        let table = gemm_threads_table(&pts);
        assert!(table.contains("2048x2048"), "{table}");
        assert!(table.contains("4.00x"), "{table}");
    }

    #[test]
    fn tiny_study_is_exact_and_positive() {
        // a micro study (not the full shapes) to keep test time sane;
        // correctness is asserted inside the study itself
        let bench = Bench::quick();
        let mut rng = Rng::new(1);
        let w = TernaryMatrix::random(96, 64, 0.3, &mut rng);
        let x: Vec<i32> = (0..96).map(|_| rng.i64(-127, 127) as i32).collect();
        assert_eq!(w.gemv(&x), ref_gemv(&x, &w));
        let r = bench.run("tiny", || w.gemv(&x));
        assert!(r.mean_ns > 0.0);
    }
}
