//! Multi-tenant LoRA serving, measured end-to-end: a mixed-tenant
//! trace through the adapter-capable `Server<HostBackend>`, with the
//! measured per-token adapter op overhead placed next to the analytic
//! [`LoraConfig::op_overhead_vs_host_projections`] model, and the
//! reload-vs-switch comparison that lands the paper's headline claim
//! as numbers: a cold task switch streams one adapter's quantized
//! bytes, a resident switch streams nothing, and a full weight reload
//! (what a conventional weight-loaded accelerator would pay to change
//! tasks) moves the entire packed mask set.
//!
//! The measured overhead comes from MAC counters incremented at the
//! point of execution ([`AdapterRegistry::record_site_macs`]), so the
//! comparison verifies the wiring — the sites actually applied, at
//! the dims actually projected — not a formula against itself.

use crate::config::{ModelConfig, ServeConfig};
use crate::coordinator::Server;
use crate::dram::DramParams;
use crate::energy::AdapterEnergy;
use crate::lora::{AdapterRegistry, LoraConfig, LoraServeStats};
use crate::runtime::HostBackend;
use crate::trace::{generate, TraceConfig};
use crate::util::table::{fmt_pct, Table};

/// Outcome of one measured multi-tenant serving run.
#[derive(Debug, Clone)]
pub struct LoraServing {
    /// Tenant adapters resident in the deployment.
    pub n_adapters: usize,
    /// Requests served (each bound to a uniformly drawn tenant).
    pub requests: usize,
    /// Tokens emitted by the trace.
    pub tokens_out: u64,
    /// Measured per-token adapter op overhead (executed adapter MACs /
    /// executed base MACs at the adapter sites).
    pub measured_overhead: f64,
    /// The analytic model's value for the same rank/placement/model.
    pub analytic_overhead: f64,
    /// Quantized bytes of ONE tenant adapter (a cold switch's stream).
    pub adapter_bytes: u64,
    /// Bytes a full weight reload would move (packed ternary mask set).
    pub full_reload_bytes: u64,
    /// Full measured adapter statistics for the trace.
    pub stats: LoraServeStats,
}

/// Serve a closed batch of `n_requests` mixed-tenant requests on a
/// fabricated `sim-tiny` host model carrying `n_adapters` adapters at
/// the paper configuration (rank 16 on V/O/Down, 6-bit weights), and
/// measure adapter overhead and task-switch traffic from the
/// registry's counters. Deterministic per seed.
pub fn lora_serving_study(
    n_adapters: usize,
    n_requests: usize,
    seed: u64,
) -> anyhow::Result<LoraServing> {
    anyhow::ensure!(n_adapters >= 1, "need at least one tenant adapter");
    anyhow::ensure!(n_requests >= 1, "need at least one request");
    let model = ModelConfig::sim_tiny();
    let lora = LoraConfig::paper();
    let registry = AdapterRegistry::fabricate(&model, &lora, n_adapters, seed ^ 0xADA9)?;
    let analytic = lora.op_overhead_vs_host_projections(&model);
    let adapter_bytes = registry.adapter_bytes();
    let full_reload_bytes = registry.full_reload_bytes();
    let backend = HostBackend::with_adapters(model.clone(), seed, registry)?;
    let serve = ServeConfig {
        max_batches: n_requests.min(4),
        n_adapters,
        seed,
        ..ServeConfig::default()
    };
    let trace = generate(&TraceConfig {
        n_requests,
        n_adapters,
        gen_len_min: 8,
        gen_len_max: 16,
        vocab_size: model.vocab_size,
        seed,
        ..TraceConfig::default()
    });
    let mut server = Server::new(backend, serve)?;
    let (done, metrics) = server.run_trace(trace)?;
    anyhow::ensure!(done.len() == n_requests, "trace did not complete");
    let stats = metrics.lora.expect("adapter-serving backend measures LoRA stats");
    Ok(LoraServing {
        n_adapters,
        requests: n_requests,
        tokens_out: metrics.tokens_out,
        measured_overhead: stats.measured_op_overhead(),
        analytic_overhead: analytic,
        adapter_bytes,
        full_reload_bytes,
        stats,
    })
}

/// The multi-tenant adapter-serving report: measured-vs-analytic
/// per-token overhead and the reload-vs-switch comparison, plus the
/// same comparison scaled analytically to the paper's Falcon3-1B
/// deployment target.
pub fn lora_serving_report() -> String {
    let r = match lora_serving_study(4, 12, 0x10ada) {
        Ok(r) => r,
        Err(e) => return format!("lora_serving failed: {e:#}\n"),
    };
    let energy = AdapterEnergy::from_stats(&r.stats);
    let reload_j = AdapterEnergy::reload_j(r.full_reload_bytes, &DramParams::default());
    let mut t = Table::new(&format!(
        "Multi-tenant LoRA serving — measured on a served trace (sim-tiny, {} tenants, \
         {} requests, rank 16 on VOD)",
        r.n_adapters, r.requests
    ))
    .header(&["quantity", "measured (serving)", "analytic model"]);
    t.row(&[
        "per-token adapter op overhead".into(),
        fmt_pct(r.measured_overhead),
        format!("{} (paper: 0.7% at Falcon3 shapes)", fmt_pct(r.analytic_overhead)),
    ]);
    t.row(&[
        "adapter / base MACs at the sites".into(),
        format!("{} / {}", r.stats.adapter_macs, r.stats.base_macs),
        "—".into(),
    ]);
    t.row(&[
        "cold task switch (adapter stream)".into(),
        format!(
            "{} B x {} loads ({:.3e} J)",
            r.adapter_bytes, r.stats.cold_loads, energy.stream_j
        ),
        "—".into(),
    ]);
    t.row(&[
        "resident task switch".into(),
        format!("0 B x {} binds (reload-free)", r.stats.binds - r.stats.cold_loads),
        "—".into(),
    ]);
    t.row(&[
        "hypothetical full weight reload".into(),
        "never happens".into(),
        format!("{} B ({:.3e} J) per switch", r.full_reload_bytes, reload_j),
    ]);
    let mut out = t.render();
    out.push_str(&format!(
        "tokens served {}; binds {}, cold loads {}, adapter rows {}; \
         |measured - analytic| = {:.2e} relative\n",
        r.tokens_out,
        r.stats.binds,
        r.stats.cold_loads,
        r.stats.adapter_rows,
        (r.measured_overhead - r.analytic_overhead).abs() / r.analytic_overhead.max(1e-300),
    ));
    // the same claim at the deployment target, analytically
    let falcon = ModelConfig::falcon3_1b();
    let lora = LoraConfig::paper();
    let fa = lora.storage_bytes(&falcon);
    let fr = AdapterRegistry::full_reload_bytes_for(&falcon);
    out.push_str(&format!(
        "falcon3-1b (analytic): adapter {} B vs reload {} B — a cold switch moves \
         {} of a reload ({:.1}x cheaper); op overhead {}\n",
        fa,
        fr,
        fmt_pct(fa as f64 / fr as f64),
        fr as f64 / fa as f64,
        fmt_pct(lora.op_overhead_vs_host_projections(&falcon)),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_overhead_lands_on_the_analytic_value() {
        // the acceptance gate's unit twin: measured per-token adapter
        // overhead within 10% relative of the analytic model at the
        // paper configuration (the MAC counters make it exact, so 10%
        // leaves room only for real wiring regressions)
        let r = lora_serving_study(3, 6, 0xADA).unwrap();
        assert!(r.analytic_overhead > 0.0);
        let rel = (r.measured_overhead - r.analytic_overhead).abs() / r.analytic_overhead;
        assert!(
            rel < 0.10,
            "measured {} vs analytic {} ({} relative)",
            r.measured_overhead,
            r.analytic_overhead,
            rel
        );
        assert_eq!(r.stats.binds as usize, r.requests);
        assert!(r.stats.adapter_rows > 0);
    }

    #[test]
    fn cold_loads_stream_each_tenant_once() {
        let r = lora_serving_study(2, 8, 0x5EED).unwrap();
        assert!(r.stats.cold_loads <= 2);
        assert_eq!(
            r.stats.bytes_streamed,
            r.stats.cold_loads * r.adapter_bytes,
            "streaming must be per cold load, not per bind"
        );
        assert!(r.stats.binds >= r.stats.cold_loads);
        assert!(r.adapter_bytes < r.full_reload_bytes);
    }

    #[test]
    fn report_renders_measured_and_analytic_columns() {
        let s = lora_serving_report();
        assert!(s.contains("measured (serving)"), "{s}");
        assert!(s.contains("reload-free"), "{s}");
        assert!(s.contains("falcon3-1b (analytic)"), "{s}");
    }
}
