//! Paper table/figure renderers — each function regenerates one
//! published artifact from the simulators (see DESIGN.md §4 for the
//! experiment index). `fig5b_serving_report` and
//! `lora_serving_report` go one step further and re-measure their
//! claims (the Fig 5(b) point; the adapter overhead and
//! reload-vs-switch comparison) on real served traces.

mod fig1a;
mod fig5b;
mod fig5b_serving;
mod gemv_perf;
mod lora_serving;
mod prefix_serving;
mod table3;

pub use fig1a::fig1a_report;
pub use fig5b::{fig5a_report, fig5b_report};
pub use fig5b_serving::{fig5b_serving_report, fig5b_serving_study, Fig5bServing};
pub use gemv_perf::{
    gemm_threads_sweep, gemm_threads_table, gemv_perf_json, gemv_perf_report, gemv_perf_study,
    gemv_perf_table, threads_speedup, GemmThreadsPoint, GemvPerfPoint, THREADS_SWEEP,
};
pub use lora_serving::{lora_serving_report, lora_serving_study, LoraServing};
pub use prefix_serving::{
    prefix_serving_report, prefix_serving_study, PrefixServing, FIG5B_MEASURED_BASELINE,
};
pub use table3::{table3_report, Table3Row};
