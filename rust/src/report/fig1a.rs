//! Fig 1(a): silicon-area estimation of CiROM architectures across
//! model sizes and fabrication nodes.

use crate::config::{HardwareConfig, ModelConfig, TechNode};
use crate::energy::{area_estimate, ModelPoint};
use crate::util::table::Table;

/// The model sweep of Fig 1(a): CNN-era baselines through LLaMA-70B in
/// fp16 CiROM cells, plus the ternary BitNet points that motivate the
/// paper.
pub fn fig1a_points() -> Vec<ModelPoint> {
    let mut pts = vec![
        ModelPoint::fp16("resnet-56 (fp16)", 850_000),
        ModelPoint::fp16("bert-base (fp16)", 110_000_000),
    ];
    for name in ["llama-7b", "llama-13b", "llama-70b"] {
        let cfg = ModelConfig::named(name).unwrap();
        pts.push(ModelPoint::fp16(
            Box::leak(format!("{name} (fp16)").into_boxed_str()),
            cfg.param_count(),
        ));
    }
    let f1 = ModelConfig::falcon3_1b();
    pts.push(ModelPoint::ternary("bitnet-falcon3-1b (1.58b)", f1.param_count()));
    let f3 = ModelConfig::named("falcon3-3b").unwrap();
    pts.push(ModelPoint::ternary("bitnet-falcon3-3b (1.58b)", f3.param_count()));
    pts
}

/// Render the Fig 1(a) area sweep (models × nodes).
pub fn fig1a_report(hw: &HardwareConfig) -> String {
    let mut t = Table::new("Fig 1(a) — CiROM silicon area (cm²) by model and node")
        .header(&["Model", "Params", "65nm", "28nm", "14nm", "Feasible@14nm"]);
    for p in fig1a_points() {
        let a65 = area_estimate(hw, &p, TechNode::N65);
        let a28 = area_estimate(hw, &p, TechNode::N28);
        let a14 = area_estimate(hw, &p, TechNode::N14);
        t.row(&[
            p.name.clone(),
            crate::util::table::fmt_si(p.params as f64),
            format!("{:.1}", a65.rom_cm2),
            format!("{:.1}", a28.rom_cm2),
            format!("{:.2}", a14.rom_cm2),
            if a14.rom_cm2 < 20.0 { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_reproduces_fig1a_shape() {
        let s = fig1a_report(&HardwareConfig::default());
        // LLaMA rows infeasible, BitNet rows feasible — the paper's point
        assert!(s.contains("llama-7b"));
        assert!(s.contains("NO"));
        assert!(s.contains("bitnet-falcon3-1b"));
        assert!(s.lines().filter(|l| l.contains("| yes")).count() >= 2);
    }

    #[test]
    fn has_all_seven_models() {
        assert_eq!(fig1a_points().len(), 7);
    }
}
