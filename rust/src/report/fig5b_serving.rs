//! Fig 5(b), end to end: the external-DRAM access reduction measured
//! from a *served* trace through the store-backed `HostBackend`,
//! placed next to the analytic model's value.
//!
//! The analytic path (`kvcache::simulate_reduction`, exact 43.6% at
//! seq 128 / 32 buffered) assumes every token of an S-token sequence
//! is written once and each decode step reads all prior tokens. The
//! serving path differs only where real serving differs: the prompt's
//! tokens are written during prefill whose attention reads stay in
//! on-chip activation buffers (no memory reads counted), so a short
//! prompt keeps the measured point within a fraction of a percentage
//! point of the analytic one — that agreement is asserted end-to-end
//! in `tests/serve_offline.rs`.

use crate::config::{ModelConfig, ServeConfig};
use crate::coordinator::Server;
use crate::energy::KvEnergy;
use crate::kvcache::{simulate_reduction, KvStoreStats};
use crate::runtime::HostBackend;
use crate::trace::Request;
use crate::util::table::{fmt_pct, Table};

/// Outcome of one measured serving run at a Fig 5(b) operating point.
#[derive(Debug, Clone)]
pub struct Fig5bServing {
    /// Sequence-length cap of the run (`ServeConfig::max_seq`).
    pub seq_len: usize,
    /// Early tokens buffered on-die.
    pub ondie_tokens: usize,
    /// Prompt length of every request (prefill writes, no reads).
    pub prompt_len: usize,
    /// Requests served.
    pub requests: usize,
    /// Tokens emitted by the trace.
    pub tokens_out: u64,
    /// Measured external-access reduction from the store's counters.
    pub measured: f64,
    /// The analytic model's value at (seq_len, ondie_tokens).
    pub analytic: f64,
    /// Full store statistics (evictions, retention health, energy).
    pub kv: KvStoreStats,
}

/// Serve a closed batch of `n_requests` full-length sequences at the
/// (seq_len, ondie_tokens) operating point on a fabricated `sim-tiny`
/// host model and measure the reduction on the store's actual
/// accesses. Deterministic per seed.
pub fn fig5b_serving_study(
    seq_len: usize,
    ondie_tokens: usize,
    n_requests: usize,
    seed: u64,
) -> anyhow::Result<Fig5bServing> {
    let model = ModelConfig::sim_tiny();
    anyhow::ensure!(
        seq_len <= model.max_seq,
        "seq_len {seq_len} exceeds sim-tiny context {}",
        model.max_seq
    );
    anyhow::ensure!(n_requests >= 1, "need at least one request");
    // short prompts keep the serving path close to the analytic model
    // (prefill reads are not memory reads — module docs)
    let prompt_len = 8.min(seq_len.max(2) - 1);
    let serve = ServeConfig {
        max_batches: n_requests,
        prefill_len: prompt_len,
        max_seq: seq_len,
        ondie_tokens,
        seed,
        ..ServeConfig::default()
    };
    // misaligned buffers (ondie_tokens not a multiple of the block
    // size) are rejected by ServeConfig::validate inside Server::new:
    // placement is per block start, so they would effectively round up
    // and the analytic column would not be the quantity measured
    let backend = HostBackend::new(model.clone(), seed)?;
    let mut server = Server::new(backend, serve)?;
    let reqs: Vec<Request> = (0..n_requests)
        .map(|i| Request {
            id: i as u64,
            arrival_s: 0.0,
            prompt: (0..prompt_len)
                .map(|t| ((i * 31 + t * 7 + 1) % model.vocab_size) as i32)
                .collect(),
            max_new_tokens: seq_len - prompt_len,
            adapter_id: None,
            priority: 0,
        })
        .collect();
    let (done, metrics) = server.run_trace(reqs)?;
    anyhow::ensure!(done.len() == n_requests, "trace did not complete");
    let kv = metrics.kv.clone().expect("host backend measures KV stats");
    Ok(Fig5bServing {
        seq_len,
        ondie_tokens,
        prompt_len,
        requests: n_requests,
        tokens_out: metrics.tokens_out,
        measured: kv.external_reduction(),
        analytic: simulate_reduction(seq_len, ondie_tokens),
        kv,
    })
}

/// Fig 5(b) reproduced from a real served trace at the paper's
/// operating point (seq 128, 32 buffered), next to the analytic value.
pub fn fig5b_serving_report() -> String {
    let r = match fig5b_serving_study(128, 32, 3, 0xF5B) {
        Ok(r) => r,
        Err(e) => return format!("fig5b_serving failed: {e:#}\n"),
    };
    let energy = KvEnergy::from_stats(&r.kv);
    let mut t = Table::new(&format!(
        "Fig 5(b) end-to-end — external DRAM access reduction measured on a served trace \
         (sim-tiny, {} requests, prompt {}, seq {})",
        r.requests, r.prompt_len, r.seq_len
    ))
    .header(&["quantity", "measured (serving)", "analytic model"]);
    t.row(&[
        format!("reduction @ (seq {}, {} buffered)", r.seq_len, r.ondie_tokens),
        fmt_pct(r.measured),
        format!("{} (paper: 43.6%)", fmt_pct(r.analytic)),
    ]);
    t.row(&[
        "on-die / external accesses".into(),
        format!(
            "{} / {}",
            r.kv.accesses.ondie_reads + r.kv.accesses.ondie_writes,
            r.kv.accesses.external_accesses()
        ),
        "—".into(),
    ]);
    t.row(&[
        "KV energy (on-die / external)".into(),
        format!("{:.3e} J / {:.3e} J", energy.ondie_j, energy.external_j),
        "—".into(),
    ]);
    let mut out = t.render();
    out.push_str(&format!(
        "tokens served {}; evictions {}, early-block spills {}, retention failures {}, \
         explicit refreshes {}; |measured - analytic| = {:.2} pp\n",
        r.tokens_out,
        r.kv.evictions,
        r.kv.spilled_early_blocks,
        r.kv.retention_failures,
        r.kv.explicit_refreshes,
        (r.measured - r.analytic).abs() * 100.0,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_point_lands_on_the_paper_value() {
        // the acceptance gate's twin at a smaller shape to keep the
        // unit suite fast: seq 64, 16 buffered (analytic 43.8%)
        let r = fig5b_serving_study(64, 16, 2, 7).unwrap();
        assert_eq!(r.kv.retention_failures, 0);
        assert!(
            (r.measured - r.analytic).abs() < 0.01,
            "measured {} vs analytic {}",
            r.measured,
            r.analytic
        );
        assert!(r.kv.accesses.external_accesses() > 0);
        assert!(r.kv.accesses.ondie_reads > 0);
    }

    #[test]
    fn misaligned_buffer_is_rejected_not_silently_rounded() {
        // 20 is not a multiple of the 8-token block: placement would
        // effectively buffer 24 tokens, so the comparison must refuse
        assert!(fig5b_serving_study(64, 20, 1, 1).is_err());
    }

    #[test]
    fn report_renders_measured_and_analytic_columns() {
        let s = fig5b_serving_report();
        assert!(s.contains("measured (serving)"), "{s}");
        assert!(s.contains("43.6%"), "{s}");
        assert!(s.contains("retention failures 0"), "{s}");
    }
}
