//! Shared-prefix serving ledger (DESIGN.md §15): the external-DRAM
//! access reduction measured when identical prompts share their full
//! prefix blocks by reference, next to the private-KV twin of the same
//! trace and the analytic `KvCacheManager::bind_prefix` model.
//!
//! The gain channel is *capacity*, not skipped writes: binding a
//! prefix frees on-die blocks, so a fleet whose private working set
//! overspills the DR-eDRAM fits when it shares — early-token reads
//! stay on-die instead of chasing spilled blocks across the external
//! interface. Invariant 11 rides along: the shared run's tokens are
//! asserted bit-identical to the private twin's.

use crate::config::{EdramParams, ModelConfig, ServeConfig};
use crate::coordinator::Server;
use crate::energy::KvEnergy;
use crate::kvcache::{KvCacheManager, KvStoreStats};
use crate::runtime::HostBackend;
use crate::trace::Request;
use crate::util::table::{fmt_pct, Table};

/// Fig 5(b) measured baseline (PR 3): the reduction a *private*
/// full-length serve achieves at the paper's operating point. The
/// shared-prefix ledger must land strictly above it.
pub const FIG5B_MEASURED_BASELINE: f64 = 0.437;

/// Outcome of the shared-prefix serving study: one donor plus two
/// cache-hit binders, served twice (prefix cache on / off) through a
/// deliberately tight DR-eDRAM.
#[derive(Debug, Clone)]
pub struct PrefixServing {
    /// Requests served (1 donor + the binders).
    pub requests: usize,
    /// Common prompt length of every request.
    pub prompt_len: usize,
    /// Tokens bound per cache hit (full blocks only).
    pub bound_tokens: usize,
    /// Prefix-cache hits observed by the store.
    pub prefix_hits: u64,
    /// Measured reduction with the prefix cache on.
    pub measured_shared: f64,
    /// Measured reduction of the private-KV twin (same trace, cache
    /// off) — the capacity-starved baseline.
    pub measured_private: f64,
    /// The analytic manager's value for the shared run.
    pub analytic_shared: f64,
    /// Whether the shared run's tokens were bit-identical to the
    /// private twin's (invariant 11).
    pub tokens_match: bool,
    /// Store statistics of the shared run.
    pub kv_shared: KvStoreStats,
    /// Store statistics of the private twin.
    pub kv_private: KvStoreStats,
}

/// The study's fixed operating point: `sim-tiny`, sequences of 64 with
/// 24 early tokens buffered, a 17-token common prompt (16 tokens — two
/// full blocks — bindable), and a DR-eDRAM sized to exactly 40 blocks:
/// three private working sets (54 early blocks) overspill it, the
/// shared fleet (30) fits.
const SEQ_LEN: usize = 64;
const ONDIE_TOKENS: usize = 24;
const PROMPT_LEN: usize = 17;
const N_REQUESTS: usize = 3;
const EDRAM_BYTES: u64 = 43_520;

fn serve_config(prefix_cache: bool, seed: u64) -> ServeConfig {
    ServeConfig {
        max_batches: N_REQUESTS,
        prefill_len: PROMPT_LEN,
        max_seq: SEQ_LEN,
        ondie_tokens: ONDIE_TOKENS,
        kv_edram_bytes: EDRAM_BYTES,
        prefix_cache,
        seed,
        ..ServeConfig::default()
    }
}

fn trace() -> Vec<Request> {
    // identical prompts; the donor arrives first, the binders a round
    // later (same-round admissions never share — DESIGN.md §15)
    let prompt: Vec<i32> = (0..PROMPT_LEN).map(|t| ((t * 7 + 13) % 256) as i32).collect();
    (0..N_REQUESTS)
        .map(|i| Request {
            id: i as u64,
            arrival_s: if i == 0 { 0.0 } else { 0.005 + i as f64 * 0.001 },
            prompt: prompt.clone(),
            max_new_tokens: SEQ_LEN - PROMPT_LEN,
            adapter_id: None,
            priority: 0,
        })
        .collect()
}

/// The analytic face of the same fleet: drive the
/// [`KvCacheManager`] twin (donor writes everything; binders bind two
/// blocks and write only the 48-token tail) and read the reduction off
/// its access counters.
fn analytic_shared() -> f64 {
    let model = ModelConfig::sim_tiny();
    let serve = serve_config(true, 0);
    let mut twin = KvCacheManager::new(&model, &serve, EdramParams::default());
    let bound = (PROMPT_LEN - 1) / serve.kv_block_tokens * serve.kv_block_tokens;
    let tbt = serve.hw_tbt_s;
    let mut now = 0.0;
    twin.start_seq(0);
    twin.prefill(0, PROMPT_LEN, now);
    for _ in 0..SEQ_LEN - PROMPT_LEN {
        now += tbt;
        twin.write_token(0, now);
        twin.read_context(0, now).expect("analytic twin retention");
    }
    for slot in 1..N_REQUESTS {
        twin.start_seq(slot);
        twin.bind_prefix(slot, 0, bound);
        now += tbt;
        twin.prefill(slot, PROMPT_LEN - bound, now);
        for _ in 0..SEQ_LEN - PROMPT_LEN {
            now += tbt;
            twin.write_token(slot, now);
            twin.read_context(slot, now).expect("analytic twin retention");
        }
    }
    twin.stats.external_reduction()
}

/// Serve the fleet twice — prefix cache on, then the private twin —
/// and measure both reductions plus the analytic value. Deterministic
/// per seed.
pub fn prefix_serving_study(seed: u64) -> anyhow::Result<PrefixServing> {
    let model = ModelConfig::sim_tiny();
    let run = |prefix_cache: bool| -> anyhow::Result<(Vec<(u64, Vec<i32>)>, KvStoreStats)> {
        let backend = HostBackend::new(model.clone(), seed)?;
        let mut server = Server::new(backend, serve_config(prefix_cache, seed))?;
        let (done, metrics) = server.run_trace(trace())?;
        anyhow::ensure!(done.len() == N_REQUESTS, "trace did not complete");
        let kv = metrics.kv.clone().expect("host backend measures KV stats");
        let mut tokens: Vec<(u64, Vec<i32>)> =
            done.into_iter().map(|d| (d.id, d.tokens)).collect();
        tokens.sort();
        Ok((tokens, kv))
    };
    let (shared_tokens, kv_shared) = run(true)?;
    let (private_tokens, kv_private) = run(false)?;
    let bound = (PROMPT_LEN - 1) / 8 * 8;
    Ok(PrefixServing {
        requests: N_REQUESTS,
        prompt_len: PROMPT_LEN,
        bound_tokens: bound,
        prefix_hits: kv_shared.prefix_hits,
        measured_shared: kv_shared.external_reduction(),
        measured_private: kv_private.external_reduction(),
        analytic_shared: analytic_shared(),
        tokens_match: shared_tokens == private_tokens,
        kv_shared,
        kv_private,
    })
}

/// Render the shared-prefix serving ledger: measured shared vs the
/// private twin vs the analytic model, on top of the Fig 5(b)
/// measured baseline.
pub fn prefix_serving_report() -> String {
    let r = match prefix_serving_study(0x9F1C) {
        Ok(r) => r,
        Err(e) => return format!("prefix_serving failed: {e:#}\n"),
    };
    let e_shared = KvEnergy::from_stats(&r.kv_shared);
    let e_private = KvEnergy::from_stats(&r.kv_private);
    let mut t = Table::new(&format!(
        "Shared-prefix serving — external DRAM reduction, {} requests sharing a \
         {}-token prompt ({} tokens bound per hit), seq {}, {} B DR-eDRAM",
        r.requests, r.prompt_len, r.bound_tokens, SEQ_LEN, EDRAM_BYTES
    ))
    .header(&["quantity", "prefix cache on", "private twin", "analytic"]);
    t.row(&[
        "external reduction".into(),
        fmt_pct(r.measured_shared),
        fmt_pct(r.measured_private),
        fmt_pct(r.analytic_shared),
    ]);
    t.row(&[
        "on-die / external accesses".into(),
        format!(
            "{} / {}",
            r.kv_shared.accesses.ondie_reads + r.kv_shared.accesses.ondie_writes,
            r.kv_shared.accesses.external_accesses()
        ),
        format!(
            "{} / {}",
            r.kv_private.accesses.ondie_reads + r.kv_private.accesses.ondie_writes,
            r.kv_private.accesses.external_accesses()
        ),
        "—".into(),
    ]);
    t.row(&[
        "KV energy (external)".into(),
        format!("{:.3e} J", e_shared.external_j),
        format!("{:.3e} J", e_private.external_j),
        "—".into(),
    ]);
    let mut out = t.render();
    out.push_str(&format!(
        "prefix hits {} ({} tokens bound); early-block spills {} (shared) vs {} (private); \
         external energy saved vs twin {}; tokens bit-identical to the private twin: {}; \
         Fig 5(b) measured baseline {} — shared serving clears it by {:.1} pp\n",
        r.prefix_hits,
        r.kv_shared.prefix_bound_tokens,
        r.kv_shared.spilled_early_blocks + r.kv_shared.evictions,
        r.kv_private.spilled_early_blocks + r.kv_private.evictions,
        fmt_pct(e_shared.external_savings_vs(&e_private)),
        r.tokens_match,
        fmt_pct(FIG5B_MEASURED_BASELINE),
        (r.measured_shared - FIG5B_MEASURED_BASELINE) * 100.0,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_prefix_reduction_beats_the_fig5b_baseline() {
        let r = prefix_serving_study(0x9F1C).unwrap();
        // invariant 11: sharing changed placement, never tokens
        assert!(r.tokens_match, "shared run diverged from its private twin");
        // both binders hit the donor's registration
        assert_eq!(r.prefix_hits, 2, "stats: {:?}", r.kv_shared);
        assert_eq!(r.kv_shared.prefix_bound_tokens, 32);
        // the acceptance gate: measured shared reduction clears the
        // PR 3 Fig 5(b) measured baseline (43.7%) AND the
        // capacity-starved private twin of the very same trace
        assert!(
            r.measured_shared > FIG5B_MEASURED_BASELINE,
            "shared {} <= baseline",
            r.measured_shared
        );
        assert!(
            r.measured_shared > r.measured_private,
            "shared {} <= private {}",
            r.measured_shared,
            r.measured_private
        );
        // the private fleet overspilled the tight eDRAM; the shared
        // fleet fit (that is the entire gain channel)
        assert!(r.kv_private.spilled_early_blocks + r.kv_private.evictions > 0);
        assert_eq!(r.kv_shared.spilled_early_blocks, 0);
        assert_eq!(r.kv_shared.evictions, 0);
    }

    #[test]
    fn measured_shared_tracks_the_analytic_twin() {
        // satellite: the manager's shared-prefix accounting lands
        // within a percentage point of the store-measured run
        let r = prefix_serving_study(0x9F1C).unwrap();
        assert!(
            (r.measured_shared - r.analytic_shared).abs() < 0.01,
            "measured {} vs analytic {}",
            r.measured_shared,
            r.analytic_shared
        );
    }

    #[test]
    fn report_renders_all_three_columns() {
        let s = prefix_serving_report();
        assert!(s.contains("prefix cache on"), "{s}");
        assert!(s.contains("private twin"), "{s}");
        assert!(s.contains("tokens bit-identical to the private twin: true"), "{s}");
    }
}
