//! Fig 5(a)/(b): KV-cache behaviour analysis and external-DRAM access
//! reduction.

use crate::kvcache::{reduction_sweep, simulate_reduction, PAPER_BUFFERS, PAPER_SEQ_LENS};
use crate::util::table::{fmt_pct, Table};

/// Fig 5(a): per-step read/write counts for a short sequence — the
/// analysis that motivates buffering early tokens.
pub fn fig5a_report(seq_len: usize) -> String {
    let mut t = Table::new(&format!(
        "Fig 5(a) — KV-cache accesses per decode step (seq {seq_len})"
    ))
    .header(&["step", "writes", "reads", "cumulative reads of token 0"]);
    let mut cum0 = 0u64;
    for step in 0..seq_len {
        if step > 0 {
            cum0 += 1; // token 0 is read at every step after it exists
        }
        t.row(&[
            step.to_string(),
            "1".to_string(),
            step.to_string(),
            cum0.to_string(),
        ]);
    }
    t.render()
}

/// Fig 5(b): the reduction grid with the paper's operating point marked.
pub fn fig5b_report() -> String {
    let pts = reduction_sweep(&PAPER_SEQ_LENS, &PAPER_BUFFERS);
    let mut t = Table::new(
        "Fig 5(b) — reduction in external DRAM access (rows: on-die tokens; cols: seq len)",
    )
    .header(&["buffered\\seq", "32", "64", "128", "256"]);
    for &b in &PAPER_BUFFERS {
        let mut row = vec![b.to_string()];
        for &s in &PAPER_SEQ_LENS {
            let p = pts
                .iter()
                .find(|p| p.seq_len == s && p.ondie_tokens == b)
                .unwrap();
            let mark = if s == 128 && b == 32 { " *" } else { "" };
            row.push(format!("{}{}", fmt_pct(p.reduction), mark));
        }
        t.row(&row);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "* paper operating point: {} (paper reports 43.6%)\n",
        fmt_pct(simulate_reduction(128, 32))
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5b_contains_paper_point() {
        let s = fig5b_report();
        assert!(s.contains("43.6% *"), "{s}");
        assert!(s.contains("paper reports 43.6%"));
    }

    #[test]
    fn fig5a_counts_grow_linearly() {
        let s = fig5a_report(8);
        // step 7 row: reads = 7
        assert!(s.lines().any(|l| l.starts_with("| 7 ") && l.contains("| 7 ")));
    }
}
