//! Table III: comparison against state-of-the-art accelerators.
//!
//! Baseline rows carry the *published* numbers (exactly as the paper's
//! comparison table does); the "This Work" row is **computed** by our
//! energy/area model from the calibrated constants, and the
//! normalization columns apply the paper's spatial-scaling rule to
//! every row.

use crate::config::{HardwareConfig, TechNode};
use crate::energy::EnergyModel;
use crate::util::table::Table;

/// One comparison row.
#[derive(Debug, Clone)]
pub struct Table3Row {
    /// Design name / citation.
    pub label: &'static str,
    /// Implementation node.
    pub node: TechNode,
    /// Compute domain (digital / analog).
    pub domain: &'static str,
    /// Reported supply voltage(s).
    pub voltage: &'static str,
    /// Workload/model class.
    pub model_type: &'static str,
    /// Storage density descriptor (bits per cell).
    pub bit_per_cell: &'static str,
    /// TOPS/W as published (at the design's own node).
    pub eff_tops_w: f64,
    /// Secondary operating point, if reported.
    pub eff_tops_w_alt: Option<f64>,
    /// Bit density as published (kb/mm²), if reported.
    pub density_kb_mm2: Option<f64>,
    /// Has KV-cache management (paper's ✓ column).
    pub kv_optimized: bool,
    /// Needs no weight reload/update at runtime.
    pub update_free: bool,
}

/// The published baselines (paper Table III).
pub fn baselines() -> Vec<Table3Row> {
    vec![
        Table3Row {
            label: "ISSCC'25 [19] Slim-Llama",
            node: TechNode::N28,
            domain: "Digital",
            voltage: "0.65",
            model_type: "1.58b/4b",
            bit_per_cell: "-",
            eff_tops_w: 255.9,
            eff_tops_w_alt: None,
            density_kb_mm2: None,
            kv_optimized: false,
            update_free: false,
        },
        Table3Row {
            label: "JSSC'23 [10]",
            node: TechNode::N65,
            domain: "Analog",
            voltage: "0.7/1.2",
            model_type: "8b/8b",
            bit_per_cell: "2",
            eff_tops_w: 4.33,
            eff_tops_w_alt: Some(1.24),
            density_kb_mm2: Some(3984.0),
            kv_optimized: false,
            update_free: true,
        },
        Table3Row {
            label: "ESSCIRC'23 [11]",
            node: TechNode::N65,
            domain: "Analog",
            voltage: "1.1",
            model_type: "2b/1b",
            bit_per_cell: "2",
            eff_tops_w: 1324.26,
            eff_tops_w_alt: None,
            density_kb_mm2: Some(375.0),
            kv_optimized: false,
            update_free: true,
        },
        Table3Row {
            label: "ASSCC'24 [4]",
            node: TechNode::N28,
            domain: "Analog",
            voltage: "0.6",
            model_type: "8b/8b",
            bit_per_cell: "4",
            eff_tops_w: 8.49,
            eff_tops_w_alt: None,
            density_kb_mm2: Some(19_660.0),
            kv_optimized: false,
            update_free: true,
        },
        Table3Row {
            label: "CICC'24 [5]",
            node: TechNode::N28,
            domain: "Analog",
            voltage: "0.7/1.1",
            model_type: "8b/8b",
            bit_per_cell: "2",
            eff_tops_w: 42.0,
            eff_tops_w_alt: Some(20.3),
            density_kb_mm2: Some(8928.0),
            kv_optimized: false,
            update_free: true,
        },
        Table3Row {
            label: "ASPDAC'25 [1] DCiROM",
            node: TechNode::N65,
            domain: "Digital",
            voltage: "0.6/1.2",
            model_type: "4b/4b",
            bit_per_cell: "1",
            eff_tops_w: 38.0,
            eff_tops_w_alt: Some(9.0),
            density_kb_mm2: Some(487.0),
            kv_optimized: false,
            update_free: true,
        },
    ]
}

/// Compute the "This Work" row from the model (not hardcoded).
pub fn this_work(sparsity: f64) -> Table3Row {
    let hw06 = HardwareConfig::default().at_voltage(0.6);
    let hw12 = HardwareConfig::default().at_voltage(1.2);
    let eff06 = EnergyModel::new(hw06.clone()).tops_per_watt_analytic(sparsity, 4);
    let eff12 = EnergyModel::new(hw12).tops_per_watt_analytic(sparsity, 4);
    let density = hw06.geometry.bit_density_kb_mm2(TechNode::N65);
    Table3Row {
        label: "This Work (BitROM)",
        node: TechNode::N65,
        domain: "Digital",
        voltage: "0.6/1.2",
        model_type: "1.58b/4b",
        bit_per_cell: "1.58x2",
        eff_tops_w: eff06,
        eff_tops_w_alt: Some(eff12),
        density_kb_mm2: Some(density),
        kv_optimized: true,
        update_free: true,
    }
}

/// Render the full comparison table (computed This-Work row +
/// normalized columns).
pub fn table3_report(sparsity: f64) -> String {
    let mut rows = baselines();
    rows.push(this_work(sparsity));

    let mut t = Table::new("Table III — comparison with state-of-the-art accelerators")
        .header(&[
            "Design",
            "Tech",
            "Domain",
            "V",
            "Model",
            "Bit/Cell",
            "Eff. (TOPS/W)",
            "Norm. Eff.",
            "Bit Density",
            "Norm. Den.",
            "KV Optm.",
            "Update-Free",
        ]);
    for r in &rows {
        let eff = match r.eff_tops_w_alt {
            Some(alt) => format!("{:.1}/{:.1}", r.eff_tops_w, alt),
            None => format!("{:.1}", r.eff_tops_w),
        };
        let norm_eff = match r.eff_tops_w_alt {
            Some(alt) => format!(
                "{:.1}/{:.1}",
                r.node.normalize_to_65(r.eff_tops_w),
                r.node.normalize_to_65(alt)
            ),
            None => format!("{:.1}", r.node.normalize_to_65(r.eff_tops_w)),
        };
        let den = r
            .density_kb_mm2
            .map(|d| format!("{:.0} kb/mm2", d))
            .unwrap_or_else(|| "-".into());
        let norm_den = r
            .density_kb_mm2
            .map(|d| format!("{:.0} kb/mm2", r.node.normalize_to_65(d)))
            .unwrap_or_else(|| "-".into());
        t.row(&[
            r.label.to_string(),
            format!("{} nm", r.node.nm()),
            r.domain.to_string(),
            r.voltage.to_string(),
            r.model_type.to_string(),
            r.bit_per_cell.to_string(),
            eff,
            norm_eff,
            den,
            norm_den,
            if r.kv_optimized { "-43.6%" } else { "x" }.to_string(),
            if r.update_free { "yes" } else { "x" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    const NOMINAL_SPARSITY: f64 = 0.30;

    #[test]
    fn this_work_row_matches_paper_numbers() {
        let r = this_work(NOMINAL_SPARSITY);
        assert!((r.eff_tops_w - 20.8).abs() < 0.2, "{}", r.eff_tops_w);
        assert!((r.eff_tops_w_alt.unwrap() - 5.2).abs() < 0.1);
        assert!((r.density_kb_mm2.unwrap() - 4967.0).abs() < 20.0);
    }

    #[test]
    fn normalization_reproduces_paper_columns() {
        let rows = baselines();
        let isscc = &rows[0];
        let n = isscc.node.normalize_to_65(isscc.eff_tops_w);
        assert!((n - 47.5).abs() < 0.5);
        let asscc = &rows[3];
        let nd = asscc.node.normalize_to_65(asscc.density_kb_mm2.unwrap());
        assert!((nd - 3648.0).abs() < 20.0);
    }

    #[test]
    fn this_work_wins_density_among_digital() {
        let tw = this_work(NOMINAL_SPARSITY);
        for b in baselines() {
            if b.domain == "Digital" {
                if let Some(d) = b.density_kb_mm2 {
                    assert!(
                        tw.density_kb_mm2.unwrap() > 10.0 * d,
                        "vs {}: {d}",
                        b.label
                    );
                }
            }
        }
    }

    #[test]
    fn renders_all_rows() {
        let s = table3_report(NOMINAL_SPARSITY);
        assert!(s.contains("This Work"));
        assert!(s.contains("DCiROM"));
        assert!(s.contains("Norm. Eff."));
        assert_eq!(s.lines().count(), 3 + 7); // title + header + sep + 7 rows
    }
}
