//! Model architecture config — the rust mirror of
//! `python/compile/configs.py` (kept in sync by the manifest check in
//! `runtime::manifest`).

use crate::util::json::Json;

/// Transformer architecture constants (mirrors the python side).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Config name (e.g. `"falcon3-1b"`).
    pub name: String,
    /// Transformer blocks.
    pub n_layers: usize,
    /// Hidden width.
    pub d_model: usize,
    /// Query heads.
    pub n_heads: usize,
    /// KV heads (GQA when < `n_heads`).
    pub n_kv_heads: usize,
    /// MLP inner width.
    pub d_ff: usize,
    /// Vocabulary size.
    pub vocab_size: usize,
    /// Maximum context length.
    pub max_seq: usize,
    /// Pipeline partitions the layers split into (paper: 6).
    pub n_partitions: usize,
    /// Activation quantization width in bits.
    pub act_bits: usize,
}

impl ModelConfig {
    /// Per-head dimension (`d_model / n_heads`).
    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Layers per pipeline partition.
    pub fn layers_per_partition(&self) -> usize {
        debug_assert_eq!(self.n_layers % self.n_partitions, 0);
        self.n_layers / self.n_partitions
    }

    /// K (or V) row width: `n_kv_heads * head_dim`.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Total weight parameter count (embeddings + blocks + head) —
    /// matches `configs.ModelConfig.param_count` on the python side.
    pub fn param_count(&self) -> u64 {
        let (d, f) = (self.d_model as u64, self.d_ff as u64);
        let kv = self.kv_dim() as u64;
        let attn = d * d + 2 * d * kv + d * d;
        let mlp = 3 * d * f;
        let block = attn + mlp + 2 * d;
        self.vocab_size as u64 * d * 2 + self.n_layers as u64 * block + d
    }

    /// Parameters held in the BiROMA arrays (= every linear projection;
    /// embeddings/norms/head live in the auxiliary processor's memory).
    pub fn rom_param_count(&self) -> u64 {
        let (d, f) = (self.d_model as u64, self.d_ff as u64);
        let kv = self.kv_dim() as u64;
        self.n_layers as u64 * (d * d + 2 * d * kv + d * d + 3 * d * f)
    }

    /// Clamp `n_partitions` down to the largest value (≥ 1, ≤ current)
    /// that divides `n_layers` evenly. Fabricated host serving accepts
    /// any named config this way (e.g. llama-7b's 32 layers drop from
    /// 6 to 4 pipeline partitions); real artifact manifests keep their
    /// exact partitioning and never go through here.
    pub fn with_divisible_partitions(mut self) -> Self {
        self.n_partitions = self.n_partitions.max(1);
        while self.n_layers % self.n_partitions != 0 {
            self.n_partitions -= 1;
        }
        self
    }

    /// KV-cache bytes per token (all layers, f16 entries as deployed).
    pub fn kv_bytes_per_token(&self, bytes_per_elem: usize) -> u64 {
        (self.n_layers * 2 * self.kv_dim() * bytes_per_elem) as u64
    }

    /// MAC operations per generated token (2 ops per MAC: mul+add
    /// convention used by the TOPS figures). Linear projections only —
    /// attention itself runs on the auxiliary processor.
    pub fn ops_per_token(&self) -> u64 {
        2 * self.rom_param_count()
    }

    // ---- built-in configs -----------------------------------------------

    /// The paper's deployment target (Falcon3-1B-Instruct, 1.58-bit).
    pub fn falcon3_1b() -> Self {
        ModelConfig {
            name: "falcon3-1b".into(),
            n_layers: 18,
            d_model: 2048,
            n_heads: 8,
            n_kv_heads: 4,
            d_ff: 8192,
            vocab_size: 131072,
            max_seq: 4096,
            n_partitions: 6,
            act_bits: 8,
        }
    }

    /// The AOT/serving config compiled into `artifacts/`.
    pub fn sim_tiny() -> Self {
        ModelConfig {
            name: "sim-tiny".into(),
            n_layers: 6,
            d_model: 128,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 384,
            vocab_size: 256,
            max_seq: 128,
            n_partitions: 6,
            act_bits: 8,
        }
    }

    /// Larger BitNet family members for the Fig 1(a) area sweep; dims
    /// follow the published Falcon3/LLaMA shapes closely enough for
    /// area purposes.
    pub fn named(name: &str) -> Option<Self> {
        let mk = |name: &str,
                  n_layers,
                  d_model,
                  n_heads,
                  n_kv_heads,
                  d_ff,
                  vocab_size| ModelConfig {
            name: name.into(),
            n_layers,
            d_model,
            n_heads,
            n_kv_heads,
            d_ff,
            vocab_size,
            max_seq: 4096,
            n_partitions: 6,
            act_bits: 8,
        };
        match name {
            "falcon3-1b" => Some(Self::falcon3_1b()),
            "sim-tiny" => Some(Self::sim_tiny()),
            "falcon3-3b" => Some(mk("falcon3-3b", 22, 3072, 12, 4, 9216, 131072)),
            "falcon3-7b" => Some(mk("falcon3-7b", 28, 3072, 12, 4, 23040, 131072)),
            "falcon3-10b" => Some(mk("falcon3-10b", 40, 3072, 12, 4, 23040, 131072)),
            "llama-7b" => Some(mk("llama-7b", 32, 4096, 32, 32, 11008, 32000)),
            "llama-13b" => Some(mk("llama-13b", 40, 5120, 40, 40, 13824, 32000)),
            "llama-70b" => Some(mk("llama-70b", 80, 8192, 64, 8, 28672, 32000)),
            _ => None,
        }
    }

    // ---- json ------------------------------------------------------------

    /// Parse from JSON (all dimension fields required).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let get = |k: &str| -> anyhow::Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("model config missing field {k:?}"))
        };
        Ok(ModelConfig {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unnamed")
                .to_string(),
            n_layers: get("n_layers")?,
            d_model: get("d_model")?,
            n_heads: get("n_heads")?,
            n_kv_heads: get("n_kv_heads")?,
            d_ff: get("d_ff")?,
            vocab_size: get("vocab_size")?,
            max_seq: get("max_seq")?,
            n_partitions: get("n_partitions")?,
            act_bits: get("act_bits")?,
        })
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("n_kv_heads", Json::num(self.n_kv_heads as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("vocab_size", Json::num(self.vocab_size as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            ("n_partitions", Json::num(self.n_partitions as f64)),
            ("act_bits", Json::num(self.act_bits as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn falcon3_1b_dims() {
        let c = ModelConfig::falcon3_1b();
        assert_eq!(c.head_dim(), 256);
        assert_eq!(c.layers_per_partition(), 3); // paper §V-B
        assert_eq!(c.kv_dim(), 1024);
        let p = c.param_count();
        assert!(
            (1_200_000_000..2_000_000_000).contains(&p),
            "param count {p}"
        );
    }

    #[test]
    fn sim_tiny_matches_python() {
        // cross-checked against compile/configs.py SIM_TINY param_count
        assert_eq!(ModelConfig::sim_tiny().param_count(), 1_246_848);
    }

    #[test]
    fn rom_params_less_than_total() {
        let c = ModelConfig::falcon3_1b();
        assert!(c.rom_param_count() < c.param_count());
        // linear layers dominate a 1B model even with a 131k vocab
        assert!(c.rom_param_count() as f64 / c.param_count() as f64 > 0.5);
    }

    #[test]
    fn kv_bytes_per_token_falcon() {
        let c = ModelConfig::falcon3_1b();
        // 18 layers * 2 (K+V) * 1024 * 2B = 73,728 B/token
        assert_eq!(c.kv_bytes_per_token(2), 73_728);
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::falcon3_1b();
        let j = c.to_json();
        let c2 = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn divisible_partitions_clamp() {
        // 6 already divides falcon3-1b's 18 layers: unchanged
        assert_eq!(ModelConfig::falcon3_1b().with_divisible_partitions().n_partitions, 6);
        // llama-7b: 32 layers, 6 -> 4
        let l7 = ModelConfig::named("llama-7b").unwrap().with_divisible_partitions();
        assert_eq!(l7.n_partitions, 4);
        assert_eq!(l7.layers_per_partition(), 8);
        // falcon3-3b: 22 layers, 6 -> 2
        let f3 = ModelConfig::named("falcon3-3b").unwrap().with_divisible_partitions();
        assert_eq!(f3.n_partitions, 2);
        // every named config becomes host-fabricable
        for name in [
            "falcon3-1b",
            "sim-tiny",
            "falcon3-3b",
            "falcon3-7b",
            "falcon3-10b",
            "llama-7b",
            "llama-13b",
            "llama-70b",
        ] {
            let c = ModelConfig::named(name).unwrap().with_divisible_partitions();
            assert!(c.n_partitions >= 1);
            assert_eq!(c.n_layers % c.n_partitions, 0, "{name}");
        }
    }

    #[test]
    fn named_lookup() {
        assert!(ModelConfig::named("llama-7b").is_some());
        assert!(ModelConfig::named("nope").is_none());
        let l7 = ModelConfig::named("llama-7b").unwrap().param_count();
        assert!((6_000_000_000..8_000_000_000).contains(&l7), "{l7}");
    }
}
