//! Configuration system: model architectures, hardware parameters, and
//! serving settings. All configs are JSON-loadable/savable and carry the
//! constants the analytical models (energy, area, eDRAM) are built from.

mod hardware;
mod model;
mod net;
mod serve;

pub use hardware::{
    EdramParams, EnergyParams, HardwareConfig, MacroGeometry, TechNode, BITS_PER_CELL,
};
pub use model::ModelConfig;
pub use net::NetConfig;
pub use serve::ServeConfig;
