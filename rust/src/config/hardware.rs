//! Hardware parameters of the BitROM accelerator — the constants every
//! analytical claim (Table III, Fig 1a, §V-B) is computed from.
//!
//! Calibration (documented in DESIGN.md §5): we do not have silicon, so
//! two constants are fitted to the paper's published design point and
//! everything else is *derived*:
//!
//! * `cell_area_um2` is fitted so the macro bit density reproduces
//!   4,967 kb/mm² at 65nm given the published 4.8% periphery overhead
//!   (paper §III-B3) and log2(3)·2 bits per transistor.
//! * the per-event energies are fitted so a 0.3-sparse ternary workload
//!   at 0.6 V / 4-bit activations yields 20.8 TOPS/W; the published
//!   5.2 TOPS/W @ 1.2 V then follows from CV² scaling with **no extra
//!   freedom** (20.8 / (1.2/0.6)² = 5.2 exactly — this is how the paper's
//!   own "20.8/5.2" pair is related, as with DCiROM's 38.0/9.0 at
//!   0.6/1.2 V).
//!
//! Everything downstream — sparsity sensitivity, the local-then-global
//! vs adder-tree-always ablation, 8-bit bit-serial costs, node scaling —
//! is computed from event counts produced by the `cirom` simulator.

use crate::util::json::Json;

/// ln2(3) · 2: information stored per single-transistor BiROMA cell
/// (two ternary weights).
pub const BITS_PER_CELL: f64 = 3.169925001442312; // 2 * log2(3)

/// CMOS technology node with first-order spatial scaling, matching the
/// normalization used in the paper's Table III footnote ("normalized to
/// a 65nm CMOS process based on spatial scaling ratios").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TechNode {
    /// 65 nm (the paper's implementation/normalization node).
    N65,
    /// 28 nm.
    N28,
    /// 14 nm.
    N14,
}

impl TechNode {
    /// Feature size in nanometers.
    pub fn nm(self) -> f64 {
        match self {
            TechNode::N65 => 65.0,
            TechNode::N28 => 28.0,
            TechNode::N14 => 14.0,
        }
    }

    /// Area scaling factor relative to 65nm: (65/node)² (spatial).
    pub fn density_scale_vs_65(self) -> f64 {
        let r = 65.0 / self.nm();
        r * r
    }

    /// Normalize a value reported at this node to 65nm (Table III rule:
    /// divide by the spatial ratio — applied to both TOPS/W and
    /// bit density).
    pub fn normalize_to_65(self, value: f64) -> f64 {
        value / self.density_scale_vs_65()
    }

    /// Parse a node name like `"65"` or `"28nm"`.
    pub fn parse(s: &str) -> Option<TechNode> {
        match s {
            "65" | "65nm" => Some(TechNode::N65),
            "28" | "28nm" => Some(TechNode::N28),
            "14" | "14nm" => Some(TechNode::N14),
            _ => None,
        }
    }
}

/// BiROMA array geometry (paper §III-B2).
#[derive(Debug, Clone, PartialEq)]
pub struct MacroGeometry {
    /// Wordlines per array.
    pub rows: usize,
    /// Single-transistor cells per row (each stores TWO ternary weights).
    pub cols: usize,
    /// BiROMA columns sharing one TriMLA (paper: 8).
    pub cols_per_trimla: usize,
    /// TriMLA input activation width (bits); 8-bit activations run
    /// bit-serial over two cycles.
    pub trimla_act_bits: usize,
    /// TriMLA output accumulator width (bits) — paper: 8-bit suffices.
    pub trimla_out_bits: usize,
    /// Fraction of macro area taken by TriMLAs + peripherals + adder
    /// tree (paper: 4.8%).
    pub periphery_fraction: f64,
    /// Fitted single-transistor ROM cell area at 65nm (µm²); see module
    /// docs for the calibration.
    pub cell_area_um2: f64,
}

impl Default for MacroGeometry {
    fn default() -> Self {
        MacroGeometry {
            rows: 2048,
            cols: 1024,
            cols_per_trimla: 8,
            trimla_act_bits: 4,
            trimla_out_bits: 8,
            periphery_fraction: 0.048,
            // fitted: BITS_PER_CELL * (1 - 0.048) / 4.967e-3 bits/µm²
            cell_area_um2: 0.6073,
        }
    }
}

impl MacroGeometry {
    /// TriMLAs per macro (`cols / cols_per_trimla`).
    pub fn n_trimla(&self) -> usize {
        self.cols / self.cols_per_trimla
    }

    /// Ternary weights stored per macro.
    pub fn weights_per_macro(&self) -> u64 {
        (self.rows * self.cols * 2) as u64
    }

    /// Information bits per macro.
    pub fn bits_per_macro(&self) -> f64 {
        (self.rows * self.cols) as f64 * BITS_PER_CELL
    }

    /// Macro area in mm² at the given node (cells + periphery).
    pub fn macro_area_mm2(&self, node: TechNode) -> f64 {
        let cell_mm2 = self.cell_area_um2 * 1e-6 / node.density_scale_vs_65();
        let array = (self.rows * self.cols) as f64 * cell_mm2;
        array / (1.0 - self.periphery_fraction)
    }

    /// Bit density in kb/mm² at the given node — the Table III metric.
    pub fn bit_density_kb_mm2(&self, node: TechNode) -> f64 {
        self.bits_per_macro() / self.macro_area_mm2(node) / 1e3
    }
}

/// Per-event energies (femtojoules) at the calibration point:
/// 65nm, 0.6 V, 4-bit activations. All voltage points scale by
/// (V/0.6)²; bit-serial 8-bit mode multiplies the per-cycle events by
/// its cycle count and toggle factors (see `cirom::energy_counters`).
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyParams {
    /// Nominal (calibration) supply voltage.
    pub v_nominal: f64,
    /// BL precharge + readout, per ternary weight read.
    pub read_fj: f64,
    /// One TriMLA local accumulate (add or sub), per non-zero weight.
    /// Zero weights SKIP this cost entirely (EN gated by the MSB
    /// comparator) — the sparsity advantage.
    pub accum_fj: f64,
    /// One global adder-tree pass over all TriMLA outputs (per channel
    /// completion, amortized across `rows` MACs by the
    /// local-then-global schedule).
    pub tree_pass_fj: f64,
    /// Control / clock / comparator overhead per MAC cycle.
    pub ctrl_fj: f64,
    /// Clock frequency at 0.6 V (Hz); scales linearly with voltage to
    /// first order in the near-threshold regime.
    pub clk_hz_nominal: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        EnergyParams {
            v_nominal: 0.6,
            read_fj: 25.0,
            accum_fj: 55.0,
            // 128 TriMLA outputs, 8b each → one tree pass; fitted order
            // of magnitude for a 128-input 8b adder tree at 0.6V/65nm.
            tree_pass_fj: 2048.0,
            // fitted so the nominal workload hits 20.8 TOPS/W (see
            // energy::tests::table3_energy_point).
            ctrl_fj: 30.65,
            clk_hz_nominal: 100e6,
        }
    }
}

impl EnergyParams {
    /// Voltage scaling factor for energy: (V/Vnom)².
    pub fn v_scale(&self, v: f64) -> f64 {
        (v / self.v_nominal) * (v / self.v_nominal)
    }

    /// Clock frequency at supply voltage `v` (linear scaling).
    pub fn clk_hz(&self, v: f64) -> f64 {
        self.clk_hz_nominal * v / self.v_nominal
    }
}

/// DR eDRAM parameters (paper §IV; eDRAM design adopted from
/// GC-eDRAM [20], retention per JESD79-5C).
#[derive(Debug, Clone, PartialEq)]
pub struct EdramParams {
    /// On-die capacity in bytes (paper §V-B: 13.5 MB for seq 128 /
    /// 32 buffered tokens on Falcon3-1B).
    pub capacity_bytes: u64,
    /// Cell retention time (tREF), seconds. JESD79-5C: 64 ms.
    pub t_ref_s: f64,
    /// Read energy per byte (pJ) — on-die, ~15× cheaper than external.
    pub read_pj_per_byte: f64,
    /// Write energy per byte (pJ).
    pub write_pj_per_byte: f64,
    /// Explicit refresh energy per row (pJ) — only spent when the
    /// refresh-on-read argument FAILS (TBT > tREF).
    pub refresh_pj_per_row: f64,
    /// Row width in bytes (refresh granularity).
    pub row_bytes: u64,
    /// Access latency (ns).
    pub latency_ns: f64,
}

impl Default for EdramParams {
    fn default() -> Self {
        EdramParams {
            capacity_bytes: 13_500_000 * 8 / 8, // 13.5 MB, paper §V-B
            t_ref_s: 0.064,
            read_pj_per_byte: 3.2,
            write_pj_per_byte: 3.6,
            refresh_pj_per_row: 180.0,
            row_bytes: 64,
            latency_ns: 5.0,
        }
    }
}

/// Full hardware configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    /// Technology node.
    pub node: TechNode,
    /// BiROMA array geometry.
    pub geometry: MacroGeometry,
    /// Calibrated per-event energies.
    pub energy: EnergyParams,
    /// DR eDRAM parameters.
    pub edram: EdramParams,
    /// Operating voltage (paper evaluates 0.6 V and 1.2 V).
    pub vdd: f64,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        HardwareConfig {
            node: TechNode::N65,
            geometry: MacroGeometry::default(),
            energy: EnergyParams::default(),
            edram: EdramParams::default(),
            vdd: 0.6,
        }
    }
}

impl HardwareConfig {
    /// This config operated at `vdd` volts.
    pub fn at_voltage(mut self, vdd: f64) -> Self {
        self.vdd = vdd;
        self
    }

    /// This config scaled to `node`.
    pub fn at_node(mut self, node: TechNode) -> Self {
        self.node = node;
        self
    }

    /// Macros required to hold a ROM image of `n_weights` ternary weights.
    pub fn macros_for_weights(&self, n_weights: u64) -> u64 {
        let per = self.geometry.weights_per_macro();
        (n_weights + per - 1) / per
    }

    /// Export the key constants as JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node_nm", Json::num(self.node.nm())),
            ("vdd", Json::num(self.vdd)),
            ("rows", Json::num(self.geometry.rows as f64)),
            ("cols", Json::num(self.geometry.cols as f64)),
            ("cell_area_um2", Json::num(self.geometry.cell_area_um2)),
            ("read_fj", Json::num(self.energy.read_fj)),
            ("accum_fj", Json::num(self.energy.accum_fj)),
            ("tree_pass_fj", Json::num(self.energy.tree_pass_fj)),
            ("ctrl_fj", Json::num(self.energy.ctrl_fj)),
            (
                "edram_capacity_bytes",
                Json::num(self.edram.capacity_bytes as f64),
            ),
            ("edram_t_ref_s", Json::num(self.edram.t_ref_s)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_per_cell_is_two_trits() {
        assert!((BITS_PER_CELL - 2.0 * 3f64.log2()).abs() < 1e-12);
    }

    #[test]
    fn bit_density_matches_paper_65nm() {
        // Table III "This Work": 4,967 kb/mm² at 65nm.
        let g = MacroGeometry::default();
        let d = g.bit_density_kb_mm2(TechNode::N65);
        assert!(
            (d - 4967.0).abs() < 15.0,
            "bit density {d:.1} kb/mm² vs paper 4967"
        );
    }

    #[test]
    fn density_10x_over_prior_digital_cirom() {
        // DCiROM [1] (ASPDAC'25): 487 kb/mm² at 65nm → paper claims 10×.
        let g = MacroGeometry::default();
        let ratio = g.bit_density_kb_mm2(TechNode::N65) / 487.0;
        assert!(ratio > 10.0, "ratio {ratio:.1}");
    }

    #[test]
    fn node_scaling_matches_table3_normalization() {
        // ISSCC'25 @28nm: 255.9 TOPS/W → 47.5 normalized (paper row).
        let n = TechNode::N28.normalize_to_65(255.9);
        assert!((n - 47.5).abs() < 0.5, "{n}");
        // ASSCC'24 @28nm: 19,660 kb/mm² → 3,648 normalized.
        let d = TechNode::N28.normalize_to_65(19_660.0);
        assert!((d - 3648.0).abs() < 10.0, "{d}");
    }

    #[test]
    fn voltage_scaling_is_cv2() {
        let e = EnergyParams::default();
        assert!((e.v_scale(1.2) - 4.0).abs() < 1e-12);
        assert!((e.v_scale(0.6) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn macro_geometry_counts() {
        let g = MacroGeometry::default();
        assert_eq!(g.n_trimla(), 128);
        assert_eq!(g.weights_per_macro(), 2048 * 1024 * 2);
    }

    #[test]
    fn macros_for_falcon3_1b() {
        let hw = HardwareConfig::default();
        let rom = crate::config::ModelConfig::falcon3_1b().rom_param_count();
        let n = hw.macros_for_weights(rom);
        // ~1.13e9 ternary weights / 4.19e6 per macro = 270 macros
        assert_eq!(n, 270);
    }

    #[test]
    fn edram_capacity_is_13_5_mb() {
        assert_eq!(EdramParams::default().capacity_bytes, 13_500_000);
    }

    #[test]
    fn json_export_has_key_fields() {
        let j = HardwareConfig::default().to_json();
        assert_eq!(j.get("node_nm").unwrap().as_f64(), Some(65.0));
        assert!(j.get("cell_area_um2").is_some());
    }
}
