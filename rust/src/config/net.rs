//! Network front-door configuration for the streaming serving plane
//! (DESIGN.md §14): listen address, admission-edge limits, and
//! connection hygiene knobs for `bitrom serve --listen`.

use crate::util::json::Json;

/// Knobs of the HTTP/1.1 front door ([`crate::net::NetServer`]). All
/// admission *policy* (per-tenant FIFO, rate buckets, queue depth)
/// lives in [`crate::coordinator::Ingress`]; this config only carries
/// the numbers it is built with plus transport limits.
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// Listen address, e.g. `127.0.0.1:8080`; port `0` binds an
    /// ephemeral port (tests read it back from the handle).
    pub listen: String,
    /// Most requests queued at the admission edge before submissions
    /// are rejected with HTTP 429 (`FailReason::Overload` sheds).
    pub max_queue: usize,
    /// Per-tenant request rate (req/s, token bucket); `0.0` = no
    /// rate limiting.
    pub rate_limit: f64,
    /// Largest accepted request body in bytes (HTTP 413 beyond it).
    pub max_body_bytes: usize,
    /// Per-connection socket read timeout (s) so a stalled client
    /// cannot pin a connection thread forever.
    pub read_timeout_s: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            listen: "127.0.0.1:8080".into(),
            max_queue: 64,
            rate_limit: 0.0,
            max_body_bytes: 1 << 20,
            read_timeout_s: 30.0,
        }
    }
}

impl NetConfig {
    /// Check internal consistency; the net server constructor calls
    /// this.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.listen.is_empty(), "listen address must be non-empty");
        anyhow::ensure!(self.max_queue >= 1, "max_queue must be >= 1");
        anyhow::ensure!(self.rate_limit >= 0.0, "rate_limit must be >= 0");
        anyhow::ensure!(self.max_body_bytes >= 1, "max_body_bytes must be >= 1");
        anyhow::ensure!(self.read_timeout_s > 0.0, "read_timeout_s must be positive");
        Ok(())
    }

    /// Serialize to JSON (all fields).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("listen", Json::str(self.listen.clone())),
            ("max_queue", Json::num(self.max_queue as f64)),
            ("rate_limit", Json::num(self.rate_limit)),
            ("max_body_bytes", Json::num(self.max_body_bytes as f64)),
            ("read_timeout_s", Json::num(self.read_timeout_s)),
        ])
    }

    /// Parse from JSON; missing fields fall back to the defaults.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = NetConfig::default();
        let cfg = NetConfig {
            listen: j
                .get("listen")
                .and_then(Json::as_str)
                .unwrap_or(&d.listen)
                .to_string(),
            max_queue: j
                .get("max_queue")
                .and_then(Json::as_usize)
                .unwrap_or(d.max_queue),
            rate_limit: j
                .get("rate_limit")
                .and_then(Json::as_f64)
                .unwrap_or(d.rate_limit),
            max_body_bytes: j
                .get("max_body_bytes")
                .and_then(Json::as_usize)
                .unwrap_or(d.max_body_bytes),
            read_timeout_s: j
                .get("read_timeout_s")
                .and_then(Json::as_f64)
                .unwrap_or(d.read_timeout_s),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        let c = NetConfig::default();
        assert!(c.validate().is_ok());
        assert_eq!(c.rate_limit, 0.0, "rate limiting off by default");
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = NetConfig::default();
        c.listen.clear();
        assert!(c.validate().is_err());
        let mut c = NetConfig::default();
        c.max_queue = 0;
        assert!(c.validate().is_err());
        let mut c = NetConfig::default();
        c.rate_limit = -1.0;
        assert!(c.validate().is_err());
        let mut c = NetConfig::default();
        c.read_timeout_s = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = NetConfig {
            listen: "0.0.0.0:9090".into(),
            max_queue: 7,
            rate_limit: 2.5,
            max_body_bytes: 4096,
            read_timeout_s: 5.0,
        };
        let c2 = NetConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
        // old configs without the fields parse to the defaults
        let j = Json::parse(r#"{"listen": ":8081"}"#).unwrap();
        let c = NetConfig::from_json(&j).unwrap();
        assert_eq!(c.listen, ":8081");
        assert_eq!(c.max_queue, 64);
    }
}
