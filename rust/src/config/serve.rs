//! Serving configuration for the coordinator (paper §V-B deployment:
//! 6 partitions, up to 6 in-flight batches, sequence length 128 with 32
//! early tokens buffered on-die).

use crate::lora::LoraConfig;
use crate::util::json::Json;

/// Knobs of one serving deployment: batching, sequence shape, KV-cache
/// placement/paging/quantization, multi-tenant adapters, sampling, and
/// the modeled hardware token cadence.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Max batches in flight through the partition pipeline (paper: 6).
    pub max_batches: usize,
    /// Prefill bucket length — prompts are padded up to this (AOT
    /// executables have a fixed prefill shape).
    pub prefill_len: usize,
    /// Max total sequence length (prompt + generated).
    pub max_seq: usize,
    /// Early tokens whose KV lives in DR eDRAM (paper: 32 @ seq 128).
    pub ondie_tokens: usize,
    /// KV-store page size in tokens (`kvcache::KvStore` blocks).
    pub kv_block_tokens: usize,
    /// KV element width: 8 (i8 + per-token scale, the deployed mode)
    /// or 32 (raw f32 reference mode).
    pub kv_quant_bits: usize,
    /// On-die KV tier capacity in bytes (paper §V-B: 13.5 MB).
    pub kv_edram_bytes: u64,
    /// Tenant LoRA adapters resident in the deployment (0 = adapter
    /// serving disabled; requests then carry no `adapter_id`).
    pub n_adapters: usize,
    /// Adapter rank when `n_adapters > 0` (paper: 16).
    pub adapter_rank: usize,
    /// Adapter placement as `lora::Proj` short names (paper: `"VOD"`;
    /// the same grammar `LoraConfig::placement_str` emits and the
    /// `--placements` CLI flag takes).
    pub adapter_placement: String,
    /// Greedy decoding (argmax) vs top-k sampling.
    pub top_k: usize,
    /// Worker threads for the parallel execution engine (DESIGN.md
    /// §12): per-slot round execution in the server plus kernel
    /// column-sharding in the backend. `0` = inherit the process
    /// default (`BITROM_THREADS`, else 1); `1` = the serial path.
    /// Thread count never changes served tokens or merged counters —
    /// only throughput.
    pub threads: usize,
    /// Sampling seed (ignored for greedy).
    pub seed: u64,
    /// Modeled hardware token-between-token time (s) used to advance
    /// the DR-eDRAM retention clock. The retention argument concerns
    /// the *accelerator's* timing, not the speed of the CPU emulating
    /// it — the energy model's Falcon3-1B estimate is ~0.4 ms/token;
    /// 5 ms is a conservative edge default (still 12x under tREF).
    pub hw_tbt_s: f64,
    /// Fault-injection seed (DESIGN.md §13). `0` (the default) disables
    /// injection entirely — the serving loop is then byte-identical to
    /// a build without the fault module. Any other value seeds a
    /// deterministic `fault::FaultPlan`.
    pub fault_seed: u64,
    /// Per-round probability of a retention-clock storm when a fault
    /// plan is active (subject to the plan's cooldown).
    pub fault_storm_p: f64,
    /// Per-slot per-round probability of a transient backend /
    /// adapter-load / KV-capacity fault when a plan is active.
    pub fault_transient_p: f64,
    /// Seconds a storm skips the DR-eDRAM retention clock forward.
    /// Anything above `tREF - hw_tbt_s` (default tREF is 64 ms)
    /// expires every resident on-die row.
    pub fault_clock_skip_s: f64,
    /// Recovery budget per request: retries granted for transient
    /// faults, and recomputes granted for retention expiries, before
    /// the request is shed with a typed reason.
    pub retry_max: usize,
    /// Admission pressure threshold in `(0, 1]`: a new request is only
    /// admitted while `ondie_blocks_in_use / ondie_block_capacity` is
    /// below this fraction (unless no slot is active — the first
    /// request always admits). `0.0` (the default) disables
    /// pressure-gated admission and keeps blind slot-count FIFO.
    pub admit_pressure: f64,
    /// Preempt the youngest active slot (KV swapped out to the
    /// external tier, values intact) when measured pressure exceeds
    /// `admit_pressure` while requests queue. Off by default.
    pub preempt_under_pressure: bool,
    /// Overload deadline (s): queued requests waiting longer are shed
    /// with `FailReason::Overload`. `0.0` (the default) never sheds.
    pub shed_after_s: f64,
    /// Shared-prefix KV caching (DESIGN.md §15): content-hash full
    /// prompt blocks, bind cache hits by reference, recompute only the
    /// unshared tail. Changes placement and traffic, never tokens
    /// (invariant 11). Off by default — the serving loop is then
    /// byte-identical to a build without prefix support.
    pub prefix_cache: bool,
    /// Model shards behind the backend (DESIGN.md §16): the seeded
    /// model is split across this many backend instances —
    /// pipeline-parallel partition ownership with per-shard KV
    /// stores/retention clocks plus a tensor-parallel exact-i64 LM
    /// head. Shard count changes throughput and placement, never
    /// tokens (invariant 12). `1` (the default) is the single-instance
    /// topology.
    pub shards: usize,
    /// What preemption does to the victim's KV: `"reload"` (the
    /// default) swaps it to the external tier and reads it back on
    /// resume; `"recompute"` drops it and replays the sequence so far
    /// through prefill when a slot frees. Recompute requires greedy
    /// decoding — the replay must re-derive the same tokens.
    pub preempt_policy: String,
    /// Fused batched decode (DESIGN.md §17): when every slot in a
    /// token round is decoding, the coordinator drives the whole batch
    /// through one partition walk so each projection site runs a single
    /// bitplane GEMM instead of per-slot GEMVs. Exact integer rows are
    /// independent, so fusion changes throughput, never tokens. On by
    /// default; `false` keeps the per-slot pool path.
    pub fused_decode: bool,
    /// Kernel engine path (`bitnet::KernelPath` names): `"auto"` (the
    /// default, size-based heuristic), `"scalar"` (word-parallel
    /// sign-select), or `"bitserial"` (popcount over activation bit
    /// lanes). All paths are bit-identical to `ref_gemv` — the knob
    /// changes throughput, never tokens.
    pub kernel_path: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batches: 6,
            prefill_len: 64,
            max_seq: 128,
            ondie_tokens: 32,
            kv_block_tokens: 8,
            kv_quant_bits: 8,
            kv_edram_bytes: 13_500_000,
            n_adapters: 0,
            adapter_rank: 16,
            adapter_placement: "VOD".into(),
            top_k: 1,
            threads: 0,
            seed: 0,
            hw_tbt_s: 0.005,
            fault_seed: 0,
            fault_storm_p: 0.25,
            fault_transient_p: 0.05,
            fault_clock_skip_s: 0.1,
            retry_max: 3,
            admit_pressure: 0.0,
            preempt_under_pressure: false,
            shed_after_s: 0.0,
            prefix_cache: false,
            shards: 1,
            preempt_policy: "reload".into(),
            fused_decode: true,
            kernel_path: "auto".into(),
        }
    }
}

impl ServeConfig {
    /// Check internal consistency; every constructor of a server
    /// calls this.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.max_batches >= 1, "max_batches must be >= 1");
        anyhow::ensure!(
            self.prefill_len <= self.max_seq,
            "prefill_len {} > max_seq {}",
            self.prefill_len,
            self.max_seq
        );
        anyhow::ensure!(
            self.ondie_tokens <= self.max_seq,
            "ondie_tokens {} > max_seq {}",
            self.ondie_tokens,
            self.max_seq
        );
        anyhow::ensure!(self.kv_block_tokens >= 1, "kv_block_tokens must be >= 1");
        // placement is per block start: a misaligned buffer would
        // silently round up to the next block boundary, so the
        // deployment would buffer more tokens than configured
        anyhow::ensure!(
            self.ondie_tokens % self.kv_block_tokens == 0,
            "ondie_tokens {} must be a multiple of kv_block_tokens {}",
            self.ondie_tokens,
            self.kv_block_tokens
        );
        // the KV store's quant-mode parser is the single source of
        // truth for which widths exist
        crate::kvcache::KvQuant::from_bits(self.kv_quant_bits)?;
        // ... and lora's placement parser for which site strings do
        if self.n_adapters > 0 {
            anyhow::ensure!(self.adapter_rank >= 1, "adapter_rank must be >= 1");
            LoraConfig::parse_placements(&self.adapter_placement)?;
        }
        anyhow::ensure!(self.top_k >= 1, "top_k must be >= 1");
        anyhow::ensure!(self.hw_tbt_s > 0.0, "hw_tbt_s must be positive");
        // fault/degradation knobs are only checked when they are on
        if self.fault_seed != 0 {
            anyhow::ensure!(
                (0.0..=1.0).contains(&self.fault_storm_p),
                "fault_storm_p must be in [0, 1]"
            );
            anyhow::ensure!(
                (0.0..=1.0).contains(&self.fault_transient_p),
                "fault_transient_p must be in [0, 1]"
            );
            anyhow::ensure!(
                self.fault_clock_skip_s >= 0.0,
                "fault_clock_skip_s must be >= 0"
            );
            // invariant 9's bit-identical-recovery guarantee needs
            // deterministic sampling: a recovered sequence re-derives
            // its remaining tokens, which only matches the fault-free
            // twin under greedy decoding
            anyhow::ensure!(
                self.top_k == 1,
                "fault injection requires greedy decoding (top_k = 1)"
            );
        }
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.admit_pressure),
            "admit_pressure must be in [0, 1]"
        );
        anyhow::ensure!(self.shed_after_s >= 0.0, "shed_after_s must be >= 0");
        if self.preempt_under_pressure {
            anyhow::ensure!(
                self.admit_pressure > 0.0,
                "preempt_under_pressure needs admit_pressure > 0 (the trigger threshold)"
            );
        }
        anyhow::ensure!(self.shards >= 1, "shards must be >= 1");
        anyhow::ensure!(
            self.preempt_policy == "reload" || self.preempt_policy == "recompute",
            "preempt_policy must be \"reload\" or \"recompute\", got {:?}",
            self.preempt_policy
        );
        // the kernel parser is the single source of truth for which
        // engine paths exist
        anyhow::ensure!(
            crate::bitnet::KernelPath::parse(&self.kernel_path).is_some(),
            "kernel_path must be \"auto\", \"scalar\" or \"bitserial\", got {:?}",
            self.kernel_path
        );
        if self.preempt_policy == "recompute" {
            // the replayed prefix must re-derive the exact tokens the
            // victim already emitted (invariant 11)
            anyhow::ensure!(
                self.top_k == 1,
                "preempt_policy \"recompute\" requires greedy decoding (top_k = 1)"
            );
        }
        Ok(())
    }

    /// The worker-pool width this deployment resolves to: the explicit
    /// [`Self::threads`] knob, else the process default
    /// (`BITROM_THREADS`, else 1 = serial).
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::util::pool::env_threads()
        } else {
            self.threads
        }
    }

    /// The adapter configuration of this deployment (`None` when
    /// adapter serving is disabled): the parsed placement at
    /// [`Self::adapter_rank`], with the paper's 6-bit weights / 8-bit
    /// activations.
    pub fn lora_config(&self) -> anyhow::Result<Option<LoraConfig>> {
        if self.n_adapters == 0 {
            return Ok(None);
        }
        anyhow::ensure!(self.adapter_rank >= 1, "adapter_rank must be >= 1");
        Ok(Some(LoraConfig {
            placement: LoraConfig::parse_placements(&self.adapter_placement)?,
            rank: self.adapter_rank,
            weight_bits: 6,
            act_bits: 8,
        }))
    }

    /// Serialize to JSON (all fields).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_batches", Json::num(self.max_batches as f64)),
            ("prefill_len", Json::num(self.prefill_len as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            ("ondie_tokens", Json::num(self.ondie_tokens as f64)),
            ("kv_block_tokens", Json::num(self.kv_block_tokens as f64)),
            ("kv_quant_bits", Json::num(self.kv_quant_bits as f64)),
            ("kv_edram_bytes", Json::num(self.kv_edram_bytes as f64)),
            ("n_adapters", Json::num(self.n_adapters as f64)),
            ("adapter_rank", Json::num(self.adapter_rank as f64)),
            ("adapter_placement", Json::str(self.adapter_placement.clone())),
            ("top_k", Json::num(self.top_k as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("hw_tbt_s", Json::num(self.hw_tbt_s)),
            ("fault_seed", Json::num(self.fault_seed as f64)),
            ("fault_storm_p", Json::num(self.fault_storm_p)),
            ("fault_transient_p", Json::num(self.fault_transient_p)),
            ("fault_clock_skip_s", Json::num(self.fault_clock_skip_s)),
            ("retry_max", Json::num(self.retry_max as f64)),
            ("admit_pressure", Json::num(self.admit_pressure)),
            ("preempt_under_pressure", Json::Bool(self.preempt_under_pressure)),
            ("shed_after_s", Json::num(self.shed_after_s)),
            ("prefix_cache", Json::Bool(self.prefix_cache)),
            ("shards", Json::num(self.shards as f64)),
            ("preempt_policy", Json::str(self.preempt_policy.clone())),
            ("fused_decode", Json::Bool(self.fused_decode)),
            ("kernel_path", Json::str(self.kernel_path.clone())),
        ])
    }

    /// Parse from JSON; missing fields fall back to the defaults.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = ServeConfig::default();
        let get = |k: &str, dv: usize| j.get(k).and_then(Json::as_usize).unwrap_or(dv);
        let cfg = ServeConfig {
            max_batches: get("max_batches", d.max_batches),
            prefill_len: get("prefill_len", d.prefill_len),
            max_seq: get("max_seq", d.max_seq),
            ondie_tokens: get("ondie_tokens", d.ondie_tokens),
            kv_block_tokens: get("kv_block_tokens", d.kv_block_tokens),
            kv_quant_bits: get("kv_quant_bits", d.kv_quant_bits),
            kv_edram_bytes: j
                .get("kv_edram_bytes")
                .and_then(Json::as_f64)
                .unwrap_or(d.kv_edram_bytes as f64) as u64,
            n_adapters: get("n_adapters", d.n_adapters),
            adapter_rank: get("adapter_rank", d.adapter_rank),
            adapter_placement: j
                .get("adapter_placement")
                .and_then(Json::as_str)
                .unwrap_or(&d.adapter_placement)
                .to_string(),
            top_k: get("top_k", d.top_k),
            threads: get("threads", d.threads),
            seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
            hw_tbt_s: j.get("hw_tbt_s").and_then(Json::as_f64).unwrap_or(d.hw_tbt_s),
            fault_seed: j.get("fault_seed").and_then(Json::as_i64).unwrap_or(0) as u64,
            fault_storm_p: j
                .get("fault_storm_p")
                .and_then(Json::as_f64)
                .unwrap_or(d.fault_storm_p),
            fault_transient_p: j
                .get("fault_transient_p")
                .and_then(Json::as_f64)
                .unwrap_or(d.fault_transient_p),
            fault_clock_skip_s: j
                .get("fault_clock_skip_s")
                .and_then(Json::as_f64)
                .unwrap_or(d.fault_clock_skip_s),
            retry_max: get("retry_max", d.retry_max),
            admit_pressure: j
                .get("admit_pressure")
                .and_then(Json::as_f64)
                .unwrap_or(d.admit_pressure),
            preempt_under_pressure: j
                .get("preempt_under_pressure")
                .and_then(Json::as_bool)
                .unwrap_or(d.preempt_under_pressure),
            shed_after_s: j
                .get("shed_after_s")
                .and_then(Json::as_f64)
                .unwrap_or(d.shed_after_s),
            prefix_cache: j
                .get("prefix_cache")
                .and_then(Json::as_bool)
                .unwrap_or(d.prefix_cache),
            shards: get("shards", d.shards),
            preempt_policy: j
                .get("preempt_policy")
                .and_then(Json::as_str)
                .unwrap_or(&d.preempt_policy)
                .to_string(),
            fused_decode: j
                .get("fused_decode")
                .and_then(Json::as_bool)
                .unwrap_or(d.fused_decode),
            kernel_path: j
                .get("kernel_path")
                .and_then(Json::as_str)
                .unwrap_or(&d.kernel_path)
                .to_string(),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_deployment() {
        let c = ServeConfig::default();
        assert_eq!(c.max_batches, 6);
        assert_eq!(c.max_seq, 128);
        assert_eq!(c.ondie_tokens, 32);
        assert_eq!(c.kv_quant_bits, 8);
        assert_eq!(c.kv_edram_bytes, 13_500_000);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = ServeConfig::default();
        c.prefill_len = 1000;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.max_batches = 0;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.kv_block_tokens = 0;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.kv_quant_bits = 4;
        assert!(c.validate().is_err());
        // misaligned buffer would silently round up to a block boundary
        let mut c = ServeConfig::default();
        c.ondie_tokens = 20;
        assert!(c.validate().is_err());
        // adapter knobs are only checked when adapters are enabled
        let mut c = ServeConfig::default();
        c.adapter_placement = "VOX".into();
        assert!(c.validate().is_ok());
        c.n_adapters = 2;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.n_adapters = 2;
        c.adapter_rank = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn lora_config_mirrors_the_adapter_knobs() {
        let c = ServeConfig::default();
        assert!(c.lora_config().unwrap().is_none(), "adapters off by default");
        let c = ServeConfig {
            n_adapters: 3,
            adapter_rank: 4,
            adapter_placement: "od".into(),
            ..ServeConfig::default()
        };
        let lora = c.lora_config().unwrap().unwrap();
        assert_eq!(lora.rank, 4);
        assert_eq!(lora.placement_str(), "OD", "canonical short names");
        assert_eq!(lora.weight_bits, 6, "paper Fig 6(a): 6-bit suffices");
        // the paper deployment's default placement parses to VOD
        let paper = ServeConfig {
            n_adapters: 1,
            ..ServeConfig::default()
        };
        let lora = paper.lora_config().unwrap().unwrap();
        assert_eq!(lora.placement, crate::lora::LoraConfig::paper().placement);
        assert_eq!(lora.rank, 16);
    }

    #[test]
    fn json_roundtrip() {
        let c = ServeConfig {
            max_batches: 3,
            prefill_len: 32,
            max_seq: 64,
            ondie_tokens: 16,
            kv_block_tokens: 4,
            kv_quant_bits: 32,
            kv_edram_bytes: 1 << 20,
            n_adapters: 3,
            adapter_rank: 8,
            adapter_placement: "QKGU".into(),
            // greedy: both fault injection and recompute preemption
            // demand top_k == 1 at validation
            top_k: 1,
            threads: 3,
            seed: 99,
            hw_tbt_s: 0.002,
            fault_seed: 41,
            fault_storm_p: 0.5,
            fault_transient_p: 0.125,
            fault_clock_skip_s: 0.25,
            retry_max: 5,
            admit_pressure: 0.75,
            preempt_under_pressure: true,
            shed_after_s: 1.5,
            prefix_cache: true,
            shards: 2,
            preempt_policy: "recompute".into(),
            fused_decode: false,
            kernel_path: "bitserial".into(),
        };
        let c2 = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn kernel_knobs_validate_and_default_on() {
        let c = ServeConfig::default();
        assert!(c.fused_decode, "fused decode is the default engine");
        assert_eq!(c.kernel_path, "auto");
        // only the three named engine paths exist
        let mut c = ServeConfig::default();
        c.kernel_path = "simd".into();
        assert!(c.validate().is_err());
        for path in ["auto", "scalar", "bitserial"] {
            c.kernel_path = path.into();
            assert!(c.validate().is_ok(), "{path} is a real engine path");
        }
        // old configs without the fields parse to the fused auto engine
        let j = Json::parse(r#"{"max_batches": 2}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert!(c.fused_decode);
        assert_eq!(c.kernel_path, "auto");
    }

    #[test]
    fn fault_knobs_validate_only_when_enabled() {
        // a bad storm probability is ignored while injection is off...
        let mut c = ServeConfig::default();
        c.fault_storm_p = 7.0;
        assert!(c.validate().is_ok());
        // ...and rejected once a seed turns the plan on
        c.fault_seed = 1;
        assert!(c.validate().is_err());
        // injection demands greedy decoding (bit-identical recovery)
        let mut c = ServeConfig::default();
        c.fault_seed = 1;
        assert!(c.validate().is_ok());
        c.top_k = 4;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.admit_pressure = 1.5;
        assert!(c.validate().is_err());
        // preemption needs a pressure threshold to trigger on
        let mut c = ServeConfig::default();
        c.preempt_under_pressure = true;
        assert!(c.validate().is_err());
        c.admit_pressure = 0.5;
        assert!(c.validate().is_ok());
        // old configs without the fields parse to injection-off
        let j = Json::parse(r#"{"max_batches": 2}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.fault_seed, 0);
        assert_eq!(c.admit_pressure, 0.0);
        assert!(!c.preempt_under_pressure);
    }

    #[test]
    fn prefix_and_preempt_policy_knobs_validate() {
        // old configs without the fields parse to the legacy behavior
        let j = Json::parse(r#"{"max_batches": 2}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert!(!c.prefix_cache);
        assert_eq!(c.shards, 1, "pre-sharding configs parse single-instance");
        assert_eq!(c.preempt_policy, "reload");
        // zero shards is meaningless
        let mut c = ServeConfig::default();
        c.shards = 0;
        assert!(c.validate().is_err());
        // only the two named policies exist
        let mut c = ServeConfig::default();
        c.preempt_policy = "drop".into();
        assert!(c.validate().is_err());
        // recompute replays the victim's tokens, so it demands greedy
        let mut c = ServeConfig::default();
        c.preempt_policy = "recompute".into();
        assert!(c.validate().is_ok());
        c.top_k = 4;
        assert!(c.validate().is_err());
        // reload has no sampling constraint
        let mut c = ServeConfig::default();
        c.top_k = 4;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn threads_resolve_explicit_over_process_default() {
        let mut c = ServeConfig::default();
        assert_eq!(c.threads, 0, "auto by default");
        // explicit widths win over the env default
        c.threads = 4;
        assert_eq!(c.resolved_threads(), 4);
        assert!(c.validate().is_ok());
        // 0 defers to the process default (serial unless BITROM_THREADS
        // is set in the environment)
        c.threads = 0;
        assert!(c.resolved_threads() >= 1);
        // old configs without the field parse to auto
        let j = Json::parse(r#"{"max_batches": 2}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().threads, 0);
    }

    #[test]
    fn json_defaults_fill_missing_kv_fields() {
        // configs written before the KV store existed still parse
        let j = Json::parse(r#"{"max_batches": 2, "max_seq": 64, "prefill_len": 16}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.kv_block_tokens, 8);
        assert_eq!(c.kv_quant_bits, 8);
        assert_eq!(c.kv_edram_bytes, 13_500_000);
    }
}
