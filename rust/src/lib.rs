//! # BitROM — weight reload-free CiROM architecture for 1.58-bit LLMs
//!
//! Full-system reproduction of *BitROM: Weight Reload-Free CiROM
//! Architecture Towards Billion-Parameter 1.58-bit LLM Inference*
//! (ASP-DAC 2026). See DESIGN.md for the system inventory and the
//! per-experiment index, EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * [`runtime`] — the backend-agnostic serving contract
//!   ([`runtime::InferenceBackend`], DESIGN.md §9) and its two
//!   implementations: the always-built offline
//!   [`runtime::HostBackend`] (BitNet-style partitioned transformer on
//!   the bitplane kernels) and the PJRT `ModelExecutor` (`pjrt`
//!   feature; AOT HLO artifacts with weights baked as constants = the
//!   ROM mask set). Manifest handling is always available. The
//!   [`runtime::ShardedBackend`] (DESIGN.md §16) splits one seeded
//!   model across N same-seed host shards — pipeline-parallel
//!   partition ownership with per-shard KV stores plus a
//!   tensor-parallel exact-i64 LM head — behind the same contract;
//!   shard count changes throughput and placement, never tokens
//!   (invariant 12).
//! * [`coordinator`] — the serving layer: dynamic batcher, the
//!   macro-partition pipeline (paper §V-B), metrics, and the
//!   [`coordinator::Server`], generic over the backend — all of it
//!   tier-1-tested offline via `Server<HostBackend>`, with shard
//!   routing left entirely to `Server<ShardedBackend>`'s backend.
//!   Token rounds run per-slot-parallel on the worker pool,
//!   bit-identically at any width (DESIGN.md §12).
//! * [`bitnet`] — ternary substrate: packed storage, quantizers, the
//!   golden `ref_gemv`, and the word-parallel [`bitnet::BitplaneMatrix`]
//!   kernel engine that every host-side functional compute path runs on.
//! * [`cirom`] — bit-accurate simulators of the paper's circuits:
//!   BiROMA, TriMLA, the shared adder tree.
//! * [`edram`] / [`dram`] / [`kvcache`] — the KV-cache layer
//!   (DESIGN.md §10): the tiered quantized [`kvcache::KvStore`] that
//!   serving's KV actually lives in, the analytic placement model, and
//!   the DR-eDRAM refresh-on-read argument checked live on every
//!   decode read.
//! * [`lora`] — the adapter layer (DESIGN.md §11): overhead
//!   accounting, the multi-tenant [`lora::AdapterRegistry`] served
//!   end-to-end by the host backend (per-sequence low-rank deltas on
//!   the bitplane base projections, reload-free task switching), and
//!   the merged-projection host compute.
//! * [`energy`] — analytical energy/area model (Table III, Fig 1a)
//!   plus the measured KV memory energy ([`energy::KvEnergy`]) and
//!   adapter task-switch energy ([`energy::AdapterEnergy`]).
//! * [`net`] — the streaming serving plane's network layer (DESIGN.md
//!   §14): std-only HTTP/1.1 front door ([`net::NetServer`]) streaming
//!   tokens as NDJSON/SSE the round they decode, the incremental-JSON
//!   [`net::jsonframe`] codec, and graceful SIGINT draining — loopback
//!   bit-identical to the offline trace twin (invariant 10).
//! * [`fault`] — the robustness layer's cause generator (DESIGN.md
//!   §13): the seeded deterministic [`fault::FaultPlan`] injecting
//!   retention-clock storms and transient backend/adapter/KV failures,
//!   consumed by the server's recovery/shedding policy (invariant 9).
//! * [`util`] — offline substrates (json, args, rng, stats, bench,
//!   property-check harness, tables, and the [`util::pool`]
//!   scoped-thread worker pool the parallel execution engine runs on).

#![warn(missing_docs)]

pub mod bitnet;
pub mod cirom;
pub mod config;
pub mod coordinator;
pub mod dram;
pub mod edram;
pub mod energy;
pub mod fault;
pub mod kvcache;
pub mod lora;
pub mod net;
pub mod report;
pub mod runtime;
pub mod trace;
pub mod util;
