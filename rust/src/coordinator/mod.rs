//! The serving coordinator — the paper's §V-B system layer, all of it
//! tier-1-tested offline.
//!
//! Requests are admitted by the dynamic batcher into one of
//! `max_batches` slots; each token round, [`PipelineSchedule::for_round`]
//! builds a skewed schedule over the *backend's* partition count (the
//! paper's deployment has 6 macro partitions, but the stage count is
//! `backend.n_partitions()`, not a constant — one partition per stage,
//! all partitions busy on different batches in the same cycle,
//! "allowing all partitions to operate in parallel and maintain full
//! macro utilization"); every KV access runs through the backend's
//! tiered [`crate::kvcache::KvStore`] (DR eDRAM or external DRAM) as
//! it happens, and the measured statistics come back in
//! [`ServeMetrics`].
//!
//! The [`Server`] is generic over [`runtime::InferenceBackend`]
//! (DESIGN.md §9): `Server<HostBackend>` runs full traces offline on
//! the bitplane kernel engine; `Server<ModelExecutor>` (`pjrt`
//! feature) executes the compiled artifacts.
//!
//! Shard routing (DESIGN.md §16): the coordinator never routes to
//! shards itself — `Server<ShardedBackend>` issues the same
//! per-partition stage calls and the backend maps each partition to
//! its owning shard (contiguous near-even `ShardPlan`), merging
//! tensor-parallel LM-head partials in exact i64. The only
//! shard-aware coordinator paths are the per-shard retention clocks
//! (a shard-targeted storm skews one shard's DR-eDRAM clock via
//! [`advance_kv_clock_shard`]) and the
//! summed per-shard KV/event/adapter accounting in [`ServeMetrics`].
//! Shard count changes throughput and placement, never tokens
//! (invariant 12).
//!
//! Two admission planes share the same round loop (DESIGN.md §14):
//! [`Server::run_trace`] consumes a closed batch offline, and
//! [`Server::run_ingress`] serves live submissions funneled through an
//! [`Ingress`] (per-tenant FIFO, token-bucket rate limits, queue-depth
//! backpressure), streaming every token through its request's
//! [`TokenSink`] the round it is produced.
//!
//! [`runtime::InferenceBackend`]: crate::runtime::InferenceBackend
//! [`advance_kv_clock_shard`]: crate::runtime::KvControl::advance_kv_clock_shard

mod batcher;
mod ingress;
mod metrics;
mod pipeline;
mod server;

pub use batcher::{Batcher, SlotState};
pub use ingress::{Ingress, Reject, TokenSink, VecSink};
pub use metrics::{FailReason, FaultMetrics, ServeMetrics, ShedRequest};
pub use pipeline::{PipelineSchedule, StageOp};
pub use server::{CompletedRequest, Server};
