//! The serving loop: batcher + pipeline schedule + PJRT execution +
//! KV-cache placement, with the eDRAM retention clock driven by real
//! wall time so the DR-eDRAM argument is live-checked on every read.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{EdramParams, ServeConfig};
use crate::kvcache::KvCacheManager;
use crate::runtime::{DecodeState, ModelExecutor, TensorF32};
use crate::trace::Request;
use crate::util::rng::Rng;

use super::batcher::{Batcher, SlotState};
use super::metrics::ServeMetrics;
use super::pipeline::PipelineSchedule;

/// A finished request with its timings.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub ttft_s: f64,
    pub finished_at_s: f64,
}

pub struct Server {
    exec: ModelExecutor,
    serve: ServeConfig,
    kv: KvCacheManager,
    rng: Rng,
}

impl Server {
    pub fn new(exec: ModelExecutor, serve: ServeConfig) -> Result<Self> {
        serve.validate()?;
        anyhow::ensure!(
            serve.prefill_len <= exec.manifest.prefill_len,
            "serve prefill_len {} exceeds artifact bucket {}",
            serve.prefill_len,
            exec.manifest.prefill_len
        );
        anyhow::ensure!(
            serve.max_seq <= exec.manifest.model.max_seq,
            "serve max_seq exceeds model max_seq"
        );
        let kv = KvCacheManager::new(&exec.manifest.model, &serve, EdramParams::default());
        Ok(Server {
            rng: Rng::new(serve.seed),
            kv,
            serve,
            exec,
        })
    }

    pub fn executor(&self) -> &ModelExecutor {
        &self.exec
    }

    pub fn kv(&self) -> &KvCacheManager {
        &self.kv
    }

    fn sample(&mut self, logits: &TensorF32) -> i32 {
        if self.serve.top_k <= 1 {
            logits.argmax() as i32
        } else {
            let cands = logits.top_k(self.serve.top_k);
            *self.rng.choice(&cands) as i32
        }
    }

    /// Run a trace to completion (continuous batching). Returns the
    /// completed requests and serving metrics.
    pub fn run_trace(&mut self, requests: Vec<Request>) -> Result<(Vec<CompletedRequest>, ServeMetrics)> {
        let n_parts = self.exec.n_partitions();
        let mut batcher = Batcher::new(self.serve.max_batches);
        for r in requests {
            anyhow::ensure!(
                r.prompt.len() <= self.serve.prefill_len,
                "request {} prompt {} exceeds prefill bucket {}",
                r.id,
                r.prompt.len(),
                self.serve.prefill_len
            );
            batcher.submit(r);
        }

        let mut states: Vec<Option<DecodeState>> = Vec::new();
        let mut last_tok: Vec<i32> = Vec::new();
        let mut last_tok_at: Vec<f64> = Vec::new();
        let mut slot_ttft: Vec<f64> = Vec::new();
        for _ in 0..self.serve.max_batches {
            states.push(None);
            last_tok.push(0);
            last_tok_at.push(0.0);
            slot_ttft.push(0.0);
        }

        let mut done = Vec::new();
        let mut metrics = ServeMetrics::new();
        let t0 = Instant::now();
        let now = |t0: &Instant| t0.elapsed().as_secs_f64();
        // The DR-eDRAM retention clock runs on *modeled hardware time*
        // (one hw_tbt per token round): the retention argument is about
        // the accelerator's cadence, not the CPU emulating it. Wall
        // time is still used for all serving metrics.
        let mut hw_time = 0.0f64;

        while !batcher.all_idle() {
            for slot in batcher.admit(now(&t0)) {
                self.kv.start_seq(slot);
                states[slot] = None;
            }
            let active = batcher.active_slots();
            if active.is_empty() {
                // waiting on a future arrival
                std::thread::sleep(std::time::Duration::from_micros(200));
                continue;
            }

            // one token round through the partition pipeline
            let sched = PipelineSchedule::for_round(&active, n_parts);
            sched
                .validate(n_parts)
                .map_err(|e| anyhow::anyhow!("pipeline invariant violated: {e}"))?;

            // per-slot hidden activations flowing between stages
            let mut hidden: Vec<Option<xla::Literal>> = (0..self.serve.max_batches)
                .map(|_| None)
                .collect();

            for op in &sched.ops {
                let slot = op.slot;
                let is_prefill =
                    batcher.slot(slot).state == SlotState::NeedsPrefill;
                if op.partition == 0 {
                    // entering the pipeline: embed
                    let h = if is_prefill {
                        let prompt = &batcher.slot(slot).request.as_ref().unwrap().prompt;
                        self.exec.embed_prompt(prompt)?
                    } else {
                        self.exec.embed_token(last_tok[slot])?
                    };
                    hidden[slot] = Some(h);
                    if states[slot].is_none() {
                        states[slot] = Some(self.exec.new_state()?);
                    }
                }
                let h_in = hidden[slot].take().expect("pipeline order broken");
                let state = states[slot].as_mut().unwrap();
                let h_out = if is_prefill {
                    self.exec.run_partition_prefill(op.partition, &h_in, state)?
                } else {
                    let pos = state.pos;
                    self.exec.run_partition_decode(op.partition, &h_in, pos, state)?
                };
                hidden[slot] = Some(h_out);
            }

            // head + sampling + KV accounting per slot
            hw_time += self.serve.hw_tbt_s; // one pipeline token round
            for &slot in &active {
                let t_now = now(&t0);
                let h = hidden[slot].take().expect("missing hidden after round");
                let state = states[slot].as_mut().unwrap();
                let is_prefill = batcher.slot(slot).state == SlotState::NeedsPrefill;
                let logits = if is_prefill {
                    let plen = batcher.slot(slot).request.as_ref().unwrap().prompt.len();
                    state.pos = plen;
                    state.prompt_len = plen;
                    self.kv.prefill(slot, plen, hw_time);
                    self.exec.head_at(&h, plen - 1)?
                } else {
                    state.pos += 1;
                    self.kv.write_token(slot, hw_time);
                    self.kv
                        .read_context(slot, hw_time)
                        .context("DR-eDRAM retention violated during decode")?;
                    self.exec.head_decode_logits(&h)?
                };
                let tok = self.sample(&logits);

                let admitted_at = batcher.slot(slot).admitted_at;
                if is_prefill {
                    slot_ttft[slot] = t_now - admitted_at;
                    metrics.record_ttft(t_now - admitted_at);
                    metrics.record_prefill(t_now - admitted_at);
                    batcher.slot_mut(slot).state = SlotState::Decoding { generated: 1 };
                } else {
                    metrics.record_tbt(t_now - last_tok_at[slot]);
                    if let SlotState::Decoding { generated } = &mut batcher.slot_mut(slot).state {
                        *generated += 1;
                    }
                }
                last_tok[slot] = tok;
                last_tok_at[slot] = t_now;
                batcher.slot_mut(slot).output.push(tok);
                metrics.tokens_out += 1;

                // completion check
                let slot_ref = batcher.slot(slot);
                let req = slot_ref.request.as_ref().unwrap();
                let produced = slot_ref.output.len();
                let out_of_room = state.pos + 1 >= self.serve.max_seq;
                if produced >= req.max_new_tokens || out_of_room {
                    let (req, tokens, admitted_at) = batcher.release(slot);
                    self.kv.end_seq(slot);
                    states[slot] = None;
                    metrics.requests_done += 1;
                    done.push(CompletedRequest {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        tokens,
                        ttft_s: slot_ttft[slot],
                        finished_at_s: t_now - admitted_at,
                    });
                }
            }
        }

        metrics.wall_s = now(&t0);
        // DR-eDRAM health postcondition (DESIGN.md invariant 5)
        anyhow::ensure!(
            self.kv.edram().retention_failures == 0,
            "retention failures occurred"
        );
        Ok((done, metrics))
    }
}
