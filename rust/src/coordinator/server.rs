//! The serving loop: batcher + pipeline schedule + backend execution +
//! KV-cache placement, with the eDRAM retention clock driven by modeled
//! hardware time so the DR-eDRAM argument is live-checked on every
//! read. Generic over [`InferenceBackend`] — the same loop serves the
//! PJRT artifact runtime and the offline host transformer.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{EdramParams, ServeConfig};
use crate::kvcache::KvCacheManager;
use crate::runtime::{InferenceBackend, Logits, SequenceState};
use crate::trace::Request;
use crate::util::rng::Rng;

use super::batcher::{Batcher, SlotState};
use super::metrics::ServeMetrics;
use super::pipeline::PipelineSchedule;

/// A finished request with its timings.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Admission-to-first-token (s).
    pub ttft_s: f64,
    /// Admission-to-last-token latency (s).
    pub latency_s: f64,
}

pub struct Server<B: InferenceBackend> {
    backend: B,
    serve: ServeConfig,
    kv: KvCacheManager,
    rng: Rng,
}

impl<B: InferenceBackend> Server<B> {
    pub fn new(backend: B, serve: ServeConfig) -> Result<Self> {
        serve.validate()?;
        anyhow::ensure!(
            serve.prefill_len <= backend.prefill_len(),
            "serve prefill_len {} exceeds backend prompt bucket {}",
            serve.prefill_len,
            backend.prefill_len()
        );
        anyhow::ensure!(
            serve.max_seq <= backend.model().max_seq,
            "serve max_seq exceeds model max_seq"
        );
        let kv = KvCacheManager::new(backend.model(), &serve, EdramParams::default());
        Ok(Server {
            rng: Rng::new(serve.seed),
            kv,
            serve,
            backend,
        })
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn kv(&self) -> &KvCacheManager {
        &self.kv
    }

    fn sample(&mut self, logits: &Logits) -> i32 {
        if self.serve.top_k <= 1 {
            logits.argmax() as i32
        } else {
            let cands = logits.top_k(self.serve.top_k);
            *self.rng.choice(&cands) as i32
        }
    }

    /// Run a trace to completion (continuous batching). Returns the
    /// completed requests and serving metrics.
    pub fn run_trace(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<(Vec<CompletedRequest>, ServeMetrics)> {
        let n_parts = self.backend.n_partitions();
        let mut batcher = Batcher::new(self.serve.max_batches);
        for r in requests {
            anyhow::ensure!(
                r.prompt.len() <= self.serve.prefill_len,
                "request {} prompt {} exceeds prefill bucket {}",
                r.id,
                r.prompt.len(),
                self.serve.prefill_len
            );
            batcher.submit(r);
        }

        let mut states: Vec<Option<B::State>> = Vec::new();
        let mut last_tok: Vec<i32> = Vec::new();
        let mut last_tok_at: Vec<f64> = Vec::new();
        let mut slot_ttft: Vec<f64> = Vec::new();
        // Backend execution time accumulated for the slot's current
        // token (embed + every partition stage + head) — what
        // prefill/decode compute metrics record, as opposed to the
        // queue wait that TTFT measures.
        let mut slot_compute: Vec<f64> = Vec::new();
        for _ in 0..self.serve.max_batches {
            states.push(None);
            last_tok.push(0);
            last_tok_at.push(0.0);
            slot_ttft.push(0.0);
            slot_compute.push(0.0);
        }

        let mut done = Vec::new();
        let mut metrics = ServeMetrics::new();
        let t0 = Instant::now();
        // The serving clock is wall time plus any idle skip: an offline
        // backend (realtime() == false) jumps straight over gaps before
        // the next queued arrival instead of sleeping through sparse
        // traces; a realtime backend sleeps so arrivals stay
        // wall-clock-true.
        let mut skipped_s = 0.0f64;
        let now = |skipped: f64| t0.elapsed().as_secs_f64() + skipped;
        // The DR-eDRAM retention clock runs on *modeled hardware time*
        // (one hw_tbt per token round): the retention argument is about
        // the accelerator's cadence, not the CPU emulating it. The
        // serving clock is still used for all latency metrics.
        let mut hw_time = 0.0f64;

        while !batcher.all_idle() {
            for slot in batcher.admit(now(skipped_s)) {
                self.kv.start_seq(slot);
                states[slot] = None;
                slot_compute[slot] = 0.0;
            }
            let active = batcher.active_slots();
            if active.is_empty() {
                // waiting on a future arrival: sleep (realtime) or skip
                // the clock ahead (offline) — never busy-wait
                let next = batcher
                    .next_arrival()
                    .context("no active slots and nothing queued")?;
                let t_now = now(skipped_s);
                if next > t_now {
                    if self.backend.realtime() {
                        let nap = (next - t_now).min(0.01);
                        std::thread::sleep(std::time::Duration::from_secs_f64(nap));
                    } else {
                        skipped_s += next - t_now;
                    }
                }
                continue;
            }

            // one token round through the partition pipeline
            let sched = PipelineSchedule::for_round(&active, n_parts);
            sched
                .validate(n_parts)
                .map_err(|e| anyhow::anyhow!("pipeline invariant violated: {e}"))?;

            // per-slot hidden activations flowing between stages
            let mut hidden: Vec<Option<B::Hidden>> =
                (0..self.serve.max_batches).map(|_| None).collect();

            for op in &sched.ops {
                let slot = op.slot;
                let is_prefill = batcher.slot(slot).state == SlotState::NeedsPrefill;
                let t_op = Instant::now();
                if op.partition == 0 {
                    // entering the pipeline: embed
                    let h = if is_prefill {
                        let prompt = &batcher.slot(slot).request.as_ref().unwrap().prompt;
                        self.backend.embed_prompt(prompt)?
                    } else {
                        self.backend.embed_token(last_tok[slot])?
                    };
                    hidden[slot] = Some(h);
                    if states[slot].is_none() {
                        states[slot] = Some(self.backend.new_state()?);
                    }
                }
                let h_in = hidden[slot].take().expect("pipeline order broken");
                let state = states[slot].as_mut().unwrap();
                let h_out = if is_prefill {
                    self.backend.run_partition_prefill(op.partition, &h_in, state)?
                } else {
                    let pos = state.pos();
                    self.backend.run_partition_decode(op.partition, &h_in, pos, state)?
                };
                hidden[slot] = Some(h_out);
                slot_compute[slot] += t_op.elapsed().as_secs_f64();
            }

            // head + sampling + KV accounting per slot
            hw_time += self.serve.hw_tbt_s; // one pipeline token round
            for &slot in &active {
                let h = hidden[slot].take().expect("missing hidden after round");
                let state = states[slot].as_mut().unwrap();
                let is_prefill = batcher.slot(slot).state == SlotState::NeedsPrefill;
                // KV accounting runs outside the compute timers: only
                // backend execution is billed to prefill/decode compute
                let logits = if is_prefill {
                    let plen = batcher.slot(slot).request.as_ref().unwrap().prompt.len();
                    state.set_pos(plen);
                    state.set_prompt_len(plen);
                    self.kv.prefill(slot, plen, hw_time);
                    let t_head = Instant::now();
                    let l = self.backend.head_at(&h, plen - 1)?;
                    slot_compute[slot] += t_head.elapsed().as_secs_f64();
                    l
                } else {
                    state.set_pos(state.pos() + 1);
                    self.kv.write_token(slot, hw_time);
                    self.kv
                        .read_context(slot, hw_time)
                        .context("DR-eDRAM retention violated during decode")?;
                    let t_head = Instant::now();
                    let l = self.backend.head_decode_logits(&h)?;
                    slot_compute[slot] += t_head.elapsed().as_secs_f64();
                    l
                };
                let tok = self.sample(&logits);
                let t_now = now(skipped_s);

                let admitted_at = batcher.slot(slot).admitted_at;
                if is_prefill {
                    slot_ttft[slot] = t_now - admitted_at;
                    metrics.record_ttft(t_now - admitted_at);
                    // actual prefill execution time, not the queue wait
                    metrics.record_prefill(slot_compute[slot]);
                    batcher.slot_mut(slot).state = SlotState::Decoding { generated: 1 };
                } else {
                    metrics.record_tbt(t_now - last_tok_at[slot]);
                    metrics.record_decode(slot_compute[slot]);
                    if let SlotState::Decoding { generated } = &mut batcher.slot_mut(slot).state {
                        *generated += 1;
                    }
                }
                slot_compute[slot] = 0.0;
                last_tok[slot] = tok;
                last_tok_at[slot] = t_now;
                batcher.slot_mut(slot).output.push(tok);
                metrics.tokens_out += 1;

                // completion check
                let slot_ref = batcher.slot(slot);
                let req = slot_ref.request.as_ref().unwrap();
                let produced = slot_ref.output.len();
                let out_of_room = state.pos() + 1 >= self.serve.max_seq;
                if produced >= req.max_new_tokens || out_of_room {
                    let (req, tokens, admitted_at) = batcher.release(slot);
                    self.kv.end_seq(slot);
                    states[slot] = None;
                    metrics.requests_done += 1;
                    done.push(CompletedRequest {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        tokens,
                        ttft_s: slot_ttft[slot],
                        latency_s: t_now - admitted_at,
                    });
                }
            }
        }

        metrics.wall_s = now(skipped_s);
        // DR-eDRAM health postcondition (DESIGN.md invariant 5)
        anyhow::ensure!(
            self.kv.edram().retention_failures == 0,
            "retention failures occurred"
        );
        Ok((done, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::runtime::HostBackend;

    fn micro() -> ModelConfig {
        ModelConfig {
            name: "host-micro".into(),
            n_layers: 2,
            d_model: 32,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 64,
            vocab_size: 64,
            max_seq: 32,
            n_partitions: 2,
            act_bits: 8,
        }
    }

    #[test]
    fn rejects_serve_config_exceeding_backend_limits() {
        let serve = ServeConfig {
            prefill_len: 64,
            max_seq: 128,
            ondie_tokens: 16,
            ..ServeConfig::default()
        };
        // micro model has max_seq 32 < serve.max_seq 128
        let backend = HostBackend::new(micro(), 1).unwrap();
        assert!(Server::new(backend, serve).is_err());
    }

    #[test]
    fn closed_batch_trace_completes_on_host_backend() {
        let backend = HostBackend::new(micro(), 2).unwrap();
        let serve = ServeConfig {
            max_batches: 2,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0,
                prompt: vec![1 + i as i32, 2, 3],
                max_new_tokens: 4,
            })
            .collect();
        let (done, mut metrics) = server.run_trace(reqs).unwrap();
        assert_eq!(done.len(), 3);
        for r in &done {
            assert_eq!(r.tokens.len(), 4);
            assert!(r.latency_s >= r.ttft_s);
        }
        assert_eq!(metrics.requests_done, 3);
        assert_eq!(metrics.tokens_out, 12);
        assert!(metrics.prefill_time.count() == 3);
        assert!(metrics.tokens_per_s() > 0.0);
        assert_eq!(server.kv().edram().retention_failures, 0);
    }
}
