//! The serving loop: batcher + pipeline schedule + backend execution,
//! with the backend's tiered KV store driven by modeled hardware time
//! so the DR-eDRAM retention argument is live-checked on every decode
//! read. Generic over [`InferenceBackend`] — the same loop serves the
//! PJRT artifact runtime and the offline host transformer.
//!
//! Execution is parallel per token round (DESIGN.md §12): each active
//! slot's chain of pipeline stages (embed → partitions 0..P−1) runs as
//! one unit of work on the worker pool — the software twin of the
//! hardware pipeline's skewed lanes, which likewise never share a
//! sequence between stages concurrently. When every slot in a round is
//! decoding and `ServeConfig::fused_decode` is on (the default), the
//! coordinator instead walks the partition chain once with the whole
//! batch via [`InferenceBackend::run_partition_decode_batch`], so each
//! projection site runs one bitplane GEMM for all slots (DESIGN.md
//! §17) — bit-identical to the per-slot path because exact integer
//! GEMM rows are independent. Everything order-sensitive
//! stays on the coordinator thread: admission, state creation and
//! adapter binding, KV page *allocation* (via
//! [`KvControl::reserve_kv`], in slot order, so shared-tier
//! placement is deterministic), the retention clock, sampling (a
//! per-request Rng derived from the serve seed and the request id, so
//! one request's token stream is independent of batching and arrival
//! order), and metrics. Served tokens and all merged counters are
//! therefore bit-identical at any `ServeConfig::threads` width.
//!
//! The same round loop serves two admission planes (DESIGN.md §14):
//! [`Server::run_trace`] consumes a closed batch of requests up front
//! (the deterministic offline twin), and [`Server::run_ingress`] pulls
//! live submissions from a shared [`Ingress`] between rounds, pushing
//! each decoded token through the request's [`TokenSink`] the moment
//! its round completes. Per-request sampling streams make the two
//! planes bit-identical on the same request set (invariant 10).
//!
//! Survivability (DESIGN.md §13, invariant 9): with a seeded
//! [`FaultPlan`] and/or the degradation knobs active, the loop gates
//! admission on measured KV pressure, preempts the youngest slot's KV
//! to the external tier under pressure, retries transiently-faulted
//! slots with bounded backoff, recovers retention-expired sequences by
//! recomputing them (bit-identical by invariant 4), and sheds what it
//! cannot recover with a typed [`FailReason`] — never a panic. All of
//! it is coordinator-side and keyed off round indices and the plan's
//! fixed draw schedule, so faulted runs are as deterministic as
//! fault-free ones. With every knob at its default the loop is
//! byte-identical to a build without the fault module.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::bitnet::KernelPath;
use crate::config::ServeConfig;
use crate::fault::{FaultKind, FaultPlan};
use crate::kvcache::{KvError, KvStoreStats};
use crate::lora::LoraServeStats;
use crate::runtime::{
    DecodeEntry, InferenceBackend, KvControl, Logits, SequenceState, ServeTuning,
};
use crate::trace::Request;
use crate::util::pool::Pool;
use crate::util::rng::Rng;

use super::batcher::{Batcher, SlotState};
use super::ingress::{Ingress, TokenSink};
use super::metrics::{FailReason, ServeMetrics, ShedRequest};
use super::pipeline::PipelineSchedule;

/// The typed shed reason an injected transient fault escalates to when
/// its retry budget runs out.
fn fail_reason(kind: FaultKind) -> FailReason {
    match kind {
        FaultKind::Backend => FailReason::Backend,
        FaultKind::AdapterLoad => FailReason::AdapterLoad,
        FaultKind::KvExhausted => FailReason::KvCapacity,
    }
}

/// A finished request with its timings.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    /// Request id from the trace.
    pub id: u64,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Tenant adapter the request decoded under (`None` = base model).
    pub adapter_id: Option<u32>,
    /// Generated token ids.
    pub tokens: Vec<i32>,
    /// Admission-to-first-token (s).
    pub ttft_s: f64,
    /// Admission-to-last-token latency (s).
    pub latency_s: f64,
}

/// The serving coordinator: owns a backend and runs request traces
/// through continuous batching + the partition pipeline. KV placement,
/// quantization and retention checking happen inside the backend's
/// [`crate::kvcache::KvStore`] (configured here from the
/// [`ServeConfig`]); the server reads the measured statistics back
/// into [`ServeMetrics`].
pub struct Server<B: InferenceBackend> {
    backend: B,
    serve: ServeConfig,
}

/// Live-admission context threaded through the serving loop by
/// [`Server::run_ingress`]: the shared ingress, the per-request token
/// sinks, and an optional published metrics snapshot for scrapers.
struct LiveCtx {
    ingress: Arc<Ingress>,
    publish: Option<Arc<Mutex<ServeMetrics>>>,
    sinks: BTreeMap<u64, Box<dyn TokenSink>>,
}

impl LiveCtx {
    /// Notify a request's sink of its typed shed and free its id.
    fn shed(&mut self, id: u64, reason: FailReason) {
        if let Some(mut sink) = self.sinks.remove(&id) {
            sink.on_shed(id, reason);
        }
        self.ingress.retire(id);
    }
}

/// The per-request sampling stream: keyed off the serve seed and the
/// request id alone, so a request's sampled tokens are independent of
/// batching, arrival order, and transport — the hinge of invariant 10
/// (HTTP-streamed tokens ≡ the offline trace twin) under top-k.
fn request_rng(seed: u64, id: u64) -> Rng {
    Rng::new(seed ^ id.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

impl<B: InferenceBackend> Server<B> {
    /// Validate `serve` against the backend's limits and build the
    /// server (this sizes the backend's KV store for the deployment
    /// via [`KvControl::configure_kv`]).
    pub fn new(backend: B, serve: ServeConfig) -> Result<Self> {
        serve.validate()?;
        anyhow::ensure!(
            serve.prefill_len <= backend.prefill_len(),
            "serve prefill_len {} exceeds backend prompt bucket {}",
            serve.prefill_len,
            backend.prefill_len()
        );
        anyhow::ensure!(
            serve.max_seq <= backend.model().max_seq,
            "serve max_seq exceeds model max_seq"
        );
        backend.configure_kv(&serve)?;
        // one width for the whole engine: the server's per-slot rounds
        // and the backend's sharded kernels (1 = the serial path)
        backend.set_threads(serve.resolved_threads());
        // ... and one kernel path, validated above — engine choice
        // changes throughput, never tokens (DESIGN.md §17)
        let path = KernelPath::parse(&serve.kernel_path)
            .expect("validate() accepted the kernel_path");
        backend.set_kernel_path(path);
        Ok(Server { serve, backend })
    }

    /// The worker-pool width this server executes rounds at.
    pub fn threads(&self) -> usize {
        self.serve.resolved_threads()
    }

    /// The backend this server schedules onto.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Measured KV-tier statistics so far (None for backends with
    /// opaque device-side KV).
    pub fn kv_stats(&self) -> Option<KvStoreStats> {
        self.backend.kv_stats()
    }

    /// Measured adapter-serving statistics so far (None for backends
    /// without an adapter registry).
    pub fn lora_stats(&self) -> Option<LoraServeStats> {
        self.backend.lora_stats()
    }

    /// Sample the next token for one slot. Greedy (`top_k <= 1`) needs
    /// no randomness; top-k draws from the slot's per-request stream.
    fn sample(&self, rng: Option<&mut Rng>, logits: &Logits) -> i32 {
        if self.serve.top_k <= 1 {
            logits.argmax() as i32
        } else {
            let cands = logits.top_k(self.serve.top_k);
            *rng.expect("top-k sampling carries a per-request rng").choice(&cands) as i32
        }
    }

    /// Run a trace to completion (continuous batching). Returns the
    /// completed requests and serving metrics.
    ///
    /// Rounds execute across the deployment's worker pool (module
    /// docs); `Sync`/`Send` bounds let workers borrow the backend and
    /// take exclusive `&mut` access to their slot's state.
    pub fn run_trace(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<(Vec<CompletedRequest>, ServeMetrics)>
    where
        B: Sync,
        B::State: Send,
        B::Hidden: Send,
    {
        let mut batcher = Batcher::new(self.serve.max_batches);
        for r in requests {
            anyhow::ensure!(
                r.prompt.len() <= self.serve.prefill_len,
                "request {} prompt {} exceeds prefill bucket {}",
                r.id,
                r.prompt.len(),
                self.serve.prefill_len
            );
            batcher.submit(r);
        }
        self.serve_loop(batcher, None)
    }

    /// Serve live submissions from `ingress` until it is shut down and
    /// drained (the streaming plane's coordinator loop — DESIGN.md
    /// §14). Requests pulled between decode rounds join the same
    /// continuous batcher as trace requests; every decoded token is
    /// pushed through the request's [`TokenSink`] the round it is
    /// produced. When `publish` is given, a metrics snapshot is
    /// refreshed there every round for `/metrics` scrapers.
    ///
    /// Submissions exceeding the batcher's prompt bucket must be
    /// rejected at the edge (configure the [`Ingress`] prompt cap to
    /// `ServeConfig::prefill_len`); an oversized request that reaches
    /// the backend fails the whole loop, exactly like a malformed
    /// offline trace.
    pub fn run_ingress(
        &mut self,
        ingress: Arc<Ingress>,
        publish: Option<Arc<Mutex<ServeMetrics>>>,
    ) -> Result<(Vec<CompletedRequest>, ServeMetrics)>
    where
        B: Sync,
        B::State: Send,
        B::Hidden: Send,
    {
        let batcher = Batcher::new(self.serve.max_batches);
        self.serve_loop(
            batcher,
            Some(LiveCtx {
                ingress,
                publish,
                sinks: BTreeMap::new(),
            }),
        )
    }

    /// The round loop shared by both admission planes: `live` is `None`
    /// for a closed-batch trace and carries the ingress + sinks for
    /// online serving.
    fn serve_loop(
        &mut self,
        mut batcher: Batcher,
        mut live: Option<LiveCtx>,
    ) -> Result<(Vec<CompletedRequest>, ServeMetrics)>
    where
        B: Sync,
        B::State: Send,
        B::Hidden: Send,
    {
        let n_parts = self.backend.n_partitions();
        let pool = Pool::new(self.serve.resolved_threads());
        // live serving runs on the wall clock even for offline
        // backends: submitters time-stamp against it, so the loop must
        // never skip ahead of them
        let realtime = self.backend.realtime() || live.is_some();

        let mut states: Vec<Option<B::State>> = Vec::new();
        let mut last_tok: Vec<i32> = Vec::new();
        let mut last_tok_at: Vec<f64> = Vec::new();
        let mut slot_ttft: Vec<f64> = Vec::new();
        // Backend execution time accumulated for the slot's current
        // token (embed + every partition stage + head) — what
        // prefill/decode compute metrics record, as opposed to the
        // queue wait that TTFT measures.
        let mut slot_compute: Vec<f64> = Vec::new();
        for _ in 0..self.serve.max_batches {
            states.push(None);
            last_tok.push(0);
            last_tok_at.push(0.0);
            slot_ttft.push(0.0);
            slot_compute.push(0.0);
        }
        // Survivability bookkeeping (DESIGN.md §13) — all per-request,
        // reset at admission. `backoff_until` and the retry/recompute
        // budgets are indexed by the coordinator's round counter, never
        // wall time, so faulted schedules replay deterministically.
        let mut retries: Vec<usize> = vec![0; self.serve.max_batches];
        let mut recomputes_used: Vec<usize> = vec![0; self.serve.max_batches];
        let mut backoff_until: Vec<u64> = vec![0; self.serve.max_batches];
        let mut admit_seq: Vec<u64> = vec![0; self.serve.max_batches];
        // preempted under the recompute policy: KV dropped, rebuild
        // from prompt + emitted tokens before the slot next runs
        let mut needs_replay: Vec<bool> = vec![false; self.serve.max_batches];
        // prompt tokens satisfied by a shared-prefix bind (0 = none):
        // the worker prefills only the unshared tail
        let mut bound_prefix: Vec<usize> = vec![0; self.serve.max_batches];
        // round-indexed virtual time per slot: the round the request was
        // admitted and the round of its latest token, for the
        // wall-clock-free TTFT/TBT percentiles
        let mut admit_round: Vec<u64> = vec![0; self.serve.max_batches];
        let mut last_tok_round: Vec<u64> = vec![0; self.serve.max_batches];
        // per-request top-k sampling streams (None under greedy)
        let mut slot_rng: Vec<Option<Rng>> = (0..self.serve.max_batches).map(|_| None).collect();
        let mut admit_counter: u64 = 0;
        let mut round_no: u64 = 0;
        let mut plan = FaultPlan::from_serve(&self.serve);

        let mut done = Vec::new();
        let mut metrics = ServeMetrics::new();
        // baselines so metrics.kv / metrics.lora report THIS trace's
        // traffic even if the same server runs multiple traces
        // (store and registry counters are lifetime-accumulated)
        let kv_baseline = self.backend.kv_stats();
        let lora_baseline = self.backend.lora_stats();
        let t0 = Instant::now();
        // The serving clock is wall time plus any idle skip: an offline
        // backend (realtime() == false) jumps straight over gaps before
        // the next queued arrival instead of sleeping through sparse
        // traces; a realtime backend sleeps so arrivals stay
        // wall-clock-true.
        let mut skipped_s = 0.0f64;
        let now = |skipped: f64| t0.elapsed().as_secs_f64() + skipped;
        // The DR-eDRAM retention clock runs on *modeled hardware time*
        // (one hw_tbt per token round): the retention argument is about
        // the accelerator's cadence, not the CPU emulating it. The
        // serving clock is still used for all latency metrics.
        let mut hw_time = 0.0f64;
        // Shard-local storm skew (DESIGN.md §16): a storm targeting one
        // shard advances only that shard's retention clock, so each
        // shard's clock is the global hw_time plus its accumulated
        // local skips. Single-shard deployments never touch this and
        // keep the exact legacy clock path.
        let mut shard_extra_s = vec![0.0f64; self.backend.n_shards()];

        loop {
            let t_now = now(skipped_s);
            // live admission edge: account edge rejections, drain the
            // ingress on shutdown, otherwise pull enough submissions to
            // keep the batcher's own queue within one slot-set (the
            // real backlog — and the 429 backpressure — lives in the
            // ingress, bounded by its max_queue)
            if let Some(ctx) = live.as_mut() {
                for s in ctx.ingress.drain_rejected() {
                    metrics.faults.shed.push(s);
                }
                if ctx.ingress.is_shutdown() {
                    for (req, mut sink) in ctx.ingress.drain_all() {
                        sink.on_shed(req.id, FailReason::Shutdown);
                        metrics.faults.shed.push(ShedRequest {
                            id: req.id,
                            reason: FailReason::Shutdown,
                        });
                        ctx.ingress.retire(req.id);
                    }
                } else {
                    let room = self.serve.max_batches.saturating_sub(batcher.queued());
                    for (mut req, sink) in ctx.ingress.pull(room) {
                        req.arrival_s = t_now;
                        ctx.sinks.insert(req.id, sink);
                        batcher.submit(req);
                    }
                }
            }
            if batcher.all_idle() {
                match &live {
                    // a trace runs to completion of its closed batch
                    None => break,
                    Some(ctx) => {
                        if ctx.ingress.is_shutdown() && ctx.ingress.queued_len() == 0 {
                            break;
                        }
                        // live and idle: wait for the next submission
                        std::thread::sleep(std::time::Duration::from_millis(1));
                        continue;
                    }
                }
            }
            // overload shedding: queued requests past their deadline
            // leave with a typed reason instead of waiting forever
            // (off at the default shed_after_s == 0)
            if self.serve.shed_after_s > 0.0 {
                for r in batcher.drop_queued_older_than(t_now, self.serve.shed_after_s) {
                    metrics.faults.shed.push(ShedRequest {
                        id: r.id,
                        reason: FailReason::Overload,
                    });
                    if let Some(ctx) = live.as_mut() {
                        ctx.shed(r.id, FailReason::Overload);
                    }
                }
                // shedding may have drained the system entirely
                if batcher.all_idle() && live.is_none() {
                    break;
                }
                if batcher.all_idle() {
                    continue;
                }
            }
            // admission, gated on measured KV pressure when the knob is
            // set — but never deferred when every slot is free, or a
            // full store could deadlock the queue
            let gate_admission = self.serve.admit_pressure > 0.0
                && self.kv_pressure() >= self.serve.admit_pressure
                && !batcher.active_slots().is_empty();
            if gate_admission {
                if batcher.next_arrival().is_some_and(|a| a <= t_now) {
                    metrics.faults.admission_deferrals += 1;
                }
            } else {
                for slot in batcher.admit(t_now) {
                    states[slot] = None;
                    slot_compute[slot] = 0.0;
                    retries[slot] = 0;
                    recomputes_used[slot] = 0;
                    backoff_until[slot] = 0;
                    needs_replay[slot] = false;
                    bound_prefix[slot] = 0;
                    admit_counter += 1;
                    admit_seq[slot] = admit_counter;
                    admit_round[slot] = round_no;
                    last_tok_round[slot] = round_no;
                    let id = batcher.slot(slot).request.as_ref().unwrap().id;
                    slot_rng[slot] = if self.serve.top_k > 1 {
                        Some(request_rng(self.serve.seed, id))
                    } else {
                        None
                    };
                }
            }
            let active = batcher.active_slots();
            if active.is_empty() {
                // waiting on a future arrival: sleep (realtime) or skip
                // the clock ahead (offline) — never busy-wait
                let next = batcher
                    .next_arrival()
                    .context("no active slots and nothing queued")?;
                let t_now = now(skipped_s);
                if next > t_now {
                    if realtime {
                        let nap = (next - t_now).min(0.01);
                        std::thread::sleep(std::time::Duration::from_secs_f64(nap));
                    } else {
                        skipped_s += next - t_now;
                    }
                }
                continue;
            }

            // preemption under pressure: the victim is the lowest
            // priority class among active slots, youngest admission
            // breaking ties — with every request at the default class
            // this is exactly the old youngest-slot choice, so the
            // priority field is invisible until someone sets it. The
            // policy knob picks what happens to the victim's KV:
            // `reload` (default) demotes it to the external DRAM tier
            // (invariant 6: tier placement never changes numerics, so
            // the sequence keeps decoding from external rows —
            // reload-free, no recompute); `recompute` drops the KV
            // entirely — every page frees *now* — and rebuilds it from
            // the prompt + emitted tokens before the slot next runs
            // (bit-identical by invariant 4, trading compute for
            // memory). Either way tokens never change — invariant 11.
            if self.serve.preempt_under_pressure
                && batcher.queued() > 0
                && self.kv_pressure() >= self.serve.admit_pressure
            {
                let victim = active.iter().copied().min_by_key(|&s| {
                    let class = batcher.slot(s).request.as_ref().map_or(0, |r| r.priority);
                    (class, std::cmp::Reverse(admit_seq[s]))
                });
                if let Some(v) = victim {
                    if self.serve.preempt_policy == "recompute" {
                        // only a decoding slot holds rebuildable KV; a
                        // not-yet-prefilled one has nothing to drop
                        if matches!(batcher.slot(v).state, SlotState::Decoding { .. })
                            && states[v].is_some()
                        {
                            states[v] = None;
                            needs_replay[v] = true;
                            metrics.faults.preemptions += 1;
                        }
                    } else if let Some(state) = states[v].as_mut() {
                        let demoted = self.backend.swap_out_kv(state)?;
                        if demoted > 0 {
                            metrics.faults.preemptions += 1;
                            metrics.faults.demoted_blocks += demoted;
                        }
                    }
                }
            }

            // draw this round's fault schedule (a fixed number of Rng
            // draws per round, so the schedule depends only on the seed
            // and the round index — DESIGN.md §13)
            round_no += 1;
            let round_faults = plan.as_mut().map(|p| p.next_round());

            // injected transient faults and backoff: a faulted slot
            // skips the round *before* any state mutation (so the retry
            // is safe), with exponentially growing round waits; past
            // retry_max it is shed with the fault's typed reason
            let mut runnable: Vec<usize> = Vec::with_capacity(active.len());
            match &round_faults {
                None => runnable.extend_from_slice(&active),
                Some(f) => {
                    let mut shed_now: Vec<(usize, FailReason)> = Vec::new();
                    for &slot in &active {
                        if backoff_until[slot] > round_no {
                            continue;
                        }
                        match f.transient.get(slot).copied().flatten() {
                            None => runnable.push(slot),
                            Some(kind) => {
                                metrics.faults.injected_transients += 1;
                                if retries[slot] >= self.serve.retry_max {
                                    shed_now.push((slot, fail_reason(kind)));
                                } else {
                                    retries[slot] += 1;
                                    metrics.faults.retries += 1;
                                    let wait = 1u64 << ((retries[slot] - 1).min(3) as u32);
                                    backoff_until[slot] = round_no + wait;
                                }
                            }
                        }
                    }
                    for (slot, reason) in shed_now {
                        let (req, _, _) = batcher.release(slot);
                        states[slot] = None;
                        metrics.faults.shed.push(ShedRequest { id: req.id, reason });
                        if let Some(ctx) = live.as_mut() {
                            ctx.shed(req.id, reason);
                        }
                    }
                }
            }

            // one token round through the partition pipeline; the
            // schedule models the hardware's skewed lanes and is still
            // validated every round — execution collapses each lane's
            // stage chain onto one pool worker (module docs)
            if !runnable.is_empty() {
                let sched = PipelineSchedule::for_round(&runnable, n_parts);
                sched
                    .validate(n_parts)
                    .map_err(|e| anyhow::anyhow!("pipeline invariant violated: {e}"))?;
            }

            // advance the retention clock before the round's KV
            // accesses: one hw_tbt per pipeline token round, plus any
            // injected retention-storm skip (DR-eDRAM clock gap)
            hw_time += self.serve.hw_tbt_s;
            if let Some(f) = &round_faults {
                if f.clock_skip_s > 0.0 {
                    match f.storm_shard {
                        // shard-local storm: skew only the target
                        // shard's clock (sharded deployments only)
                        Some(s) if s < shard_extra_s.len() && shard_extra_s.len() > 1 => {
                            shard_extra_s[s] += f.clock_skip_s
                        }
                        _ => hw_time += f.clock_skip_s,
                    }
                    metrics.faults.injected_skips += 1;
                }
            }
            if shard_extra_s.len() <= 1 {
                self.backend.advance_kv_clock(hw_time);
            } else {
                for (s, extra) in shard_extra_s.iter().enumerate() {
                    self.backend.advance_kv_clock_shard(s, hw_time + extra);
                }
            }

            // coordinator-side, in slot order (deterministic at any
            // pool width): create + bind fresh prefill states (shared
            // prefixes bound here, before reservation, so the reserve
            // covers only the unshared tail), rebuild recompute-
            // preempted states, then reserve the round's KV pages so
            // tier placement never depends on worker interleaving
            for &slot in &runnable {
                let is_prefill = batcher.slot(slot).state == SlotState::NeedsPrefill;
                if is_prefill && states[slot].is_none() {
                    let mut state = self.backend.new_state()?;
                    // bind the request's tenant adapter before any
                    // partition runs: the adapter shapes every
                    // projection of the sequence, prefill included
                    let adapter = batcher.slot(slot).request.as_ref().unwrap().adapter_id;
                    self.backend.bind_adapter(&mut state, adapter)?;
                    // bind the longest published shared prefix into
                    // the fresh sequence — a recovery re-prefill never
                    // binds (the shared block may be what expired; a
                    // private rebuild is what breaks the loop)
                    bound_prefix[slot] = 0;
                    if self.serve.prefix_cache && recomputes_used[slot] == 0 {
                        let prompt = &batcher.slot(slot).request.as_ref().unwrap().prompt;
                        bound_prefix[slot] = self.backend.bind_prefix_kv(&mut state, prompt)?;
                    }
                    states[slot] = Some(state);
                }
                if !is_prefill && states[slot].is_none() && needs_replay[slot] {
                    // recompute-policy preemption dropped this KV: one
                    // prefill-shaped pass over the prompt + all emitted
                    // tokens but the last rebuilds it (invariant 4 ⇒
                    // bit-identical rows; the preemption budget is
                    // separate from the fault retry budget)
                    let sref = batcher.slot(slot);
                    let req = sref.request.as_ref().expect("active slot has a request");
                    let out = &sref.output;
                    let replay: Vec<i32> = req
                        .prompt
                        .iter()
                        .chain(out[..out.len() - 1].iter())
                        .copied()
                        .collect();
                    let plen = req.prompt.len();
                    let adapter = req.adapter_id;
                    let mut st = self.backend.new_state()?;
                    self.backend.bind_adapter(&mut st, adapter)?;
                    self.backend.reserve_kv(&mut st, replay.len())?;
                    run_slot_round(&self.backend, n_parts, Some(&replay), 0, &mut st)?;
                    st.set_pos(replay.len());
                    st.set_prompt_len(plen);
                    states[slot] = Some(st);
                    needs_replay[slot] = false;
                    metrics.faults.recomputes += 1;
                    metrics.faults.recomputed_tokens += replay.len() as u64;
                }
                let n_tokens = if is_prefill {
                    batcher.slot(slot).request.as_ref().unwrap().prompt.len() - bound_prefix[slot]
                } else {
                    1
                };
                self.backend.reserve_kv(states[slot].as_mut().unwrap(), n_tokens)?;
            }

            // round execution (embed + every partition stage). An
            // all-decode round under `fused_decode` walks the partition
            // chain once with the whole batch — one bitplane GEMM per
            // projection site (DESIGN.md §17); any round containing a
            // prefill, and every round with fusion off, runs per slot
            // across the pool with each worker owning its slot's state.
            // Both paths produce bit-identical hiddens and errors.
            let backend = &self.backend;
            let batcher_ref = &batcher;
            let bound_ref = &bound_prefix;
            let items: Vec<(usize, &mut B::State)> = states
                .iter_mut()
                .enumerate()
                .filter(|(slot, s)| runnable.contains(slot) && s.is_some())
                .map(|(slot, s)| (slot, s.as_mut().unwrap()))
                .collect();
            let all_decode = !items.is_empty()
                && items
                    .iter()
                    .all(|(slot, _)| batcher_ref.slot(*slot).state != SlotState::NeedsPrefill);
            let round: Vec<(usize, Result<B::Hidden>, f64)> =
                if self.serve.fused_decode && all_decode {
                    let batch: Vec<(usize, i32, &mut B::State)> = items
                        .into_iter()
                        .map(|(slot, state)| (slot, last_tok[slot], state))
                        .collect();
                    run_decode_round_fused(backend, n_parts, batch)
                } else {
                    pool.map(items, |(slot, state)| {
                        let t_op = Instant::now();
                        let sref = batcher_ref.slot(slot);
                        let prompt = if sref.state == SlotState::NeedsPrefill {
                            // a bound shared prefix is already in the block
                            // tables: prefill only the unshared tail
                            Some(&sref.request.as_ref().unwrap().prompt[bound_ref[slot]..])
                        } else {
                            None
                        };
                        let h = run_slot_round(backend, n_parts, prompt, last_tok[slot], state);
                        (slot, h, t_op.elapsed().as_secs_f64())
                    })
                };

            // per-slot hidden activations for the head/sampling phase.
            // This is the failure interception point: with a fault plan
            // active, a retention expiry is classified via the typed
            // KvError payload and recovered; every other error — and
            // any error without a plan — stays fatal, exactly as before
            let mut hidden: Vec<Option<B::Hidden>> =
                (0..self.serve.max_batches).map(|_| None).collect();
            let mut to_recover: Vec<usize> = Vec::new();
            for (slot, h, compute_s) in round {
                slot_compute[slot] += compute_s;
                match h {
                    Ok(h) => hidden[slot] = Some(h),
                    Err(e) => {
                        let retention = plan.is_some()
                            && e.downcast_ref::<KvError>()
                                .is_some_and(|k| matches!(k, KvError::Retention(_)));
                        if !retention {
                            return Err(e);
                        }
                        slot_compute[slot] = 0.0;
                        to_recover.push(slot);
                    }
                }
            }

            // retention recovery, coordinator-side in slot order: the
            // expired state is dropped (its pages retire — a retry in
            // place would see the failed round's partial appends) and
            // the sequence is recomputed from its prompt plus every
            // token it already emitted. Invariant 4 (prefill ≡ chunked
            // decode) makes the rebuilt KV bit-identical, so the
            // request's remaining tokens match its fault-free twin.
            for slot in to_recover {
                states[slot] = None;
                metrics.faults.retention_events += 1;
                if recomputes_used[slot] >= self.serve.retry_max {
                    let (req, _, _) = batcher.release(slot);
                    metrics.faults.shed.push(ShedRequest {
                        id: req.id,
                        reason: FailReason::Retention,
                    });
                    if let Some(ctx) = live.as_mut() {
                        ctx.shed(req.id, FailReason::Retention);
                    }
                    continue;
                }
                recomputes_used[slot] += 1;
                metrics.faults.recomputes += 1;
                let sref = batcher.slot(slot);
                let req = sref.request.as_ref().expect("active slot has a request");
                if sref.state == SlotState::NeedsPrefill {
                    // expired before the first token: the slot stays
                    // NeedsPrefill and next round re-runs the prefill
                    // on a fresh state
                    continue;
                }
                // replay = prompt + all emitted tokens except the last
                // (which still seeds the next decode round unchanged)
                let out = &sref.output;
                let replay: Vec<i32> = req
                    .prompt
                    .iter()
                    .chain(out[..out.len() - 1].iter())
                    .copied()
                    .collect();
                let mut st = self.backend.new_state()?;
                self.backend.bind_adapter(&mut st, req.adapter_id)?;
                self.backend.reserve_kv(&mut st, replay.len())?;
                // one prefill-shaped pass rebuilds the KV rows; the
                // hidden state is discarded — the last token is known
                run_slot_round(&self.backend, n_parts, Some(&replay), 0, &mut st)?;
                st.set_pos(replay.len());
                st.set_prompt_len(req.prompt.len());
                states[slot] = Some(st);
                metrics.faults.recomputed_tokens += replay.len() as u64;
            }

            // head + sampling per slot (KV reads/writes already ran —
            // and were tier-accounted — inside the partition stages)
            for &slot in &runnable {
                let h = match hidden[slot].take() {
                    Some(h) => h,
                    // recovered or shed this round: no token to sample
                    None => continue,
                };
                let state = states[slot].as_mut().unwrap();
                let is_prefill = batcher.slot(slot).state == SlotState::NeedsPrefill;
                let logits = if is_prefill {
                    let plen = batcher.slot(slot).request.as_ref().unwrap().prompt.len();
                    state.set_pos(plen);
                    state.set_prompt_len(plen);
                    let t_head = Instant::now();
                    // the prefill hidden rows cover only the unshared
                    // tail; the sampled last prompt token is always in
                    // it (a bind never swallows the whole prompt)
                    let l = self.backend.head_at(&h, plen - 1 - bound_prefix[slot])?;
                    slot_compute[slot] += t_head.elapsed().as_secs_f64();
                    // publish this sequence's full prompt-prefix
                    // blocks for later admissions — here, in slot
                    // order, after every bind of this round, so
                    // same-round admissions never share with each
                    // other and donors are width-invariant
                    if self.serve.prefix_cache {
                        let req = batcher.slot(slot).request.as_ref().unwrap();
                        self.backend.register_prefix_kv(state, &req.prompt)?;
                    }
                    l
                } else {
                    state.set_pos(state.pos() + 1);
                    let t_head = Instant::now();
                    let l = self.backend.head_decode_logits(&h)?;
                    slot_compute[slot] += t_head.elapsed().as_secs_f64();
                    l
                };
                let tok = self.sample(slot_rng[slot].as_mut(), &logits);
                let t_now = now(skipped_s);

                let admitted_at = batcher.slot(slot).admitted_at;
                if is_prefill {
                    slot_ttft[slot] = t_now - admitted_at;
                    metrics.record_ttft(t_now - admitted_at);
                    metrics.record_ttft_rounds(round_no - admit_round[slot]);
                    // actual prefill execution time, not the queue wait
                    metrics.record_prefill(slot_compute[slot]);
                    batcher.slot_mut(slot).state = SlotState::Decoding { generated: 1 };
                } else {
                    metrics.record_tbt(t_now - last_tok_at[slot]);
                    metrics.record_tbt_rounds(round_no - last_tok_round[slot]);
                    metrics.record_decode(slot_compute[slot]);
                    if let SlotState::Decoding { generated } = &mut batcher.slot_mut(slot).state {
                        *generated += 1;
                    }
                }
                slot_compute[slot] = 0.0;
                last_tok[slot] = tok;
                last_tok_at[slot] = t_now;
                last_tok_round[slot] = round_no;
                batcher.slot_mut(slot).output.push(tok);
                metrics.tokens_out += 1;

                // stream the token out the round it was produced; a
                // dead sink means the client went away — free the slot
                // and account the typed disconnect
                if let Some(ctx) = live.as_mut() {
                    let id = batcher.slot(slot).request.as_ref().unwrap().id;
                    let alive = match ctx.sinks.get_mut(&id) {
                        Some(sink) => sink.on_token(id, tok),
                        None => true,
                    };
                    if !alive {
                        let (req, _, _) = batcher.release(slot);
                        states[slot] = None;
                        metrics.faults.shed.push(ShedRequest {
                            id: req.id,
                            reason: FailReason::Disconnect,
                        });
                        ctx.shed(req.id, FailReason::Disconnect);
                        continue;
                    }
                }

                // completion check
                let slot_ref = batcher.slot(slot);
                let req = slot_ref.request.as_ref().unwrap();
                let produced = slot_ref.output.len();
                let out_of_room = state.pos() + 1 >= self.serve.max_seq;
                if produced >= req.max_new_tokens || out_of_room {
                    let (req, tokens, admitted_at) = batcher.release(slot);
                    // dropping the state retires its KV pages
                    states[slot] = None;
                    metrics.requests_done += 1;
                    done.push(CompletedRequest {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        adapter_id: req.adapter_id,
                        tokens,
                        ttft_s: slot_ttft[slot],
                        latency_s: t_now - admitted_at,
                    });
                    if let Some(ctx) = live.as_mut() {
                        let finished = done.last().expect("just pushed");
                        if let Some(mut sink) = ctx.sinks.remove(&finished.id) {
                            sink.on_complete(finished);
                        }
                        ctx.ingress.retire(finished.id);
                    }
                }
            }

            // refresh the published snapshot for /metrics scrapers once
            // per round; the hot loop itself never shares `metrics`
            if let Some(publish) = live.as_ref().and_then(|c| c.publish.as_ref()) {
                let mut snap = metrics.clone();
                snap.wall_s = now(skipped_s);
                *publish.lock().unwrap_or_else(|p| p.into_inner()) = snap;
            }
        }

        metrics.wall_s = now(skipped_s);
        metrics.kv = match (self.backend.kv_stats(), &kv_baseline) {
            (Some(end), Some(start)) => Some(end.since(start)),
            (end, _) => end,
        };
        metrics.lora = match (self.backend.lora_stats(), &lora_baseline) {
            (Some(end), Some(start)) => Some(end.since(start)),
            (end, _) => end,
        };
        // DR-eDRAM health postcondition (DESIGN.md invariant 5): a
        // violation would already have erred out of a decode read, but
        // assert the measured counters agree. Under a fault plan the
        // analogue (invariant 9) is that every store-counted expiry was
        // observed and recovered or shed by the coordinator.
        if let Some(kv) = &metrics.kv {
            if plan.is_none() {
                anyhow::ensure!(kv.retention_failures == 0, "retention failures occurred");
            } else {
                anyhow::ensure!(
                    kv.retention_failures == metrics.faults.retention_events,
                    "unaccounted retention failures: store counted {}, coordinator handled {}",
                    kv.retention_failures,
                    metrics.faults.retention_events
                );
            }
        }
        // final snapshot: scrapers racing shutdown still see the
        // complete counters (kv/lora deltas included)
        if let Some(publish) = live.as_ref().and_then(|c| c.publish.as_ref()) {
            *publish.lock().unwrap_or_else(|p| p.into_inner()) = metrics.clone();
        }
        Ok((done, metrics))
    }

    /// Measured on-die KV occupancy in [0, 1] — the admission /
    /// preemption pressure signal. Backends with opaque device-side KV
    /// report 0 (the knobs are inert there); a store configured with
    /// zero on-die capacity reports 1 (always under pressure).
    fn kv_pressure(&self) -> f64 {
        self.backend.kv_stats().map_or(0.0, |s| {
            if s.ondie_block_capacity == 0 {
                1.0
            } else {
                s.ondie_blocks_in_use as f64 / s.ondie_block_capacity as f64
            }
        })
    }
}

/// One slot's full token round: embed (prompt or last token), then its
/// chain of partition stages in order — the unit of work a pool worker
/// executes. `prompt` is `Some` for the prefill round, `None` for
/// decode (which runs every stage at the slot's current fixed `pos`;
/// the coordinator advances `pos` afterwards in the sampling phase).
fn run_slot_round<B: InferenceBackend>(
    backend: &B,
    n_parts: usize,
    prompt: Option<&[i32]>,
    last_tok: i32,
    state: &mut B::State,
) -> Result<B::Hidden> {
    let mut h = match prompt {
        Some(p) => backend.embed_prompt(p)?,
        None => backend.embed_token(last_tok)?,
    };
    let pos = state.pos();
    for part in 0..n_parts {
        h = match prompt {
            Some(_) => backend.run_partition_prefill(part, &h, state)?,
            None => backend.run_partition_decode(part, &h, pos, state)?,
        };
    }
    Ok(h)
}

/// One fused all-decode token round: embed every slot's seed token,
/// then walk the partition chain once with the whole batch via
/// [`InferenceBackend::run_partition_decode_batch`] — the backend runs
/// one bitplane GEMM per projection site instead of per-slot GEMVs
/// (DESIGN.md §17). A slot that errs at any stage drops out of the
/// remaining stages and carries its error in the returned round,
/// exactly like the per-slot path; the other slots' integers are
/// untouched because exact GEMM rows are independent. Compute time is
/// measured for the batch and attributed evenly across its slots.
fn run_decode_round_fused<B: InferenceBackend>(
    backend: &B,
    n_parts: usize,
    mut batch: Vec<(usize, i32, &mut B::State)>,
) -> Vec<(usize, Result<B::Hidden>, f64)> {
    let t_op = Instant::now();
    let n = batch.len();
    let mut out: Vec<Option<Result<B::Hidden>>> = (0..n).map(|_| None).collect();
    // indices (into `batch`) still flowing through the stage chain,
    // with their activations kept in lockstep
    let mut alive: Vec<usize> = Vec::with_capacity(n);
    let mut hs: Vec<B::Hidden> = Vec::with_capacity(n);
    for (i, (_, tok, _)) in batch.iter().enumerate() {
        match backend.embed_token(*tok) {
            Ok(h) => {
                alive.push(i);
                hs.push(h);
            }
            Err(e) => out[i] = Some(Err(e)),
        }
    }
    for part in 0..n_parts {
        if alive.is_empty() {
            break;
        }
        // re-borrow the surviving slots' states for this stage; `alive`
        // is sorted, so one pass over the batch collects them in order
        let mut entries: Vec<DecodeEntry<'_, B::State>> = Vec::with_capacity(alive.len());
        let mut ai = 0;
        for (i, (_, _, state)) in batch.iter_mut().enumerate() {
            if ai < alive.len() && alive[ai] == i {
                let pos = state.pos();
                entries.push(DecodeEntry { state: &mut **state, pos });
                ai += 1;
            }
        }
        let results =
            backend.run_partition_decode_batch(part, std::mem::take(&mut hs), &mut entries);
        let mut next_alive = Vec::with_capacity(alive.len());
        for (j, r) in results.into_iter().enumerate() {
            match r {
                Ok(h) => {
                    next_alive.push(alive[j]);
                    hs.push(h);
                }
                Err(e) => out[alive[j]] = Some(Err(e)),
            }
        }
        alive = next_alive;
    }
    for (i, h) in alive.into_iter().zip(hs) {
        out[i] = Some(Ok(h));
    }
    let per_slot_s = t_op.elapsed().as_secs_f64() / n.max(1) as f64;
    batch
        .into_iter()
        .zip(out)
        .map(|((slot, _, _), h)| {
            (slot, h.expect("every batched slot resolved to Ok or Err"), per_slot_s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::ingress::VecSink;
    use super::*;
    use crate::config::ModelConfig;
    use crate::runtime::HostBackend;

    fn micro() -> ModelConfig {
        ModelConfig {
            name: "host-micro".into(),
            n_layers: 2,
            d_model: 32,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 64,
            vocab_size: 64,
            max_seq: 32,
            n_partitions: 2,
            act_bits: 8,
        }
    }

    #[test]
    fn rejects_serve_config_exceeding_backend_limits() {
        let serve = ServeConfig {
            prefill_len: 64,
            max_seq: 128,
            ondie_tokens: 16,
            ..ServeConfig::default()
        };
        // micro model has max_seq 32 < serve.max_seq 128
        let backend = HostBackend::new(micro(), 1).unwrap();
        assert!(Server::new(backend, serve).is_err());
    }

    #[test]
    fn closed_batch_trace_completes_on_host_backend() {
        let backend = HostBackend::new(micro(), 2).unwrap();
        let serve = ServeConfig {
            max_batches: 2,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0,
                prompt: vec![1 + i as i32, 2, 3],
                max_new_tokens: 4,
                adapter_id: None,
                priority: 0,
            })
            .collect();
        let (done, mut metrics) = server.run_trace(reqs).unwrap();
        assert_eq!(done.len(), 3);
        for r in &done {
            assert_eq!(r.tokens.len(), 4);
            assert!(r.latency_s >= r.ttft_s);
        }
        assert_eq!(metrics.requests_done, 3);
        assert_eq!(metrics.tokens_out, 12);
        assert!(metrics.prefill_time.count() == 3);
        assert!(metrics.tokens_per_s() > 0.0);
        // measured KV statistics came from the store, not a model
        let kv = metrics.kv.as_ref().expect("host backend has a KV store");
        assert_eq!(kv.retention_failures, 0);
        assert_eq!(kv.explicit_refreshes, 0);
        assert!(kv.accesses.ondie_writes > 0);
        assert!(kv.kv_energy_j() > 0.0);
        // all pages were retired when the requests completed
        assert_eq!(server.kv_stats().unwrap().ondie_blocks_in_use, 0);
    }

    #[test]
    fn kv_metrics_are_per_trace_not_store_lifetime() {
        // two identically-shaped traces through ONE server must report
        // identical per-trace KV counts (the store's counters are
        // lifetime-accumulated; run_trace must report the delta)
        let backend = HostBackend::new(micro(), 2).unwrap();
        let serve = ServeConfig {
            max_batches: 2,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs = |off: i32| -> Vec<Request> {
            (0..2)
                .map(|i| Request {
                    id: i,
                    arrival_s: 0.0,
                    prompt: vec![off + i as i32, 2, 3],
                    max_new_tokens: 4,
                    adapter_id: None,
                    priority: 0,
                })
                .collect()
        };
        let (_, m1) = server.run_trace(reqs(1)).unwrap();
        let (_, m2) = server.run_trace(reqs(5)).unwrap();
        let (k1, k2) = (m1.kv.unwrap(), m2.kv.unwrap());
        assert_eq!(k1.accesses.total_accesses(), k2.accesses.total_accesses());
        assert!(k2.kv_energy_j() > 0.0);
        assert!((k1.kv_energy_j() - k2.kv_energy_j()).abs() < 1e-12);
    }

    #[test]
    fn fused_decode_rounds_match_the_per_slot_path() {
        // DESIGN.md §17: fusing an all-decode round into one batched
        // partition walk changes kernel shape, never tokens or KV
        // traffic — exact integer GEMM rows are independent
        let run = |fused: bool| {
            let backend = HostBackend::new(micro(), 2).unwrap();
            let serve = ServeConfig {
                max_batches: 3,
                prefill_len: 8,
                max_seq: 32,
                ondie_tokens: 8,
                fused_decode: fused,
                ..ServeConfig::default()
            };
            let mut server = Server::new(backend, serve).unwrap();
            let reqs: Vec<Request> = (0..3)
                .map(|i| Request {
                    id: i,
                    arrival_s: 0.0,
                    prompt: vec![1 + i as i32, 2, 3],
                    max_new_tokens: 6,
                    adapter_id: None,
                    priority: 0,
                })
                .collect();
            server.run_trace(reqs).unwrap()
        };
        let (fused, mf) = run(true);
        let (unfused, mu) = run(false);
        assert_eq!(fused.len(), unfused.len());
        for (a, b) in fused.iter().zip(&unfused) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "fusion changed request {}", a.id);
        }
        assert_eq!(mf.tokens_out, mu.tokens_out);
        assert_eq!(
            mf.kv.unwrap().accesses.total_accesses(),
            mu.kv.unwrap().accesses.total_accesses(),
            "fusion changed KV traffic"
        );
    }

    #[test]
    fn kernel_path_knob_never_changes_served_tokens() {
        let run = |path: &str| {
            let backend = HostBackend::new(micro(), 2).unwrap();
            let serve = ServeConfig {
                max_batches: 2,
                prefill_len: 8,
                max_seq: 32,
                ondie_tokens: 8,
                kernel_path: path.into(),
                ..ServeConfig::default()
            };
            let mut server = Server::new(backend, serve).unwrap();
            let reqs: Vec<Request> = (0..2)
                .map(|i| Request {
                    id: i,
                    arrival_s: 0.0,
                    prompt: vec![1 + i as i32, 2, 3],
                    max_new_tokens: 5,
                    adapter_id: None,
                    priority: 0,
                })
                .collect();
            let (done, _) = server.run_trace(reqs).unwrap();
            done.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        let auto = run("auto");
        assert_eq!(run("scalar"), auto, "scalar path diverged");
        assert_eq!(run("bitserial"), auto, "bit-serial path diverged");
    }

    #[test]
    fn adapter_request_on_plain_backend_fails_loudly() {
        // a trace carrying adapter ids must not silently decode on the
        // base model when the backend has no registry
        let backend = HostBackend::new(micro(), 2).unwrap();
        let serve = ServeConfig {
            max_batches: 1,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs = vec![Request {
            id: 0,
            arrival_s: 0.0,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            adapter_id: Some(0),
            priority: 0,
        }];
        assert!(server.run_trace(reqs).is_err());
    }

    #[test]
    fn lora_metrics_are_per_trace_not_registry_lifetime() {
        use crate::lora::{AdapterRegistry, LoraConfig};
        let reg = AdapterRegistry::fabricate(&micro(), &LoraConfig::paper(), 2, 5).unwrap();
        let backend = HostBackend::with_adapters(micro(), 2, reg).unwrap();
        let serve = ServeConfig {
            max_batches: 2,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            n_adapters: 2,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs = || -> Vec<Request> {
            (0..2)
                .map(|i| Request {
                    id: i,
                    arrival_s: 0.0,
                    prompt: vec![1 + i as i32, 2, 3],
                    max_new_tokens: 4,
                    adapter_id: Some(i as u32),
                    priority: 0,
                })
                .collect()
        };
        let (done1, m1) = server.run_trace(reqs()).unwrap();
        let (_, m2) = server.run_trace(reqs()).unwrap();
        assert!(done1.iter().any(|r| r.adapter_id == Some(1)));
        let (l1, l2) = (m1.lora.unwrap(), m2.lora.unwrap());
        assert_eq!(l1.binds, 2);
        assert_eq!(l2.binds, 2);
        assert_eq!(l1.cold_loads, 2, "first trace streams both tenants");
        assert_eq!(l2.cold_loads, 0, "second trace binds resident tenants for free");
        assert_eq!(l1.adapter_macs, l2.adapter_macs, "identical work per trace");
        assert!(l1.measured_op_overhead() > 0.0);
    }

    /// A [`VecSink`] behind a shared handle: the coordinator owns the
    /// boxed sink while the test watches the stream from outside.
    #[derive(Clone, Default)]
    struct SharedSink(Arc<Mutex<VecSink>>);

    impl SharedSink {
        fn lock(&self) -> std::sync::MutexGuard<'_, VecSink> {
            self.0.lock().unwrap()
        }
    }

    impl TokenSink for SharedSink {
        fn on_token(&mut self, id: u64, tok: i32) -> bool {
            self.lock().on_token(id, tok)
        }
        fn on_complete(&mut self, done: &CompletedRequest) {
            self.lock().on_complete(done);
        }
        fn on_shed(&mut self, id: u64, reason: FailReason) {
            self.lock().on_shed(id, reason);
        }
    }

    #[test]
    fn live_ingress_matches_the_offline_twin_and_notifies_sinks() {
        // invariant 10 at the unit level, under top-k so the
        // per-request sampling streams are load-bearing: the same
        // request set served live through the ingress emits exactly the
        // tokens of the closed-batch trace twin
        let serve = || ServeConfig {
            max_batches: 2,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            top_k: 3,
            ..ServeConfig::default()
        };
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0,
                prompt: vec![1 + i as i32, 2, 3],
                max_new_tokens: 4,
                adapter_id: None,
                priority: 0,
            })
            .collect();

        let mut twin_server =
            Server::new(HostBackend::new(micro(), 2).unwrap(), serve()).unwrap();
        let (twin, _) = twin_server.run_trace(reqs.clone()).unwrap();

        let ingress = Arc::new(Ingress::new(8, 0.0, 8));
        let sinks: Vec<SharedSink> = (0..reqs.len()).map(|_| SharedSink::default()).collect();
        ingress.pause();
        for (r, s) in reqs.iter().zip(&sinks) {
            ingress.submit_at(r.clone(), Box::new(s.clone()), 0.0).unwrap();
        }
        ingress.resume();
        let watch = sinks.clone();
        let ing = ingress.clone();
        let watcher = std::thread::spawn(move || loop {
            if watch.iter().all(|s| s.lock().done.is_some()) {
                ing.shutdown();
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        let published = Arc::new(Mutex::new(ServeMetrics::new()));
        let mut server = Server::new(HostBackend::new(micro(), 2).unwrap(), serve()).unwrap();
        let (done, metrics) = server.run_ingress(ingress, Some(published.clone())).unwrap();
        watcher.join().unwrap();

        assert_eq!(done.len(), 3);
        assert_eq!(metrics.requests_done, 3);
        assert!(metrics.faults.shed.is_empty());
        // round-indexed latency percentiles recorded without any wall
        // clock involvement
        assert_eq!(metrics.ttft_rounds.len(), 3);
        assert!(metrics.tbt_rounds.len() > 0);
        for t in &twin {
            let live = done.iter().find(|d| d.id == t.id).unwrap();
            assert_eq!(live.tokens, t.tokens, "request {} diverged from its twin", t.id);
        }
        for (r, s) in reqs.iter().zip(&sinks) {
            let g = s.lock();
            let d = g.done.as_ref().expect("sink saw completion");
            assert_eq!(d.id, r.id);
            assert_eq!(g.tokens, d.tokens, "streamed ≠ completion record");
            assert_eq!(g.tokens.len(), r.max_new_tokens);
        }
        // the final published snapshot carries the run's full counters
        assert_eq!(published.lock().unwrap().requests_done, 3);
    }

    #[test]
    fn shutdown_sheds_queued_live_requests_with_typed_reason() {
        let serve = ServeConfig {
            max_batches: 1,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            ..ServeConfig::default()
        };
        let ingress = Arc::new(Ingress::new(8, 0.0, 8));
        let sink = SharedSink::default();
        ingress.pause();
        ingress
            .submit_at(
                Request {
                    id: 9,
                    arrival_s: 0.0,
                    prompt: vec![1, 2],
                    max_new_tokens: 4,
                    adapter_id: None,
                    priority: 0,
                },
                Box::new(sink.clone()),
                0.0,
            )
            .unwrap();
        ingress.shutdown();
        let mut server = Server::new(HostBackend::new(micro(), 1).unwrap(), serve).unwrap();
        let (done, metrics) = server.run_ingress(ingress.clone(), None).unwrap();
        assert!(done.is_empty());
        assert_eq!(metrics.faults.shed_count(FailReason::Shutdown), 1);
        assert_eq!(sink.lock().shed, Some(FailReason::Shutdown));
        assert!(sink.lock().tokens.is_empty());
        assert_eq!(ingress.queued_len(), 0, "drained queue holds nothing");
    }
}
