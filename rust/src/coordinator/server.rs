//! The serving loop: batcher + pipeline schedule + backend execution,
//! with the backend's tiered KV store driven by modeled hardware time
//! so the DR-eDRAM retention argument is live-checked on every decode
//! read. Generic over [`InferenceBackend`] — the same loop serves the
//! PJRT artifact runtime and the offline host transformer.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::kvcache::KvStoreStats;
use crate::lora::LoraServeStats;
use crate::runtime::{InferenceBackend, Logits, SequenceState};
use crate::trace::Request;
use crate::util::rng::Rng;

use super::batcher::{Batcher, SlotState};
use super::metrics::ServeMetrics;
use super::pipeline::PipelineSchedule;

/// A finished request with its timings.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    /// Request id from the trace.
    pub id: u64,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Tenant adapter the request decoded under (`None` = base model).
    pub adapter_id: Option<u32>,
    /// Generated token ids.
    pub tokens: Vec<i32>,
    /// Admission-to-first-token (s).
    pub ttft_s: f64,
    /// Admission-to-last-token latency (s).
    pub latency_s: f64,
}

/// The serving coordinator: owns a backend and runs request traces
/// through continuous batching + the partition pipeline. KV placement,
/// quantization and retention checking happen inside the backend's
/// [`crate::kvcache::KvStore`] (configured here from the
/// [`ServeConfig`]); the server reads the measured statistics back
/// into [`ServeMetrics`].
pub struct Server<B: InferenceBackend> {
    backend: B,
    serve: ServeConfig,
    rng: Rng,
}

impl<B: InferenceBackend> Server<B> {
    /// Validate `serve` against the backend's limits and build the
    /// server (this sizes the backend's KV store for the deployment
    /// via [`InferenceBackend::configure_kv`]).
    pub fn new(backend: B, serve: ServeConfig) -> Result<Self> {
        serve.validate()?;
        anyhow::ensure!(
            serve.prefill_len <= backend.prefill_len(),
            "serve prefill_len {} exceeds backend prompt bucket {}",
            serve.prefill_len,
            backend.prefill_len()
        );
        anyhow::ensure!(
            serve.max_seq <= backend.model().max_seq,
            "serve max_seq exceeds model max_seq"
        );
        backend.configure_kv(&serve)?;
        Ok(Server {
            rng: Rng::new(serve.seed),
            serve,
            backend,
        })
    }

    /// The backend this server schedules onto.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Measured KV-tier statistics so far (None for backends with
    /// opaque device-side KV).
    pub fn kv_stats(&self) -> Option<KvStoreStats> {
        self.backend.kv_stats()
    }

    /// Measured adapter-serving statistics so far (None for backends
    /// without an adapter registry).
    pub fn lora_stats(&self) -> Option<LoraServeStats> {
        self.backend.lora_stats()
    }

    fn sample(&mut self, logits: &Logits) -> i32 {
        if self.serve.top_k <= 1 {
            logits.argmax() as i32
        } else {
            let cands = logits.top_k(self.serve.top_k);
            *self.rng.choice(&cands) as i32
        }
    }

    /// Run a trace to completion (continuous batching). Returns the
    /// completed requests and serving metrics.
    pub fn run_trace(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<(Vec<CompletedRequest>, ServeMetrics)> {
        let n_parts = self.backend.n_partitions();
        let mut batcher = Batcher::new(self.serve.max_batches);
        for r in requests {
            anyhow::ensure!(
                r.prompt.len() <= self.serve.prefill_len,
                "request {} prompt {} exceeds prefill bucket {}",
                r.id,
                r.prompt.len(),
                self.serve.prefill_len
            );
            batcher.submit(r);
        }

        let mut states: Vec<Option<B::State>> = Vec::new();
        let mut last_tok: Vec<i32> = Vec::new();
        let mut last_tok_at: Vec<f64> = Vec::new();
        let mut slot_ttft: Vec<f64> = Vec::new();
        // Backend execution time accumulated for the slot's current
        // token (embed + every partition stage + head) — what
        // prefill/decode compute metrics record, as opposed to the
        // queue wait that TTFT measures.
        let mut slot_compute: Vec<f64> = Vec::new();
        for _ in 0..self.serve.max_batches {
            states.push(None);
            last_tok.push(0);
            last_tok_at.push(0.0);
            slot_ttft.push(0.0);
            slot_compute.push(0.0);
        }

        let mut done = Vec::new();
        let mut metrics = ServeMetrics::new();
        // baselines so metrics.kv / metrics.lora report THIS trace's
        // traffic even if the same server runs multiple traces
        // (store and registry counters are lifetime-accumulated)
        let kv_baseline = self.backend.kv_stats();
        let lora_baseline = self.backend.lora_stats();
        let t0 = Instant::now();
        // The serving clock is wall time plus any idle skip: an offline
        // backend (realtime() == false) jumps straight over gaps before
        // the next queued arrival instead of sleeping through sparse
        // traces; a realtime backend sleeps so arrivals stay
        // wall-clock-true.
        let mut skipped_s = 0.0f64;
        let now = |skipped: f64| t0.elapsed().as_secs_f64() + skipped;
        // The DR-eDRAM retention clock runs on *modeled hardware time*
        // (one hw_tbt per token round): the retention argument is about
        // the accelerator's cadence, not the CPU emulating it. The
        // serving clock is still used for all latency metrics.
        let mut hw_time = 0.0f64;

        while !batcher.all_idle() {
            for slot in batcher.admit(now(skipped_s)) {
                states[slot] = None;
                slot_compute[slot] = 0.0;
            }
            let active = batcher.active_slots();
            if active.is_empty() {
                // waiting on a future arrival: sleep (realtime) or skip
                // the clock ahead (offline) — never busy-wait
                let next = batcher
                    .next_arrival()
                    .context("no active slots and nothing queued")?;
                let t_now = now(skipped_s);
                if next > t_now {
                    if self.backend.realtime() {
                        let nap = (next - t_now).min(0.01);
                        std::thread::sleep(std::time::Duration::from_secs_f64(nap));
                    } else {
                        skipped_s += next - t_now;
                    }
                }
                continue;
            }

            // one token round through the partition pipeline
            let sched = PipelineSchedule::for_round(&active, n_parts);
            sched
                .validate(n_parts)
                .map_err(|e| anyhow::anyhow!("pipeline invariant violated: {e}"))?;

            // advance the retention clock before the round's KV
            // accesses: one hw_tbt per pipeline token round
            hw_time += self.serve.hw_tbt_s;
            self.backend.advance_kv_clock(hw_time);

            // per-slot hidden activations flowing between stages
            let mut hidden: Vec<Option<B::Hidden>> =
                (0..self.serve.max_batches).map(|_| None).collect();

            for op in &sched.ops {
                let slot = op.slot;
                let is_prefill = batcher.slot(slot).state == SlotState::NeedsPrefill;
                let t_op = Instant::now();
                if op.partition == 0 {
                    // entering the pipeline: embed
                    let h = if is_prefill {
                        let prompt = &batcher.slot(slot).request.as_ref().unwrap().prompt;
                        self.backend.embed_prompt(prompt)?
                    } else {
                        self.backend.embed_token(last_tok[slot])?
                    };
                    hidden[slot] = Some(h);
                    if states[slot].is_none() {
                        let mut state = self.backend.new_state()?;
                        // bind the request's tenant adapter before any
                        // partition runs: the adapter shapes every
                        // projection of the sequence, prefill included
                        let adapter = batcher.slot(slot).request.as_ref().unwrap().adapter_id;
                        self.backend.bind_adapter(&mut state, adapter)?;
                        states[slot] = Some(state);
                    }
                }
                let h_in = hidden[slot].take().expect("pipeline order broken");
                let state = states[slot].as_mut().unwrap();
                let h_out = if is_prefill {
                    self.backend.run_partition_prefill(op.partition, &h_in, state)?
                } else {
                    let pos = state.pos();
                    self.backend.run_partition_decode(op.partition, &h_in, pos, state)?
                };
                hidden[slot] = Some(h_out);
                slot_compute[slot] += t_op.elapsed().as_secs_f64();
            }

            // head + sampling per slot (KV reads/writes already ran —
            // and were tier-accounted — inside the partition stages)
            for &slot in &active {
                let h = hidden[slot].take().expect("missing hidden after round");
                let state = states[slot].as_mut().unwrap();
                let is_prefill = batcher.slot(slot).state == SlotState::NeedsPrefill;
                let logits = if is_prefill {
                    let plen = batcher.slot(slot).request.as_ref().unwrap().prompt.len();
                    state.set_pos(plen);
                    state.set_prompt_len(plen);
                    let t_head = Instant::now();
                    let l = self.backend.head_at(&h, plen - 1)?;
                    slot_compute[slot] += t_head.elapsed().as_secs_f64();
                    l
                } else {
                    state.set_pos(state.pos() + 1);
                    let t_head = Instant::now();
                    let l = self.backend.head_decode_logits(&h)?;
                    slot_compute[slot] += t_head.elapsed().as_secs_f64();
                    l
                };
                let tok = self.sample(&logits);
                let t_now = now(skipped_s);

                let admitted_at = batcher.slot(slot).admitted_at;
                if is_prefill {
                    slot_ttft[slot] = t_now - admitted_at;
                    metrics.record_ttft(t_now - admitted_at);
                    // actual prefill execution time, not the queue wait
                    metrics.record_prefill(slot_compute[slot]);
                    batcher.slot_mut(slot).state = SlotState::Decoding { generated: 1 };
                } else {
                    metrics.record_tbt(t_now - last_tok_at[slot]);
                    metrics.record_decode(slot_compute[slot]);
                    if let SlotState::Decoding { generated } = &mut batcher.slot_mut(slot).state {
                        *generated += 1;
                    }
                }
                slot_compute[slot] = 0.0;
                last_tok[slot] = tok;
                last_tok_at[slot] = t_now;
                batcher.slot_mut(slot).output.push(tok);
                metrics.tokens_out += 1;

                // completion check
                let slot_ref = batcher.slot(slot);
                let req = slot_ref.request.as_ref().unwrap();
                let produced = slot_ref.output.len();
                let out_of_room = state.pos() + 1 >= self.serve.max_seq;
                if produced >= req.max_new_tokens || out_of_room {
                    let (req, tokens, admitted_at) = batcher.release(slot);
                    // dropping the state retires its KV pages
                    states[slot] = None;
                    metrics.requests_done += 1;
                    done.push(CompletedRequest {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        adapter_id: req.adapter_id,
                        tokens,
                        ttft_s: slot_ttft[slot],
                        latency_s: t_now - admitted_at,
                    });
                }
            }
        }

        metrics.wall_s = now(skipped_s);
        metrics.kv = match (self.backend.kv_stats(), &kv_baseline) {
            (Some(end), Some(start)) => Some(end.since(start)),
            (end, _) => end,
        };
        metrics.lora = match (self.backend.lora_stats(), &lora_baseline) {
            (Some(end), Some(start)) => Some(end.since(start)),
            (end, _) => end,
        };
        // DR-eDRAM health postcondition (DESIGN.md invariant 5): a
        // violation would already have erred out of a decode read, but
        // assert the measured counters agree
        if let Some(kv) = &metrics.kv {
            anyhow::ensure!(kv.retention_failures == 0, "retention failures occurred");
        }
        Ok((done, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::runtime::HostBackend;

    fn micro() -> ModelConfig {
        ModelConfig {
            name: "host-micro".into(),
            n_layers: 2,
            d_model: 32,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 64,
            vocab_size: 64,
            max_seq: 32,
            n_partitions: 2,
            act_bits: 8,
        }
    }

    #[test]
    fn rejects_serve_config_exceeding_backend_limits() {
        let serve = ServeConfig {
            prefill_len: 64,
            max_seq: 128,
            ondie_tokens: 16,
            ..ServeConfig::default()
        };
        // micro model has max_seq 32 < serve.max_seq 128
        let backend = HostBackend::new(micro(), 1).unwrap();
        assert!(Server::new(backend, serve).is_err());
    }

    #[test]
    fn closed_batch_trace_completes_on_host_backend() {
        let backend = HostBackend::new(micro(), 2).unwrap();
        let serve = ServeConfig {
            max_batches: 2,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0,
                prompt: vec![1 + i as i32, 2, 3],
                max_new_tokens: 4,
                adapter_id: None,
            })
            .collect();
        let (done, mut metrics) = server.run_trace(reqs).unwrap();
        assert_eq!(done.len(), 3);
        for r in &done {
            assert_eq!(r.tokens.len(), 4);
            assert!(r.latency_s >= r.ttft_s);
        }
        assert_eq!(metrics.requests_done, 3);
        assert_eq!(metrics.tokens_out, 12);
        assert!(metrics.prefill_time.count() == 3);
        assert!(metrics.tokens_per_s() > 0.0);
        // measured KV statistics came from the store, not a model
        let kv = metrics.kv.as_ref().expect("host backend has a KV store");
        assert_eq!(kv.retention_failures, 0);
        assert_eq!(kv.explicit_refreshes, 0);
        assert!(kv.accesses.ondie_writes > 0);
        assert!(kv.kv_energy_j() > 0.0);
        // all pages were retired when the requests completed
        assert_eq!(server.kv_stats().unwrap().ondie_blocks_in_use, 0);
    }

    #[test]
    fn kv_metrics_are_per_trace_not_store_lifetime() {
        // two identically-shaped traces through ONE server must report
        // identical per-trace KV counts (the store's counters are
        // lifetime-accumulated; run_trace must report the delta)
        let backend = HostBackend::new(micro(), 2).unwrap();
        let serve = ServeConfig {
            max_batches: 2,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs = |off: i32| -> Vec<Request> {
            (0..2)
                .map(|i| Request {
                    id: i,
                    arrival_s: 0.0,
                    prompt: vec![off + i as i32, 2, 3],
                    max_new_tokens: 4,
                    adapter_id: None,
                })
                .collect()
        };
        let (_, m1) = server.run_trace(reqs(1)).unwrap();
        let (_, m2) = server.run_trace(reqs(5)).unwrap();
        let (k1, k2) = (m1.kv.unwrap(), m2.kv.unwrap());
        assert_eq!(k1.accesses.total_accesses(), k2.accesses.total_accesses());
        assert!(k2.kv_energy_j() > 0.0);
        assert!((k1.kv_energy_j() - k2.kv_energy_j()).abs() < 1e-12);
    }

    #[test]
    fn adapter_request_on_plain_backend_fails_loudly() {
        // a trace carrying adapter ids must not silently decode on the
        // base model when the backend has no registry
        let backend = HostBackend::new(micro(), 2).unwrap();
        let serve = ServeConfig {
            max_batches: 1,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs = vec![Request {
            id: 0,
            arrival_s: 0.0,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            adapter_id: Some(0),
        }];
        assert!(server.run_trace(reqs).is_err());
    }

    #[test]
    fn lora_metrics_are_per_trace_not_registry_lifetime() {
        use crate::lora::{AdapterRegistry, LoraConfig};
        let reg = AdapterRegistry::fabricate(&micro(), &LoraConfig::paper(), 2, 5).unwrap();
        let backend = HostBackend::with_adapters(micro(), 2, reg).unwrap();
        let serve = ServeConfig {
            max_batches: 2,
            prefill_len: 8,
            max_seq: 32,
            ondie_tokens: 8,
            n_adapters: 2,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve).unwrap();
        let reqs = || -> Vec<Request> {
            (0..2)
                .map(|i| Request {
                    id: i,
                    arrival_s: 0.0,
                    prompt: vec![1 + i as i32, 2, 3],
                    max_new_tokens: 4,
                    adapter_id: Some(i as u32),
                })
                .collect()
        };
        let (done1, m1) = server.run_trace(reqs()).unwrap();
        let (_, m2) = server.run_trace(reqs()).unwrap();
        assert!(done1.iter().any(|r| r.adapter_id == Some(1)));
        let (l1, l2) = (m1.lora.unwrap(), m2.lora.unwrap());
        assert_eq!(l1.binds, 2);
        assert_eq!(l2.binds, 2);
        assert_eq!(l1.cold_loads, 2, "first trace streams both tenants");
        assert_eq!(l2.cold_loads, 0, "second trace binds resident tenants for free");
        assert_eq!(l1.adapter_macs, l2.adapter_macs, "identical work per trace");
        assert!(l1.measured_op_overhead() > 0.0);
    }
}
