//! Live admission for the streaming serving plane (DESIGN.md §14).
//!
//! Offline serving hands [`Server::run_trace`] a closed batch up
//! front. The online plane instead feeds requests into the continuous
//! batcher *mid-flight*: connection threads [`submit`] into an
//! [`Ingress`], and the coordinator loop [`pull`]s admitted requests
//! between decode rounds. Admission control happens here, at the edge,
//! before a request ever reaches the batcher:
//!
//! * **per-tenant FIFO** — one queue per `adapter_id`, drained
//!   round-robin so a single chatty tenant cannot starve the rest;
//! * **token-bucket rate limit** — per-tenant, refilled on the
//!   submitting clock; over-rate requests are rejected with a
//!   `Retry-After` hint ([`Reject::RateLimit`]);
//! * **queue-depth backpressure** — a global cap on queued requests;
//!   beyond it submissions are rejected ([`Reject::QueueFull`], HTTP
//!   429) and recorded as typed [`FailReason::Overload`] sheds so
//!   `ServeMetrics::faults` counts them exactly like coordinator-side
//!   overload sheds.
//!
//! Each request carries a [`TokenSink`]: the decode loop pushes tokens
//! through it the round they are produced, without knowing whether the
//! other end is a socket, a bench accumulator, or a test vector.
//!
//! [`submit`]: Ingress::submit_at
//! [`pull`]: Ingress::pull
//! [`Server::run_trace`]: super::Server::run_trace

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use super::metrics::{FailReason, ShedRequest};
use super::server::CompletedRequest;
use crate::trace::Request;

/// Where one request's decoded tokens go, the round they are produced.
/// Implementations must be cheap and non-blocking — the coordinator
/// calls them between decode rounds.
pub trait TokenSink: Send {
    /// One decoded token. Return `false` if the consumer is gone (the
    /// coordinator then sheds the sequence as [`FailReason::Disconnect`]
    /// and frees its slot).
    fn on_token(&mut self, id: u64, tok: i32) -> bool;
    /// The sequence finished; `done` carries the full token list and
    /// latency accounting.
    fn on_complete(&mut self, done: &CompletedRequest);
    /// The sequence was shed before completing, with its typed reason.
    fn on_shed(&mut self, id: u64, reason: FailReason);
}

/// [`TokenSink`] that buffers everything in memory (tests, benches,
/// and the offline twin of a streamed run).
#[derive(Debug, Default)]
pub struct VecSink {
    /// Tokens received, in emission order.
    pub tokens: Vec<i32>,
    /// The completion record, once the sequence finishes.
    pub done: Option<CompletedRequest>,
    /// The shed reason, if the sequence was shed instead.
    pub shed: Option<FailReason>,
}

impl TokenSink for VecSink {
    fn on_token(&mut self, _id: u64, tok: i32) -> bool {
        self.tokens.push(tok);
        true
    }

    fn on_complete(&mut self, done: &CompletedRequest) {
        self.done = Some(done.clone());
    }

    fn on_shed(&mut self, _id: u64, reason: FailReason) {
        self.shed = Some(reason);
    }
}

/// Why a submission was rejected at the admission edge.
#[derive(Debug, Clone, PartialEq)]
pub enum Reject {
    /// The tenant's token bucket is empty; retry after the hint.
    RateLimit {
        /// Seconds until the bucket refills enough for one request.
        retry_after_s: f64,
    },
    /// The global admission queue is at `max_queue`.
    QueueFull,
    /// The server is draining for shutdown.
    ShuttingDown,
    /// A live request already carries this id.
    DuplicateId,
    /// The request itself is unusable (empty prompt, zero budget, ...).
    Invalid(String),
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reject::RateLimit { retry_after_s } => {
                write!(f, "rate limited (retry after {retry_after_s:.2}s)")
            }
            Reject::QueueFull => write!(f, "admission queue full"),
            Reject::ShuttingDown => write!(f, "shutting down"),
            Reject::DuplicateId => write!(f, "duplicate request id"),
            Reject::Invalid(why) => write!(f, "invalid request: {why}"),
        }
    }
}

/// Classic token bucket: `tokens` refills at `rate`/s up to `cap`.
struct Bucket {
    tokens: f64,
    last_s: f64,
}

/// One tenant's admission state.
struct TenantQueue {
    fifo: VecDeque<(Request, Box<dyn TokenSink>)>,
    bucket: Bucket,
    /// Deficit round-robin credit: banked each turn (one request costs
    /// one credit), reset while the tenant's queue is idle.
    deficit: f64,
}

/// Pop the next request honoring priority classes: the *first* queued
/// request of the highest class leaves first, so classes are strict
/// and order within a class stays FIFO. All-default (class 0) traffic
/// reduces to a plain `pop_front`.
fn pop_next(
    fifo: &mut VecDeque<(Request, Box<dyn TokenSink>)>,
) -> Option<(Request, Box<dyn TokenSink>)> {
    if fifo.is_empty() {
        return None;
    }
    let mut best = 0;
    for i in 1..fifo.len() {
        if fifo[i].0.priority > fifo[best].0.priority {
            best = i;
        }
    }
    fifo.remove(best)
}

struct Inner {
    tenants: BTreeMap<Option<u32>, TenantQueue>,
    /// Cross-tenant fairness weights (default 1.0), settable ahead of
    /// a tenant's first submission.
    weights: BTreeMap<Option<u32>, f64>,
    /// Round-robin cursor over tenant keys (index into the sorted key
    /// set at pull time).
    rr: usize,
    /// Edge rejections that are typed sheds (rate-limit, queue-full);
    /// the coordinator drains these into `ServeMetrics::faults` so the
    /// accounting matches coordinator-side sheds exactly.
    rejected: Vec<ShedRequest>,
    /// Ids admitted or pulled and not yet retired — the duplicate
    /// guard.
    live: BTreeSet<u64>,
    queued: usize,
}

/// Thread-safe admission funnel between connection threads and the
/// coordinator loop. Shared as `Arc<Ingress>`.
pub struct Ingress {
    inner: Mutex<Inner>,
    shutdown: AtomicBool,
    paused: AtomicBool,
    max_queue: usize,
    /// Requests/s per tenant; `0` disables rate limiting.
    rate_limit: f64,
    /// Longest admissible prompt; `0` disables the check.
    max_prompt: usize,
}

impl Ingress {
    /// Admission funnel holding at most `max_queue` queued requests in
    /// total, each tenant limited to `rate_limit` submissions/s
    /// (`0.0` = unlimited), rejecting prompts longer than `max_prompt`
    /// tokens (`0` = unchecked). Online serving must set `max_prompt`
    /// to `ServeConfig::prefill_len`: an oversized prompt that reaches
    /// the backend fails the whole serving loop.
    pub fn new(max_queue: usize, rate_limit: f64, max_prompt: usize) -> Self {
        Ingress {
            inner: Mutex::new(Inner {
                tenants: BTreeMap::new(),
                weights: BTreeMap::new(),
                rr: 0,
                rejected: Vec::new(),
                live: BTreeSet::new(),
                queued: 0,
            }),
            shutdown: AtomicBool::new(false),
            paused: AtomicBool::new(false),
            max_queue,
            rate_limit,
            max_prompt,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // a poisoned ingress mutex means a panicking submitter; the
        // queues themselves are still structurally sound
        match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Submit one request for admission at time `now_s` (any monotonic
    /// clock — the wall for sockets, the virtual serving clock in
    /// tests). On rejection the sink is dropped: the submitter owns the
    /// transport and reports the rejection itself.
    pub fn submit_at(
        &self,
        req: Request,
        sink: Box<dyn TokenSink>,
        now_s: f64,
    ) -> Result<(), Reject> {
        if self.shutdown.load(Ordering::SeqCst) {
            return Err(Reject::ShuttingDown);
        }
        if req.prompt.is_empty() {
            return Err(Reject::Invalid("empty prompt".into()));
        }
        if req.max_new_tokens == 0 {
            return Err(Reject::Invalid("max_new_tokens must be positive".into()));
        }
        if self.max_prompt > 0 && req.prompt.len() > self.max_prompt {
            return Err(Reject::Invalid(format!(
                "prompt {} exceeds prefill bucket {}",
                req.prompt.len(),
                self.max_prompt
            )));
        }
        let mut guard = self.lock();
        let inner = &mut *guard;
        if inner.live.contains(&req.id) {
            return Err(Reject::DuplicateId);
        }
        if inner.queued >= self.max_queue {
            inner.rejected.push(ShedRequest {
                id: req.id,
                reason: FailReason::Overload,
            });
            return Err(Reject::QueueFull);
        }
        let rate = self.rate_limit;
        let tq = inner.tenants.entry(req.adapter_id).or_insert_with(|| TenantQueue {
            fifo: VecDeque::new(),
            deficit: 0.0,
            bucket: Bucket {
                // a fresh bucket starts full: short bursts up to the
                // per-second rate are fine, sustained overrate is not
                tokens: if rate > 0.0 { rate.ceil().max(1.0) } else { 0.0 },
                last_s: now_s,
            },
        });
        if rate > 0.0 {
            let b = &mut tq.bucket;
            let cap = rate.ceil().max(1.0);
            b.tokens = (b.tokens + (now_s - b.last_s).max(0.0) * rate).min(cap);
            b.last_s = now_s;
            if b.tokens < 1.0 {
                let retry_after_s = (1.0 - b.tokens) / rate;
                inner.rejected.push(ShedRequest {
                    id: req.id,
                    reason: FailReason::RateLimit,
                });
                return Err(Reject::RateLimit { retry_after_s });
            }
            b.tokens -= 1.0;
        }
        let id = req.id;
        tq.fifo.push_back((req, sink));
        inner.live.insert(id);
        inner.queued += 1;
        Ok(())
    }

    /// Set a tenant's cross-tenant fairness weight (default 1.0). Over
    /// many pulls tenants are served in proportion to their weights;
    /// every positive weight guarantees eventual service (no
    /// starvation). Clamped below at 0.01 so a zero weight cannot
    /// stall the deficit loop. Takes effect on the next pull and may
    /// be set before the tenant's first submission.
    pub fn set_tenant_weight(&self, tenant: Option<u32>, weight: f64) {
        self.lock().weights.insert(tenant, weight.max(0.01));
    }

    /// Pull up to `max` admitted requests: weighted deficit round-robin
    /// across tenants — each turn banks the tenant's weight, one
    /// request costs one credit, and an idle tenant banks nothing — so
    /// service converges to the weight proportions without starving
    /// anyone. Within a tenant the highest priority class leaves
    /// first, FIFO within a class. With every weight at the default
    /// 1.0 and every request at class 0 this is exactly one-per-turn
    /// FIFO round-robin. Returns nothing while admission is paused.
    pub fn pull(&self, max: usize) -> Vec<(Request, Box<dyn TokenSink>)> {
        if self.paused.load(Ordering::SeqCst) {
            return Vec::new();
        }
        let mut guard = self.lock();
        let inner = &mut *guard;
        let mut out = Vec::new();
        while out.len() < max && inner.queued > 0 {
            let keys: Vec<Option<u32>> = inner.tenants.keys().copied().collect();
            let k = keys[inner.rr % keys.len()];
            inner.rr = (inner.rr + 1) % keys.len();
            let weight = inner.weights.get(&k).copied().unwrap_or(1.0);
            if let Some(tq) = inner.tenants.get_mut(&k) {
                if tq.fifo.is_empty() {
                    // an idle tenant banks no credit (classic DRR)
                    tq.deficit = 0.0;
                    continue;
                }
                tq.deficit += weight;
                while tq.deficit >= 1.0 && out.len() < max {
                    match pop_next(&mut tq.fifo) {
                        Some(item) => {
                            tq.deficit -= 1.0;
                            inner.queued -= 1;
                            out.push(item);
                        }
                        None => break,
                    }
                }
                if tq.fifo.is_empty() {
                    tq.deficit = 0.0;
                }
                // empty tenant queues stay registered: their rate
                // buckets keep their level across idle gaps
            }
        }
        out
    }

    /// Drain every queued request (graceful shutdown: the coordinator
    /// sheds them as [`FailReason::Shutdown`] with their sinks
    /// notified).
    pub fn drain_all(&self) -> Vec<(Request, Box<dyn TokenSink>)> {
        let mut inner = self.lock();
        let mut out = Vec::new();
        for tq in inner.tenants.values_mut() {
            out.extend(tq.fifo.drain(..));
        }
        inner.queued = 0;
        out
    }

    /// Take the typed sheds recorded for edge rejections since the
    /// last call.
    pub fn drain_rejected(&self) -> Vec<ShedRequest> {
        std::mem::take(&mut self.lock().rejected)
    }

    /// A pulled request finished (completed or shed): free its id.
    pub fn retire(&self, id: u64) {
        self.lock().live.remove(&id);
    }

    /// Requests currently queued (admitted, not yet pulled).
    pub fn queued_len(&self) -> usize {
        self.lock().queued
    }

    /// Hold queued requests back from [`Ingress::pull`] (submissions
    /// still admit). Lets a test or replay enqueue a complete request
    /// set before the coordinator starts, reproducing closed-batch
    /// admission order exactly.
    pub fn pause(&self) {
        self.paused.store(true, Ordering::SeqCst);
    }

    /// Release a [`Ingress::pause`].
    pub fn resume(&self) {
        self.paused.store(false, Ordering::SeqCst);
    }

    /// Begin draining: all further submissions are rejected with
    /// [`Reject::ShuttingDown`]; in-flight sequences run to completion.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// True once [`Ingress::shutdown`] was called.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, adapter_id: Option<u32>) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            adapter_id,
            priority: 0,
        }
    }

    fn sink() -> Box<dyn TokenSink> {
        Box::new(VecSink::default())
    }

    #[test]
    fn admits_and_pulls_fifo_within_a_tenant() {
        let ing = Ingress::new(8, 0.0, 0);
        for id in 0..3 {
            ing.submit_at(req(id, None), sink(), 0.0).unwrap();
        }
        assert_eq!(ing.queued_len(), 3);
        let got = ing.pull(8);
        let ids: Vec<u64> = got.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(ing.queued_len(), 0);
    }

    #[test]
    fn round_robins_across_tenants() {
        let ing = Ingress::new(16, 0.0, 0);
        // tenant 0 floods first, tenant 1 arrives later: round-robin
        // still alternates instead of draining tenant 0 first
        for id in 0..4 {
            ing.submit_at(req(id, Some(0)), sink(), 0.0).unwrap();
        }
        for id in 10..12 {
            ing.submit_at(req(id, Some(1)), sink(), 0.0).unwrap();
        }
        let ids: Vec<u64> = ing.pull(16).iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![0, 10, 1, 11, 2, 3]);
    }

    #[test]
    fn weighted_drr_serves_tenants_in_proportion() {
        let ing = Ingress::new(32, 0.0, 0);
        ing.set_tenant_weight(Some(0), 3.0);
        // weights may be set before a tenant's first submission
        ing.set_tenant_weight(Some(1), 1.0);
        for id in 0..8 {
            ing.submit_at(req(id, Some(0)), sink(), 0.0).unwrap();
        }
        for id in 10..14 {
            ing.submit_at(req(id, Some(1)), sink(), 0.0).unwrap();
        }
        let ids: Vec<u64> = ing.pull(8).iter().map(|(r, _)| r.id).collect();
        // each pass: tenant 0 banks 3 credits (3 requests), tenant 1
        // banks 1 — a 3:1 service ratio, never zero for tenant 1
        assert_eq!(ids, vec![0, 1, 2, 10, 3, 4, 5, 11]);
        let rest: Vec<u64> = ing.pull(32).iter().map(|(r, _)| r.id).collect();
        assert_eq!(rest, vec![6, 7, 12, 13], "drained tenants reset, nobody starves");
    }

    #[test]
    fn fractional_weights_bank_credit_without_starving() {
        let ing = Ingress::new(32, 0.0, 0);
        ing.set_tenant_weight(Some(1), 0.5);
        for id in 0..6 {
            ing.submit_at(req(id, Some(0)), sink(), 0.0).unwrap();
        }
        for id in 10..13 {
            ing.submit_at(req(id, Some(1)), sink(), 0.0).unwrap();
        }
        let ids: Vec<u64> = ing.pull(9).iter().map(|(r, _)| r.id).collect();
        // tenant 1 pops every second turn (0.5 + 0.5 = 1 credit): a
        // fractional weight delays service but never denies it
        assert_eq!(ids, vec![0, 1, 10, 2, 3, 11, 4, 5, 12]);
    }

    #[test]
    fn priority_classes_preempt_fifo_within_a_tenant() {
        let ing = Ingress::new(8, 0.0, 0);
        let mut lo = req(0, None);
        lo.priority = 0;
        let mut hi1 = req(1, None);
        hi1.priority = 2;
        let mut mid = req(2, None);
        mid.priority = 1;
        let mut hi2 = req(3, None);
        hi2.priority = 2;
        for r in [lo, hi1, mid, hi2] {
            ing.submit_at(r, sink(), 0.0).unwrap();
        }
        let ids: Vec<u64> = ing.pull(8).iter().map(|(r, _)| r.id).collect();
        // strict classes, FIFO inside a class
        assert_eq!(ids, vec![1, 3, 2, 0]);
    }

    #[test]
    fn queue_depth_backpressure_records_typed_overload_sheds() {
        let ing = Ingress::new(2, 0.0, 0);
        ing.submit_at(req(0, None), sink(), 0.0).unwrap();
        ing.submit_at(req(1, None), sink(), 0.0).unwrap();
        assert_eq!(ing.submit_at(req(2, None), sink(), 0.0), Err(Reject::QueueFull));
        assert_eq!(ing.submit_at(req(3, None), sink(), 0.0), Err(Reject::QueueFull));
        let shed = ing.drain_rejected();
        assert_eq!(shed.len(), 2);
        assert!(shed.iter().all(|s| s.reason == FailReason::Overload));
        assert_eq!(shed[0].id, 2);
        // draining is destructive
        assert!(ing.drain_rejected().is_empty());
    }

    #[test]
    fn token_bucket_rate_limits_per_tenant() {
        let ing = Ingress::new(64, 2.0, 0); // 2 req/s, burst of 2
        ing.submit_at(req(0, Some(0)), sink(), 0.0).unwrap();
        ing.submit_at(req(1, Some(0)), sink(), 0.0).unwrap();
        let r = ing.submit_at(req(2, Some(0)), sink(), 0.0);
        match r {
            Err(Reject::RateLimit { retry_after_s }) => {
                assert!(retry_after_s > 0.0 && retry_after_s <= 0.5, "{retry_after_s}");
            }
            other => panic!("expected rate limit, got {other:?}"),
        }
        // an unrelated tenant has its own bucket
        ing.submit_at(req(3, Some(1)), sink(), 0.0).unwrap();
        // half a second refills one token at 2/s
        ing.submit_at(req(2, Some(0)), sink(), 0.5).unwrap();
        assert_eq!(ing.drain_rejected().len(), 1);
        assert_eq!(
            ing.drain_rejected().len(),
            0,
            "the eventually-admitted retry left no stale shed"
        );
    }

    #[test]
    fn duplicate_ids_are_rejected_until_retired() {
        let ing = Ingress::new(8, 0.0, 0);
        ing.submit_at(req(7, None), sink(), 0.0).unwrap();
        assert_eq!(ing.submit_at(req(7, None), sink(), 0.0), Err(Reject::DuplicateId));
        let _ = ing.pull(8);
        // still live while decoding
        assert_eq!(ing.submit_at(req(7, None), sink(), 0.0), Err(Reject::DuplicateId));
        ing.retire(7);
        ing.submit_at(req(7, None), sink(), 0.0).unwrap();
    }

    #[test]
    fn invalid_requests_are_rejected_up_front() {
        let ing = Ingress::new(8, 0.0, 0);
        let mut empty = req(0, None);
        empty.prompt.clear();
        assert!(matches!(
            ing.submit_at(empty, sink(), 0.0),
            Err(Reject::Invalid(_))
        ));
        let mut zero = req(1, None);
        zero.max_new_tokens = 0;
        assert!(matches!(ing.submit_at(zero, sink(), 0.0), Err(Reject::Invalid(_))));
    }

    #[test]
    fn oversized_prompts_are_rejected_at_the_edge() {
        let ing = Ingress::new(8, 0.0, 4);
        ing.submit_at(req(0, None), sink(), 0.0).unwrap();
        let mut long = req(1, None);
        long.prompt = vec![1; 5];
        match ing.submit_at(long, sink(), 0.0) {
            Err(Reject::Invalid(why)) => assert!(why.contains("prefill bucket"), "{why}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
        // the cap is exact
        let mut fits = req(2, None);
        fits.prompt = vec![1; 4];
        ing.submit_at(fits, sink(), 0.0).unwrap();
    }

    #[test]
    fn pause_holds_pull_but_not_submission() {
        let ing = Ingress::new(8, 0.0, 0);
        ing.pause();
        ing.submit_at(req(0, None), sink(), 0.0).unwrap();
        assert!(ing.pull(8).is_empty(), "paused ingress releases nothing");
        assert_eq!(ing.queued_len(), 1);
        ing.resume();
        assert_eq!(ing.pull(8).len(), 1);
    }

    #[test]
    fn shutdown_rejects_new_work_and_drains_the_queue() {
        let ing = Ingress::new(8, 0.0, 0);
        ing.submit_at(req(0, None), sink(), 0.0).unwrap();
        ing.shutdown();
        assert!(ing.is_shutdown());
        assert_eq!(ing.submit_at(req(1, None), sink(), 0.0), Err(Reject::ShuttingDown));
        let drained = ing.drain_all();
        assert_eq!(drained.len(), 1);
        assert_eq!(ing.queued_len(), 0);
    }

    #[test]
    fn vec_sink_records_the_stream() {
        let mut s = VecSink::default();
        assert!(s.on_token(1, 10));
        assert!(s.on_token(1, 11));
        s.on_shed(1, FailReason::Shutdown);
        assert_eq!(s.tokens, vec![10, 11]);
        assert_eq!(s.shed, Some(FailReason::Shutdown));
        assert!(s.done.is_none());
    }
}
