//! The 6-stage partition pipeline schedule (paper §V-B): each batch
//! traverses partitions 0→P−1, one partition per pipeline cycle; up to
//! P batches are in flight, each on a *different* partition in any
//! given cycle — full macro utilization at steady state.
//!
//! The schedule itself is pure and exhaustively testable; the server
//! executes the ops it emits against the PJRT runtime.

/// One unit of work: `slot`'s current token-step runs on `partition`
/// during `cycle`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageOp {
    /// Pipeline cycle the op executes in.
    pub cycle: usize,
    /// Partition (pipeline stage) executing it.
    pub partition: usize,
    /// Batch slot whose token it advances.
    pub slot: usize,
}

/// Compute the pipelined schedule for one "token round": every slot in
/// `slots` must pass through all `n_partitions` stages in order. Slot
/// `i` is skewed by `i` cycles, so at steady state all partitions are
/// busy simultaneously on different slots.
#[derive(Debug, Clone)]
pub struct PipelineSchedule {
    /// Stage ops in execution order (sorted by cycle).
    pub ops: Vec<StageOp>,
    /// Cycles the round occupies.
    pub n_cycles: usize,
}

impl PipelineSchedule {
    /// Schedule one token round for `slots` over `n_partitions` stages.
    pub fn for_round(slots: &[usize], n_partitions: usize) -> Self {
        let mut ops = Vec::with_capacity(slots.len() * n_partitions);
        let mut n_cycles = 0;
        for (lane, &slot) in slots.iter().enumerate() {
            for part in 0..n_partitions {
                let cycle = lane + part;
                ops.push(StageOp {
                    cycle,
                    partition: part,
                    slot,
                });
                n_cycles = n_cycles.max(cycle + 1);
            }
        }
        // execute in cycle order (then partition order for determinism)
        ops.sort_by_key(|o| (o.cycle, o.partition));
        PipelineSchedule { ops, n_cycles }
    }

    /// Pipeline utilization: busy partition-cycles / total
    /// partition-cycles.
    pub fn utilization(&self, n_partitions: usize) -> f64 {
        if self.n_cycles == 0 {
            return 0.0;
        }
        self.ops.len() as f64 / (self.n_cycles * n_partitions) as f64
    }

    /// Validate the two pipeline invariants (DESIGN.md §7.8):
    /// 1. no partition executes two slots in the same cycle;
    /// 2. each slot visits partitions strictly in order, one per cycle.
    pub fn validate(&self, n_partitions: usize) -> Result<(), String> {
        use std::collections::HashMap;
        let mut busy: HashMap<(usize, usize), usize> = HashMap::new();
        for op in &self.ops {
            if let Some(prev) = busy.insert((op.cycle, op.partition), op.slot) {
                return Err(format!(
                    "partition {} double-booked in cycle {} (slots {} and {})",
                    op.partition, op.cycle, prev, op.slot
                ));
            }
        }
        let mut per_slot: HashMap<usize, Vec<(usize, usize)>> = HashMap::new();
        for op in &self.ops {
            per_slot.entry(op.slot).or_default().push((op.cycle, op.partition));
        }
        for (slot, mut visits) in per_slot {
            visits.sort();
            let parts: Vec<usize> = visits.iter().map(|v| v.1).collect();
            if parts != (0..n_partitions).collect::<Vec<_>>() {
                return Err(format!("slot {slot} visited partitions out of order: {parts:?}"));
            }
            for w in visits.windows(2) {
                if w[1].0 != w[0].0 + 1 {
                    return Err(format!("slot {slot} skipped a cycle: {visits:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::prop_assert;

    #[test]
    fn single_slot_runs_sequentially() {
        let s = PipelineSchedule::for_round(&[0], 6);
        assert_eq!(s.n_cycles, 6);
        assert_eq!(s.ops.len(), 6);
        s.validate(6).unwrap();
    }

    #[test]
    fn full_load_reaches_steady_state_utilization() {
        // 6 slots × 6 partitions: 11 cycles, 36 ops → 54.5% for one
        // round; at streaming steady state (round after round) the
        // middle cycles are 100% busy.
        let slots: Vec<usize> = (0..6).collect();
        let s = PipelineSchedule::for_round(&slots, 6);
        assert_eq!(s.n_cycles, 11);
        assert_eq!(s.ops.len(), 36);
        s.validate(6).unwrap();
        // cycle 5 (0-indexed) must have all 6 partitions busy
        let busy5 = s.ops.iter().filter(|o| o.cycle == 5).count();
        assert_eq!(busy5, 6);
    }

    #[test]
    fn schedule_valid_for_any_slot_set() {
        check(0x5CED, 100, |g| {
            let n_parts = g.usize(1, 8);
            let n_slots = g.usize(0, 8);
            let slots: Vec<usize> = (0..n_slots).collect();
            let s = PipelineSchedule::for_round(&slots, n_parts);
            if let Err(e) = s.validate(n_parts) {
                return Err(e);
            }
            prop_assert!(
                s.ops.len() == n_slots * n_parts,
                "op count {} != {}",
                s.ops.len(),
                n_slots * n_parts
            );
            Ok(())
        });
    }

    #[test]
    fn non_contiguous_slot_sets_schedule_cleanly() {
        // continuous batching frees slots mid-flight, so rounds
        // routinely run over gappy sets like {1, 3, 5}: lanes are
        // positional (skew by lane index), slot ids pass through
        let s = PipelineSchedule::for_round(&[1, 3, 5], 4);
        s.validate(4).unwrap();
        assert_eq!(s.ops.len(), 12);
        assert_eq!(s.n_cycles, 4 + 2); // 3 lanes, last starts at cycle 2
        let mut seen: Vec<usize> = s.ops.iter().map(|o| o.slot).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen, vec![1, 3, 5]);
        // lane skew follows list position, not slot id: slot 3 (lane 1)
        // runs partition 0 in cycle 1
        assert!(s
            .ops
            .iter()
            .any(|o| o.slot == 3 && o.partition == 0 && o.cycle == 1));
    }

    #[test]
    fn schedule_valid_for_sparse_random_slot_ids() {
        check(0x51A7, 100, |g| {
            let n_parts = g.usize(1, 8);
            let n_slots = g.usize(0, 6);
            // strictly increasing ids with random gaps (slot ids carry
            // no contiguity guarantee whatsoever)
            let mut slots = Vec::with_capacity(n_slots);
            let mut next = g.usize(0, 3);
            for _ in 0..n_slots {
                slots.push(next);
                next += g.usize(1, 5);
            }
            let s = PipelineSchedule::for_round(&slots, n_parts);
            if let Err(e) = s.validate(n_parts) {
                return Err(e);
            }
            prop_assert!(
                s.ops.len() == slots.len() * n_parts,
                "op count {} != {}",
                s.ops.len(),
                slots.len() * n_parts
            );
            for (lane, &slot) in slots.iter().enumerate() {
                prop_assert!(
                    s.ops
                        .iter()
                        .any(|o| o.slot == slot && o.partition == 0 && o.cycle == lane),
                    "slot {slot} does not enter at its lane cycle {lane}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn utilization_improves_with_batching() {
        let u1 = PipelineSchedule::for_round(&[0], 6).utilization(6);
        let u6 = PipelineSchedule::for_round(&[0, 1, 2, 3, 4, 5], 6).utilization(6);
        assert!(u6 > 3.0 * u1, "u1={u1} u6={u6}");
    }

    #[test]
    fn ops_emitted_in_cycle_order() {
        let s = PipelineSchedule::for_round(&[0, 1, 2], 4);
        for w in s.ops.windows(2) {
            assert!(w[0].cycle <= w[1].cycle);
        }
    }
}
