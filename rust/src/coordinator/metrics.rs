//! Serving metrics: TTFT (time to first token), TBT (token-between-
//! token), throughput, compute-time summaries, and the measured
//! KV-tier and adapter-serving statistics read back from the
//! backend's KV store / adapter registry after a trace.

use crate::kvcache::KvStoreStats;
use crate::lora::LoraServeStats;
use crate::util::stats::{Percentiles, Summary};
use crate::util::table::fmt_pct;

/// Aggregate metrics of one served trace.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Time-to-first-token distribution (admission to first token).
    pub ttft: Percentiles,
    /// Token-between-token gap distribution.
    pub tbt: Percentiles,
    /// Total tokens emitted.
    pub tokens_out: u64,
    /// Requests run to completion.
    pub requests_done: u64,
    /// Serving-clock span of the whole trace (s).
    pub wall_s: f64,
    /// Actual prefill *execution* time per request (embed + all
    /// partition stages + head) — distinct from TTFT, which also
    /// contains the admission-queue wait.
    pub prefill_time: Summary,
    /// Actual decode execution time per token (same decomposition).
    pub decode_time: Summary,
    /// Measured KV-store statistics for the trace: tiered access
    /// counts (the end-to-end Fig 5(b) quantity), evictions, retention
    /// health and memory energy. `None` when the backend's KV is
    /// opaque to the host (the PJRT runtime).
    pub kv: Option<KvStoreStats>,
    /// Measured adapter-serving statistics for the trace: tenant
    /// binds, cold-load streaming against the tiered memory model, and
    /// the adapter/base MACs actually executed (the measured per-token
    /// op overhead). `None` when the backend serves no adapter
    /// registry.
    pub lora: Option<LoraServeStats>,
}

impl ServeMetrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Default::default()
    }

    /// Admission-to-first-token (includes any wait for a pipeline
    /// round, not just prefill compute).
    pub fn record_ttft(&mut self, s: f64) {
        self.ttft.add(s);
    }

    /// Wall gap between consecutive tokens of one sequence.
    pub fn record_tbt(&mut self, s: f64) {
        self.tbt.add(s);
    }

    /// Backend execution time of one prefill (compute only).
    pub fn record_prefill(&mut self, s: f64) {
        self.prefill_time.add(s);
    }

    /// Backend execution time of one decode token (compute only).
    pub fn record_decode(&mut self, s: f64) {
        self.decode_time.add(s);
    }

    /// Trace throughput over the serving clock.
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.wall_s
        }
    }

    /// Max observed TBT — must stay below the eDRAM tREF for the
    /// refresh-on-read argument to hold (checked by the server).
    pub fn max_tbt(&mut self) -> f64 {
        self.tbt.pct(100.0)
    }

    /// Human-readable summary (latency, throughput, and — when the
    /// backend exposes a KV store — the measured tier statistics).
    pub fn report(&mut self) -> String {
        let max_tbt = self.max_tbt();
        let mut out = format!(
            "requests={} tokens={} wall={:.2}s throughput={:.1} tok/s\n\
             TTFT  p50={:.1}ms p95={:.1}ms\n\
             TBT   p50={:.2}ms p95={:.2}ms max={:.2}ms",
            self.requests_done,
            self.tokens_out,
            self.wall_s,
            self.tokens_per_s(),
            self.ttft.pct(50.0) * 1e3,
            self.ttft.pct(95.0) * 1e3,
            self.tbt.pct(50.0) * 1e3,
            self.tbt.pct(95.0) * 1e3,
            max_tbt * 1e3,
        );
        if let Some(kv) = &self.kv {
            out.push_str(&format!(
                "\nKV    on-die {} / external {} accesses ({} external reduction, \
                 q{} blocks of {}); evictions={} spills={} refreshes={} \
                 energy {:.3e} J",
                kv.accesses.ondie_reads + kv.accesses.ondie_writes,
                kv.accesses.external_accesses(),
                fmt_pct(kv.external_reduction()),
                kv.quant_bits,
                kv.block_tokens,
                kv.evictions,
                kv.spilled_early_blocks,
                kv.explicit_refreshes,
                kv.kv_energy_j(),
            ));
        }
        if let Some(lora) = &self.lora {
            if lora.binds > 0 {
                out.push_str(&format!(
                    "\nLoRA  binds={} cold-loads={} ({} B streamed, {:.3e} J); \
                     adapter/base MACs {}/{} = {} measured op overhead",
                    lora.binds,
                    lora.cold_loads,
                    lora.bytes_streamed,
                    lora.stream_energy_j,
                    lora.adapter_macs,
                    lora.base_macs,
                    fmt_pct(lora.measured_op_overhead()),
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServeMetrics::new();
        m.record_ttft(0.100);
        m.record_tbt(0.010);
        m.record_tbt(0.020);
        m.tokens_out = 3;
        m.wall_s = 0.130;
        m.requests_done = 1;
        assert!((m.tokens_per_s() - 23.08).abs() < 0.1);
        assert!((m.max_tbt() - 0.020).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("TTFT"));
    }

    #[test]
    fn compute_times_are_independent_of_latency_metrics() {
        // prefill compute is its own series: recording a TTFT (queue
        // wait included) must not pollute it, and TBT must not leak
        // into decode compute.
        let mut m = ServeMetrics::new();
        m.record_ttft(0.500);
        m.record_tbt(0.100);
        assert_eq!(m.prefill_time.count(), 0);
        assert_eq!(m.decode_time.count(), 0);
        m.record_prefill(0.004);
        m.record_decode(0.002);
        assert_eq!(m.prefill_time.count(), 1);
        assert!((m.prefill_time.mean() - 0.004).abs() < 1e-12);
        assert!((m.decode_time.mean() - 0.002).abs() < 1e-12);
    }

    #[test]
    fn report_includes_kv_section_only_when_measured() {
        let mut m = ServeMetrics::new();
        m.record_ttft(0.1);
        assert!(!m.report().contains("KV "));
        let mut kv = KvStoreStats::default();
        kv.accesses.ondie_reads = 30;
        kv.accesses.external_reads = 10;
        kv.quant_bits = 8;
        kv.block_tokens = 8;
        m.kv = Some(kv);
        let r = m.report();
        assert!(r.contains("external reduction"), "{r}");
        assert!(r.contains("evictions=0"), "{r}");
    }

    #[test]
    fn report_includes_lora_section_only_when_adapters_served() {
        let mut m = ServeMetrics::new();
        m.record_ttft(0.1);
        assert!(!m.report().contains("LoRA"), "no registry, no section");
        // a registry that saw zero binds stays silent too (invariant 7
        // runs report identically to adapter-free runs)
        m.lora = Some(LoraServeStats::default());
        assert!(!m.report().contains("LoRA"));
        m.lora = Some(LoraServeStats {
            binds: 3,
            cold_loads: 2,
            bytes_streamed: 1024,
            stream_energy_j: 1e-9,
            adapter_macs: 100,
            base_macs: 10_000,
            adapter_rows: 12,
        });
        let r = m.report();
        assert!(r.contains("binds=3"), "{r}");
        assert!(r.contains("1.0%"), "measured overhead rendered: {r}");
    }
}
