//! Serving metrics: TTFT (time to first token), TBT (token-between-
//! token), throughput, plus the eDRAM-health counters the DR argument
//! depends on.

use crate::util::stats::{Percentiles, Summary};

#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub ttft: Percentiles,
    pub tbt: Percentiles,
    pub tokens_out: u64,
    pub requests_done: u64,
    pub wall_s: f64,
    pub prefill_time: Summary,
    pub decode_time: Summary,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Default::default()
    }

    pub fn record_ttft(&mut self, s: f64) {
        self.ttft.add(s);
    }

    pub fn record_tbt(&mut self, s: f64) {
        self.tbt.add(s);
        self.decode_time.add(s);
    }

    pub fn record_prefill(&mut self, s: f64) {
        self.prefill_time.add(s);
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.wall_s
        }
    }

    /// Max observed TBT — must stay below the eDRAM tREF for the
    /// refresh-on-read argument to hold (checked by the server).
    pub fn max_tbt(&mut self) -> f64 {
        self.tbt.pct(100.0)
    }

    pub fn report(&mut self) -> String {
        let max_tbt = self.max_tbt();
        format!(
            "requests={} tokens={} wall={:.2}s throughput={:.1} tok/s\n\
             TTFT  p50={:.1}ms p95={:.1}ms\n\
             TBT   p50={:.2}ms p95={:.2}ms max={:.2}ms",
            self.requests_done,
            self.tokens_out,
            self.wall_s,
            self.tokens_per_s(),
            self.ttft.pct(50.0) * 1e3,
            self.ttft.pct(95.0) * 1e3,
            self.tbt.pct(50.0) * 1e3,
            self.tbt.pct(95.0) * 1e3,
            max_tbt * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServeMetrics::new();
        m.record_ttft(0.100);
        m.record_tbt(0.010);
        m.record_tbt(0.020);
        m.tokens_out = 3;
        m.wall_s = 0.130;
        m.requests_done = 1;
        assert!((m.tokens_per_s() - 23.08).abs() < 0.1);
        assert!((m.max_tbt() - 0.020).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("TTFT"));
    }
}
