//! Serving metrics: TTFT (time to first token), TBT (token-between-
//! token), throughput, plus the eDRAM-health counters the DR argument
//! depends on.

use crate::util::stats::{Percentiles, Summary};

#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub ttft: Percentiles,
    pub tbt: Percentiles,
    pub tokens_out: u64,
    pub requests_done: u64,
    pub wall_s: f64,
    /// Actual prefill *execution* time per request (embed + all
    /// partition stages + head) — distinct from TTFT, which also
    /// contains the admission-queue wait.
    pub prefill_time: Summary,
    /// Actual decode execution time per token (same decomposition).
    pub decode_time: Summary,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Default::default()
    }

    /// Admission-to-first-token (includes any wait for a pipeline
    /// round, not just prefill compute).
    pub fn record_ttft(&mut self, s: f64) {
        self.ttft.add(s);
    }

    /// Wall gap between consecutive tokens of one sequence.
    pub fn record_tbt(&mut self, s: f64) {
        self.tbt.add(s);
    }

    /// Backend execution time of one prefill (compute only).
    pub fn record_prefill(&mut self, s: f64) {
        self.prefill_time.add(s);
    }

    /// Backend execution time of one decode token (compute only).
    pub fn record_decode(&mut self, s: f64) {
        self.decode_time.add(s);
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.wall_s
        }
    }

    /// Max observed TBT — must stay below the eDRAM tREF for the
    /// refresh-on-read argument to hold (checked by the server).
    pub fn max_tbt(&mut self) -> f64 {
        self.tbt.pct(100.0)
    }

    pub fn report(&mut self) -> String {
        let max_tbt = self.max_tbt();
        format!(
            "requests={} tokens={} wall={:.2}s throughput={:.1} tok/s\n\
             TTFT  p50={:.1}ms p95={:.1}ms\n\
             TBT   p50={:.2}ms p95={:.2}ms max={:.2}ms",
            self.requests_done,
            self.tokens_out,
            self.wall_s,
            self.tokens_per_s(),
            self.ttft.pct(50.0) * 1e3,
            self.ttft.pct(95.0) * 1e3,
            self.tbt.pct(50.0) * 1e3,
            self.tbt.pct(95.0) * 1e3,
            max_tbt * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServeMetrics::new();
        m.record_ttft(0.100);
        m.record_tbt(0.010);
        m.record_tbt(0.020);
        m.tokens_out = 3;
        m.wall_s = 0.130;
        m.requests_done = 1;
        assert!((m.tokens_per_s() - 23.08).abs() < 0.1);
        assert!((m.max_tbt() - 0.020).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("TTFT"));
    }

    #[test]
    fn compute_times_are_independent_of_latency_metrics() {
        // prefill compute is its own series: recording a TTFT (queue
        // wait included) must not pollute it, and TBT must not leak
        // into decode compute.
        let mut m = ServeMetrics::new();
        m.record_ttft(0.500);
        m.record_tbt(0.100);
        assert_eq!(m.prefill_time.count(), 0);
        assert_eq!(m.decode_time.count(), 0);
        m.record_prefill(0.004);
        m.record_decode(0.002);
        assert_eq!(m.prefill_time.count(), 1);
        assert!((m.prefill_time.mean() - 0.004).abs() < 1e-12);
        assert!((m.decode_time.mean() - 0.002).abs() < 1e-12);
    }
}
