//! Serving metrics: TTFT (time to first token), TBT (token-between-
//! token), throughput, compute-time summaries, the measured
//! KV-tier and adapter-serving statistics read back from the
//! backend's KV store / adapter registry after a trace, and — when a
//! fault plan or degradation policy is active — the fault/recovery
//! accounting ([`FaultMetrics`]) with per-request shed reasons
//! ([`FailReason`]).

use crate::kvcache::KvStoreStats;
use crate::lora::LoraServeStats;
use crate::util::stats::{Percentiles, Summary};
use crate::util::table::fmt_pct;

/// Why one request was failed/shed instead of completed (DESIGN.md
/// §13). Every non-completion is accounted under exactly one of these —
/// invariant 9's "typed reason".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    /// DR-eDRAM retention expired and the recompute budget ran out.
    Retention,
    /// Transient backend faults exhausted the retry budget.
    Backend,
    /// Transient adapter cold-load faults exhausted the retry budget.
    AdapterLoad,
    /// KV capacity faults exhausted the retry budget.
    KvCapacity,
    /// Shed from the admission queue after waiting past the overload
    /// deadline, or rejected because the ingress queue was full.
    Overload,
    /// Rejected by the per-tenant token-bucket rate limit (HTTP 429).
    RateLimit,
    /// The client went away mid-stream; decoding stopped.
    Disconnect,
    /// In the queue when a graceful shutdown drained the server.
    Shutdown,
}

impl FailReason {
    /// Every reason, for exhaustive per-reason accounting (metrics
    /// exposition prints one series per reason so scrape shape is
    /// stable).
    pub const ALL: [FailReason; 8] = [
        FailReason::Retention,
        FailReason::Backend,
        FailReason::AdapterLoad,
        FailReason::KvCapacity,
        FailReason::Overload,
        FailReason::RateLimit,
        FailReason::Disconnect,
        FailReason::Shutdown,
    ];
}

impl std::fmt::Display for FailReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FailReason::Retention => write!(f, "retention"),
            FailReason::Backend => write!(f, "backend"),
            FailReason::AdapterLoad => write!(f, "adapter-load"),
            FailReason::KvCapacity => write!(f, "kv-capacity"),
            FailReason::Overload => write!(f, "overload"),
            FailReason::RateLimit => write!(f, "rate-limit"),
            FailReason::Disconnect => write!(f, "disconnect"),
            FailReason::Shutdown => write!(f, "shutdown"),
        }
    }
}

/// One shed/failed request: its trace id and the typed reason.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedRequest {
    /// The request's trace id.
    pub id: u64,
    /// Why it was shed.
    pub reason: FailReason,
}

/// Fault-injection and degradation accounting for one served trace.
/// All-zero (the `Default`) when no fault plan or pressure policy was
/// configured — the report then prints no Faults section, keeping
/// fault-free output byte-identical.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct FaultMetrics {
    /// Retention-clock storm skips injected by the plan.
    pub injected_skips: u64,
    /// Transient faults injected by the plan (before retry handling).
    pub injected_transients: u64,
    /// Retention expiries observed on KV reads (each maps 1:1 onto a
    /// `KvStore` retention failure).
    pub retention_events: u64,
    /// Sequences recovered by dropping their expired KV and replaying
    /// prompt + emitted tokens (bit-identical by invariant 4).
    pub recomputes: u64,
    /// Tokens re-derived by those recomputes.
    pub recomputed_tokens: u64,
    /// Transient-fault retries granted (skip-round backoff).
    pub retries: u64,
    /// Active slots preempted under memory pressure (KV swapped out to
    /// the external tier; values intact, no recompute).
    pub preemptions: u64,
    /// KV blocks demoted by those preemptions.
    pub demoted_blocks: u64,
    /// Admissions deferred because measured KV pressure was above the
    /// configured threshold.
    pub admission_deferrals: u64,
    /// Requests shed with their typed reasons, in shed order.
    pub shed: Vec<ShedRequest>,
}

impl FaultMetrics {
    /// Shed-request count per reason (for reports and gates).
    pub fn shed_count(&self, reason: FailReason) -> u64 {
        self.shed.iter().filter(|s| s.reason == reason).count() as u64
    }
}

/// Aggregate metrics of one served trace. `Clone` so the live serving
/// plane can publish consistent snapshots to `/metrics` scrapers while
/// the coordinator keeps mutating its working copy.
#[derive(Debug, Default, Clone)]
pub struct ServeMetrics {
    /// Time-to-first-token distribution (admission to first token).
    pub ttft: Percentiles,
    /// Token-between-token gap distribution.
    pub tbt: Percentiles,
    /// TTFT measured in *decode rounds* (round-indexed virtual time):
    /// rounds from admission to the first emitted token. Wall-clock
    /// free, so trace mode reports identical values on every machine.
    pub ttft_rounds: Percentiles,
    /// Per-token gap measured in decode rounds (1.0 = the sequence
    /// produced a token every round; higher = backoff/recovery stalls).
    pub tbt_rounds: Percentiles,
    /// Total tokens emitted.
    pub tokens_out: u64,
    /// Requests run to completion.
    pub requests_done: u64,
    /// Serving-clock span of the whole trace (s).
    pub wall_s: f64,
    /// Actual prefill *execution* time per request (embed + all
    /// partition stages + head) — distinct from TTFT, which also
    /// contains the admission-queue wait.
    pub prefill_time: Summary,
    /// Actual decode execution time per token (same decomposition).
    pub decode_time: Summary,
    /// Measured KV-store statistics for the trace: tiered access
    /// counts (the end-to-end Fig 5(b) quantity), evictions, retention
    /// health and memory energy. `None` when the backend's KV is
    /// opaque to the host (the PJRT runtime).
    pub kv: Option<KvStoreStats>,
    /// Measured adapter-serving statistics for the trace: tenant
    /// binds, cold-load streaming against the tiered memory model, and
    /// the adapter/base MACs actually executed (the measured per-token
    /// op overhead). `None` when the backend serves no adapter
    /// registry.
    pub lora: Option<LoraServeStats>,
    /// Fault-injection and degradation accounting (DESIGN.md §13).
    /// Stays all-zero — and absent from the report — when no fault
    /// plan or pressure policy is configured.
    pub faults: FaultMetrics,
}

impl ServeMetrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Default::default()
    }

    /// Admission-to-first-token (includes any wait for a pipeline
    /// round, not just prefill compute).
    pub fn record_ttft(&mut self, s: f64) {
        self.ttft.add(s);
    }

    /// Wall gap between consecutive tokens of one sequence.
    pub fn record_tbt(&mut self, s: f64) {
        self.tbt.add(s);
    }

    /// Rounds from admission to first token (round-indexed TTFT).
    pub fn record_ttft_rounds(&mut self, rounds: u64) {
        self.ttft_rounds.add(rounds as f64);
    }

    /// Rounds between consecutive tokens of one sequence.
    pub fn record_tbt_rounds(&mut self, rounds: u64) {
        self.tbt_rounds.add(rounds as f64);
    }

    /// Backend execution time of one prefill (compute only).
    pub fn record_prefill(&mut self, s: f64) {
        self.prefill_time.add(s);
    }

    /// Backend execution time of one decode token (compute only).
    pub fn record_decode(&mut self, s: f64) {
        self.decode_time.add(s);
    }

    /// Trace throughput over the serving clock.
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.wall_s
        }
    }

    /// Max observed TBT — must stay below the eDRAM tREF for the
    /// refresh-on-read argument to hold (checked by the server).
    pub fn max_tbt(&mut self) -> f64 {
        self.tbt.pct(100.0)
    }

    /// Human-readable summary (latency, throughput, and — when the
    /// backend exposes a KV store — the measured tier statistics).
    pub fn report(&mut self) -> String {
        let max_tbt = self.max_tbt();
        let mut out = format!(
            "requests={} tokens={} wall={:.2}s throughput={:.1} tok/s\n\
             TTFT  p50={:.1}ms p95={:.1}ms p99={:.1}ms\n\
             TBT   p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.requests_done,
            self.tokens_out,
            self.wall_s,
            self.tokens_per_s(),
            self.ttft.pct(50.0) * 1e3,
            self.ttft.pct(95.0) * 1e3,
            self.ttft.pct(99.0) * 1e3,
            self.tbt.pct(50.0) * 1e3,
            self.tbt.pct(95.0) * 1e3,
            self.tbt.pct(99.0) * 1e3,
            max_tbt * 1e3,
        );
        if !self.ttft_rounds.is_empty() {
            out.push_str(&format!(
                "\nRound TTFT p50={:.0} p95={:.0} p99={:.0}; \
                 TBT p50={:.1} p95={:.1} p99={:.1} (decode rounds)",
                self.ttft_rounds.pct(50.0),
                self.ttft_rounds.pct(95.0),
                self.ttft_rounds.pct(99.0),
                self.tbt_rounds.pct(50.0),
                self.tbt_rounds.pct(95.0),
                self.tbt_rounds.pct(99.0),
            ));
        }
        if let Some(kv) = &self.kv {
            out.push_str(&format!(
                "\nKV    on-die {} / external {} accesses ({} external reduction, \
                 q{} blocks of {}); evictions={} spills={} refreshes={} \
                 energy {:.3e} J",
                kv.accesses.ondie_reads + kv.accesses.ondie_writes,
                kv.accesses.external_accesses(),
                fmt_pct(kv.external_reduction()),
                kv.quant_bits,
                kv.block_tokens,
                kv.evictions,
                kv.spilled_early_blocks,
                kv.explicit_refreshes,
                kv.kv_energy_j(),
            ));
            // only when sharing actually happened: prefix-free runs
            // keep their report byte-identical (invariant 7)
            if kv.prefix_hits > 0 {
                out.push_str(&format!(
                    "\nPrefix hits={} bound tokens={} cow forks={}",
                    kv.prefix_hits, kv.prefix_bound_tokens, kv.cow_forks,
                ));
            }
        }
        if self.faults != FaultMetrics::default() {
            let f = &self.faults;
            out.push_str(&format!(
                "\nFault injected skips={} transients={}; retention events={} \
                 recomputes={} ({} tokens) retries={}; preemptions={} \
                 (blocks demoted={}) deferrals={}; shed={}",
                f.injected_skips,
                f.injected_transients,
                f.retention_events,
                f.recomputes,
                f.recomputed_tokens,
                f.retries,
                f.preemptions,
                f.demoted_blocks,
                f.admission_deferrals,
                f.shed.len(),
            ));
            for s in &f.shed {
                out.push_str(&format!("\n      shed request {} ({})", s.id, s.reason));
            }
        }
        if let Some(lora) = &self.lora {
            if lora.binds > 0 {
                out.push_str(&format!(
                    "\nLoRA  binds={} cold-loads={} ({} B streamed, {:.3e} J); \
                     adapter/base MACs {}/{} = {} measured op overhead",
                    lora.binds,
                    lora.cold_loads,
                    lora.bytes_streamed,
                    lora.stream_energy_j,
                    lora.adapter_macs,
                    lora.base_macs,
                    fmt_pct(lora.measured_op_overhead()),
                ));
            }
        }
        out
    }

    /// Prometheus text exposition (served at `GET /metrics`). Counters
    /// carry the `_total` suffix; latency distributions are rendered as
    /// quantile-labelled gauges (full summaries would need streaming
    /// quantile sketches — out of scope for a reference server). One
    /// `bitrom_faults_shed_total` series per [`FailReason`] is always
    /// present so scrape shape is stable across fault-free and faulted
    /// runs.
    pub fn prometheus(&mut self) -> String {
        let mut out = String::new();
        out.push_str("# TYPE bitrom_requests_done_total counter\n");
        out.push_str(&format!("bitrom_requests_done_total {}\n", self.requests_done));
        out.push_str("# TYPE bitrom_tokens_total counter\n");
        out.push_str(&format!("bitrom_tokens_total {}\n", self.tokens_out));
        out.push_str("# TYPE bitrom_throughput_tokens_per_second gauge\n");
        out.push_str(&format!(
            "bitrom_throughput_tokens_per_second {}\n",
            self.tokens_per_s()
        ));
        fn quantiles(out: &mut String, name: &str, p: &mut Percentiles) {
            if p.is_empty() {
                return;
            }
            out.push_str(&format!("# TYPE {name} summary\n"));
            for q in [50.0, 95.0, 99.0] {
                out.push_str(&format!(
                    "{name}{{quantile=\"{}\"}} {}\n",
                    q / 100.0,
                    p.pct(q)
                ));
            }
        }
        quantiles(&mut out, "bitrom_ttft_seconds", &mut self.ttft);
        quantiles(&mut out, "bitrom_tbt_seconds", &mut self.tbt);
        quantiles(&mut out, "bitrom_ttft_rounds", &mut self.ttft_rounds);
        quantiles(&mut out, "bitrom_tbt_rounds", &mut self.tbt_rounds);
        let f = &self.faults;
        for (name, v) in [
            ("bitrom_faults_injected_skips_total", f.injected_skips),
            ("bitrom_faults_injected_transients_total", f.injected_transients),
            ("bitrom_faults_retention_events_total", f.retention_events),
            ("bitrom_faults_recomputes_total", f.recomputes),
            ("bitrom_faults_recomputed_tokens_total", f.recomputed_tokens),
            ("bitrom_faults_retries_total", f.retries),
            ("bitrom_faults_preemptions_total", f.preemptions),
            ("bitrom_faults_admission_deferrals_total", f.admission_deferrals),
        ] {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        out.push_str("# TYPE bitrom_faults_shed_total counter\n");
        for reason in FailReason::ALL {
            out.push_str(&format!(
                "bitrom_faults_shed_total{{reason=\"{reason}\"}} {}\n",
                self.faults.shed_count(reason)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = ServeMetrics::new();
        m.record_ttft(0.100);
        m.record_tbt(0.010);
        m.record_tbt(0.020);
        m.tokens_out = 3;
        m.wall_s = 0.130;
        m.requests_done = 1;
        assert!((m.tokens_per_s() - 23.08).abs() < 0.1);
        assert!((m.max_tbt() - 0.020).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("TTFT"));
    }

    #[test]
    fn compute_times_are_independent_of_latency_metrics() {
        // prefill compute is its own series: recording a TTFT (queue
        // wait included) must not pollute it, and TBT must not leak
        // into decode compute.
        let mut m = ServeMetrics::new();
        m.record_ttft(0.500);
        m.record_tbt(0.100);
        assert_eq!(m.prefill_time.count(), 0);
        assert_eq!(m.decode_time.count(), 0);
        m.record_prefill(0.004);
        m.record_decode(0.002);
        assert_eq!(m.prefill_time.count(), 1);
        assert!((m.prefill_time.mean() - 0.004).abs() < 1e-12);
        assert!((m.decode_time.mean() - 0.002).abs() < 1e-12);
    }

    #[test]
    fn report_includes_kv_section_only_when_measured() {
        let mut m = ServeMetrics::new();
        m.record_ttft(0.1);
        assert!(!m.report().contains("KV "));
        let mut kv = KvStoreStats::default();
        kv.accesses.ondie_reads = 30;
        kv.accesses.external_reads = 10;
        kv.quant_bits = 8;
        kv.block_tokens = 8;
        m.kv = Some(kv);
        let r = m.report();
        assert!(r.contains("external reduction"), "{r}");
        assert!(r.contains("evictions=0"), "{r}");
        // the prefix line appears only once sharing actually happened
        assert!(!r.contains("Prefix"), "{r}");
        m.kv.as_mut().unwrap().prefix_hits = 2;
        m.kv.as_mut().unwrap().prefix_bound_tokens = 16;
        m.kv.as_mut().unwrap().cow_forks = 1;
        let r = m.report();
        assert!(r.contains("Prefix hits=2 bound tokens=16 cow forks=1"), "{r}");
    }

    #[test]
    fn report_includes_fault_section_only_when_something_happened() {
        let mut m = ServeMetrics::new();
        m.record_ttft(0.1);
        assert!(!m.report().contains("Fault"), "quiet run, no section");
        m.faults.retention_events = 2;
        m.faults.recomputes = 2;
        m.faults.shed.push(ShedRequest {
            id: 7,
            reason: FailReason::Overload,
        });
        let r = m.report();
        assert!(r.contains("retention events=2"), "{r}");
        assert!(r.contains("shed request 7 (overload)"), "{r}");
        assert_eq!(m.faults.shed_count(FailReason::Overload), 1);
        assert_eq!(m.faults.shed_count(FailReason::Backend), 0);
    }

    #[test]
    fn fail_reasons_render_distinctly() {
        let shown: std::collections::BTreeSet<String> =
            FailReason::ALL.iter().map(|r| r.to_string()).collect();
        assert_eq!(shown.len(), FailReason::ALL.len());
    }

    #[test]
    fn round_latency_percentiles_are_wall_clock_free() {
        let mut m = ServeMetrics::new();
        assert!(!m.report().contains("Round TTFT"), "no samples, no section");
        m.record_ttft_rounds(1);
        m.record_ttft_rounds(3);
        m.record_tbt_rounds(1);
        m.record_tbt_rounds(1);
        m.record_tbt_rounds(7); // a recovery stall
        assert_eq!(m.tbt_rounds.pct(50.0), 1.0);
        assert_eq!(m.tbt_rounds.pct(100.0), 7.0);
        assert!(m.report().contains("Round TTFT"), "{}", m.report());
    }

    #[test]
    fn prometheus_exposition_is_scrape_stable() {
        let mut m = ServeMetrics::new();
        m.tokens_out = 5;
        m.requests_done = 2;
        m.wall_s = 1.0;
        let quiet = m.prometheus();
        assert!(quiet.contains("bitrom_tokens_total 5\n"), "{quiet}");
        assert!(quiet.contains("bitrom_requests_done_total 2\n"));
        // empty latency series are omitted (no NaN quantiles)...
        assert!(!quiet.contains("bitrom_ttft_seconds"));
        // ...but every shed-reason series is present even at zero
        for reason in FailReason::ALL {
            assert!(
                quiet.contains(&format!("bitrom_faults_shed_total{{reason=\"{reason}\"}} 0\n")),
                "{quiet}"
            );
        }
        m.record_ttft(0.25);
        m.record_ttft_rounds(2);
        m.faults.shed.push(ShedRequest {
            id: 9,
            reason: FailReason::RateLimit,
        });
        let hot = m.prometheus();
        assert!(hot.contains("bitrom_ttft_seconds{quantile=\"0.5\"} 0.25\n"), "{hot}");
        assert!(hot.contains("bitrom_ttft_rounds{quantile=\"0.99\"} 2\n"), "{hot}");
        assert!(hot.contains("bitrom_faults_shed_total{reason=\"rate-limit\"} 1\n"), "{hot}");
    }

    #[test]
    fn report_includes_lora_section_only_when_adapters_served() {
        let mut m = ServeMetrics::new();
        m.record_ttft(0.1);
        assert!(!m.report().contains("LoRA"), "no registry, no section");
        // a registry that saw zero binds stays silent too (invariant 7
        // runs report identically to adapter-free runs)
        m.lora = Some(LoraServeStats::default());
        assert!(!m.report().contains("LoRA"));
        m.lora = Some(LoraServeStats {
            binds: 3,
            cold_loads: 2,
            bytes_streamed: 1024,
            stream_energy_j: 1e-9,
            adapter_macs: 100,
            base_macs: 10_000,
            adapter_rows: 12,
        });
        let r = m.report();
        assert!(r.contains("binds=3"), "{r}");
        assert!(r.contains("1.0%"), "measured overhead rendered: {r}");
    }
}
