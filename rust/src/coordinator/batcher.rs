//! Dynamic batcher: admits queued requests into free pipeline slots
//! (continuous batching at token granularity — a finished sequence
//! frees its slot for the next request mid-flight, vLLM-style, bounded
//! by the paper's 6 in-flight batches).

use std::collections::VecDeque;

use crate::trace::Request;

/// What a pipeline slot is doing.
#[derive(Debug, Clone, PartialEq)]
pub enum SlotState {
    /// Empty, awaiting admission.
    Free,
    /// Admitted, prefill not yet executed.
    NeedsPrefill,
    /// Decoding; `generated` tokens produced so far.
    Decoding { generated: usize },
}

/// One pipeline slot (an in-flight batch lane).
#[derive(Debug)]
pub struct Slot {
    /// Current lifecycle state.
    pub state: SlotState,
    /// The request occupying the slot, if any.
    pub request: Option<Request>,
    /// Tokens generated so far (including the prefill's first token).
    pub output: Vec<i32>,
    /// Admission timestamp (s).
    pub admitted_at: f64,
}

impl Slot {
    fn free() -> Self {
        Slot {
            state: SlotState::Free,
            request: None,
            output: Vec::new(),
            admitted_at: 0.0,
        }
    }
}

/// FIFO continuous batcher over a fixed set of pipeline slots.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<Request>,
    slots: Vec<Slot>,
}

impl Batcher {
    /// Batcher with `max_batches` slots.
    pub fn new(max_batches: usize) -> Self {
        Batcher {
            queue: VecDeque::new(),
            slots: (0..max_batches).map(|_| Slot::free()).collect(),
        }
    }

    /// Number of pipeline slots.
    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Enqueue a request for admission.
    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Arrival time of the next admissible queued request (admission is
    /// FIFO, so this is the earliest instant `admit` can make progress
    /// — the serving loop skips or sleeps to it when idle).
    pub fn next_arrival(&self) -> Option<f64> {
        self.queue.front().map(|r| r.arrival_s)
    }

    /// Admit arrived requests into free slots. Returns admitted slot ids.
    pub fn admit(&mut self, now: f64) -> Vec<usize> {
        let mut admitted = Vec::new();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if slot.state != SlotState::Free {
                continue;
            }
            // FIFO admission of requests whose arrival time has passed
            match self.queue.front() {
                Some(r) if r.arrival_s <= now => {
                    let req = self.queue.pop_front().unwrap();
                    slot.state = SlotState::NeedsPrefill;
                    slot.request = Some(req);
                    slot.output.clear();
                    slot.admitted_at = now;
                    admitted.push(i);
                }
                _ => break,
            }
        }
        admitted
    }

    /// Inspect slot `i`.
    pub fn slot(&self, i: usize) -> &Slot {
        &self.slots[i]
    }

    /// Mutate slot `i`.
    pub fn slot_mut(&mut self, i: usize) -> &mut Slot {
        &mut self.slots[i]
    }

    /// Slots currently holding work (prefill or decode).
    pub fn active_slots(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.state != SlotState::Free)
            .map(|(i, _)| i)
            .collect()
    }

    /// Release a finished slot, returning its request + output.
    pub fn release(&mut self, i: usize) -> (Request, Vec<i32>, f64) {
        let slot = std::mem::replace(&mut self.slots[i], Slot::free());
        (
            slot.request.expect("releasing empty slot"),
            slot.output,
            slot.admitted_at,
        )
    }

    /// True when nothing is queued and every slot is free.
    pub fn all_idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(|s| s.state == SlotState::Free)
    }

    /// Overload shedding: pop queued requests that have waited longer
    /// than `max_wait` seconds (FIFO head first, so shedding preserves
    /// arrival order for everyone behind). Returns the shed requests;
    /// the caller accounts them with a typed reason (DESIGN.md §13).
    pub fn drop_queued_older_than(&mut self, now: f64, max_wait: f64) -> Vec<Request> {
        let mut shed = Vec::new();
        while let Some(head) = self.queue.front() {
            if head.arrival_s + max_wait < now {
                shed.push(self.queue.pop_front().expect("non-empty queue head"));
            } else {
                break;
            }
        }
        shed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request {
            id,
            arrival_s: arrival,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            adapter_id: None,
            priority: 0,
        }
    }

    #[test]
    fn admits_up_to_capacity() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.submit(req(i, 0.0));
        }
        let admitted = b.admit(0.0);
        assert_eq!(admitted, vec![0, 1]);
        assert_eq!(b.queued(), 3);
        assert_eq!(b.active_slots(), vec![0, 1]);
    }

    #[test]
    fn next_arrival_tracks_fifo_head() {
        let mut b = Batcher::new(1);
        assert_eq!(b.next_arrival(), None);
        b.submit(req(0, 1.5));
        b.submit(req(1, 9.0));
        assert_eq!(b.next_arrival(), Some(1.5));
        b.admit(2.0);
        assert_eq!(b.next_arrival(), Some(9.0));
    }

    #[test]
    fn respects_arrival_times() {
        let mut b = Batcher::new(4);
        b.submit(req(0, 0.0));
        b.submit(req(1, 10.0));
        assert_eq!(b.admit(0.5).len(), 1);
        assert_eq!(b.admit(0.6).len(), 0); // #1 hasn't arrived
        assert_eq!(b.admit(10.5).len(), 1);
    }

    #[test]
    fn fifo_order_is_preserved() {
        let mut b = Batcher::new(1);
        b.submit(req(7, 0.0));
        b.submit(req(8, 0.0));
        b.admit(0.0);
        assert_eq!(b.slot(0).request.as_ref().unwrap().id, 7);
        let (r, _, _) = b.release(0);
        assert_eq!(r.id, 7);
        b.admit(0.0);
        assert_eq!(b.slot(0).request.as_ref().unwrap().id, 8);
    }

    #[test]
    fn release_frees_capacity() {
        let mut b = Batcher::new(1);
        b.submit(req(0, 0.0));
        b.submit(req(1, 0.0));
        b.admit(0.0);
        b.slot_mut(0).output.push(42);
        let (r0, out, _) = b.release(0);
        assert_eq!(r0.id, 0);
        assert_eq!(out, vec![42]);
        assert_eq!(b.admit(1.0), vec![0]);
        assert!(!b.all_idle());
        b.release(0);
        assert!(b.all_idle());
    }

    #[test]
    #[should_panic(expected = "empty slot")]
    fn releasing_free_slot_panics() {
        Batcher::new(1).release(0);
    }

    #[test]
    fn overload_shedding_drops_only_expired_queue_heads() {
        let mut b = Batcher::new(1);
        b.submit(req(0, 0.0));
        b.submit(req(1, 0.5));
        b.submit(req(2, 5.0));
        // at t=2 with a 1s deadline: #0 (waited 2s) and #1 (1.5s) shed,
        // #2 hasn't even arrived
        let shed = b.drop_queued_older_than(2.0, 1.0);
        assert_eq!(shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.queued(), 1);
        assert_eq!(b.next_arrival(), Some(5.0));
        // nothing more to shed
        assert!(b.drop_queued_older_than(2.0, 1.0).is_empty());
    }

    #[test]
    fn admission_is_fifo_and_tenant_blind() {
        // mixed adapter_ids ride the same FIFO queue: admission order
        // and slot assignment never depend on the tenant, so no
        // adapter can starve another (fairness is arrival order)
        let mut b = Batcher::new(2);
        let tenants = [Some(1u32), None, Some(0), Some(1), None];
        for (i, &t) in tenants.iter().enumerate() {
            b.submit(Request {
                adapter_id: t,
                ..req(i as u64, 0.0)
            });
        }
        assert_eq!(b.admit(0.0), vec![0, 1]);
        assert_eq!(b.slot(0).request.as_ref().unwrap().adapter_id, Some(1));
        assert_eq!(b.slot(1).request.as_ref().unwrap().adapter_id, None);
        let (r0, _, _) = b.release(0);
        assert_eq!(r0.id, 0);
        // the freed slot takes the FIFO head regardless of tenant
        assert_eq!(b.admit(0.0), vec![0]);
        let got = b.slot(0).request.as_ref().unwrap();
        assert_eq!((got.id, got.adapter_id), (2, Some(0)));
    }
}
