//! ModelExecutor — owns the PJRT client and the compiled partition
//! executables; exposes prefill / decode-step operations over explicit
//! per-sequence KV state. This is the compute backend the coordinator's
//! pipeline schedules onto.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::ModelConfig;

use super::backend::{InferenceBackend, KvControl, Logits, SequenceState, ServeTuning};
use super::manifest::Manifest;
use super::tensor::{i32_scalar, tokens_to_literal, TensorF32};

/// Per-sequence decoding state: the KV literals for every partition and
/// the current absolute position.
pub struct DecodeState {
    /// `[n_partitions]` cache pairs, each `[L_p, max_seq, kv_heads, hd]`.
    k: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    /// Number of positions already written (next token goes here).
    pub pos: usize,
    /// Prompt length after prefill.
    pub prompt_len: usize,
}

/// Whole-model decode state for the fused fast path: one cache pair
/// spanning all layers.
pub struct FusedState {
    k: xla::Literal,
    v: xla::Literal,
    /// Positions already written.
    pub pos: usize,
}

/// The PJRT artifact runtime: compiled executables loaded once,
/// weights resident as constants (the CiROM deployment model).
pub struct ModelExecutor {
    /// The artifact manifest this executor was loaded from.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    embed_prefill: xla::PjRtLoadedExecutable,
    embed_decode: xla::PjRtLoadedExecutable,
    head_prefill: xla::PjRtLoadedExecutable,
    head_decode: xla::PjRtLoadedExecutable,
    parts_prefill: Vec<xla::PjRtLoadedExecutable>,
    parts_decode: Vec<xla::PjRtLoadedExecutable>,
    /// Fused whole-model executables (one PJRT dispatch per token) —
    /// the single-stream fast path (EXPERIMENTS.md §Perf L3). Optional:
    /// absent in older artifact sets.
    fused_prefill: Option<xla::PjRtLoadedExecutable>,
    fused_decode: Option<xla::PjRtLoadedExecutable>,
    /// Wall time of the load+compile power-on (s).
    pub load_time_s: f64,
}

impl ModelExecutor {
    /// Load + compile every artifact ("power-on"): after this returns,
    /// no weight data ever moves again.
    pub fn load(dir: &Path) -> Result<Self> {
        let t0 = Instant::now();
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let info = manifest.artifact(name)?;
            let proto = xla::HloModuleProto::from_text_file(&info.file)
                .map_err(|e| anyhow!("parsing {}: {e:?}", info.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))
        };

        let mut parts_prefill = Vec::new();
        let mut parts_decode = Vec::new();
        for p in 0..manifest.model.n_partitions {
            parts_prefill.push(compile(&format!("part{p}_prefill"))?);
            parts_decode.push(compile(&format!("part{p}_decode"))?);
        }
        let fused_prefill = manifest
            .artifact("full_prefill")
            .ok()
            .map(|_| compile("full_prefill"))
            .transpose()?;
        let fused_decode = manifest
            .artifact("full_decode")
            .ok()
            .map(|_| compile("full_decode"))
            .transpose()?;
        Ok(ModelExecutor {
            embed_prefill: compile("embed_prefill")?,
            embed_decode: compile("embed_decode")?,
            head_prefill: compile("head_prefill")?,
            head_decode: compile("head_decode")?,
            parts_prefill,
            parts_decode,
            fused_prefill,
            fused_decode,
            load_time_s: t0.elapsed().as_secs_f64(),
            client,
            manifest,
        })
    }

    /// True when fused whole-model executables are available.
    pub fn has_fused(&self) -> bool {
        self.fused_prefill.is_some() && self.fused_decode.is_some()
    }

    /// Pipeline partitions in the compiled model.
    pub fn n_partitions(&self) -> usize {
        self.manifest.model.n_partitions
    }

    fn cache_dims(&self) -> Vec<usize> {
        let m = &self.manifest.model;
        vec![
            m.layers_per_partition(),
            m.max_seq,
            m.n_kv_heads,
            m.head_dim(),
        ]
    }

    /// Fresh (zeroed) decode state.
    pub fn new_state(&self) -> Result<DecodeState> {
        let dims = self.cache_dims();
        let n = self.n_partitions();
        let mut k = Vec::with_capacity(n);
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            k.push(TensorF32::zeros(dims.clone()).to_literal()?);
            v.push(TensorF32::zeros(dims.clone()).to_literal()?);
        }
        Ok(DecodeState {
            k,
            v,
            pos: 0,
            prompt_len: 0,
        })
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = exe.execute::<&xla::Literal>(inputs)?;
        let out = bufs[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// One partition's prefill step (exposed for pipeline scheduling).
    pub fn run_partition_prefill(
        &self,
        part: usize,
        h: &xla::Literal,
        state: &mut DecodeState,
    ) -> Result<xla::Literal> {
        let outs = self.run(
            &self.parts_prefill[part],
            &[h, &state.k[part], &state.v[part]],
        )?;
        let mut it = outs.into_iter();
        let h_out = it.next().ok_or_else(|| anyhow!("missing h output"))?;
        state.k[part] = it.next().ok_or_else(|| anyhow!("missing k output"))?;
        state.v[part] = it.next().ok_or_else(|| anyhow!("missing v output"))?;
        Ok(h_out)
    }

    /// One partition's decode step at absolute position `pos`.
    pub fn run_partition_decode(
        &self,
        part: usize,
        h: &xla::Literal,
        pos: usize,
        state: &mut DecodeState,
    ) -> Result<xla::Literal> {
        let pos_lit = i32_scalar(pos as i32);
        let outs = self.run(
            &self.parts_decode[part],
            &[h, &state.k[part], &state.v[part], &pos_lit],
        )?;
        let mut it = outs.into_iter();
        let h_out = it.next().ok_or_else(|| anyhow!("missing h output"))?;
        state.k[part] = it.next().ok_or_else(|| anyhow!("missing k output"))?;
        state.v[part] = it.next().ok_or_else(|| anyhow!("missing v output"))?;
        Ok(h_out)
    }

    /// Embed a padded prompt bucket.
    pub fn embed_prompt(&self, prompt: &[i32]) -> Result<xla::Literal> {
        let p = self.manifest.prefill_len;
        anyhow::ensure!(
            !prompt.is_empty() && prompt.len() <= p,
            "prompt length {} not in 1..={p}",
            prompt.len()
        );
        let mut padded = prompt.to_vec();
        padded.resize(p, 0); // causal masking makes pad contents invisible
        let toks = tokens_to_literal(&padded)?;
        let outs = self.run(&self.embed_prefill, &[&toks])?;
        outs.into_iter().next().ok_or_else(|| anyhow!("no embed output"))
    }

    /// Embed a single decode token.
    pub fn embed_token(&self, token: i32) -> Result<xla::Literal> {
        let toks = tokens_to_literal(&[token])?;
        let outs = self.run(&self.embed_decode, &[&toks])?;
        outs.into_iter().next().ok_or_else(|| anyhow!("no embed output"))
    }

    /// LM head over prefill hidden states at row `idx`.
    pub fn head_at(&self, h: &xla::Literal, idx: usize) -> Result<TensorF32> {
        let outs = self.run(&self.head_prefill, &[h, &i32_scalar(idx as i32)])?;
        let logits = outs.into_iter().next().ok_or_else(|| anyhow!("no logits"))?;
        TensorF32::from_literal(&logits, vec![self.manifest.model.vocab_size])
    }

    /// LM head over a decode hidden state.
    pub fn head_decode_logits(&self, h: &xla::Literal) -> Result<TensorF32> {
        let outs = self.run(&self.head_decode, &[h])?;
        let logits = outs.into_iter().next().ok_or_else(|| anyhow!("no logits"))?;
        TensorF32::from_literal(&logits, vec![self.manifest.model.vocab_size])
    }

    /// Full prefill: runs the prompt through every partition in order
    /// and returns (state, last-token logits).
    pub fn prefill(&self, prompt: &[i32]) -> Result<(DecodeState, TensorF32)> {
        let mut state = self.new_state()?;
        let mut h = self.embed_prompt(prompt)?;
        for part in 0..self.n_partitions() {
            h = self.run_partition_prefill(part, &h, &mut state)?;
        }
        let logits = self.head_at(&h, prompt.len() - 1)?;
        state.pos = prompt.len();
        state.prompt_len = prompt.len();
        Ok((state, logits))
    }

    /// One full decode step for `token` (written at `state.pos`);
    /// returns next-token logits.
    pub fn decode_step(&self, state: &mut DecodeState, token: i32) -> Result<TensorF32> {
        let max_seq = self.manifest.model.max_seq;
        anyhow::ensure!(state.pos < max_seq, "sequence exceeds max_seq {max_seq}");
        let mut h = self.embed_token(token)?;
        let pos = state.pos;
        for part in 0..self.n_partitions() {
            h = self.run_partition_decode(part, &h, pos, state)?;
        }
        state.pos += 1;
        self.head_decode_logits(&h)
    }

    // ---- fused fast path ---------------------------------------------

    fn full_cache_dims(&self) -> Vec<usize> {
        let m = &self.manifest.model;
        vec![m.n_layers, m.max_seq, m.n_kv_heads, m.head_dim()]
    }

    /// Whole-model prefill in one PJRT dispatch.
    pub fn fused_prefill(&self, prompt: &[i32]) -> Result<(FusedState, TensorF32)> {
        let exe = self
            .fused_prefill
            .as_ref()
            .ok_or_else(|| anyhow!("artifacts lack full_prefill (rerun make artifacts)"))?;
        let p = self.manifest.prefill_len;
        anyhow::ensure!(
            !prompt.is_empty() && prompt.len() <= p,
            "prompt length {} not in 1..={p}",
            prompt.len()
        );
        let mut padded = prompt.to_vec();
        padded.resize(p, 0);
        let toks = tokens_to_literal(&padded)?;
        let dims = self.full_cache_dims();
        let k0 = TensorF32::zeros(dims.clone()).to_literal()?;
        let v0 = TensorF32::zeros(dims).to_literal()?;
        let idx = i32_scalar(prompt.len() as i32 - 1);
        let outs = self.run(exe, &[&toks, &k0, &v0, &idx])?;
        let mut it = outs.into_iter();
        let logits = it.next().ok_or_else(|| anyhow!("missing logits"))?;
        let k = it.next().ok_or_else(|| anyhow!("missing k"))?;
        let v = it.next().ok_or_else(|| anyhow!("missing v"))?;
        Ok((
            FusedState {
                k,
                v,
                pos: prompt.len(),
            },
            TensorF32::from_literal(&logits, vec![self.manifest.model.vocab_size])?,
        ))
    }

    /// Whole-model decode step in one PJRT dispatch.
    pub fn fused_decode_step(&self, state: &mut FusedState, token: i32) -> Result<TensorF32> {
        let exe = self
            .fused_decode
            .as_ref()
            .ok_or_else(|| anyhow!("artifacts lack full_decode (rerun make artifacts)"))?;
        let max_seq = self.manifest.model.max_seq;
        anyhow::ensure!(state.pos < max_seq, "sequence exceeds max_seq {max_seq}");
        let toks = tokens_to_literal(&[token])?;
        let pos = i32_scalar(state.pos as i32);
        let outs = self.run(exe, &[&toks, &state.k, &state.v, &pos])?;
        let mut it = outs.into_iter();
        let logits = it.next().ok_or_else(|| anyhow!("missing logits"))?;
        state.k = it.next().ok_or_else(|| anyhow!("missing k"))?;
        state.v = it.next().ok_or_else(|| anyhow!("missing v"))?;
        state.pos += 1;
        TensorF32::from_literal(&logits, vec![self.manifest.model.vocab_size])
    }

    /// Greedy generation (prefill + n steps). Uses the fused fast path
    /// when the artifacts provide it; the coordinator's batched
    /// pipeline always uses the partitioned executables.
    pub fn generate_greedy(&self, prompt: &[i32], n_new: usize) -> Result<Vec<i32>> {
        if self.has_fused() {
            let (mut state, logits) = self.fused_prefill(prompt)?;
            let mut out = Vec::with_capacity(n_new);
            let mut tok = logits.argmax() as i32;
            out.push(tok);
            for _ in 1..n_new {
                let logits = self.fused_decode_step(&mut state, tok)?;
                tok = logits.argmax() as i32;
                out.push(tok);
            }
            return Ok(out);
        }
        self.generate_greedy_partitioned(prompt, n_new)
    }

    /// Greedy generation through the partitioned (pipeline-unit) path.
    pub fn generate_greedy_partitioned(
        &self,
        prompt: &[i32],
        n_new: usize,
    ) -> Result<Vec<i32>> {
        let (mut state, logits) = self.prefill(prompt)?;
        let mut out = Vec::with_capacity(n_new);
        let mut tok = logits.argmax() as i32;
        out.push(tok);
        for _ in 1..n_new {
            let logits = self.decode_step(&mut state, tok)?;
            tok = logits.argmax() as i32;
            out.push(tok);
        }
        Ok(out)
    }
}

impl SequenceState for DecodeState {
    fn pos(&self) -> usize {
        self.pos
    }
    fn set_pos(&mut self, pos: usize) {
        self.pos = pos;
    }
    fn prompt_len(&self) -> usize {
        self.prompt_len
    }
    fn set_prompt_len(&mut self, len: usize) {
        self.prompt_len = len;
    }
}

/// Device-side KV is opaque to the host (DESIGN.md §10), so every
/// [`KvControl`] hook keeps its no-op/miss default — the executor only
/// pins the sequence-state type.
impl KvControl for ModelExecutor {
    type Seq = DecodeState;
}

/// No host-side kernels or adapter registry to tune: the compiled
/// artifacts fix both, so the [`ServeTuning`] defaults (no-op width
/// and path setters, `None` adapter stats) are exactly right.
impl ServeTuning for ModelExecutor {}

/// The PJRT executor is the hardware-shaped implementation of the
/// serving contract (DESIGN.md §9) — pure delegation to the inherent
/// methods above, no behavior change. `realtime()` is true: PJRT
/// dispatch latency is wall-clock-meaningful, so the coordinator honors
/// request arrival times by sleeping instead of skipping ahead.
impl InferenceBackend for ModelExecutor {
    type State = DecodeState;
    type Hidden = xla::Literal;

    fn model(&self) -> &ModelConfig {
        &self.manifest.model
    }

    fn prefill_len(&self) -> usize {
        self.manifest.prefill_len
    }

    fn realtime(&self) -> bool {
        true
    }

    fn new_state(&self) -> Result<DecodeState> {
        ModelExecutor::new_state(self)
    }

    fn embed_prompt(&self, prompt: &[i32]) -> Result<xla::Literal> {
        ModelExecutor::embed_prompt(self, prompt)
    }

    fn embed_token(&self, token: i32) -> Result<xla::Literal> {
        ModelExecutor::embed_token(self, token)
    }

    fn run_partition_prefill(
        &self,
        part: usize,
        h: &xla::Literal,
        state: &mut DecodeState,
    ) -> Result<xla::Literal> {
        ModelExecutor::run_partition_prefill(self, part, h, state)
    }

    fn run_partition_decode(
        &self,
        part: usize,
        h: &xla::Literal,
        pos: usize,
        state: &mut DecodeState,
    ) -> Result<xla::Literal> {
        ModelExecutor::run_partition_decode(self, part, h, pos, state)
    }

    fn head_at(&self, h: &xla::Literal, idx: usize) -> Result<Logits> {
        Ok(Logits::new(ModelExecutor::head_at(self, h, idx)?.data))
    }

    fn head_decode_logits(&self, h: &xla::Literal) -> Result<Logits> {
        Ok(Logits::new(ModelExecutor::head_decode_logits(self, h)?.data))
    }
}
