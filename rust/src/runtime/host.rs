//! HostBackend — the always-built, fully offline implementation of
//! [`InferenceBackend`]: a small BitNet-style partitioned transformer
//! whose ternary projections run on the word-parallel bitplane kernel
//! engine ([`TernaryMatrix`] GEMV/GEMM, DESIGN.md §8), with f32
//! attention + RMSNorm, and per-sequence KV held in the tiered
//! [`KvStore`] (DESIGN.md §10): K/V rows are 8-bit quantized into
//! paged blocks that live in DR eDRAM or spill to external DRAM, so a
//! served trace *measures* the paper's KV-placement claims instead of
//! modeling them on the side. Attention reads dequantize per block;
//! because rows are quantized once at append time, prefill and chunked
//! decode still agree bit-exactly (invariant 4).
//!
//! Weights are fabricated deterministically from a [`ModelConfig`] +
//! seed: absmean-quantized gaussians scaled by 1/√fan_in, which
//! reproduces the ~30% zero-weight statistics of a real BitNet b1.58
//! mask set. The model is random, not trained — what it exercises is
//! the *serving machinery*: continuous batching, the partition
//! pipeline, the KV data plane with live retention checking, and
//! metrics all run end-to-end under tier-1 with no artifacts and no
//! PJRT. KV pages are allocated on demand (a [`HostState`] starts
//! empty), but fabricating a billion-parameter config still allocates
//! the full f32 embedding table — clamp `ModelConfig::max_seq` to the
//! context you actually serve before constructing (the `bitrom --host`
//! CLI paths do).
//!
//! Optionally ([`HostBackend::with_cirom_events`]) every projection is
//! routed through the `cirom` macro/bank circuit simulators instead of
//! the bitplane fast path, so a served trace doubles as an
//! event-counting energy study — the two paths are property-tested
//! bit-identical, only the speed (and the [`EventCounters`]) differ.
//!
//! Multi-tenant LoRA serving ([`HostBackend::with_adapters`], DESIGN.md
//! §11): a sequence bound to a tenant adapter via
//! [`ServeTuning::bind_adapter`] gets that tenant's rank-r f32
//! deltas applied on top of the ternary base projections at the
//! registry's placement sites — per sequence, so one batch freely
//! mixes tenants. The base weights never move (task switching is
//! reload-free), and with no adapter bound the compute path is
//! bit-identical to an adapter-free build (invariant 7).
//!
//! The backend is `Sync` and its states are `Send` (DESIGN.md §12):
//! the serving loop runs per-slot prefill/decode rounds on worker
//! threads while admission, KV *allocation* (via
//! [`KvControl::reserve_kv`]), and sampling stay on the coordinator.
//! Projections run through a [`KernelCtx`] (DESIGN.md §17) that shards
//! output columns across the configured worker pool
//! ([`ServeTuning::set_threads`] / `BITROM_THREADS`) on the configured
//! kernel path ([`ServeTuning::set_kernel_path`]); event and adapter
//! counters are tallied per op and merged under a lock — all counters
//! are commutative integer sums, so totals are bit-identical at every
//! thread count and kernel path. Fused batched decode
//! ([`InferenceBackend::run_partition_decode_batch`]) runs one GEMM
//! per projection site across a whole round's decode batch — weight
//! words decoded once per site instead of once per slot — and is
//! bit-identical to the per-slot loop (rows are independent in an
//! exact integer GEMM and each row keeps its own quantization scale).

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, Context, Result};

use crate::bitnet::{absmax_quantize, KernelCtx, KernelPath, QuantizedActs, TernaryMatrix};
use crate::cirom::{EventCounters, MacroBank};
use crate::config::{MacroGeometry, ModelConfig, ServeConfig};
use crate::kvcache::{KvSeq, KvStore, KvStoreConfig, KvStoreStats};
use crate::lora::{apply_adapter_delta, AdapterRegistry, LoraServeStats, Proj};
use crate::util::pool::{env_threads, Pool};
use crate::util::rng::Rng;

use super::backend::{DecodeEntry, InferenceBackend, KvControl, Logits, SequenceState, ServeTuning};

/// Lock-free [`KernelPath`] cell (the path is read per projection, so
/// a mutex would serialize worker threads on a knob that never changes
/// mid-serve).
fn path_to_u8(p: KernelPath) -> u8 {
    match p {
        KernelPath::Auto => 0,
        KernelPath::Scalar => 1,
        KernelPath::BitSerial => 2,
    }
}

fn path_from_u8(v: u8) -> KernelPath {
    match v {
        1 => KernelPath::Scalar,
        2 => KernelPath::BitSerial,
        _ => KernelPath::Auto,
    }
}

/// One ternary projection: packed weights with the cached bitplane
/// compute view, plus (event mode only) the macro-bank tiling.
struct Projection {
    w: TernaryMatrix,
    bank: Option<MacroBank>,
}

impl Projection {
    /// Fabricate `fan_in × fan_out` absmean-ternarized gaussian weights
    /// with variance 1/fan_in (so projected activations stay O(1)).
    fn fabricate(
        fan_in: usize,
        fan_out: usize,
        rng: &mut Rng,
        geom: Option<&MacroGeometry>,
    ) -> Self {
        let inv_sqrt = 1.0 / (fan_in as f64).sqrt();
        let wf: Vec<f32> = (0..fan_in * fan_out)
            .map(|_| (rng.normal() * inv_sqrt) as f32)
            .collect();
        let w = TernaryMatrix::quantize(fan_in, fan_out, &wf);
        let bank = geom.map(|g| MacroBank::fabricate(g.clone(), &w));
        Projection { w, bank }
    }
}

/// One transformer block's weights (pre-norm attention + SwiGLU MLP).
struct Layer {
    wq: Projection,
    wk: Projection,
    wv: Projection,
    wo: Projection,
    w_gate: Projection,
    w_up: Projection,
    w_down: Projection,
}

/// Per-sequence state: block tables into the backend's shared
/// [`KvStore`] (the K/V rows themselves live there, quantized and
/// tiered) plus decode progress. Dropping the state retires its pages
/// back to the store, so on-die tier capacity is recycled across
/// requests.
pub struct HostState {
    /// Per-layer block tables into `store`.
    kv: KvSeq,
    /// The store that owns this state's pages (shared with the backend
    /// and every sibling sequence; `Mutex` because partition stages of
    /// different slots may run on worker threads).
    store: Arc<Mutex<KvStore>>,
    /// Dequantization scratch reused across layers and decode steps
    /// (gather would otherwise re-allocate twice per layer per token).
    kbuf: Vec<f32>,
    vbuf: Vec<f32>,
    /// Number of positions already written (next token goes here).
    pub pos: usize,
    /// Prompt length after prefill.
    pub prompt_len: usize,
    /// Tenant LoRA adapter bound to this sequence (`None` = the frozen
    /// base model). Set once by `bind_adapter` before prefill; every
    /// projection the sequence executes applies this tenant's deltas
    /// at the registry's placement sites.
    pub adapter: Option<u32>,
}

impl Drop for HostState {
    fn drop(&mut self) {
        // recycle this sequence's pages; a poisoned lock (a worker
        // panicked mid-store-op) degrades to a capacity leak instead
        // of a double panic
        if let Ok(mut store) = self.store.lock() {
            store.retire_seq(&mut self.kv);
        }
    }
}

impl SequenceState for HostState {
    fn pos(&self) -> usize {
        self.pos
    }
    fn set_pos(&mut self, pos: usize) {
        self.pos = pos;
    }
    fn prompt_len(&self) -> usize {
        self.prompt_len
    }
    fn set_prompt_len(&mut self, len: usize) {
        self.prompt_len = len;
    }
}

/// The offline serving backend: fabricated ternary weights on the
/// bitplane kernels, KV in the tiered quantized store (module docs).
pub struct HostBackend {
    model: ModelConfig,
    /// Token embedding table, `vocab_size × d_model` row-major f32.
    embed: Vec<f32>,
    layers: Vec<Layer>,
    /// LM head, `d_model × vocab_size`.
    head: Projection,
    /// Present iff constructed with [`Self::with_cirom_events`]:
    /// accumulated circuit events across every projection executed.
    /// Each op tallies into a local counter and merges it here under
    /// the lock — integer sums commute, so the totals are identical at
    /// any thread count (DESIGN.md §12).
    events: Option<Mutex<EventCounters>>,
    /// The tiered KV store every sequence's K/V rows live in. The
    /// outer RwLock lets [`KvControl::configure_kv`] swap in a
    /// deployment-sized store; states keep an `Arc` to the store that
    /// allocated their pages, so a swap never orphans live sequences.
    store: RwLock<Arc<Mutex<KvStore>>>,
    /// Present iff constructed with [`Self::with_adapters`]: the
    /// multi-tenant adapter weights plus residency/MAC accounting.
    /// When absent (or a sequence is bound to `None`) the compute
    /// path is the unmodified base path — adapter-disabled serving is
    /// bit-identical to an adapter-free build (DESIGN.md invariant 7).
    lora: Option<AdapterRegistry>,
    /// Kernel worker-pool width (1 = serial). Seeded from
    /// `BITROM_THREADS` at construction; the server overrides it with
    /// the deployment's `ServeConfig::threads` via
    /// [`ServeTuning::set_threads`]. Width changes speed, never
    /// results.
    threads: AtomicUsize,
    /// Encoded [`KernelPath`] every projection's [`KernelCtx`] uses
    /// (see [`path_to_u8`]); set via [`ServeTuning::set_kernel_path`].
    /// Path changes speed, never results (DESIGN.md §17).
    kernel_path: AtomicU8,
    seed: u64,
}

pub(crate) fn rmsnorm(x: &[f32]) -> Vec<f32> {
    let ms = x.iter().map(|&v| v as f64 * v as f64).sum::<f64>() / x.len().max(1) as f64;
    let inv = (1.0 / (ms + 1e-6).sqrt()) as f32;
    x.iter().map(|&v| v * inv).collect()
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

impl HostBackend {
    /// Fabricate a model on the bitplane fast path.
    pub fn new(model: ModelConfig, seed: u64) -> Result<Self> {
        Self::build(model, seed, None, None)
    }

    /// Fabricate a model whose projections run through the `cirom`
    /// macro/bank simulators with the given geometry, counting energy
    /// events (orders of magnitude slower; same integers).
    pub fn with_cirom_events(model: ModelConfig, seed: u64, geom: MacroGeometry) -> Result<Self> {
        Self::build(model, seed, Some(geom), None)
    }

    /// Fabricate a model that serves the registry's tenant adapters:
    /// sequences bound to an adapter id get that tenant's low-rank
    /// deltas applied at the registry's placement sites; unbound
    /// sequences run the identical base path. The registry is
    /// fabricated from its own seed, so the base weights here match
    /// [`Self::new`] with the same `(model, seed)` exactly.
    pub fn with_adapters(
        model: ModelConfig,
        seed: u64,
        adapters: AdapterRegistry,
    ) -> Result<Self> {
        adapters.compatible_with(&model)?;
        Self::build(model, seed, None, Some(adapters))
    }

    fn build(
        model: ModelConfig,
        seed: u64,
        geom: Option<MacroGeometry>,
        lora: Option<AdapterRegistry>,
    ) -> Result<Self> {
        anyhow::ensure!(
            model.n_layers > 0 && model.n_layers % model.n_partitions == 0,
            "n_layers {} must be a positive multiple of n_partitions {}",
            model.n_layers,
            model.n_partitions
        );
        anyhow::ensure!(
            model.d_model % model.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            model.d_model,
            model.n_heads
        );
        anyhow::ensure!(
            model.n_heads % model.n_kv_heads == 0,
            "n_heads {} not divisible by n_kv_heads {}",
            model.n_heads,
            model.n_kv_heads
        );
        anyhow::ensure!(model.act_bits >= 2, "act_bits must be >= 2");
        let mut rng = Rng::new(seed);
        let (d, kv, ff) = (model.d_model, model.kv_dim(), model.d_ff);
        let embed: Vec<f32> = (0..model.vocab_size * d).map(|_| rng.normal() as f32).collect();
        let g = geom.as_ref();
        let layers: Vec<Layer> = (0..model.n_layers)
            .map(|_| Layer {
                wq: Projection::fabricate(d, d, &mut rng, g),
                wk: Projection::fabricate(d, kv, &mut rng, g),
                wv: Projection::fabricate(d, kv, &mut rng, g),
                wo: Projection::fabricate(d, d, &mut rng, g),
                w_gate: Projection::fabricate(d, ff, &mut rng, g),
                w_up: Projection::fabricate(d, ff, &mut rng, g),
                w_down: Projection::fabricate(ff, d, &mut rng, g),
            })
            .collect();
        let head = Projection::fabricate(d, model.vocab_size, &mut rng, g);
        let store = KvStore::new(KvStoreConfig::for_model(&model));
        Ok(HostBackend {
            events: geom.map(|_| Mutex::new(EventCounters::new())),
            embed,
            layers,
            head,
            store: RwLock::new(Arc::new(Mutex::new(store))),
            lora,
            threads: AtomicUsize::new(env_threads()),
            kernel_path: AtomicU8::new(path_to_u8(KernelPath::Auto)),
            model,
            seed,
        })
    }

    /// The kernel worker pool at the currently configured width.
    fn pool(&self) -> Pool {
        Pool::new(self.threads.load(Ordering::Relaxed))
    }

    /// The [`KernelCtx`] every projection runs through: currently
    /// configured pool width + kernel path (DESIGN.md §17).
    fn ctx(&self) -> KernelCtx {
        KernelCtx::new(self.pool()).with_path(self.kernel_path())
    }

    /// Currently configured kernel worker count.
    pub fn threads(&self) -> usize {
        self.threads.load(Ordering::Relaxed)
    }

    /// Currently configured kernel compute path.
    pub fn kernel_path(&self) -> KernelPath {
        path_from_u8(self.kernel_path.load(Ordering::Relaxed))
    }

    /// The tenant adapter registry, if this backend serves adapters.
    pub fn adapters(&self) -> Option<&AdapterRegistry> {
        self.lora.as_ref()
    }

    /// The weight-fabrication seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The architecture this backend was fabricated for.
    pub fn model(&self) -> &ModelConfig {
        &self.model
    }

    /// Handle to the current KV store (accounting inspection; new
    /// states allocate their pages here).
    ///
    /// The `expect`s on this and every other store-lock acquisition are
    /// documented infallibility, not a panic edge: a poisoned lock
    /// means a worker thread already panicked while holding the store,
    /// and since every store operation returns typed
    /// [`KvError`](crate::kvcache::KvError)s instead of panicking
    /// (invariant 9), that can only be a bug in
    /// the kernels themselves — state no recovery policy could trust.
    pub fn kv_store(&self) -> Arc<Mutex<KvStore>> {
        self.store.read().expect("KV store handle poisoned").clone()
    }

    /// The fabricated LM-head ternary weights (`d_model × vocab_size`)
    /// — what [`ShardedBackend`](crate::runtime::ShardedBackend)
    /// column-splits for its tensor-parallel head.
    pub(crate) fn head_weights(&self) -> &TernaryMatrix {
        &self.head.w
    }

    /// [`KvControl::reserve_kv`] restricted to layers
    /// `[l0, l1)`: a shard of a sharded deployment reserves pages only
    /// for the layers it owns, so per-shard on-die capacity is spent
    /// only on that shard's KV. Same placement-determinism contract as
    /// the full-range reserve.
    pub(crate) fn reserve_kv_range(
        &self,
        state: &mut HostState,
        n_tokens: usize,
        l0: usize,
        l1: usize,
    ) -> Result<()> {
        if n_tokens == 0 {
            return Ok(());
        }
        let mut store = state.store.lock().expect("KV store lock poisoned");
        for li in l0..l1 {
            store.reserve(&mut state.kv, li, n_tokens)?;
        }
        Ok(())
    }

    /// Mean zero-weight fraction across every fabricated projection
    /// (the "ROM sparsity" of this mask set).
    pub fn rom_sparsity(&self) -> f64 {
        let mut total = 0u64;
        let mut zeros = 0f64;
        let mut add = |p: &Projection| {
            let n = (p.w.rows * p.w.cols) as u64;
            total += n;
            zeros += p.w.sparsity() * n as f64;
        };
        for l in &self.layers {
            for p in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down] {
                add(p);
            }
        }
        add(&self.head);
        if total == 0 {
            0.0
        } else {
            zeros / total as f64
        }
    }

    /// Snapshot of the accumulated circuit events (None on the bitplane
    /// fast path).
    pub fn events(&self) -> Option<EventCounters> {
        self.events
            .as_ref()
            .map(|e| e.lock().expect("event counters poisoned").clone())
    }

    /// Zero the accumulated circuit events (event mode only).
    pub fn reset_events(&self) {
        if let Some(e) = &self.events {
            *e.lock().expect("event counters poisoned") = EventCounters::new();
        }
    }

    /// f32 → f32 projection: absmax-quantize the activation, exact
    /// integer GEMV (bitplane or event-counted macro bank), rescale.
    fn project(&self, p: &Projection, x: &[f32]) -> Vec<f32> {
        self.project_q(p, &absmax_quantize(x, self.model.act_bits))
    }

    /// Projection of one already-quantized activation row (bitplane
    /// GEMV or event-counted macro bank), rescaled to f32. Event mode
    /// tallies the op into a local counter and merges it under the
    /// lock — one brief critical section per op, order-independent.
    fn project_q(&self, p: &Projection, acts: &QuantizedActs) -> Vec<f32> {
        let y = match (&p.bank, &self.events) {
            (Some(bank), Some(ev)) => {
                let mut tally = EventCounters::new();
                let y = bank.gemv(acts, &mut tally);
                ev.lock().expect("event counters poisoned").merge(&tally);
                y
            }
            _ => self.ctx().gemv(p.w.bitplanes(), &acts.values),
        };
        let s = acts.scale * p.w.scale;
        y.into_iter().map(|v| v as f32 * s).collect()
    }

    /// Batched projection over activation rows. The bitplane path uses
    /// the batched GEMM kernel; rows are quantized independently, so
    /// the result is bit-identical to mapping [`Self::project`] —
    /// prefill and decode agree exactly (invariant 4).
    fn project_rows(&self, p: &Projection, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let qs: Vec<QuantizedActs> = xs
            .iter()
            .map(|x| absmax_quantize(x, self.model.act_bits))
            .collect();
        self.project_rows_q(p, &qs)
    }

    /// [`Self::project_rows`] over pre-quantized rows: batched flat
    /// bitplane GEMM on the fast path (one allocation for the whole
    /// batch, weight words decoded once per column tile), per-row
    /// event-counted GEMV in event mode — rows are independent either
    /// way.
    fn project_rows_q(&self, p: &Projection, qs: &[QuantizedActs]) -> Vec<Vec<f32>> {
        if self.events.is_some() {
            return qs.iter().map(|q| self.project_q(p, q)).collect();
        }
        let ints: Vec<&[i32]> = qs.iter().map(|q| q.values.as_slice()).collect();
        let mut flat = Vec::new();
        self.ctx().gemm_flat(p.w.bitplanes(), &ints, &mut flat);
        flat.chunks(p.w.cols.max(1))
            .zip(qs)
            .map(|(y, q)| {
                let s = q.scale * p.w.scale;
                y.iter().map(|&v| v as f32 * s).collect()
            })
            .collect()
    }

    /// Batched projection with the bound tenant's low-rank delta
    /// applied when (`li`, `proj`) is an adapter site: base term via
    /// the usual bitplane/event path, then the shared
    /// [`apply_adapter_delta`] per row from the *same* quantized
    /// activations (so merged and dynamic adapters agree bit-exactly,
    /// and prefill ≡ chunked decode survives — the delta is a pure
    /// per-row function). Off-site or unbound calls take the
    /// unmodified base path.
    fn project_rows_site(
        &self,
        p: &Projection,
        xs: &[Vec<f32>],
        li: usize,
        proj: Proj,
        adapter: Option<u32>,
    ) -> Vec<Vec<f32>> {
        let pair = match (&self.lora, adapter) {
            (Some(reg), Some(id)) => reg.site(id, li, proj),
            _ => None,
        };
        let pair = match pair {
            Some(pair) => pair,
            None => return self.project_rows(p, xs),
        };
        let reg = self.lora.as_ref().expect("adapter site implies a registry");
        let qs: Vec<QuantizedActs> = xs
            .iter()
            .map(|x| absmax_quantize(x, self.model.act_bits))
            .collect();
        let mut ys = self.project_rows_q(p, &qs);
        for (q, y) in qs.iter().zip(ys.iter_mut()) {
            apply_adapter_delta(q, &pair.a, &pair.b, reg.lora().rank, reg.alpha(), y);
        }
        reg.record_site_macs(xs.len() as u64, p.w.rows, p.w.cols);
        ys
    }

    /// [`Self::project_rows_site`] for a batch that mixes tenants —
    /// the fused-decode projection. One base GEMM covers every row;
    /// rows whose own adapter places a delta at (`li`, `proj`) then
    /// get it applied from their own quantized activations, exactly as
    /// the per-slot path would. Per-row results (and per-row MAC
    /// accounting totals) are bit-identical to calling
    /// [`Self::project_rows_site`] once per row.
    fn project_rows_sites(
        &self,
        p: &Projection,
        xs: &[Vec<f32>],
        li: usize,
        proj: Proj,
        adapters: &[Option<u32>],
    ) -> Vec<Vec<f32>> {
        debug_assert_eq!(xs.len(), adapters.len());
        let reg = match &self.lora {
            Some(reg) if adapters.iter().any(|a| a.is_some()) => reg,
            _ => return self.project_rows(p, xs),
        };
        let qs: Vec<QuantizedActs> = xs
            .iter()
            .map(|x| absmax_quantize(x, self.model.act_bits))
            .collect();
        let mut ys = self.project_rows_q(p, &qs);
        for ((q, y), ad) in qs.iter().zip(ys.iter_mut()).zip(adapters) {
            let pair = match ad.and_then(|id| reg.site(id, li, proj)) {
                Some(pair) => pair,
                None => continue,
            };
            apply_adapter_delta(q, &pair.a, &pair.b, reg.lora().rank, reg.alpha(), y);
            reg.record_site_macs(1, p.w.rows, p.w.cols);
        }
        ys
    }

    /// Multi-head causal attention for one query row: keys/values are
    /// rows `0..n_ctx` of the gathered (dequantized) K/V buffers (GQA
    /// maps query head `h` to KV head `h / (n_heads / n_kv_heads)`).
    fn attention(&self, q: &[f32], k: &[f32], v: &[f32], n_ctx: usize) -> Vec<f32> {
        let m = &self.model;
        let hd = m.head_dim();
        let kv_dim = m.kv_dim();
        let group = m.n_heads / m.n_kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = vec![0f32; m.d_model];
        for h in 0..m.n_heads {
            let qh = &q[h * hd..(h + 1) * hd];
            let kvh = (h / group) * hd;
            let mut scores = Vec::with_capacity(n_ctx);
            let mut smax = f32::NEG_INFINITY;
            for t in 0..n_ctx {
                let kt = &k[t * kv_dim + kvh..t * kv_dim + kvh + hd];
                let mut dot = 0f32;
                for i in 0..hd {
                    dot += qh[i] * kt[i];
                }
                let s = dot * scale;
                smax = smax.max(s);
                scores.push(s);
            }
            let mut denom = 0f32;
            for s in scores.iter_mut() {
                *s = (*s - smax).exp();
                denom += *s;
            }
            let inv = 1.0 / denom;
            let oh = &mut out[h * hd..(h + 1) * hd];
            for (t, w) in scores.iter().enumerate() {
                let wt = w * inv;
                let vt = &v[t * kv_dim + kvh..t * kv_dim + kvh + hd];
                for i in 0..hd {
                    oh[i] += wt * vt[i];
                }
            }
        }
        out
    }

    /// One transformer block over `xs.len()` consecutive token rows
    /// whose absolute positions start at `base_pos`: appends this
    /// layer's K/V rows to the store (quantize-on-write), gathers the
    /// context back (dequantize-on-read, with tier accounting and the
    /// retention check on decode reads), then pre-norm attention +
    /// SwiGLU MLP with residuals. Row `r` attends causally over
    /// positions `0..=base_pos + r`.
    fn layer_rows(
        &self,
        li: usize,
        xs: &[Vec<f32>],
        state: &mut HostState,
        base_pos: usize,
        is_prefill: bool,
    ) -> Result<Vec<Vec<f32>>> {
        let layer = &self.layers[li];
        assert!(
            base_pos + xs.len() <= self.model.max_seq,
            "KV write past max_seq"
        );
        assert_eq!(
            state.kv.len(li),
            base_pos,
            "KV append out of order in layer {li}"
        );
        let adapter = state.adapter;
        let xns: Vec<Vec<f32>> = xs.iter().map(|x| rmsnorm(x)).collect();
        let qs = self.project_rows_site(&layer.wq, &xns, li, Proj::Q, adapter);
        let ks = self.project_rows_site(&layer.wk, &xns, li, Proj::K, adapter);
        let vs = self.project_rows_site(&layer.wv, &xns, li, Proj::V, adapter);
        let n_ctx = base_pos + xs.len();
        {
            let mut store = state.store.lock().expect("KV store lock poisoned");
            for (kk, vv) in ks.iter().zip(&vs) {
                // `?` keeps the typed KvError as the anyhow payload, so
                // the serving layer can classify the failure
                store.append(&mut state.kv, li, kk, vv)?;
            }
            // prefill attention reads on-chip activation buffers, so
            // only decode gathers count as (retention-checked) memory
            // reads — the Fig 5(a) convention
            store
                .gather(&state.kv, li, n_ctx, !is_prefill, &mut state.kbuf, &mut state.vbuf)
                .context("DR-eDRAM retention violated during decode")?;
        }
        let attns: Vec<Vec<f32>> = qs
            .iter()
            .enumerate()
            .map(|(r, q)| self.attention(q, &state.kbuf, &state.vbuf, base_pos + r + 1))
            .collect();
        let os = self.project_rows_site(&layer.wo, &attns, li, Proj::O, adapter);
        let mut x1: Vec<Vec<f32>> = xs
            .iter()
            .zip(&os)
            .map(|(x, o)| x.iter().zip(o).map(|(a, b)| a + b).collect())
            .collect();
        let xn2: Vec<Vec<f32>> = x1.iter().map(|x| rmsnorm(x)).collect();
        let gates = self.project_rows_site(&layer.w_gate, &xn2, li, Proj::Gate, adapter);
        let ups = self.project_rows_site(&layer.w_up, &xn2, li, Proj::Up, adapter);
        let acts: Vec<Vec<f32>> = gates
            .iter()
            .zip(&ups)
            .map(|(g, u)| g.iter().zip(u).map(|(a, b)| silu(*a) * b).collect())
            .collect();
        let downs = self.project_rows_site(&layer.w_down, &acts, li, Proj::Down, adapter);
        for (x, d) in x1.iter_mut().zip(&downs) {
            for (xi, di) in x.iter_mut().zip(d) {
                *xi += di;
            }
        }
        Ok(x1)
    }

    fn embed_rows(&self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let d = self.model.d_model;
        tokens
            .iter()
            .map(|&t| {
                let t = t as usize;
                anyhow::ensure!(
                    t < self.model.vocab_size,
                    "token {t} out of vocab {}",
                    self.model.vocab_size
                );
                Ok(self.embed[t * d..(t + 1) * d].to_vec())
            })
            .collect()
    }

    fn head_logits(&self, x: &[f32]) -> Logits {
        Logits::new(self.project(&self.head, &rmsnorm(x)))
    }
}

impl KvControl for HostBackend {
    type Seq = HostState;

    /// Swap in a deployment-sized store (on-die capacity, early-token
    /// threshold, page size, quantization from the [`ServeConfig`]).
    /// States created before the swap keep their original store alive
    /// through their `Arc` until they retire.
    fn configure_kv(&self, serve: &ServeConfig) -> Result<()> {
        let cfg = KvStoreConfig::for_serve(&self.model, serve)?;
        *self.store.write().expect("KV store handle poisoned") =
            Arc::new(Mutex::new(KvStore::new(cfg)));
        Ok(())
    }

    fn advance_kv_clock(&self, now_s: f64) {
        self.kv_store().lock().expect("KV store lock poisoned").set_now(now_s);
    }

    fn kv_stats(&self) -> Option<KvStoreStats> {
        Some(self.kv_store().lock().expect("KV store lock poisoned").stats())
    }

    /// Pre-place the blocks for this sequence's next `n_tokens`
    /// positions in every layer (coordinator-side KV allocation —
    /// module docs / DESIGN.md §12). Never counts accesses or changes
    /// values; appends from worker threads then land in the reserved
    /// blocks.
    fn reserve_kv(&self, state: &mut HostState, n_tokens: usize) -> Result<()> {
        if n_tokens == 0 {
            return Ok(());
        }
        let mut store = state.store.lock().expect("KV store lock poisoned");
        for li in 0..self.model.n_layers {
            store.reserve(&mut state.kv, li, n_tokens)?;
        }
        Ok(())
    }

    /// Demote this sequence's resident on-die KV blocks to external
    /// DRAM via [`KvStore::demote_seq`] — the preemption swap-out.
    /// Stored values are untouched (placement never changes numerics),
    /// so a preempted sequence resumes bit-identically with no
    /// recompute.
    fn swap_out_kv(&self, state: &mut HostState) -> Result<u64> {
        let mut store = state.store.lock().expect("KV store lock poisoned");
        Ok(store.demote_seq(&state.kv)?)
    }

    /// Bind the longest registered shared prefix of `prompt` under the
    /// sequence's bound adapter ([`KvStore::bind_prefix`]). Values
    /// cannot change: the model has no positional encoding term and KV
    /// rows are write-once (invariant 4), so a donor's stored rows for
    /// the same (adapter, prompt-prefix) are bit-identical to what
    /// this sequence's own prefill would have written.
    fn bind_prefix_kv(&self, state: &mut HostState, prompt: &[i32]) -> Result<usize> {
        let mut store = state.store.lock().expect("KV store lock poisoned");
        Ok(store.bind_prefix(&mut state.kv, state.adapter, prompt))
    }

    /// Publish this sequence's full prompt-prefix blocks
    /// ([`KvStore::register_prefix`]); keyed under its bound adapter.
    fn register_prefix_kv(&self, state: &mut HostState, prompt: &[i32]) -> Result<()> {
        let mut store = state.store.lock().expect("KV store lock poisoned");
        store.register_prefix(&state.kv, state.adapter, prompt);
        Ok(())
    }
}

impl ServeTuning for HostBackend {
    /// Shard kernels across `threads` workers (0 keeps the current
    /// width; 1 is the serial path). Bit-identical at any width.
    fn set_threads(&self, threads: usize) {
        if threads >= 1 {
            self.threads.store(threads, Ordering::Relaxed);
        }
    }

    /// Select the bitplane compute path every subsequent projection's
    /// [`KernelCtx`] uses. Bit-identical on every path (DESIGN.md
    /// §17) — only throughput changes.
    fn set_kernel_path(&self, path: KernelPath) {
        self.kernel_path.store(path_to_u8(path), Ordering::Relaxed);
    }

    /// Point the sequence at a tenant adapter (validated against the
    /// registry, which also accounts the task switch: a cold load
    /// streams the adapter's quantized bytes once, a resident bind
    /// moves nothing). `None` always succeeds and serves the base
    /// model; `Some` without a registry is an error.
    fn bind_adapter(&self, state: &mut HostState, adapter: Option<u32>) -> Result<()> {
        match (&self.lora, adapter) {
            (_, None) => state.adapter = None,
            (Some(reg), Some(id)) => {
                reg.bind(id)?;
                state.adapter = Some(id);
            }
            (None, Some(id)) => {
                anyhow::bail!("no adapter registry loaded (requested adapter {id})")
            }
        }
        Ok(())
    }

    fn lora_stats(&self) -> Option<LoraServeStats> {
        self.lora.as_ref().map(|reg| reg.stats())
    }
}

impl InferenceBackend for HostBackend {
    type State = HostState;
    /// Hidden activations: one `d_model` row per in-flight token
    /// position (prefill carries the whole prompt, decode one row).
    type Hidden = Vec<Vec<f32>>;

    fn model(&self) -> &ModelConfig {
        &self.model
    }

    /// Host prefill has no AOT shape bucket: anything up to the model's
    /// context length embeds directly (no padding).
    fn prefill_len(&self) -> usize {
        self.model.max_seq
    }

    fn new_state(&self) -> Result<HostState> {
        let store = self.kv_store();
        let kv = store.lock().expect("KV store lock poisoned").new_seq();
        Ok(HostState {
            kv,
            store,
            kbuf: Vec::new(),
            vbuf: Vec::new(),
            pos: 0,
            prompt_len: 0,
            adapter: None,
        })
    }

    fn embed_prompt(&self, prompt: &[i32]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            !prompt.is_empty() && prompt.len() <= self.prefill_len(),
            "prompt length {} not in 1..={}",
            prompt.len(),
            self.prefill_len()
        );
        self.embed_rows(prompt)
    }

    fn embed_token(&self, token: i32) -> Result<Vec<Vec<f32>>> {
        self.embed_rows(&[token])
    }

    fn run_partition_prefill(
        &self,
        part: usize,
        h: &Vec<Vec<f32>>,
        state: &mut HostState,
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(part < self.n_partitions(), "partition {part} out of range");
        anyhow::ensure!(!h.is_empty(), "empty prefill hidden");
        let lpp = self.model.layers_per_partition();
        let first = part * lpp;
        // A fresh sequence starts at 0; a sequence that bound a shared
        // prefix already holds that many rows in *every* layer, so its
        // prefill appends (and attends) after them — tail-only prefill.
        let base = state.kv.len(first);
        let mut rows = self.layer_rows(first, h, state, base, true)?;
        for li in first + 1..(part + 1) * lpp {
            rows = self.layer_rows(li, &rows, state, base, true)?;
        }
        Ok(rows)
    }

    fn run_partition_decode(
        &self,
        part: usize,
        h: &Vec<Vec<f32>>,
        pos: usize,
        state: &mut HostState,
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(part < self.n_partitions(), "partition {part} out of range");
        anyhow::ensure!(h.len() == 1, "decode hidden must be a single row");
        anyhow::ensure!(pos < self.model.max_seq, "position {pos} past max_seq");
        let lpp = self.model.layers_per_partition();
        let mut rows = self.layer_rows(part * lpp, h, state, pos, false)?;
        for li in part * lpp + 1..(part + 1) * lpp {
            rows = self.layer_rows(li, &rows, state, pos, false)?;
        }
        Ok(rows)
    }

    /// Fused batched decode (DESIGN.md §17): one partition stage for a
    /// whole round's decode batch, with **one flat GEMM per projection
    /// site** across every still-alive slot — weight words are decoded
    /// once per site instead of once per slot, the TOM/BitROM
    /// batch-amortization win. KV append/gather and attention stay
    /// per-slot (each sequence owns its block tables and attends over
    /// its own context), as does error capture: a slot that fails
    /// (e.g. a retention violation) gets its error recorded and drops
    /// out of the remaining layers' batches, leaving every other
    /// slot's integers untouched — rows of an exact integer GEMM are
    /// independent, so fusion is bit-identical to the per-slot loop.
    fn run_partition_decode_batch(
        &self,
        part: usize,
        hs: Vec<Vec<Vec<f32>>>,
        entries: &mut [DecodeEntry<'_, HostState>],
    ) -> Vec<Result<Vec<Vec<f32>>>> {
        assert_eq!(hs.len(), entries.len(), "fused decode batch mismatch");
        if part >= self.n_partitions() {
            return (0..entries.len())
                .map(|_| Err(anyhow!("partition {part} out of range")))
                .collect();
        }
        let n = hs.len();
        let mut out: Vec<Option<Result<Vec<Vec<f32>>>>> = (0..n).map(|_| None).collect();
        // alive[j] = slot index of batch row j
        let mut alive: Vec<usize> = Vec::with_capacity(n);
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(n);
        for (i, h) in hs.into_iter().enumerate() {
            if h.len() != 1 {
                out[i] = Some(Err(anyhow!("decode hidden must be a single row")));
            } else if entries[i].pos >= self.model.max_seq {
                out[i] = Some(Err(anyhow!("position {} past max_seq", entries[i].pos)));
            } else {
                alive.push(i);
                rows.push(h.into_iter().next().expect("checked single row"));
            }
        }
        let lpp = self.model.layers_per_partition();
        for li in part * lpp..(part + 1) * lpp {
            if alive.is_empty() {
                break;
            }
            let layer = &self.layers[li];
            let adapters: Vec<Option<u32>> =
                alive.iter().map(|&i| entries[i].state.adapter).collect();
            let xns: Vec<Vec<f32>> = rows.iter().map(|x| rmsnorm(x)).collect();
            let q_rows = self.project_rows_sites(&layer.wq, &xns, li, Proj::Q, &adapters);
            let k_rows = self.project_rows_sites(&layer.wk, &xns, li, Proj::K, &adapters);
            let v_rows = self.project_rows_sites(&layer.wv, &xns, li, Proj::V, &adapters);
            // per-slot KV append + retention-checked gather + attention;
            // failed slots drop out of the rest of the partition
            let mut next_alive = Vec::with_capacity(alive.len());
            let mut kept_rows = Vec::with_capacity(alive.len());
            let mut attns = Vec::with_capacity(alive.len());
            for (j, &i) in alive.iter().enumerate() {
                let e = &mut entries[i];
                let pos = e.pos;
                let st: &mut HostState = e.state;
                assert_eq!(
                    st.kv.len(li),
                    pos,
                    "KV append out of order in layer {li}"
                );
                let stored = (|| -> Result<()> {
                    let mut store = st.store.lock().expect("KV store lock poisoned");
                    // same error shape as the per-slot path: append
                    // surfaces the typed KvError directly, the decode
                    // gather adds the retention context
                    store.append(&mut st.kv, li, &k_rows[j], &v_rows[j])?;
                    store
                        .gather(&st.kv, li, pos + 1, true, &mut st.kbuf, &mut st.vbuf)
                        .context("DR-eDRAM retention violated during decode")?;
                    Ok(())
                })();
                match stored {
                    Ok(()) => {
                        attns.push(self.attention(&q_rows[j], &st.kbuf, &st.vbuf, pos + 1));
                        kept_rows.push(std::mem::take(&mut rows[j]));
                        next_alive.push(i);
                    }
                    Err(err) => out[i] = Some(Err(err)),
                }
            }
            alive = next_alive;
            let adapters: Vec<Option<u32>> =
                alive.iter().map(|&i| entries[i].state.adapter).collect();
            let os = self.project_rows_sites(&layer.wo, &attns, li, Proj::O, &adapters);
            let mut x1: Vec<Vec<f32>> = kept_rows
                .iter()
                .zip(&os)
                .map(|(x, o)| x.iter().zip(o).map(|(a, b)| a + b).collect())
                .collect();
            let xn2: Vec<Vec<f32>> = x1.iter().map(|x| rmsnorm(x)).collect();
            let gates = self.project_rows_sites(&layer.w_gate, &xn2, li, Proj::Gate, &adapters);
            let ups = self.project_rows_sites(&layer.w_up, &xn2, li, Proj::Up, &adapters);
            let acts: Vec<Vec<f32>> = gates
                .iter()
                .zip(&ups)
                .map(|(g, u)| g.iter().zip(u).map(|(a, b)| silu(*a) * b).collect())
                .collect();
            let downs = self.project_rows_sites(&layer.w_down, &acts, li, Proj::Down, &adapters);
            for (x, d) in x1.iter_mut().zip(&downs) {
                for (xi, di) in x.iter_mut().zip(d) {
                    *xi += di;
                }
            }
            rows = x1;
        }
        for (j, &i) in alive.iter().enumerate() {
            out[i] = Some(Ok(vec![std::mem::take(&mut rows[j])]));
        }
        out.into_iter()
            .map(|o| o.expect("every fused-decode slot resolved"))
            .collect()
    }

    fn head_at(&self, h: &Vec<Vec<f32>>, idx: usize) -> Result<Logits> {
        let row = h
            .get(idx)
            .ok_or_else(|| anyhow!("head index {idx} past {} hidden rows", h.len()))?;
        Ok(self.head_logits(row))
    }

    fn head_decode_logits(&self, h: &Vec<Vec<f32>>) -> Result<Logits> {
        let row = h.last().ok_or_else(|| anyhow!("empty decode hidden"))?;
        Ok(self.head_logits(row))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro() -> ModelConfig {
        ModelConfig {
            name: "host-micro".into(),
            n_layers: 2,
            d_model: 32,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 64,
            vocab_size: 64,
            max_seq: 32,
            n_partitions: 2,
            act_bits: 8,
        }
    }

    #[test]
    fn fabrication_is_deterministic_per_seed() {
        let a = HostBackend::new(micro(), 7).unwrap();
        let b = HostBackend::new(micro(), 7).unwrap();
        let c = HostBackend::new(micro(), 8).unwrap();
        let prompt = [1, 2, 3];
        let ta = a.generate_greedy(&prompt, 8).unwrap();
        assert_eq!(ta, b.generate_greedy(&prompt, 8).unwrap());
        assert_ne!(ta, c.generate_greedy(&prompt, 8).unwrap());
        assert!(ta.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn fabricated_sparsity_matches_bitnet_statistics() {
        let b = HostBackend::new(micro(), 1).unwrap();
        let s = b.rom_sparsity();
        assert!((0.15..0.55).contains(&s), "sparsity {s}");
    }

    #[test]
    fn prefill_equals_chunked_prefill_plus_decode() {
        // DESIGN.md invariant 4 on the host backend: batched-GEMM
        // prefill rows and single-row decode steps must produce the
        // same activations. This now also covers the KV store: rows
        // are quantized once at append time, so the dequantized view
        // is identical no matter when it is gathered.
        let b = HostBackend::new(micro(), 3).unwrap();
        let prompt = [5, 9, 2, 40, 11, 7];
        let (_, full) = b.prefill(&prompt).unwrap();
        let (mut state, _) = b.prefill(&prompt[..2]).unwrap();
        let mut last = None;
        for &t in &prompt[2..] {
            last = Some(b.decode_step(&mut state, t).unwrap());
        }
        let inc = last.unwrap();
        let max_err = full
            .data
            .iter()
            .zip(&inc.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-5, "prefill/decode divergence {max_err}");
        assert_eq!(full.argmax(), inc.argmax());
    }

    #[test]
    fn cirom_event_routing_matches_functional_path() {
        let geom = MacroGeometry {
            rows: 32,
            cols: 16,
            cols_per_trimla: 8,
            ..Default::default()
        };
        let fast = HostBackend::new(micro(), 5).unwrap();
        let slow = HostBackend::with_cirom_events(micro(), 5, geom).unwrap();
        let prompt = [3, 1, 4];
        let t_fast = fast.generate_greedy(&prompt, 4).unwrap();
        let t_slow = slow.generate_greedy(&prompt, 4).unwrap();
        assert_eq!(t_fast, t_slow, "event path must compute the same integers");
        let ev = slow.events().unwrap();
        assert!(ev.macs > 0 && ev.weight_reads > 0);
        assert_eq!(ev.saturations, 0, "TriMLA accumulators must not saturate");
        assert!(fast.events().is_none());
        slow.reset_events();
        assert_eq!(slow.events().unwrap().macs, 0);
    }

    #[test]
    fn embed_prompt_rejects_bad_inputs() {
        let b = HostBackend::new(micro(), 1).unwrap();
        assert!(b.embed_prompt(&[]).is_err());
        assert!(b.embed_prompt(&[999]).is_err());
        let long = vec![1i32; b.prefill_len() + 1];
        assert!(b.embed_prompt(&long).is_err());
    }

    #[test]
    fn states_are_isolated_across_sequences() {
        // interleaved decoding of two sequences must equal the solo
        // runs — per-sequence block tables into the shared store are
        // fully isolated
        let b = HostBackend::new(micro(), 9).unwrap();
        let solo_a = b.generate_greedy(&[1, 2, 3], 5).unwrap();
        let solo_b = b.generate_greedy(&[30, 20], 5).unwrap();
        let (mut sa, la) = b.prefill(&[1, 2, 3]).unwrap();
        let (mut sb, lb) = b.prefill(&[30, 20]).unwrap();
        let (mut ta, mut tb) = (la.argmax() as i32, lb.argmax() as i32);
        let (mut out_a, mut out_b) = (vec![ta], vec![tb]);
        for _ in 1..5 {
            ta = b.decode_step(&mut sa, ta).unwrap().argmax() as i32;
            tb = b.decode_step(&mut sb, tb).unwrap().argmax() as i32;
            out_a.push(ta);
            out_b.push(tb);
        }
        assert_eq!(out_a, solo_a);
        assert_eq!(out_b, solo_b);
    }

    #[test]
    fn generation_is_invariant_to_kv_placement() {
        // tier placement (on-die vs spilled) must never change the
        // model's numerics: a store with a starved on-die tier (all
        // blocks spill) generates the same tokens as the default
        let roomy = HostBackend::new(micro(), 21).unwrap();
        let starved = HostBackend::new(micro(), 21).unwrap();
        starved
            .configure_kv(&ServeConfig {
                max_seq: 32,
                prefill_len: 16,
                ondie_tokens: 16,
                kv_edram_bytes: 0, // nothing fits on-die
                ..ServeConfig::default()
            })
            .unwrap();
        let prompt = [4, 8, 15, 16];
        let a = roomy.generate_greedy(&prompt, 10).unwrap();
        let b = starved.generate_greedy(&prompt, 10).unwrap();
        assert_eq!(a, b, "placement changed generated tokens");
        let stats = starved.kv_stats().unwrap();
        assert_eq!(stats.accesses.ondie_writes, 0);
        assert!(stats.accesses.external_writes > 0);
        assert!(stats.spilled_early_blocks > 0);
    }

    #[test]
    fn state_drop_recycles_ondie_pages() {
        let b = HostBackend::new(micro(), 13).unwrap();
        let store = b.kv_store();
        {
            let (_state, _) = b.prefill(&[1, 2, 3, 4, 5]).unwrap();
            assert!(store.lock().unwrap().ondie_blocks_in_use() > 0);
        }
        assert_eq!(store.lock().unwrap().ondie_blocks_in_use(), 0);
    }

    #[test]
    fn backend_is_sync_and_states_are_send() {
        // the serving loop's parallel rounds depend on exactly these
        // bounds (DESIGN.md §12); a RefCell/Rc regression breaks them
        fn takes_sync<T: Sync + Send>() {}
        fn takes_send<T: Send>() {}
        takes_sync::<HostBackend>();
        takes_send::<HostState>();
    }

    /// MLP projections at/above the kernels' parallel cutoff, so the
    /// pooled paths genuinely fork inside the backend.
    fn wide() -> ModelConfig {
        ModelConfig {
            name: "host-wide".into(),
            n_layers: 2,
            d_model: 128,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 512,
            vocab_size: 64,
            max_seq: 32,
            n_partitions: 2,
            act_bits: 8,
        }
    }

    #[test]
    fn generation_is_invariant_to_kernel_thread_count() {
        // sharded projections must emit bit-identical logits: compare
        // full generations at 1/2/4/7 kernel workers on a model whose
        // MLP shapes clear the parallel cutoff
        let prompt = [7, 3, 11];
        let serial = {
            let b = HostBackend::new(wide(), 17).unwrap();
            b.set_threads(1);
            b.generate_greedy(&prompt, 6).unwrap()
        };
        for threads in [2usize, 4, 7] {
            let b = HostBackend::new(wide(), 17).unwrap();
            b.set_threads(threads);
            assert_eq!(b.threads(), threads);
            assert_eq!(
                b.generate_greedy(&prompt, 6).unwrap(),
                serial,
                "generation diverged at {threads} kernel threads"
            );
        }
    }

    #[test]
    fn generation_is_invariant_to_kernel_path() {
        // DESIGN.md §17: the kernel path changes throughput, never
        // results — full generations on a model wide enough to hit the
        // dense/bit-serial cutovers must be bit-identical
        let prompt = [7, 3, 11];
        let reference = HostBackend::new(wide(), 17).unwrap().generate_greedy(&prompt, 6).unwrap();
        for path in [KernelPath::Scalar, KernelPath::BitSerial] {
            let b = HostBackend::new(wide(), 17).unwrap();
            b.set_kernel_path(path);
            assert_eq!(b.kernel_path(), path);
            assert_eq!(
                b.generate_greedy(&prompt, 6).unwrap(),
                reference,
                "generation diverged on the {} kernel path",
                path.as_str()
            );
        }
    }

    #[test]
    fn fused_batched_decode_is_bit_identical_to_per_slot() {
        // the fused hook runs one GEMM per projection site across the
        // batch; every slot's tokens must match the per-slot loop
        // exactly, including a mixed-tenant batch where adapter deltas
        // apply per row
        let b = HostBackend::with_adapters(micro(), 11, micro_registry(2, 99)).unwrap();
        let prompts: [&[i32]; 4] = [&[1, 2, 3], &[30, 20], &[7], &[9, 4, 2, 30]];
        let adapters = [None, Some(0), Some(1), None];

        let run = |fused: bool| -> Vec<Vec<i32>> {
            let mut states = Vec::new();
            let mut tokens: Vec<Vec<i32>> = Vec::new();
            for (p, &a) in prompts.iter().zip(&adapters) {
                let (s, l) = b.prefill_bound(p, a).unwrap();
                states.push(s);
                tokens.push(vec![l.argmax() as i32]);
            }
            for _ in 0..5 {
                if fused {
                    let mut hs: Vec<_> = tokens
                        .iter()
                        .map(|t| b.embed_token(*t.last().unwrap()).unwrap())
                        .collect();
                    let poss: Vec<usize> = states.iter().map(|s| s.pos()).collect();
                    for part in 0..b.n_partitions() {
                        let mut entries: Vec<DecodeEntry<'_, HostState>> = states
                            .iter_mut()
                            .zip(&poss)
                            .map(|(s, &pos)| DecodeEntry { state: s, pos })
                            .collect();
                        let outs = b.run_partition_decode_batch(part, hs, &mut entries);
                        hs = outs.into_iter().map(|r| r.unwrap()).collect();
                    }
                    for ((s, t), h) in states.iter_mut().zip(tokens.iter_mut()).zip(&hs) {
                        s.set_pos(s.pos() + 1);
                        t.push(b.head_decode_logits(h).unwrap().argmax() as i32);
                    }
                } else {
                    for (s, t) in states.iter_mut().zip(tokens.iter_mut()) {
                        let l = b.decode_step(s, *t.last().unwrap()).unwrap();
                        t.push(l.argmax() as i32);
                    }
                }
            }
            tokens
        };

        let per_slot = run(false);
        let fused = run(true);
        assert_eq!(fused, per_slot, "fused decode diverged from per-slot decode");
    }

    #[test]
    fn reserve_kv_never_changes_results_or_counts() {
        // reserving a round's pages up front (what the serving
        // coordinator does) is invisible to both numerics and access
        // accounting
        let plain = HostBackend::new(micro(), 23).unwrap();
        let reserved = HostBackend::new(micro(), 23).unwrap();
        let prompt = [9, 4, 2, 30];
        let (_, l_plain) = plain.prefill(&prompt).unwrap();
        let mut state = reserved.new_state().unwrap();
        reserved.reserve_kv(&mut state, prompt.len()).unwrap();
        let mut h = reserved.embed_prompt(&prompt).unwrap();
        for part in 0..reserved.n_partitions() {
            h = reserved.run_partition_prefill(part, &h, &mut state).unwrap();
        }
        let l_res = reserved.head_at(&h, prompt.len() - 1).unwrap();
        assert_eq!(l_plain, l_res, "reservation changed logits");
        let (a, b) = (plain.kv_stats().unwrap(), reserved.kv_stats().unwrap());
        assert_eq!(a.accesses.ondie_writes, b.accesses.ondie_writes);
        assert_eq!(a.accesses.external_writes, b.accesses.external_writes);
    }

    #[test]
    fn bound_prefix_prefill_matches_plain_prefill() {
        // a binder that reuses a donor's full-block prefix KV and
        // prefills only the unshared tail must land on the same logits
        let b = HostBackend::new(micro(), 23).unwrap();
        let prompt = [9, 4, 2, 30, 7, 11, 3, 8, 1]; // 8-token block + 1 tail token
        let mut donor = b.new_state().unwrap();
        let mut h = b.embed_prompt(&prompt).unwrap();
        for part in 0..b.n_partitions() {
            h = b.run_partition_prefill(part, &h, &mut donor).unwrap();
        }
        let l_donor = b.head_at(&h, prompt.len() - 1).unwrap();
        b.register_prefix_kv(&mut donor, &prompt).unwrap();

        let mut binder = b.new_state().unwrap();
        let bound = b.bind_prefix_kv(&mut binder, &prompt).unwrap();
        assert_eq!(bound, 8, "the full block binds; the tail recomputes");
        let before = b.kv_stats().unwrap();
        let mut h = b.embed_prompt(&prompt[bound..]).unwrap();
        for part in 0..b.n_partitions() {
            h = b.run_partition_prefill(part, &h, &mut binder).unwrap();
        }
        let l_bind = b.head_at(&h, prompt.len() - 1 - bound).unwrap();
        assert_eq!(l_donor, l_bind, "binding a shared prefix changed logits");
        let after = b.kv_stats().unwrap();
        let wrote = (after.accesses.ondie_writes + after.accesses.external_writes)
            - (before.accesses.ondie_writes + before.accesses.external_writes);
        assert_eq!(wrote, 2, "only the tail token wrote KV (one row per layer)");
        assert_eq!(after.prefix_hits, 1);
    }

    fn micro_registry(n_adapters: usize, seed: u64) -> AdapterRegistry {
        AdapterRegistry::fabricate(&micro(), &crate::lora::LoraConfig::paper(), n_adapters, seed)
            .unwrap()
    }

    #[test]
    fn unbound_adapter_backend_is_bit_identical_to_plain() {
        // DESIGN.md invariant 7 at the backend level: carrying a
        // registry changes nothing until a sequence actually binds
        let plain = HostBackend::new(micro(), 11).unwrap();
        let adapted = HostBackend::with_adapters(micro(), 11, micro_registry(2, 99)).unwrap();
        let prompt = [3, 14, 15, 9];
        let a = plain.generate_greedy(&prompt, 8).unwrap();
        let b = adapted.generate_greedy_bound(&prompt, 8, None).unwrap();
        assert_eq!(a, b, "unbound serving must match the adapter-free build");
        let stats = adapted.lora_stats().unwrap();
        assert_eq!(stats.binds, 0);
        assert_eq!(stats.adapter_macs, 0);
        assert!(plain.lora_stats().is_none());
    }

    #[test]
    fn bound_adapters_specialize_generation() {
        let b = HostBackend::with_adapters(micro(), 11, micro_registry(2, 99)).unwrap();
        let prompt = [3, 14, 15, 9];
        let base = b.generate_greedy_bound(&prompt, 8, None).unwrap();
        let t0 = b.generate_greedy_bound(&prompt, 8, Some(0)).unwrap();
        let t1 = b.generate_greedy_bound(&prompt, 8, Some(1)).unwrap();
        assert!(
            t0 != base || t1 != base,
            "adapter deltas at the paper placement had no effect on generation"
        );
        assert!(t0.iter().chain(&t1).all(|&t| (t as usize) < 64));
        // binding out of range or without a registry fails loudly
        let mut state = b.new_state().unwrap();
        assert!(b.bind_adapter(&mut state, Some(2)).is_err());
        let plain = HostBackend::new(micro(), 11).unwrap();
        let mut state = plain.new_state().unwrap();
        assert!(plain.bind_adapter(&mut state, Some(0)).is_err());
    }

    #[test]
    fn adapter_prefill_equals_chunked_prefill_plus_decode() {
        // invariant 4 extended to bound sequences: the delta is a pure
        // per-row function of the row's own quantization, so prefill
        // and chunked decode still agree bit-exactly
        let b = HostBackend::with_adapters(micro(), 3, micro_registry(1, 31)).unwrap();
        let prompt = [5, 9, 2, 40, 11, 7];
        let (_, full) = b.prefill_bound(&prompt, Some(0)).unwrap();
        let (mut state, _) = b.prefill_bound(&prompt[..2], Some(0)).unwrap();
        let mut last = None;
        for &t in &prompt[2..] {
            last = Some(b.decode_step(&mut state, t).unwrap());
        }
        let inc = last.unwrap();
        let max_err = full
            .data
            .iter()
            .zip(&inc.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-5, "adapter prefill/decode divergence {max_err}");
        assert_eq!(full.argmax(), inc.argmax());
    }

    #[test]
    fn adapter_states_are_isolated_across_tenants() {
        // interleaved decoding of two tenants must equal their solo
        // bound runs — the adapter binding is per sequence
        let b = HostBackend::with_adapters(micro(), 9, micro_registry(2, 17)).unwrap();
        let solo_a = b.generate_greedy_bound(&[1, 2, 3], 5, Some(0)).unwrap();
        let solo_b = b.generate_greedy_bound(&[30, 20], 5, Some(1)).unwrap();
        let (mut sa, la) = b.prefill_bound(&[1, 2, 3], Some(0)).unwrap();
        let (mut sb, lb) = b.prefill_bound(&[30, 20], Some(1)).unwrap();
        let (mut ta, mut tb) = (la.argmax() as i32, lb.argmax() as i32);
        let (mut out_a, mut out_b) = (vec![ta], vec![tb]);
        for _ in 1..5 {
            ta = b.decode_step(&mut sa, ta).unwrap().argmax() as i32;
            tb = b.decode_step(&mut sb, tb).unwrap().argmax() as i32;
            out_a.push(ta);
            out_b.push(tb);
        }
        assert_eq!(out_a, solo_a);
        assert_eq!(out_b, solo_b);
    }

    #[test]
    fn adapter_mac_accounting_tracks_execution() {
        let b = HostBackend::with_adapters(micro(), 5, micro_registry(2, 7)).unwrap();
        b.generate_greedy_bound(&[4, 5, 6], 4, Some(1)).unwrap();
        b.generate_greedy_bound(&[4, 5, 6], 2, Some(1)).unwrap();
        let s = b.lora_stats().unwrap();
        assert_eq!(s.binds, 2);
        assert_eq!(s.cold_loads, 1, "second bind of the same tenant is free");
        let reg = b.adapters().unwrap();
        assert_eq!(s.bytes_streamed, reg.adapter_bytes());
        // token rows through the 3 VOD sites of both layers: first run
        // processes 3 prompt + 3 decode rows, second 3 + 1, per layer
        // per site
        let rows = (3 + 3 + 3 + 1) * micro().n_layers as u64 * 3;
        assert_eq!(s.adapter_rows, rows);
        let analytic = reg.lora().op_overhead_vs_host_projections(&micro());
        assert!(
            (s.measured_op_overhead() - analytic).abs() < 1e-12,
            "measured {} vs analytic {analytic}",
            s.measured_op_overhead()
        );
    }

    #[test]
    fn kv_stats_track_decode_traffic() {
        let b = HostBackend::new(micro(), 2).unwrap();
        b.generate_greedy(&[1, 2, 3], 6).unwrap();
        let stats = b.kv_stats().unwrap();
        // 3 prompt + 5 decode-written tokens, per layer
        assert_eq!(stats.accesses.ondie_writes + stats.accesses.external_writes, 8 * 2);
        assert!(stats.accesses.ondie_reads > 0);
        assert_eq!(stats.retention_failures, 0);
        assert_eq!(stats.quant_bits, 8);
    }
}
