//! `artifacts/manifest.json` — the contract between the python compile
//! path and the rust runtime. The loader validates shapes and hashes so
//! a stale or mismatched artifact directory fails fast instead of
//! producing garbage logits.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

/// One compiled artifact (an AOT-compiled HLO program on disk).
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Artifact name (e.g. `"prefill_p0"`).
    pub name: String,
    /// Path to the serialized program.
    pub file: PathBuf,
    /// Content hash recorded at compile time.
    pub sha256: String,
    /// File size in bytes.
    pub bytes: u64,
}

/// The golden trace the python side recorded (integration oracle).
#[derive(Debug, Clone)]
pub struct Golden {
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Greedy continuation the python model produced.
    pub generated: Vec<i32>,
    /// Last-position prefill logits for numeric comparison.
    pub prefill_last_logits: Vec<f32>,
}

/// Parsed `manifest.json`: the compile path's description of an
/// artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Model architecture the artifacts were compiled for.
    pub model: ModelConfig,
    /// Fixed prefill shape of the compiled executables.
    pub prefill_len: usize,
    /// Seed the weights were fabricated/trained from.
    pub weight_seed: u64,
    /// Zero-weight fraction of the compiled mask set.
    pub rom_sparsity: f64,
    /// Whether the pallas kernel path compiled the programs.
    pub pallas_kernel: bool,
    /// Whether a trained checkpoint (vs seed weights) was baked in.
    pub trained_checkpoint: bool,
    /// Every compiled program in the directory.
    pub artifacts: Vec<ArtifactInfo>,
    /// Golden trace for integration testing, if recorded.
    pub golden: Option<Golden>,
}

impl Manifest {
    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))
            .context("loading artifacts manifest (run `make artifacts`)")?;
        let model = ModelConfig::from_json(
            j.get("config").ok_or_else(|| anyhow!("manifest missing config"))?,
        )?;
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|(name, info)| {
                Ok(ArtifactInfo {
                    name: name.clone(),
                    file: dir.join(
                        info.get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
                    ),
                    sha256: info
                        .get("sha256")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    bytes: info.get("bytes").and_then(Json::as_i64).unwrap_or(0) as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let golden = j.get("golden").map(|g| -> Result<Golden> {
            let ints = |k: &str| -> Result<Vec<i32>> {
                Ok(g.get(k)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("golden missing {k}"))?
                    .iter()
                    .filter_map(Json::as_i64)
                    .map(|v| v as i32)
                    .collect())
            };
            Ok(Golden {
                prompt: ints("prompt")?,
                generated: ints("generated")?,
                prefill_last_logits: g
                    .get("prefill_last_logits")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_f64)
                    .map(|v| v as f32)
                    .collect(),
            })
        });
        let golden = match golden {
            Some(g) => Some(g?),
            None => None,
        };

        let m = Manifest {
            dir: dir.to_path_buf(),
            model,
            prefill_len: j
                .get("prefill_len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest missing prefill_len"))?,
            weight_seed: j.get("weight_seed").and_then(Json::as_i64).unwrap_or(0) as u64,
            rom_sparsity: j.get("rom_sparsity").and_then(Json::as_f64).unwrap_or(0.0),
            pallas_kernel: j
                .get("pallas_kernel")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            trained_checkpoint: j
                .get("trained_checkpoint")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            artifacts,
            golden,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural validation: every expected entry point present, every
    /// file on disk.
    pub fn validate(&self) -> Result<()> {
        let mut expected: Vec<String> = vec![
            "embed_prefill".into(),
            "embed_decode".into(),
            "head_prefill".into(),
            "head_decode".into(),
        ];
        for p in 0..self.model.n_partitions {
            expected.push(format!("part{p}_prefill"));
            expected.push(format!("part{p}_decode"));
        }
        for name in &expected {
            let info = self
                .artifacts
                .iter()
                .find(|a| &a.name == name)
                .ok_or_else(|| anyhow!("manifest missing artifact {name}"))?;
            anyhow::ensure!(
                info.file.exists(),
                "artifact file missing: {} (run `make artifacts`)",
                info.file.display()
            );
        }
        anyhow::ensure!(
            self.prefill_len <= self.model.max_seq,
            "prefill_len exceeds max_seq"
        );
        Ok(())
    }

    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("no artifact named {name}"))
    }

    /// Default artifacts dir: `$BITROM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("BITROM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the workspace root
        Manifest::default_dir()
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.name, "sim-tiny");
        assert_eq!(m.model.n_partitions, 6);
        assert!(m.artifacts.len() >= 16, "{}", m.artifacts.len());
        assert!(m.rom_sparsity > 0.1 && m.rom_sparsity < 0.8);
        let g = m.golden.as_ref().expect("golden trace present");
        assert!(!g.prompt.is_empty());
        assert_eq!(g.prefill_last_logits.len(), m.model.vocab_size);
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
